//! §II narrative ablation: the Calico VPN overlay bottleneck.
//!
//! Running the submit node as an unprivileged pod puts it behind the
//! Kubernetes VPN; the paper observed encap processing capping throughput
//! at ~25 Gbps, and had to run the submit container without the VPN to
//! exceed 90 Gbps.
//!
//!     cargo run --release --example vpn_overhead [scale]

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    let scale: u32 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(1);
    let novpn = Experiment::scenario(Scenario::LanPaper).scaled(scale).run()?;
    let vpn = Experiment::scenario(Scenario::LanVpn).scaled(scale).run()?;
    println!("{}", novpn.table_row(Some(90.0), Some(32.0)));
    println!("{}", vpn.table_row(Some(25.0), None));
    println!(
        "\nVPN ceiling: {:.1} Gbps (paper: ~25 Gbps); host-network speedup {:.1}x",
        vpn.sustained_gbps(),
        novpn.sustained_gbps() / vpn.sustained_gbps()
    );
    Ok(())
}
