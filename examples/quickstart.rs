//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Runs a real-mode pool on loopback: a submit-node file server seals every
//! byte through the AOT Pallas/JAX artifact executed via PJRT (L1+L2), the
//! Rust coordinator moves it over authenticated TCP sessions (L3), and the
//! workers verify integrity frame-by-frame and decrypt.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` for the PJRT engine (falls back to the native
//! engine with a warning otherwise).

use htcdm::fabric::{run_real_pool, RealPoolConfig};

fn main() -> anyhow::Result<()> {
    let cfg = RealPoolConfig {
        n_jobs: 24,
        workers: 4,
        input_bytes: 8 << 20, // 8 MiB per job
        output_bytes: 4096,
        ..Default::default()
    };
    eprintln!(
        "quickstart: {} jobs x {} MiB input over {} workers (loopback TCP, sealed)",
        cfg.n_jobs,
        cfg.input_bytes >> 20,
        cfg.workers
    );
    let r = run_real_pool(cfg)?;
    println!("engine          : {}", r.engine_desc);
    println!("jobs completed  : {} (errors {})", r.jobs_completed, r.errors);
    println!(
        "payload moved   : {:.1} MiB",
        r.total_payload_bytes as f64 / (1 << 20) as f64
    );
    println!("wall time       : {:.2} s", r.wall_secs);
    println!("goodput         : {:.3} Gbps (single host loopback)", r.gbps);
    println!(
        "transfer times  : median {:.3} s, p90 {:.3} s",
        r.transfer_secs.median(),
        r.transfer_secs.percentile(90.0)
    );
    assert_eq!(r.errors, 0, "all transfers must verify integrity");
    Ok(())
}
