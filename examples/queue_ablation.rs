//! §III narrative ablation: the default file-transfer queue vs disabled.
//!
//! HTCondor's shipped disk-load throttle is tuned for spinning disks; on
//! the paper's page-cached dataset it halves throughput ("Using the default
//! settings, a similar 10k job test completed in 64 minutes, i.e. in about
//! double the time").
//!
//!     cargo run --release --example queue_ablation [scale]

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    let scale: u32 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(1);
    let tuned = Experiment::scenario(Scenario::LanPaper).scaled(scale).run()?;
    let default = Experiment::scenario(Scenario::LanDefaultQueue).scaled(scale).run()?;
    println!("{}", tuned.table_row(Some(90.0), Some(32.0)));
    println!("{}", default.table_row(None, Some(64.0)));
    let ratio = default.makespan.as_secs_f64() / tuned.makespan.as_secs_f64();
    println!(
        "\nmakespan ratio default/disabled = {ratio:.2}x (paper: 64/32 = 2.0x)\n\
         peak concurrent transfers: disabled {} vs default {}",
        tuned.peak_concurrent_transfers, default.peak_concurrent_transfers
    );
    Ok(())
}
