//! Reproduce the paper's §IV / Fig. 2: the cross-US WAN benchmark.
//!
//! Same workload as Fig. 1, but the 200 slots live in New York
//! (1×100 Gbps + 4×10 Gbps NICs) behind a shared 100 Gbps backbone with
//! 58 ms RTT. Paper: ~60 Gbps sustained, all jobs done in 49 min.
//!
//!     cargo run --release --example wan_crossus [scale]

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    let scale: u32 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(1);
    let report = Experiment::scenario(Scenario::WanPaper).scaled(scale).run()?;
    println!(
        "{}",
        report.table_row(
            Scenario::WanPaper.paper_sustained_gbps(),
            Scenario::WanPaper.paper_makespan_min()
        )
    );
    println!("\nFig. 2 (submit NIC, 5-min bins):\n{}", report.figure(100.0));
    Ok(())
}
