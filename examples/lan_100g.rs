//! Reproduce the paper's §III / Fig. 1: the LAN benchmark.
//!
//! 10k jobs × 2 GB unique (hard-linked) inputs, 200 slots on six
//! 100 Gbps-NIC workers, transfer queue disabled — on the simulated UCSD
//! testbed. Paper: ~90 Gbps sustained, all jobs done in 32 min.
//!
//!     cargo run --release --example lan_100g [scale]

use htcdm::coordinator::{Experiment, Scenario};

fn main() -> anyhow::Result<()> {
    let scale: u32 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(1);
    let report = Experiment::scenario(Scenario::LanPaper).scaled(scale).run()?;
    println!(
        "{}",
        report.table_row(
            Scenario::LanPaper.paper_sustained_gbps(),
            Scenario::LanPaper.paper_makespan_min()
        )
    );
    println!("\nFig. 1 (submit NIC, 5-min bins):\n{}", report.figure(100.0));
    Ok(())
}
