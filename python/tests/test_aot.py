"""AOT lowering tests: the HLO-text artifacts and their manifest ABI."""

import json
import os

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_probe_lowering_deterministic(self):
        a = aot.lower_one("seal", 16, 16)
        b = aot.lower_one("seal", 16, 16)
        assert a == b

    def test_hlo_text_is_text_not_proto(self):
        text = aot.lower_one("seal", 16, 16)
        assert text.startswith("HloModule")
        # Entry layout carries the (payload, digest) tuple ABI.
        assert "u32[16,16]" in text and "u32[4]" in text

    def test_seal_unseal_differ(self):
        assert aot.lower_one("seal", 16, 16) != aot.lower_one("unseal", 16, 16)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_abi_version(self, manifest):
        assert manifest["abi_version"] == aot.ABI_VERSION

    def test_all_geometries_present(self, manifest):
        have = {(e["kind"], e["name"]) for e in manifest["entries"]}
        want = {
            (k, n) for k in ("seal", "unseal") for n in model.CHUNK_GEOMETRIES
        }
        assert want <= have

    def test_files_exist_and_hash(self, manifest):
        import hashlib

        for e in manifest["entries"]:
            path = os.path.join(ART_DIR, e["file"])
            assert os.path.exists(path), e["file"]
            with open(path) as f:
                text = f.read()
            assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]

    def test_abi_shapes(self, manifest):
        for e in manifest["entries"]:
            n = e["n_blocks"]
            assert e["chunk_bytes"] == 64 * n
            assert e["args"][0]["shape"] == [8]
            assert e["args"][1]["shape"] == [4]
            assert e["args"][2]["shape"] == [n, 16]
            assert e["outputs"][0]["shape"] == [n, 16]
            assert e["outputs"][1]["shape"] == [4]
