"""Pallas kernel vs pure-jnp reference — the CORE correctness signal.

The `seal_chunk` Pallas kernel must match `ref.py` bit-for-bit for every
geometry, tile size, and digest mode. A sweep over shapes stands in for
hypothesis (not available offline): every (n_blocks, tile) pair that divides
evenly is exercised with multiple random seeds.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import chacha, ref
from compile import model


def rand_words(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, 2**32, shape, dtype=np.uint32))


def run_ref(key, iv, data, digest_input):
    if digest_input:
        return ref.unseal_ref(key, iv[1:4], iv[0], data)
    return ref.seal_ref(key, iv[1:4], iv[0], data)


SWEEP = [
    # (n_blocks, tile)
    (16, 16),
    (16, 8),
    (32, 16),
    (64, 64),
    (64, 16),
    (128, 32),
    (256, 256),
    (1024, 1024),
    (1024, 256),
    (4096, 2048),
]


class TestKernelVsRef:
    @pytest.mark.parametrize("n_blocks,tile", SWEEP)
    @pytest.mark.parametrize("digest_input", [False, True])
    def test_matches_ref(self, n_blocks, tile, digest_input):
        key = rand_words((8,), seed=n_blocks)
        iv = rand_words((4,), seed=tile + 1)
        data = rand_words((n_blocks, 16), seed=n_blocks * 31 + tile)
        out, dig = chacha.seal_chunk(
            key, iv, data, n_blocks=n_blocks, tile=tile, digest_input=digest_input
        )
        exp_out, exp_dig = run_ref(key, iv, data, digest_input)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp_out))
        np.testing.assert_array_equal(np.asarray(dig), np.asarray(exp_dig))

    @pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
    def test_seed_sweep(self, seed):
        key = rand_words((8,), seed=seed)
        iv = rand_words((4,), seed=seed + 100)
        data = rand_words((64, 16), seed=seed + 200)
        out, dig = chacha.seal_chunk(key, iv, data, n_blocks=64, tile=16)
        exp_out, exp_dig = run_ref(key, iv, data, False)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp_out))
        np.testing.assert_array_equal(np.asarray(dig), np.asarray(exp_dig))

    def test_tile_invariance(self):
        """The kernel result must not depend on the tiling choice."""
        key = rand_words((8,), seed=9)
        iv = rand_words((4,), seed=10)
        data = rand_words((256, 16), seed=11)
        outs = []
        for tile in (16, 32, 64, 128, 256):
            out, dig = chacha.seal_chunk(key, iv, data, n_blocks=256, tile=tile)
            outs.append((np.asarray(out), np.asarray(dig)))
        for out, dig in outs[1:]:
            np.testing.assert_array_equal(out, outs[0][0])
            np.testing.assert_array_equal(dig, outs[0][1])

    def test_roundtrip(self):
        """unseal(seal(x)) == x and both compute the same ciphertext digest."""
        key = rand_words((8,), seed=20)
        iv = rand_words((4,), seed=21)
        data = rand_words((128, 16), seed=22)
        cipher, d_seal = chacha.seal_chunk(key, iv, data, n_blocks=128, tile=32)
        plain, d_unseal = chacha.seal_chunk(
            key, iv, cipher, n_blocks=128, tile=32, digest_input=True
        )
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(data))
        np.testing.assert_array_equal(np.asarray(d_seal), np.asarray(d_unseal))

    def test_bad_tile_rejected(self):
        key = rand_words((8,), seed=0)
        iv = rand_words((4,), seed=0)
        data = rand_words((64, 16), seed=0)
        with pytest.raises(ValueError, match="not a multiple"):
            chacha.seal_chunk(key, iv, data, n_blocks=64, tile=48)

    def test_counter_continuity_across_chunks(self):
        """Sealing [A;B] as one chunk == sealing A then B with advanced ctr.

        This is the property the Rust stream framing relies on: a file is
        split into chunks, each sealed independently with counter0 advanced
        by the rows already consumed.
        """
        key = rand_words((8,), seed=30)
        iv = rand_words((4,), seed=31)
        data = rand_words((128, 16), seed=32)
        whole, dig_whole = chacha.seal_chunk(key, iv, data, n_blocks=128, tile=32)

        iv2 = iv.at[0].set(iv[0] + jnp.uint32(64))
        head, dig_head = chacha.seal_chunk(key, iv, data[:64], n_blocks=64, tile=32)
        tail, dig_tail = chacha.seal_chunk(key, iv2, data[64:], n_blocks=64, tile=32)
        np.testing.assert_array_equal(
            np.asarray(whole), np.concatenate([np.asarray(head), np.asarray(tail)])
        )
        # Lane digests XOR-combine across chunks.
        np.testing.assert_array_equal(
            np.asarray(dig_whole), np.asarray(dig_head) ^ np.asarray(dig_tail)
        )


class TestVmemBudget:
    """Real-TPU feasibility estimates asserted (see DESIGN.md §Hardware)."""

    @pytest.mark.parametrize("name", list(model.CHUNK_GEOMETRIES))
    def test_geometry_fits_vmem(self, name):
        _, tile = model.CHUNK_GEOMETRIES[name]
        assert chacha.vmem_bytes(tile) < 16 * 1024 * 1024

    def test_default_tile_headroom(self):
        # Default tile must leave >50% VMEM headroom for double buffering.
        assert chacha.vmem_bytes(chacha.DEFAULT_TILE) < 8 * 1024 * 1024
