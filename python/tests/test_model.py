"""L2 model tests: the seal/unseal pipeline ABI the Rust runtime depends on."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def rand_words(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.array(rng.integers(0, 2**32, shape, dtype=np.uint32))


@pytest.mark.parametrize("name", list(model.CHUNK_GEOMETRIES))
class TestPipelinePerGeometry:
    def test_seal_matches_ref(self, name):
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 1), rand_words((4,), 2)
        data = rand_words((n, 16), 3)
        c, d = model.run("seal", name, key, iv, data)
        ce, de = model.seal_ref_fn(key, iv, data)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(ce))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(de))

    def test_unseal_roundtrip(self, name):
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 4), rand_words((4,), 5)
        data = rand_words((n, 16), 6)
        c, d_seal = model.run("seal", name, key, iv, data)
        p, d_unseal = model.run("unseal", name, key, iv, c)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(data))
        np.testing.assert_array_equal(np.asarray(d_seal), np.asarray(d_unseal))

    def test_output_shapes_and_dtypes(self, name):
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 7), rand_words((4,), 8)
        data = rand_words((n, 16), 9)
        c, d = model.run("seal", name, key, iv, data)
        assert c.shape == (n, 16) and c.dtype == jnp.uint32
        assert d.shape == (4,) and d.dtype == jnp.uint32


class TestTamperDetection:
    """The properties the worker relies on to reject corrupted sandboxes."""

    def test_corrupted_cipher_changes_digest(self):
        name = "probe"
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 10), rand_words((4,), 11)
        data = rand_words((n, 16), 12)
        c, d = model.run("seal", name, key, iv, data)
        c_bad = c.at[3, 7].set(c[3, 7] ^ jnp.uint32(0x80))
        _, d_bad = model.run("unseal", name, key, iv, c_bad)
        assert not np.array_equal(np.asarray(d), np.asarray(d_bad))

    def test_wrong_key_garbles_but_digest_still_matches(self):
        """Digest is over ciphertext: a wrong key yields garbage plaintext
        with a *valid* digest — confidentiality and integrity are separate
        properties (as in HTCondor, where AES and the integrity MAC use
        session keys from the same handshake)."""
        name = "probe"
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 13), rand_words((4,), 14)
        data = rand_words((n, 16), 15)
        c, d = model.run("seal", name, key, iv, data)
        key_bad = key.at[0].set(key[0] ^ jnp.uint32(1))
        p_bad, d_ok = model.run("unseal", name, key_bad, iv, c)
        np.testing.assert_array_equal(np.asarray(d_ok), np.asarray(d))
        assert not np.array_equal(np.asarray(p_bad), np.asarray(data))

    def test_wrong_nonce_changes_digest(self):
        name = "probe"
        n, _ = model.CHUNK_GEOMETRIES[name]
        key, iv = rand_words((8,), 16), rand_words((4,), 17)
        data = rand_words((n, 16), 18)
        _, d = model.run("seal", name, key, iv, data)
        iv2 = iv.at[2].set(iv[2] ^ jnp.uint32(1))
        _, d2 = model.run("seal", name, key, iv2, data)
        assert not np.array_equal(np.asarray(d), np.asarray(d2))


class TestGeometryTable:
    def test_chunk_bytes(self):
        assert model.CHUNK_GEOMETRIES["probe"][0] * 64 == 1024
        assert model.CHUNK_GEOMETRIES["64k"][0] * 64 == 64 * 1024
        assert model.CHUNK_GEOMETRIES["256k"][0] * 64 == 256 * 1024
        assert model.CHUNK_GEOMETRIES["1m"][0] * 64 == 1024 * 1024

    def test_tiles_divide(self):
        for name, (n, tile) in model.CHUNK_GEOMETRIES.items():
            assert n % tile == 0, name
