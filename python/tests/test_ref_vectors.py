"""Validate the pure-jnp reference (ref.py) against published ChaCha20
test vectors (RFC 7539) and check the integrity-digest design properties.

These tests anchor the whole stack: the Pallas kernel is tested against
ref.py, the Rust native engine is tested against the AOT artifact, and the
artifact is the lowering of the functions tested here.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref


def words(hexstr: str) -> np.ndarray:
    return np.frombuffer(bytes.fromhex(hexstr.replace(" ", "").replace("\n", "")), dtype="<u4")


RFC_KEY = words("000102030405060708090a0b0c0d0e0f101112131415161718191a1b1c1d1e1f")


class TestRfc7539Block:
    """RFC 7539 §2.3.2 block function test vector."""

    def test_keystream_block(self):
        nonce = words("000000090000004a00000000")
        ks = ref.chacha20_keystream(jnp.array(RFC_KEY), jnp.array(nonce), 1, 1)
        got = np.asarray(ks).astype("<u4").tobytes()
        exp = bytes.fromhex(
            "10f1e7e4d13b5915500fdd1fa32071c4"
            "c7d1f4c733c0680304 22aa9ac3d46c4e"
            "d2826446079faa0914c2d705d98b02a2"
            "b5129cd1de164eb9cbd083e8a2503c4e".replace(" ", "")
        )
        assert got == exp

    def test_keystream_counter_advances(self):
        """Row i of an n-block keystream equals a 1-block call at ctr0+i."""
        nonce = words("000000090000004a00000000")
        ks = np.asarray(ref.chacha20_keystream(jnp.array(RFC_KEY), jnp.array(nonce), 7, 5))
        for i in range(5):
            one = np.asarray(ref.chacha20_keystream(jnp.array(RFC_KEY), jnp.array(nonce), 7 + i, 1))
            np.testing.assert_array_equal(ks[i : i + 1], one)


class TestRfc7539Encryption:
    """RFC 7539 §2.4.2 encryption test vector (the sunscreen plaintext)."""

    PLAINTEXT = (
        b"Ladies and Gentlemen of the class of '99: If I could offer you "
        b"only one tip for the future, sunscreen would be it."
    )
    EXPECTED = bytes.fromhex(
        "6e2e359a2568f98041ba0728dd0d6981"
        "e97e7aec1d4360c20a27afccfd9fae0b"
        "f91b65c5524733ab8f593dabcd62b357"
        "1639d624e65152ab8f530c359f0861d8"
        "07ca0dbf500d6a6156a38e088a22b65e"
        "52bc514d16ccf806818ce91ab7793736"
        "5af90bbf74a35be6b40b8eedf2785e42"
        "874d"
    )

    def test_encrypt(self):
        nonce = words("000000000000004a00000000")
        data = jnp.array(ref.bytes_to_words(self.PLAINTEXT))
        cipher = ref.chacha20_xor(jnp.array(RFC_KEY), jnp.array(nonce), 1, data)
        got = ref.words_to_bytes(np.asarray(cipher))[: len(self.PLAINTEXT)]
        assert got == self.EXPECTED

    def test_decrypt_roundtrip(self):
        nonce = words("000000000000004a00000000")
        data = jnp.array(ref.bytes_to_words(self.PLAINTEXT))
        cipher = ref.chacha20_xor(jnp.array(RFC_KEY), jnp.array(nonce), 1, data)
        plain = ref.chacha20_xor(jnp.array(RFC_KEY), jnp.array(nonce), 1, cipher)
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(data))


class TestKeystreamProperties:
    def test_key_sensitivity(self):
        nonce = jnp.zeros(3, dtype=jnp.uint32)
        k1 = jnp.array(RFC_KEY)
        k2 = k1.at[0].set(k1[0] ^ jnp.uint32(1))
        a = np.asarray(ref.chacha20_keystream(k1, nonce, 0, 4))
        b = np.asarray(ref.chacha20_keystream(k2, nonce, 0, 4))
        # Avalanche: roughly half the bits differ in every block.
        diff = np.unpackbits((a ^ b).view(np.uint8)).mean()
        assert 0.4 < diff < 0.6

    def test_nonce_sensitivity(self):
        key = jnp.array(RFC_KEY)
        n1 = jnp.zeros(3, dtype=jnp.uint32)
        n2 = n1.at[2].set(jnp.uint32(1))
        a = np.asarray(ref.chacha20_keystream(key, n1, 0, 2))
        b = np.asarray(ref.chacha20_keystream(key, n2, 0, 2))
        assert not np.array_equal(a, b)

    @pytest.mark.parametrize("n_blocks", [1, 2, 3, 7, 16, 64])
    def test_block_independence(self, n_blocks):
        """Keystream of n blocks is the concat of per-block keystreams."""
        key = jnp.array(RFC_KEY)
        nonce = jnp.array(words("000000090000004a00000000"))
        full = np.asarray(ref.chacha20_keystream(key, nonce, 3, n_blocks))
        parts = [
            np.asarray(ref.chacha20_keystream(key, nonce, 3 + i, 1))[0]
            for i in range(n_blocks)
        ]
        np.testing.assert_array_equal(full, np.stack(parts))


class TestPoly16Digest:
    def _rand(self, n, seed=0):
        rng = np.random.default_rng(seed)
        return jnp.array(rng.integers(0, 2**32, (n, 16), dtype=np.uint32))

    def test_deterministic(self):
        d = self._rand(32)
        a = np.asarray(ref.poly16_digest(d))
        b = np.asarray(ref.poly16_digest(d))
        np.testing.assert_array_equal(a, b)

    def test_order_sensitive(self):
        d = self._rand(8)
        swapped = jnp.concatenate([d[1:2], d[0:1], d[2:]], axis=0)
        assert not np.array_equal(
            np.asarray(ref.poly16_digest(d)), np.asarray(ref.poly16_digest(swapped))
        )

    def test_single_bit_flip_detected(self):
        d = self._rand(16)
        for (i, j, bit) in [(0, 0, 0), (7, 3, 13), (15, 15, 31)]:
            flipped = d.at[i, j].set(d[i, j] ^ jnp.uint32(1 << bit))
            assert not np.array_equal(
                np.asarray(ref.poly16_digest(d)), np.asarray(ref.poly16_digest(flipped))
            ), (i, j, bit)

    @pytest.mark.parametrize("split", [1, 4, 8, 15])
    def test_chunk_decomposable(self, split):
        """digest(whole) == digest(head, row0=0) XOR digest(tail, row0=split)."""
        d = self._rand(16, seed=3)
        whole = np.asarray(ref.poly16_digest(d, row0=0))
        head = np.asarray(ref.poly16_digest(d[:split], row0=0))
        tail = np.asarray(ref.poly16_digest(d[split:], row0=split))
        np.testing.assert_array_equal(whole, head ^ tail)

    def test_row0_matters(self):
        d = self._rand(4, seed=5)
        a = np.asarray(ref.poly16_digest(d, row0=0))
        b = np.asarray(ref.poly16_digest(d, row0=1))
        assert not np.array_equal(a, b)

    def test_finalize_binds_length_and_nonce(self):
        d = self._rand(4, seed=7)
        lane = ref.poly16_digest(d)
        nonce = jnp.array([1, 2, 3], dtype=jnp.uint32)
        base = np.asarray(ref.digest_finalize(lane, 64, nonce))
        assert not np.array_equal(base, np.asarray(ref.digest_finalize(lane, 65, nonce)))
        nonce2 = nonce.at[1].set(jnp.uint32(9))
        assert not np.array_equal(base, np.asarray(ref.digest_finalize(lane, 64, nonce2)))

    def test_zero_data_nonzero_digest(self):
        """The row/lane tweak whitens all-zero data to a non-trivial digest."""
        d = jnp.zeros((8, 16), dtype=jnp.uint32)
        dig = np.asarray(ref.poly16_digest(d))
        assert np.count_nonzero(dig) >= 14


class TestByteHelpers:
    @pytest.mark.parametrize("n", [0, 1, 63, 64, 65, 1000])
    def test_roundtrip_padding(self, n):
        b = bytes(range(256)) * 4
        b = b[:n]
        w = ref.bytes_to_words(b)
        assert w.shape[1] == 16 and w.shape[0] * 64 >= n
        assert ref.words_to_bytes(w)[:n] == b
        assert set(ref.words_to_bytes(w)[n:]) <= {0}
