"""AOT-lower the L2 transfer pipeline to HLO text artifacts for Rust.

Emits, per chunk geometry in `model.CHUNK_GEOMETRIES`:

    artifacts/seal_<name>.hlo.txt
    artifacts/unseal_<name>.hlo.txt

plus `artifacts/manifest.json` describing the ABI (arg shapes/dtypes, output
arity, chunk geometry) that the Rust runtime consumes.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids, which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from the `python/` directory, as the Makefile does):

    python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

ABI_VERSION = 1


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (return_tuple=True ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(kind: str, n_blocks: int, tile: int) -> str:
    """Trace + lower one (kind, geometry) pair to HLO text."""
    import jax.numpy as jnp

    key = jax.ShapeDtypeStruct((8,), jnp.uint32)
    iv = jax.ShapeDtypeStruct((4,), jnp.uint32)
    data = jax.ShapeDtypeStruct((n_blocks, 16), jnp.uint32)
    fn = model.lowerable(kind, n_blocks, tile)
    lowered = jax.jit(fn).lower(key, iv, data)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated geometry names to build (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(model.CHUNK_GEOMETRIES)
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    manifest = {"abi_version": ABI_VERSION, "entries": []}
    for name in names:
        n_blocks, tile = model.CHUNK_GEOMETRIES[name]
        for kind in ("seal", "unseal"):
            text = lower_one(kind, n_blocks, tile)
            fname = f"{kind}_{name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "kind": kind,
                    "name": name,
                    "file": fname,
                    "n_blocks": n_blocks,
                    "tile": tile,
                    "chunk_bytes": 64 * n_blocks,
                    # args: key (8,) u32, iv (4,) u32, data (n_blocks,16) u32
                    "args": [
                        {"shape": [8], "dtype": "u32"},
                        {"shape": [4], "dtype": "u32"},
                        {"shape": [n_blocks, 16], "dtype": "u32"},
                    ],
                    # outputs (1-tuple of 2): payload (n_blocks,16) u32, digest (4,) u32
                    "outputs": [
                        {"shape": [n_blocks, 16], "dtype": "u32"},
                        {"shape": [4], "dtype": "u32"},
                    ],
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
