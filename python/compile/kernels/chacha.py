"""L1 Pallas kernel: fused ChaCha20 stream cipher + poly16 integrity digest.

This is the data-plane hot-spot of the htcdm transfer pipeline: every byte
that moves through the submit node is encrypted (or decrypted) and
integrity-digested by this kernel.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):

  * The chunk is an (N, 16) uint32 array — N independent 64-byte ChaCha
    blocks. The grid tiles N into `tile` rows per step; each tile is
    (tile, 16) u32 = 64·tile bytes in VMEM for input, the same for output,
    plus 16 column vectors of registers for the round state. With the
    default tile of 2048 rows that is 128 KiB in + 128 KiB out — far below
    the ~16 MiB VMEM budget, leaving room for double-buffering the HBM↔VMEM
    pipeline that `BlockSpec` expresses.
  * The 20 ChaCha rounds are a statically unrolled loop of 8 vectorized
    quarter-rounds per double round over (tile,) lanes — pure VPU
    add/xor/rotl work, no MXU. This mirrors how the paper's testbed ran
    AES-NI on CPU cores: bulk, embarrassingly parallel over counter blocks.
  * The digest is XOR-decomposable across tiles, so each grid step XORs its
    tile's lane digest into a (16,) accumulator output that all grid steps
    share (same output block). Grid steps execute in order, and step 0
    initializes the accumulator.

`interpret=True` always: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the AOT artifact runs on
any backend. Real-TPU performance is estimated in DESIGN.md from the VMEM
footprint and VPU roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

DEFAULT_TILE = 2048


def _qr(x, a, b, c, d):
    """In-place quarter round on state columns a,b,c,d of the list x."""
    x[a] = (x[a] + x[b]).astype(jnp.uint32)
    x[d] = ref.rotl32(x[d] ^ x[a], 16)
    x[c] = (x[c] + x[d]).astype(jnp.uint32)
    x[b] = ref.rotl32(x[b] ^ x[c], 12)
    x[a] = (x[a] + x[b]).astype(jnp.uint32)
    x[d] = ref.rotl32(x[d] ^ x[a], 8)
    x[c] = (x[c] + x[d]).astype(jnp.uint32)
    x[b] = ref.rotl32(x[b] ^ x[c], 7)


def _chacha_tile_keystream(key, nonce, counters):
    """Keystream for one tile: counters is (tile,) u32 -> (tile, 16) u32."""
    tile = counters.shape[0]
    ones = jnp.ones((tile,), dtype=jnp.uint32)
    x = [ones * np.uint32(c) for c in ref.CHACHA_CONSTANTS]
    x += [ones * key[i] for i in range(8)]
    x += [counters.astype(jnp.uint32)]
    x += [ones * nonce[i] for i in range(3)]
    x0 = list(x)
    for _ in range(10):
        _qr(x, 0, 4, 8, 12)
        _qr(x, 1, 5, 9, 13)
        _qr(x, 2, 6, 10, 14)
        _qr(x, 3, 7, 11, 15)
        _qr(x, 0, 5, 10, 15)
        _qr(x, 1, 6, 11, 12)
        _qr(x, 2, 7, 8, 13)
        _qr(x, 3, 4, 9, 14)
    out = [(xi + x0i).astype(jnp.uint32) for xi, x0i in zip(x, x0)]
    return jnp.stack(out, axis=1)


def _tile_digest(chunk, row0_abs):
    """poly16 digest of one (tile, 16) u32 chunk at absolute row offset."""
    tile = chunk.shape[0]
    rows = (row0_abs + jnp.arange(tile, dtype=jnp.uint32))[:, None]
    lanes = jnp.arange(16, dtype=jnp.uint32)[None, :]
    tweak = ((rows + np.uint32(1)) * np.uint32(ref.PHI32)
             + lanes * np.uint32(ref.LANE_C)).astype(jnp.uint32)
    x = (chunk.astype(jnp.uint32) + tweak).astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = (x * np.uint32(ref.MIX_M1)).astype(jnp.uint32)
    x = x ^ (x >> np.uint32(15))
    x = (x * np.uint32(ref.MIX_M2)).astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    return jax.lax.reduce(x, jnp.uint32(0), jax.lax.bitwise_xor, dimensions=(0,))


def _seal_kernel(iv_ref, data_ref, key_ref, cipher_ref, digest_ref, *, tile, digest_input):
    """Pallas kernel body for one grid step (one tile of rows).

    iv_ref: (4,) u32 — [counter0, nonce0, nonce1, nonce2] (scalar prefetch).
    data_ref: (tile, 16) u32 input block.
    key_ref: (8,) u32 key (full, every step).
    cipher_ref: (tile, 16) u32 output block.
    digest_ref: (16,) u32 accumulator shared by all grid steps.

    digest_input=False → digest the XORed output (seal path);
    digest_input=True  → digest the raw input (unseal path).
    """
    pid = pl.program_id(0)
    key = key_ref[...]
    iv = iv_ref[...]
    counter0 = iv[0]
    nonce = iv[1:4]

    row0 = (pid.astype(jnp.uint32) * np.uint32(tile)).astype(jnp.uint32)
    counters = (counter0 + row0 + jnp.arange(tile, dtype=jnp.uint32)).astype(jnp.uint32)

    data = data_ref[...]
    ks = _chacha_tile_keystream(key, nonce, counters)
    out = (data ^ ks).astype(jnp.uint32)
    cipher_ref[...] = out

    # Digest is defined over the ciphertext: the input on the unseal path,
    # the output on the seal path. Absolute row index = counter0 + row0 so
    # the digest is invariant to how the stream is chunked.
    dig_src = data if digest_input else out
    tile_dig = _tile_digest(dig_src, (counter0 + row0).astype(jnp.uint32))

    @pl.when(pid == 0)
    def _init():
        digest_ref[...] = tile_dig

    @pl.when(pid != 0)
    def _acc():
        digest_ref[...] = digest_ref[...] ^ tile_dig


@functools.partial(jax.jit, static_argnames=("n_blocks", "tile", "digest_input"))
def seal_chunk(key, iv, data, *, n_blocks, tile=DEFAULT_TILE, digest_input=False):
    """Fused encrypt/decrypt + digest of one (n_blocks, 16) u32 chunk.

    Args:
      key: (8,) u32 ChaCha key words.
      iv: (4,) u32 — [counter0, nonce0, nonce1, nonce2].
      data: (n_blocks, 16) u32 chunk (plaintext to seal / ciphertext to
        unseal — the XOR is symmetric).
      n_blocks: static row count; must be a multiple of `tile`.
      tile: grid tile height (rows per grid step).
      digest_input: False → digest output (seal); True → digest input
        (unseal).

    Returns:
      (out (n_blocks,16) u32, lane_digest (16,) u32).
    """
    if n_blocks % tile != 0:
        raise ValueError(f"n_blocks={n_blocks} not a multiple of tile={tile}")
    grid = n_blocks // tile
    kernel = functools.partial(_seal_kernel, tile=tile, digest_input=digest_input)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, 16), jnp.uint32),
            jax.ShapeDtypeStruct((16,), jnp.uint32),
        ],
        interpret=True,
    )(iv.astype(jnp.uint32), data.astype(jnp.uint32), key.astype(jnp.uint32))


def vmem_bytes(tile: int) -> int:
    """Estimated VMEM footprint of one grid step (input + output + state).

    Used by DESIGN.md's real-TPU feasibility estimate and asserted in tests
    to stay under the 16 MiB VMEM budget with double-buffering headroom.
    """
    io = 2 * tile * 16 * 4          # data in + cipher out
    state = 33 * tile * 4           # 16 working cols + 16 initial cols + counters
    small = (4 + 8 + 16) * 4        # iv, key, digest
    return 2 * io + state + small   # ×2 for double buffering of the IO blocks
