"""Pure-jnp correctness oracle for the sealed-transfer kernels.

This module is the *reference semantics* for the data-plane hot path of the
htcdm transfer pipeline:

  * ChaCha20 keystream generation (RFC 7539 block function, vectorized over
    independent counter blocks) and the XOR stream cipher built on it.
  * The 16-lane polynomial integrity digest ("poly16") computed over the
    ciphertext, plus its 4-word finalizer.

The Pallas kernel in `chacha.py` must match these functions bit-for-bit
(pytest enforces it), and `ref.py` itself is validated against the RFC 7539
test vectors in `python/tests/test_ref_vectors.py`.

Everything here is uint32 arithmetic; jnp/numpy uint32 wraps modulo 2^32,
which is exactly the ChaCha semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ChaCha20 "expand 32-byte k" constants (RFC 7539 §2.3).
CHACHA_CONSTANTS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)

# Digest mixing constants: golden-ratio odd constant and murmur3-style
# finalizer multipliers. Odd multipliers are invertible mod 2^32, so the
# per-row mix is a bijection of the input word.
PHI32 = 0x9E3779B1
MIX_M1 = 0x7FEB352D
MIX_M2 = 0x846CA68B
LANE_C = 0x85EBCA6B


def rotl32(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Rotate-left each uint32 lane by the static amount `n`."""
    x = x.astype(jnp.uint32)
    return (x << np.uint32(n)) | (x >> np.uint32(32 - n))


def _quarter_round(a, b, c, d):
    """One ChaCha quarter round on four uint32 lanes (vectorized)."""
    a = (a + b).astype(jnp.uint32)
    d = rotl32(d ^ a, 16)
    c = (c + d).astype(jnp.uint32)
    b = rotl32(b ^ c, 12)
    a = (a + b).astype(jnp.uint32)
    d = rotl32(d ^ a, 8)
    c = (c + d).astype(jnp.uint32)
    b = rotl32(b ^ c, 7)
    return a, b, c, d


def chacha20_keystream(key: jnp.ndarray, nonce: jnp.ndarray, counter0, n_blocks: int) -> jnp.ndarray:
    """ChaCha20 keystream for `n_blocks` consecutive counter values.

    Args:
      key: (8,) uint32 — the 256-bit key as little-endian words.
      nonce: (3,) uint32 — the 96-bit nonce as little-endian words.
      counter0: scalar uint32 — block counter of the first block.
      n_blocks: static number of 64-byte blocks.

    Returns:
      (n_blocks, 16) uint32 keystream words; row i is the block with counter
      counter0 + i, serialized as the usual 16 little-endian words.
    """
    key = key.astype(jnp.uint32)
    nonce = nonce.astype(jnp.uint32)
    counters = jnp.uint32(counter0) + jnp.arange(n_blocks, dtype=jnp.uint32)

    # State as 16 column vectors of shape (n_blocks,).
    ones = jnp.ones((n_blocks,), dtype=jnp.uint32)
    x = [ones * np.uint32(c) for c in CHACHA_CONSTANTS]
    x += [ones * key[i] for i in range(8)]
    x += [counters]
    x += [ones * nonce[i] for i in range(3)]
    x0 = list(x)

    for _ in range(10):  # 10 double rounds = 20 rounds
        # Column rounds.
        x[0], x[4], x[8], x[12] = _quarter_round(x[0], x[4], x[8], x[12])
        x[1], x[5], x[9], x[13] = _quarter_round(x[1], x[5], x[9], x[13])
        x[2], x[6], x[10], x[14] = _quarter_round(x[2], x[6], x[10], x[14])
        x[3], x[7], x[11], x[15] = _quarter_round(x[3], x[7], x[11], x[15])
        # Diagonal rounds.
        x[0], x[5], x[10], x[15] = _quarter_round(x[0], x[5], x[10], x[15])
        x[1], x[6], x[11], x[12] = _quarter_round(x[1], x[6], x[11], x[12])
        x[2], x[7], x[8], x[13] = _quarter_round(x[2], x[7], x[8], x[13])
        x[3], x[4], x[9], x[14] = _quarter_round(x[3], x[4], x[9], x[14])

    out = [(xi + x0i).astype(jnp.uint32) for xi, x0i in zip(x, x0)]
    return jnp.stack(out, axis=1)


def chacha20_xor(key, nonce, counter0, data: jnp.ndarray) -> jnp.ndarray:
    """XOR `data` ((N,16) uint32 view of a byte chunk) with the keystream.

    Encryption and decryption are the same operation.
    """
    n_blocks = data.shape[0]
    ks = chacha20_keystream(key, nonce, counter0, n_blocks)
    return (data.astype(jnp.uint32) ^ ks).astype(jnp.uint32)


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3-style avalanche finalizer on each uint32 lane."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = (x * np.uint32(MIX_M1)).astype(jnp.uint32)
    x = x ^ (x >> np.uint32(15))
    x = (x * np.uint32(MIX_M2)).astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    return x


def poly16_digest(data: jnp.ndarray, row0=0) -> jnp.ndarray:
    """16-lane order-sensitive integrity digest over an (N,16) uint32 chunk.

    Each row is whitened by a bijective mix keyed by its absolute row index
    (row0 + i) and lane index, then XOR-folded. XOR folding makes the digest
    fully parallel / tile-decomposable, while the row-index whitening keeps
    it order-sensitive (swapping rows changes the digest).

    Args:
      data: (N, 16) uint32 chunk (ciphertext for encrypt-then-digest).
      row0: absolute index of row 0 within the whole stream, so that chunked
        digests can be XOR-combined by the caller.

    Returns:
      (16,) uint32 lane digest.
    """
    n = data.shape[0]
    rows = (jnp.uint32(row0) + jnp.arange(n, dtype=jnp.uint32))[:, None]
    lanes = jnp.arange(16, dtype=jnp.uint32)[None, :]
    tweak = ((rows + np.uint32(1)) * np.uint32(PHI32) + lanes * np.uint32(LANE_C)).astype(jnp.uint32)
    mixed = _mix32(data.astype(jnp.uint32) + tweak)
    # XOR-reduce over rows.
    acc = jax.lax.reduce(mixed, jnp.uint32(0), jax.lax.bitwise_xor, dimensions=(0,))
    return acc.astype(jnp.uint32)


def digest_finalize(lane_digest: jnp.ndarray, total_words, nonce) -> jnp.ndarray:
    """Fold a (16,) lane digest into the final (4,) transfer digest.

    Binds the total length (in words) and the nonce so that truncation or
    nonce-swapping is detected.
    """
    nonce = jnp.asarray(nonce, dtype=jnp.uint32)
    d = lane_digest.astype(jnp.uint32)
    d = d.at[0].set(d[0] ^ jnp.uint32(total_words))
    d = d.at[1].set(d[1] ^ nonce[0])
    d = d.at[2].set(d[2] ^ nonce[1])
    d = d.at[3].set(d[3] ^ nonce[2])
    folded = _mix32((d[0:4] + _mix32((d[4:8] + _mix32((d[8:12] + _mix32(d[12:16])).astype(jnp.uint32))).astype(jnp.uint32))).astype(jnp.uint32))
    return folded.astype(jnp.uint32)


def seal_ref(key, nonce, counter0, data):
    """Reference seal: encrypt, then digest the ciphertext lanes.

    Returns (ciphertext (N,16) u32, lane digest (16,) u32).
    """
    cipher = chacha20_xor(key, nonce, counter0, data)
    return cipher, poly16_digest(cipher, row0=counter0)


def unseal_ref(key, nonce, counter0, cipher):
    """Reference unseal: digest the ciphertext lanes, then decrypt.

    Returns (plaintext (N,16) u32, lane digest (16,) u32). The digest is over
    the *input* ciphertext, mirroring encrypt-then-digest on the seal side.
    """
    plain = chacha20_xor(key, nonce, counter0, cipher)
    return plain, poly16_digest(cipher, row0=counter0)


# ---------------------------------------------------------------------------
# Plain-numpy helpers for the test suite (byte-level API).
# ---------------------------------------------------------------------------

def bytes_to_words(b: bytes) -> np.ndarray:
    """Little-endian bytes -> (N,16) uint32 words, zero-padded to 64B blocks."""
    pad = (-len(b)) % 64
    b = b + b"\x00" * pad
    return np.frombuffer(b, dtype="<u4").reshape(-1, 16).copy()


def words_to_bytes(w: np.ndarray) -> bytes:
    return np.asarray(w, dtype="<u4").tobytes()
