"""L2: the JAX transfer-pipeline compute graph built on the Pallas kernel.

One sealed-transfer chunk is processed by a single fused computation:

    seal(key, iv, data)   -> (ciphertext, digest4)   # submit-node side
    unseal(key, iv, data) -> (plaintext,  digest4)   # worker side

`data` is an (N, 16) uint32 view of a 64·N-byte chunk; `iv` is
[counter0, nonce0, nonce1, nonce2]. The ChaCha20 XOR and the 16-lane
digest run in the Pallas kernel (`kernels.chacha`); the 4-word digest
finalizer (which binds length and nonce) is plain jnp fused into the same
HLO module by XLA.

These functions are traced and AOT-lowered once per supported chunk size by
`aot.py`; the Rust runtime executes the resulting artifacts on the PJRT CPU
client. Python never runs on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import chacha, ref

#: Supported chunk geometries: name -> (n_blocks, tile).
#: Chunk bytes = 64 * n_blocks.
CHUNK_GEOMETRIES = {
    "probe": (16, 16),        # 1 KiB — handshake probe + cheap tests
    "64k": (1024, 1024),      # 64 KiB
    "256k": (4096, 2048),     # 256 KiB — default stream chunk
    "1m": (16384, 2048),      # 1 MiB — bulk mode
}


def seal_fn(key, iv, data, *, n_blocks, tile=chacha.DEFAULT_TILE):
    """Seal one chunk: encrypt then digest the ciphertext.

    Returns (cipher (N,16) u32, digest (4,) u32).
    """
    cipher, lane_dig = chacha.seal_chunk(
        key, iv, data, n_blocks=n_blocks, tile=tile, digest_input=False
    )
    digest = ref.digest_finalize(lane_dig, jnp.uint32(n_blocks * 16), iv[1:4])
    return cipher, digest


def unseal_fn(key, iv, data, *, n_blocks, tile=chacha.DEFAULT_TILE):
    """Unseal one chunk: digest the (input) ciphertext and decrypt.

    Returns (plain (N,16) u32, digest (4,) u32). The caller compares the
    digest against the frame trailer before trusting the plaintext.
    """
    plain, lane_dig = chacha.seal_chunk(
        key, iv, data, n_blocks=n_blocks, tile=tile, digest_input=True
    )
    digest = ref.digest_finalize(lane_dig, jnp.uint32(n_blocks * 16), iv[1:4])
    return plain, digest


def seal_ref_fn(key, iv, data):
    """Pure-jnp oracle for seal_fn (any N, no tiling constraint)."""
    cipher, lane_dig = ref.seal_ref(key, iv[1:4], iv[0], data)
    digest = ref.digest_finalize(lane_dig, jnp.uint32(data.shape[0] * 16), iv[1:4])
    return cipher, digest


def unseal_ref_fn(key, iv, data):
    """Pure-jnp oracle for unseal_fn."""
    plain, lane_dig = ref.unseal_ref(key, iv[1:4], iv[0], data)
    digest = ref.digest_finalize(lane_dig, jnp.uint32(data.shape[0] * 16), iv[1:4])
    return plain, digest


def lowerable(kind: str, n_blocks: int, tile: int):
    """Return an AOT-lowerable f(key, iv, data) for the given geometry.

    The returned callable returns a tuple so that `return_tuple=True`
    lowering yields a stable 2-tuple ABI: (payload, digest).
    """
    base = seal_fn if kind == "seal" else unseal_fn

    def fn(key, iv, data):
        out, digest = base(key, iv, data, n_blocks=n_blocks, tile=tile)
        return (out, digest)

    return fn


@functools.lru_cache(maxsize=None)
def _jitted(kind: str, n_blocks: int, tile: int):
    return jax.jit(lowerable(kind, n_blocks, tile))


def run(kind: str, name: str, key, iv, data):
    """Execute the same computation the artifact contains, in-process.

    Used by the python test-suite to validate artifact semantics without
    round-tripping through Rust.
    """
    n_blocks, tile = CHUNK_GEOMETRIES[name]
    return _jitted(kind, n_blocks, tile)(key, iv, data)
