//! Cross-module property tests (mini-prop testkit; no proptest offline).

use htcdm::classad::{matches, parse_expr, Ad, Value};
use htcdm::metrics::BinSeries;
use htcdm::mover::{
    AdmissionConfig, AdmissionQueue, DataSource, PoolRouter, Routed, RouterConfig, RouterPolicy,
    ShadowPool, SiteSelector, SourcePlan, SourceSelector, TransferRequest,
};
use htcdm::netsim::NetSim;
use htcdm::storage::ExtentId;
use htcdm::security::chacha;
use htcdm::transfer::{ThrottlePolicy, TransferQueue};
use htcdm::util::testkit::check;
use htcdm::util::units::{Gbps, SimTime};
use std::collections::HashMap;

/// Uniform sim router built through the one-shot config path: `n_nodes`
/// single-shard nodes, each with its own copy of the admission policy.
fn cfg_router(
    n_nodes: u32,
    admission: AdmissionConfig,
    policy: RouterPolicy,
    cfg: RouterConfig,
) -> PoolRouter {
    let n = n_nodes.max(1) as usize;
    let nodes = (0..n).map(|_| ShadowPool::sim(1, admission.clone())).collect();
    PoolRouter::from_config(nodes, vec![1.0; n], policy, cfg)
}

/// Sealed roundtrip through random chunking always restores plaintext and
/// digests XOR-combine across the chunk boundary structure.
#[test]
fn prop_chunked_seal_equals_whole() {
    check("chunked-seal", 30, |g| {
        let mut key = [0u32; 8];
        let mut nonce = [0u32; 3];
        key.iter_mut().for_each(|k| *k = g.rng.next_u32());
        nonce.iter_mut().for_each(|n| *n = g.rng.next_u32());
        let blocks = g.rng.range_usize(2, 40);
        let data: Vec<u32> = (0..blocks * 16).map(|_| g.rng.next_u32()).collect();

        // Whole-buffer seal.
        let mut whole = data.clone();
        chacha::xor_stream(&key, &nonce, 0, &mut whole);

        // Random split seal with advancing counters.
        let cut = g.rng.range_usize(1, blocks - 1) * 16;
        let mut head = data[..cut].to_vec();
        let mut tail = data[cut..].to_vec();
        chacha::xor_stream(&key, &nonce, 0, &mut head);
        chacha::xor_stream(&key, &nonce, (cut / 16) as u32, &mut tail);
        assert_eq!(&whole[..cut], &head[..]);
        assert_eq!(&whole[cut..], &tail[..]);

        // Lane digests XOR-combine.
        let d_whole = chacha::poly16_digest(&whole, 0);
        let d_head = chacha::poly16_digest(&head, 0);
        let d_tail = chacha::poly16_digest(&tail, (cut / 16) as u32);
        for i in 0..16 {
            assert_eq!(d_whole[i], d_head[i] ^ d_tail[i]);
        }
    });
}

/// NetSim conservation: bytes carried on a single-link topology equal the
/// sum of all completed flow sizes, regardless of arrival pattern.
#[test]
fn prop_netsim_byte_conservation() {
    check("netsim-conservation", 25, |g| {
        let mut net = NetSim::new();
        let link = net.add_link("nic", Gbps(g.rng.range_f64(1.0, 100.0)));
        let n = g.rng.range_usize(1, 30);
        let mut total = 0.0;
        let mut pending = Vec::new();
        for _ in 0..n {
            let bytes = g.rng.range_f64(1e6, 1e9);
            total += bytes;
            pending.push(net.start_flow(vec![link], bytes, g.rng.range_f64(0.01e9, 2e9)));
        }
        let mut guard = 0;
        while net.active_flows() > 0 {
            guard += 1;
            assert!(guard < 10_000, "stuck");
            let t = net.next_completion().expect("flows active");
            net.advance_to(t);
            for f in net.completed() {
                net.finish_flow(f);
            }
        }
        let carried = net.link(link).bytes_carried;
        let rel = (carried - total).abs() / total;
        assert!(rel < 1e-6, "carried {carried} vs total {total}");
    });
}

/// TcpDynamic degenerates to FairShare in the zero-loss, vanishing-RTT
/// limit. The solver floors path RTT at the calibrated LAN value
/// (0.2 ms), so the initial window already sustains IW/RTT ≈ 73 MB/s;
/// with link caps <= 1 Gbps and >= 2 flows every fair share sits below
/// that, the window never binds, and the dynamic solver must reproduce
/// max-min completion times exactly.
#[test]
fn prop_tcp_dynamic_matches_fair_share_in_limit() {
    use htcdm::netsim::solver::SolverKind;
    use htcdm::netsim::FlowId;
    check("tcp-fair-share-limit", 15, |g| {
        let cap = Gbps(g.rng.range_f64(0.1, 1.0));
        let n = g.rng.range_usize(2, 12);
        let sizes: Vec<f64> = (0..n).map(|_| g.rng.range_f64(10e6, 500e6)).collect();
        let run = |kind: SolverKind| -> Vec<f64> {
            let mut net = NetSim::new();
            net.set_solver(kind.build(17));
            let link = net.add_link("nic", cap);
            net.set_link_profile(link, 1e-6, 0.0); // zero loss, ~zero RTT
            let ids: Vec<FlowId> = sizes
                .iter()
                .map(|b| net.start_flow(vec![link], *b, f64::INFINITY))
                .collect();
            let mut done: HashMap<FlowId, f64> = HashMap::new();
            let mut guard = 0;
            while net.active_flows() > 0 {
                guard += 1;
                assert!(guard < 100_000, "stuck under {}", kind.label());
                let t = net.next_completion().expect("flows active");
                net.advance_to(t);
                for f in net.completed() {
                    net.finish_flow(f);
                    done.insert(f, net.now().as_secs_f64());
                }
            }
            ids.iter().map(|f| done[f]).collect()
        };
        let fs = run(SolverKind::FairShare);
        let tcp = run(SolverKind::TcpDynamic);
        for (i, (a, b)) in fs.iter().zip(&tcp).enumerate() {
            let rel = (a - b).abs() / a.max(1e-9);
            assert!(
                rel < 1e-3,
                "flow {i}: fair-share finished at {a:.6}s, tcp-dynamic at {b:.6}s"
            );
        }
    });
}

/// Transfer queue: FIFO admission order is preserved under random churn.
#[test]
fn prop_queue_fifo_order() {
    check("queue-fifo", 40, |g| {
        let cap = g.rng.range_u64(1, 8) as u32;
        let mut q: TransferQueue<u64> = TransferQueue::new(ThrottlePolicy::MaxConcurrent(cap));
        let mut next_ticket = 0u64;
        let mut admitted = Vec::new();
        for _ in 0..300 {
            if g.rng.next_f64() < 0.55 {
                admitted.extend(q.enqueue(next_ticket));
                next_ticket += 1;
            } else if q.active() > 0 {
                admitted.extend(q.release());
            }
        }
        // Admission order must be exactly ticket order (FIFO).
        let sorted: Vec<u64> = {
            let mut v = admitted.clone();
            v.sort();
            v
        };
        assert_eq!(admitted, sorted);
    });
}

/// ClassAd evaluator never panics on random well-formed expressions, and
/// bilateral matching is symmetric in its result.
#[test]
fn prop_classad_total_and_match_symmetric() {
    const ATTRS: &[&str] = &["Memory", "Cpus", "Disk", "KFlops"];
    const OPS: &[&str] = &["+", "-", "*", "/", "<", ">=", "==", "&&", "||"];
    check("classad-total", 60, |g| {
        // Random expression tree over the attr pool.
        let mut expr = String::new();
        let depth = g.rng.range_usize(1, 4);
        for i in 0..depth {
            if i > 0 {
                expr.push_str(OPS[g.rng.range_usize(0, OPS.len() - 1)]);
            }
            match g.rng.range_usize(0, 2) {
                0 => expr.push_str(ATTRS[g.rng.range_usize(0, ATTRS.len() - 1)]),
                1 => expr.push_str(&format!("{}", g.rng.range_u64(0, 100))),
                _ => expr.push_str(&format!("TARGET.{}", ATTRS[g.rng.range_usize(0, ATTRS.len() - 1)])),
            }
        }
        let parsed = parse_expr(&expr).expect("generated exprs are well-formed");

        let mut a = Ad::new("Job");
        let mut b = Ad::new("Machine");
        for attr in ATTRS {
            if g.rng.next_f64() < 0.7 {
                a.insert(attr, g.rng.range_u64(0, 1 << 20) as i64);
            }
            if g.rng.next_f64() < 0.7 {
                b.insert(attr, g.rng.range_u64(0, 1 << 20) as i64);
            }
        }
        a.insert_expr("Requirements", &parsed.to_string()).unwrap();
        b.insert_expr("Requirements", &parsed.to_string()).unwrap();
        // Evaluation is total (no panic) and match is symmetric.
        let _ = a.eval_with(&b, "Requirements");
        assert_eq!(matches(&a, &b).unwrap(), matches(&b, &a).unwrap());
    });
}

/// BinSeries: spreading preserves totals for arbitrary interval patterns.
#[test]
fn prop_binseries_total_preserved() {
    check("binseries-total", 40, |g| {
        let mut s = BinSeries::new(SimTime::from_secs(g.rng.range_u64(1, 120)));
        let mut total = 0.0;
        for _ in 0..g.rng.range_usize(1, 50) {
            let t0 = g.rng.range_u64(0, 10_000);
            let dt = g.rng.range_u64(0, 5_000);
            let bytes = g.rng.range_f64(1.0, 1e9);
            total += bytes;
            s.add_spread(
                SimTime::from_millis(t0),
                SimTime::from_millis(t0 + dt),
                bytes,
            );
        }
        let rel = (s.total_bytes() - total).abs() / total;
        assert!(rel < 1e-9, "total drifted by {rel}");
        // Rebin twice preserves again.
        let coarse = s.rebin(SimTime(s.bin_width().0 * 5));
        assert!((coarse.total_bytes() - total).abs() / total < 1e-9);
    });
}

/// Every admission policy keeps the active count at or below its limit
/// under random enqueue/complete churn, the queue's bookkeeping matches
/// an independently tracked active set, and no request is ever lost.
#[test]
fn prop_policy_active_never_exceeds_limit() {
    check("policy-limit", 30, |g| {
        let limit = g.rng.range_u64(1, 10) as u32;
        let configs = [
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(limit)),
            AdmissionConfig::Throttle(ThrottlePolicy::htcondor_default()),
            AdmissionConfig::FairShare { limit },
            AdmissionConfig::WeightedBySize { limit },
        ];
        for cfg in configs {
            let lim = cfg.limit();
            let mut q = AdmissionQueue::new(cfg.build());
            let mut active: Vec<u32> = Vec::new();
            let mut ticket = 0u32;
            let mut enqueued = 0u64;
            for _ in 0..150 {
                if g.rng.next_f64() < 0.6 {
                    let owner = format!("u{}", g.rng.range_u64(0, 3));
                    let bytes = g.rng.range_u64(1, 1_000_000);
                    let adm = q.enqueue(TransferRequest::new(ticket, owner, bytes));
                    ticket += 1;
                    enqueued += 1;
                    active.extend(adm.iter().map(|a| a.ticket));
                } else if !active.is_empty() {
                    let i = g.rng.range_usize(0, active.len() - 1);
                    let adm = q.complete(active.swap_remove(i));
                    active.extend(adm.iter().map(|a| a.ticket));
                }
                assert!(q.active() <= lim, "active {} > limit {lim}", q.active());
                assert_eq!(q.active() as usize, active.len(), "bookkeeping agrees");
            }
            // Drain: every enqueued request is eventually admitted.
            let mut guard = 0;
            while q.active() > 0 || q.waiting() > 0 {
                guard += 1;
                assert!(guard < 10_000, "drain stuck");
                assert!(!active.is_empty(), "waiting requests but nothing active");
                let i = g.rng.range_usize(0, active.len() - 1);
                let adm = q.complete(active.swap_remove(i));
                active.extend(adm.iter().map(|a| a.ticket));
            }
            assert_eq!(q.total_admitted, enqueued, "no request lost");
            assert_eq!(q.released_without_active, 0);
            assert!(q.peak_active <= lim);
        }
    });
}

/// FairShare never starves an owner: with every owner continuously
/// backlogged, admissions rotate so per-owner admitted counts never
/// drift apart by more than one.
#[test]
fn prop_fair_share_never_starves() {
    check("fair-share-no-starvation", 30, |g| {
        let owners = g.rng.range_usize(2, 5);
        let per_owner = g.rng.range_usize(3, 8);
        let limit = g.rng.range_u64(1, 4) as u32;
        let mut q = AdmissionQueue::new(AdmissionConfig::FairShare { limit }.build());
        let mut active: Vec<u32> = Vec::new();

        // Fill capacity with dummy transfers so that none of the real
        // owners' requests admit during the arrival phase — every real
        // admission then happens under full backlog.
        for d in 0..limit {
            let adm = q.enqueue(TransferRequest::new(1_000_000 + d, "zz-dummy", 1));
            active.extend(adm.iter().map(|a| a.ticket));
        }
        assert_eq!(active.len(), limit as usize);

        let mut arrivals: Vec<usize> = (0..owners)
            .flat_map(|o| std::iter::repeat(o).take(per_owner))
            .collect();
        g.rng.shuffle(&mut arrivals);
        let mut ticket = 0u32;
        for o in arrivals {
            let adm = q.enqueue(TransferRequest::new(ticket, format!("owner{o}"), 100));
            assert!(adm.is_empty(), "capacity is full during arrivals");
            ticket += 1;
        }
        assert_eq!(q.waiting(), owners * per_owner);

        // Random completion churn; track per-owner admitted counts and
        // remaining backlog.
        let mut admitted_count: HashMap<String, usize> = HashMap::new();
        let mut remaining: HashMap<String, usize> = (0..owners)
            .map(|o| (format!("owner{o}"), per_owner))
            .collect();
        let mut all_backlogged = true;
        let mut total = 0usize;
        let mut guard = 0;
        while q.active() > 0 || q.waiting() > 0 {
            guard += 1;
            assert!(guard < 10_000, "stuck");
            let i = g.rng.range_usize(0, active.len() - 1);
            for a in q.complete(active.swap_remove(i)) {
                active.push(a.ticket);
                if a.owner == "zz-dummy" {
                    continue;
                }
                *admitted_count.entry(a.owner.clone()).or_insert(0) += 1;
                *remaining.get_mut(&a.owner).unwrap() -= 1;
                total += 1;
                if remaining.values().any(|&r| r == 0) {
                    // An owner drained its backlog; the balance invariant
                    // only applies while everyone is backlogged.
                    all_backlogged = false;
                }
                if all_backlogged {
                    let max = admitted_count.values().max().copied().unwrap_or(0);
                    let min = (0..owners)
                        .map(|o| {
                            admitted_count
                                .get(&format!("owner{o}"))
                                .copied()
                                .unwrap_or(0)
                        })
                        .min()
                        .unwrap();
                    assert!(
                        max - min <= 1,
                        "rotation drifted: counts {admitted_count:?}"
                    );
                }
            }
        }
        assert_eq!(total, owners * per_owner, "every owner fully served");
        assert!(remaining.values().all(|&r| r == 0), "nobody starved");
    });
}

/// Owner-affinity routing is deterministic per owner: within a run an
/// owner never changes submit node, and a fresh router (same node count)
/// reproduces the identical owner → node mapping.
#[test]
fn prop_owner_affinity_deterministic_per_owner() {
    check("owner-affinity-deterministic", 30, |g| {
        let nodes = g.rng.range_u64(2, 6) as u32;
        let n_owners = g.rng.range_usize(1, 6);
        let make = || {
            PoolRouter::sim(
                nodes,
                1,
                AdmissionConfig::Throttle(htcdm::transfer::ThrottlePolicy::Disabled),
                RouterPolicy::OwnerAffinity,
            )
        };
        let mut a = make();
        let mut b = make();
        let mut homes: HashMap<String, usize> = HashMap::new();
        for t in 0..60u32 {
            let owner = format!("owner{}", g.rng.range_usize(0, n_owners - 1));
            let adm_a = a.request(TransferRequest::new(t, owner.clone(), 1));
            let adm_b = b.request(TransferRequest::new(t, owner.clone(), 1));
            assert_eq!(adm_a.len(), 1);
            let node = adm_a[0].node;
            assert_eq!(node, adm_b[0].node, "two routers disagree for {owner}");
            let prev = homes.entry(owner.clone()).or_insert(node);
            assert_eq!(*prev, node, "{owner} moved node mid-run");
            // Random churn must not perturb affinity.
            if g.rng.next_f64() < 0.5 {
                a.complete(t);
                b.complete(t);
            }
        }
    });
}

/// Least-loaded routing never routes to a node that has strictly more
/// active transfers than some other live node: the chosen node is always
/// at the minimum active count at decision time.
#[test]
fn prop_least_loaded_routes_to_minimum() {
    check("least-loaded-minimum", 30, |g| {
        let nodes = g.rng.range_u64(2, 5) as u32;
        let mut router = PoolRouter::sim(
            nodes,
            1,
            AdmissionConfig::Throttle(htcdm::transfer::ThrottlePolicy::Disabled),
            RouterPolicy::LeastLoaded,
        );
        let mut inflight: Vec<u32> = Vec::new();
        for t in 0..120u32 {
            if g.rng.next_f64() < 0.6 || inflight.is_empty() {
                let before = router.active_per_node();
                let min = *before.iter().min().unwrap();
                let adm = router.request(TransferRequest::new(t, "o", 1));
                assert_eq!(adm.len(), 1, "unthrottled: admits immediately");
                let chosen = adm[0].node;
                assert_eq!(
                    before[chosen], min,
                    "routed to node {chosen} with {} active while another had {min}",
                    before[chosen]
                );
                inflight.push(t);
            } else {
                let i = g.rng.range_usize(0, inflight.len() - 1);
                router.complete(inflight.swap_remove(i));
            }
        }
    });
}

/// Round-robin spread stays within ±1 across nodes regardless of
/// completion churn (routing ignores load by design).
#[test]
fn prop_round_robin_spread_within_one() {
    check("round-robin-spread", 30, |g| {
        let nodes = g.rng.range_u64(2, 8) as u32;
        let mut router = PoolRouter::sim(
            nodes,
            1,
            AdmissionConfig::Throttle(htcdm::transfer::ThrottlePolicy::Disabled),
            RouterPolicy::RoundRobin,
        );
        let n_reqs = g.rng.range_u64(10, 200) as u32;
        let mut inflight: Vec<u32> = Vec::new();
        for t in 0..n_reqs {
            router.request(TransferRequest::new(t, "o", 1));
            inflight.push(t);
            if g.rng.next_f64() < 0.4 && !inflight.is_empty() {
                let i = g.rng.range_usize(0, inflight.len() - 1);
                router.complete(inflight.swap_remove(i));
            }
        }
        let routed = router.router_stats().routed_per_node;
        assert_eq!(routed.iter().sum::<u64>(), n_reqs as u64);
        let max = routed.iter().max().unwrap();
        let min = routed.iter().min().unwrap();
        assert!(
            max - min <= 1,
            "round-robin drifted: {routed:?} over {nodes} nodes"
        );
    });
}

/// Failover race: a `complete()` for a transfer already re-routed off a
/// dead node must cancel its new entry, never double-release an
/// admission slot. Under random request / complete / kill / recover /
/// rebalance churn — with every ticket completed exactly once, at an
/// arbitrary point relative to its re-routes — per-node active counts
/// never exceed the policy limit, failed nodes hold no work, and no
/// spurious release is ever recorded.
#[test]
fn prop_complete_racing_fail_node_never_double_releases() {
    check("fail-node-complete-race", 25, |g| {
        let n_nodes = g.rng.range_u64(2, 4) as u32;
        let limit = g.rng.range_u64(1, 3) as u32;
        let mut router = PoolRouter::sim(
            n_nodes,
            1,
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(limit)),
            RouterPolicy::LeastLoaded,
        );
        let mut outstanding: Vec<u32> = Vec::new();
        let mut next_ticket = 0u32;
        for _ in 0..200 {
            match g.rng.range_u64(0, 9) {
                0..=4 => {
                    let owner = format!("u{}", next_ticket % 3);
                    router.request(TransferRequest::new(next_ticket, owner, 10));
                    outstanding.push(next_ticket);
                    next_ticket += 1;
                }
                5..=7 => {
                    // The executor reports in — possibly for a ticket
                    // that was re-routed (now waiting on another node)
                    // or stranded. Exactly once per ticket.
                    if !outstanding.is_empty() {
                        let i = g.rng.range_usize(0, outstanding.len() - 1);
                        router.complete(outstanding.swap_remove(i));
                    }
                }
                8 => {
                    let node = g.rng.range_usize(0, n_nodes as usize - 1);
                    router.fail_node(node);
                }
                _ => {
                    let node = g.rng.range_usize(0, n_nodes as usize - 1);
                    router.recover_node(node);
                    router.rebalance(1);
                }
            }
            let active = router.active_per_node();
            let waiting = router.waiting_per_node();
            for i in 0..n_nodes as usize {
                assert!(
                    active[i] <= limit,
                    "node {i} active {} > limit {limit}",
                    active[i]
                );
                if router.is_failed(i) {
                    assert_eq!(active[i], 0, "failed node {i} still active");
                    assert_eq!(waiting[i], 0, "failed node {i} still queues");
                }
            }
            assert_eq!(router.stats().released_without_active, 0);
        }
        // Drain: completing every outstanding ticket exactly once (some
        // were re-routed several times) empties the router entirely.
        if router.first_live_node().is_none() {
            router.recover_node(0);
        }
        let mut guard = 0;
        while let Some(t) = outstanding.pop() {
            guard += 1;
            assert!(guard < 10_000, "drain stuck");
            router.complete(t);
        }
        assert_eq!(router.active(), 0, "slot leaked or double-released");
        assert_eq!(router.waiting(), 0, "ghost waiting entry survived");
        assert_eq!(router.stats().released_without_active, 0);
    });
}

/// Hybrid-plan source selection is deterministic — two identical
/// routers fed the same request sequence make identical placements —
/// and respects the size threshold exactly at the boundary: a request
/// of `threshold` bytes goes via a DTN, `threshold - 1` via the funnel,
/// under arbitrary completion churn and fleet sizes.
#[test]
fn prop_hybrid_source_selection_deterministic_and_threshold_exact() {
    check("hybrid-source-threshold", 30, |g| {
        let n_dtns = g.rng.range_usize(1, 4);
        let threshold = g.rng.range_u64(2, 1_000_000);
        let make = || {
            cfg_router(
                1,
                AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
                RouterPolicy::LeastLoaded,
                RouterConfig {
                    source_plan: SourcePlan::Hybrid { threshold },
                    dtn_capacity: vec![1.0; n_dtns],
                    ..RouterConfig::default()
                },
            )
        };
        let mut a = make();
        let mut b = make();
        let mut inflight: Vec<u32> = Vec::new();
        for t in 0..80u32 {
            // Mix of sizes clustered around the boundary, including the
            // exact threshold and threshold - 1.
            let bytes = match g.rng.range_usize(0, 3) {
                0 => threshold,
                1 => threshold - 1,
                2 => g.rng.range_u64(1, threshold - 1),
                _ => threshold + g.rng.range_u64(0, threshold),
            };
            let adm_a = a.request(TransferRequest::new(t, "o", bytes));
            let adm_b = b.request(TransferRequest::new(t, "o", bytes));
            assert_eq!(adm_a.len(), 1, "unthrottled: admits immediately");
            assert_eq!(
                adm_a[0].source, adm_b[0].source,
                "two identical routers disagree on ticket {t} ({bytes} B)"
            );
            match adm_a[0].source {
                DataSource::Dtn { .. } => assert!(
                    bytes >= threshold,
                    "{bytes} B below threshold {threshold} placed on a DTN"
                ),
                DataSource::Funnel { .. } => assert!(
                    bytes < threshold,
                    "{bytes} B at/above threshold {threshold} stayed on the funnel"
                ),
            }
            inflight.push(t);
            // Random completion churn must not perturb determinism
            // (both routers see the same churn).
            if g.rng.next_f64() < 0.4 && !inflight.is_empty() {
                let i = g.rng.range_usize(0, inflight.len() - 1);
                let done = inflight.swap_remove(i);
                a.complete(done);
                b.complete(done);
            }
        }
        // Per-DTN placement counts agree exactly.
        assert_eq!(
            a.router_stats().routed_per_dtn,
            b.router_stats().routed_per_dtn
        );
    });
}

/// Cache-aware source selection is deterministic and affine: two
/// identical routers fed the same burst (same extents, same completion
/// churn) make identical placements, and once an extent has been served
/// by some data node every later transfer of that extent lands on the
/// SAME node — serving warmed it there.
#[test]
fn prop_cache_affinity_deterministic_and_sticky() {
    check("cache-affinity-deterministic", 30, |g| {
        let n_dtns = g.rng.range_usize(2, 4);
        let n_ext = g.rng.range_u64(2, 6);
        let make = || {
            cfg_router(
                1,
                AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
                RouterPolicy::LeastLoaded,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; n_dtns],
                    source_selector: SourceSelector::CacheAware,
                    ..RouterConfig::default()
                },
            )
        };
        let mut a = make();
        let mut b = make();
        let mut home: std::collections::HashMap<u64, usize> = HashMap::new();
        let mut inflight: Vec<u32> = Vec::new();
        for t in 0..80u32 {
            let e = g.rng.range_u64(0, n_ext - 1);
            let req = TransferRequest::new(t, "o", 100).with_extent(ExtentId(e));
            let adm_a = a.request(req.clone());
            let adm_b = b.request(req);
            assert_eq!(adm_a.len(), 1, "unthrottled: admits immediately");
            assert_eq!(
                adm_a[0].source, adm_b[0].source,
                "two identical routers disagree on ticket {t} (extent {e})"
            );
            let DataSource::Dtn { dtn } = adm_a[0].source else {
                panic!("dedicated plan placed {:?}", adm_a[0].source);
            };
            let prev = home.entry(e).or_insert(dtn);
            assert_eq!(*prev, dtn, "extent {e} moved data node mid-run");
            inflight.push(t);
            // Completion churn must not perturb either determinism or
            // affinity (residency outlives the transfer).
            if g.rng.next_f64() < 0.4 && !inflight.is_empty() {
                let i = g.rng.range_usize(0, inflight.len() - 1);
                let done = inflight.swap_remove(i);
                a.complete(done);
                b.complete(done);
            }
        }
        assert_eq!(
            a.router_stats().routed_per_dtn,
            b.router_stats().routed_per_dtn
        );
    });
}

/// Owner-affinity source selection re-pins on kill: an owner's sandboxes
/// ride one stable data node; when that node dies, the owner's in-flight
/// transfers re-source AND the owner re-pins onto exactly one live node,
/// where it stays — even after the dead node recovers (no flap-back).
#[test]
fn prop_owner_affinity_source_repins_on_kill() {
    check("owner-affinity-repin", 25, |g| {
        let n_dtns = g.rng.range_usize(2, 4);
        let owners = ["alice", "bob", "carol"];
        let mut router = cfg_router(
            1,
            AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            RouterPolicy::LeastLoaded,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; n_dtns],
                source_selector: SourceSelector::OwnerAffinity,
                ..RouterConfig::default()
            },
        );

        // Establish pins under churn; each owner must never move.
        let mut pin: HashMap<&str, usize> = HashMap::new();
        let mut t = 0u32;
        let mut inflight: Vec<u32> = Vec::new();
        for _ in 0..8 {
            for &o in &owners {
                let adm = router.request(TransferRequest::new(t, o, 10));
                let DataSource::Dtn { dtn } = adm[0].source else {
                    panic!("dedicated plan placed {:?}", adm[0].source);
                };
                assert_eq!(*pin.entry(o).or_insert(dtn), dtn, "{o} moved pre-kill");
                inflight.push(t);
                t += 1;
                if g.rng.next_f64() < 0.3 && !inflight.is_empty() {
                    let i = g.rng.range_usize(0, inflight.len() - 1);
                    router.complete(inflight.swap_remove(i));
                }
            }
        }

        // Kill a pinned node: every re-sourced transfer lands on a live
        // node, and each affected owner's new pin is stable.
        let victim = pin["alice"];
        let moved = router.fail_dtn(victim);
        for m in &moved {
            match m.source {
                DataSource::Dtn { dtn } => {
                    assert_ne!(dtn, victim, "re-sourced back onto the corpse")
                }
                DataSource::Funnel { .. } => {
                    assert_eq!(n_dtns, 1, "funnel only when no DTN survives")
                }
            }
        }
        for &o in &owners {
            let adm = router.request(TransferRequest::new(t, o, 10));
            t += 1;
            let DataSource::Dtn { dtn } = adm[0].source else {
                panic!("live fleet exists, got {:?}", adm[0].source);
            };
            assert!(!router.is_dtn_failed(dtn));
            if pin[o] != victim {
                assert_eq!(dtn, pin[o], "unaffected owner {o} moved");
            }
            assert_eq!(router.dtn_pin_of(o), Some(dtn));
        }
        // Recovery does not flap owners back to the recovered node.
        router.recover_dtn(victim);
        for &o in &owners {
            let before = router.dtn_pin_of(o).expect("pinned");
            let adm = router.request(TransferRequest::new(t, o, 10));
            t += 1;
            assert_eq!(
                adm[0].source,
                DataSource::Dtn { dtn: before },
                "{o} flapped after recovery"
            );
        }
    });
}

/// DTN slot accounting under failure: after `fail_dtn` on a data node
/// carrying both slot-holders and queued waiters, the dead node's slot
/// count and wait queue are exactly empty, every affected ticket is
/// re-sourced exactly once off the corpse, and fleet-wide accounting is
/// conserved (every DTN-sourced ticket holds exactly one slot or queue
/// entry) — no leaked or double-released slots, for every selector.
#[test]
fn prop_dtn_slot_accounting_exact_under_fail() {
    check("dtn-slot-accounting-fail", 40, |g| {
        let n_dtns = g.rng.range_usize(2, 5);
        let slots = g.rng.range_u64(1, 3) as u32;
        let depth = g.rng.range_u64(1, 3) as u32;
        let selector = [
            SourceSelector::RoundRobin,
            SourceSelector::CacheAware,
            SourceSelector::OwnerAffinity,
            SourceSelector::WeightedByCapacity,
        ][g.rng.range_usize(0, 3)];
        let mut router = cfg_router(
            2,
            AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            RouterPolicy::RoundRobin,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; n_dtns],
                source_selector: selector,
                dtn_slots: slots,
                dtn_queue_depth: depth,
                ..RouterConfig::default()
            },
        );

        // Enough traffic to fill every slot and park waiters somewhere.
        let full = n_dtns * (slots + depth) as usize;
        let n_req = g.rng.range_usize(n_dtns * slots as usize + 2, full + 4);
        let mut tickets: Vec<u32> = Vec::new();
        for t in 0..n_req as u32 {
            let owner = format!("u{}", g.rng.range_u64(0, 5));
            let adm = router
                .request(TransferRequest::new(t, owner, 10).with_extent(ExtentId(t as u64 % 3)));
            assert_eq!(adm.len(), 1, "disabled throttle admits immediately");
            tickets.push(t);
        }
        let victim = g.rng.range_usize(0, n_dtns - 1);
        let active_before = router.dtn_active_per_node()[victim] as usize;
        let queued_before = router.dtn_queued_per_node()[victim];
        let on_victim: Vec<u32> = tickets
            .iter()
            .copied()
            .filter(|&t| router.source_of(t) == Some(DataSource::Dtn { dtn: victim }))
            .collect();
        assert_eq!(on_victim.len(), active_before + queued_before);

        let moved = router.fail_dtn(victim);

        // Every affected ticket is re-sourced exactly once, off the corpse.
        let mut moved_tickets: Vec<u32> = moved.iter().map(|m| m.ticket).collect();
        moved_tickets.sort_unstable();
        let mut expected = on_victim.clone();
        expected.sort_unstable();
        assert_eq!(moved_tickets, expected, "re-source set != affected set");
        for m in &moved {
            if let DataSource::Dtn { dtn } = m.source {
                assert_ne!(dtn, victim, "re-sourced onto the corpse");
            }
        }

        // The dead node's accounting is exactly zero…
        assert_eq!(router.dtn_active_per_node()[victim], 0, "slots leaked on the corpse");
        assert_eq!(router.dtn_queued_per_node()[victim], 0, "waiters leaked on the corpse");

        // …and fleet-wide accounting is conserved: every ticket with a
        // DTN source holds exactly one slot or queue entry.
        let dtn_sourced = tickets
            .iter()
            .filter(|&&t| matches!(router.source_of(t), Some(DataSource::Dtn { .. })))
            .count();
        let held: usize = router
            .dtn_active_per_node()
            .iter()
            .map(|&a| a as usize)
            .sum::<usize>()
            + router.dtn_queued_per_node().iter().sum::<usize>();
        assert_eq!(held, dtn_sourced, "slot+queue entries != DTN-sourced tickets");

        // Completing everything drains back to zero: no double releases.
        for t in tickets {
            router.complete(t);
        }
        assert!(router.dtn_active_per_node().iter().all(|&a| a == 0));
        assert!(router.dtn_queued_per_node().iter().all(|&q| q == 0));
        assert_eq!(router.stats().released_without_active, 0);
    });
}

/// Shard-count transparency: the sharded router state is a pure
/// partitioning of the old flat maps, so for ANY shard count the router
/// must emit byte-identical `Routed` decisions — across random
/// policies, source selectors, budgets, queue depths, and a churn of
/// requests, completes, node/DTN kills and recoveries, and rebalances.
#[test]
fn prop_state_shards_do_not_change_decisions() {
    #[derive(Clone)]
    enum Op {
        Request { ticket: u32, owner: u8, bytes: u64, extent: u64 },
        Complete(u32),
        FailNode(usize),
        RecoverNode(usize),
        FailDtn(usize),
        RecoverDtn(usize),
        Rebalance(usize),
    }
    check("state-shards-transparent", 20, |g| {
        let n_nodes = g.rng.range_u64(2, 5) as u32;
        let n_dtns = g.rng.range_usize(2, 4);
        let budget = g.rng.range_u64(0, 3) as u32;
        let depth = g.rng.range_u64(0, 2) as u32;
        let policy = [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::OwnerAffinity,
        ][g.rng.range_usize(0, 2)];
        let selector = [
            SourceSelector::RoundRobin,
            SourceSelector::CacheAware,
            SourceSelector::OwnerAffinity,
        ][g.rng.range_usize(0, 2)];
        let limit = g.rng.range_u64(1, 4) as u32;

        // Materialize one random op tape, then replay it against routers
        // that differ ONLY in their state shard count.
        let mut ops: Vec<Op> = Vec::new();
        let mut outstanding: Vec<u32> = Vec::new();
        let mut ticket = 0u32;
        for _ in 0..160 {
            match g.rng.range_u64(0, 9) {
                0..=4 => {
                    ops.push(Op::Request {
                        ticket,
                        owner: g.rng.range_u64(0, 6) as u8,
                        bytes: g.rng.range_u64(1, 1_000_000),
                        extent: g.rng.range_u64(0, 4),
                    });
                    outstanding.push(ticket);
                    ticket += 1;
                }
                5..=6 => {
                    if !outstanding.is_empty() {
                        let i = g.rng.range_usize(0, outstanding.len() - 1);
                        ops.push(Op::Complete(outstanding.swap_remove(i)));
                    }
                }
                7 => {
                    let node = g.rng.range_usize(0, n_nodes as usize - 1);
                    ops.push(if g.rng.next_f64() < 0.5 {
                        Op::FailNode(node)
                    } else {
                        Op::RecoverNode(node)
                    });
                }
                8 => {
                    let dtn = g.rng.range_usize(0, n_dtns - 1);
                    ops.push(if g.rng.next_f64() < 0.5 {
                        Op::FailDtn(dtn)
                    } else {
                        Op::RecoverDtn(dtn)
                    });
                }
                _ => ops.push(Op::Rebalance(g.rng.range_u64(1, 3) as usize)),
            }
        }

        let run = |shards: usize| -> (Vec<Routed>, htcdm::mover::MoverStats, Vec<u64>) {
            let mut router = cfg_router(
                n_nodes,
                AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(limit)),
                policy,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; n_dtns],
                    source_selector: selector,
                    dtn_slots: budget,
                    dtn_queue_depth: depth,
                    state_shards: shards,
                    ..RouterConfig::default()
                },
            );
            let mut decisions: Vec<Routed> = Vec::new();
            for op in &ops {
                match *op {
                    Op::Request { ticket, owner, bytes, extent } => decisions.extend(
                        router.request(
                            TransferRequest::new(ticket, format!("u{owner}"), bytes)
                                .with_extent(ExtentId(extent)),
                        ),
                    ),
                    Op::Complete(t) => decisions.extend(router.complete(t)),
                    Op::FailNode(n) => decisions.extend(router.fail_node(n)),
                    Op::RecoverNode(n) => decisions.extend(router.recover_node(n)),
                    Op::FailDtn(d) => decisions.extend(router.fail_dtn(d)),
                    Op::RecoverDtn(d) => router.recover_dtn(d),
                    Op::Rebalance(th) => decisions.extend(router.rebalance(th)),
                }
            }
            (decisions, router.stats(), router.router_stats().routed_per_dtn)
        };

        let baseline = run(1);
        for shards in [2, 7, htcdm::mover::DEFAULT_ROUTER_SHARDS] {
            let sharded = run(shards);
            assert_eq!(
                baseline.0, sharded.0,
                "decisions diverged at {shards} shards ({policy:?}/{selector:?})"
            );
            assert_eq!(baseline.1, sharded.1, "stats diverged at {shards} shards");
            assert_eq!(baseline.2, sharded.2, "DTN placement diverged at {shards} shards");
        }
    });
}

/// Two-level (site → DTN) selection is deterministic and
/// shard-transparent: replaying one random op tape — including whole-site
/// kills and recoveries — against routers that differ only in
/// `ROUTER_SHARDS` (1, 2, 16) must emit byte-identical `Routed`
/// decisions, stats, and per-DTN placements, for every site selector.
#[test]
fn prop_two_level_selection_shard_invariant_under_site_kill() {
    #[derive(Clone)]
    enum Op {
        Request { ticket: u32, owner: u8, bytes: u64, extent: u64 },
        Complete(u32),
        FailDtn(usize),
        RecoverDtn(usize),
        FailSite(usize),
        RecoverSite(usize),
        Rebalance(usize),
    }
    check("site-kill-shard-transparent", 20, |g| {
        let n_sites = g.rng.range_usize(2, 3);
        let n_nodes = (n_sites * g.rng.range_usize(1, 2)) as u32;
        let n_dtns = n_sites * g.rng.range_usize(1, 3);
        let selector = [
            SiteSelector::LocalFirst,
            SiteSelector::CacheAware,
            SiteSelector::RoundRobin,
        ][g.rng.range_usize(0, 2)];
        let limit = g.rng.range_u64(1, 4) as u32;

        // One random op tape with whole-site chaos woven in; replayed
        // verbatim against every shard count.
        let mut ops: Vec<Op> = Vec::new();
        let mut outstanding: Vec<u32> = Vec::new();
        let mut ticket = 0u32;
        for _ in 0..160 {
            match g.rng.range_u64(0, 10) {
                0..=4 => {
                    ops.push(Op::Request {
                        ticket,
                        owner: g.rng.range_u64(0, 6) as u8,
                        bytes: g.rng.range_u64(1, 1_000_000),
                        extent: g.rng.range_u64(0, 4),
                    });
                    outstanding.push(ticket);
                    ticket += 1;
                }
                5..=6 => {
                    if !outstanding.is_empty() {
                        let i = g.rng.range_usize(0, outstanding.len() - 1);
                        ops.push(Op::Complete(outstanding.swap_remove(i)));
                    }
                }
                7 => {
                    let dtn = g.rng.range_usize(0, n_dtns - 1);
                    ops.push(if g.rng.next_f64() < 0.5 {
                        Op::FailDtn(dtn)
                    } else {
                        Op::RecoverDtn(dtn)
                    });
                }
                8..=9 => {
                    let site = g.rng.range_usize(0, n_sites - 1);
                    ops.push(if g.rng.next_f64() < 0.5 {
                        Op::FailSite(site)
                    } else {
                        Op::RecoverSite(site)
                    });
                }
                _ => ops.push(Op::Rebalance(g.rng.range_u64(1, 3) as usize)),
            }
        }

        let run = |shards: usize| -> (Vec<Routed>, htcdm::mover::MoverStats, Vec<u64>) {
            let mut router = cfg_router(
                n_nodes,
                AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(limit)),
                RouterPolicy::RoundRobin,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; n_dtns],
                    source_selector: SourceSelector::RoundRobin,
                    n_sites,
                    site_selector: selector,
                    state_shards: shards,
                    ..RouterConfig::default()
                },
            );
            let mut decisions: Vec<Routed> = Vec::new();
            for op in &ops {
                match *op {
                    Op::Request { ticket, owner, bytes, extent } => decisions.extend(
                        router.request(
                            TransferRequest::new(ticket, format!("u{owner}"), bytes)
                                .with_extent(ExtentId(extent)),
                        ),
                    ),
                    Op::Complete(t) => decisions.extend(router.complete(t)),
                    Op::FailDtn(d) => decisions.extend(router.fail_dtn(d)),
                    Op::RecoverDtn(d) => router.recover_dtn(d),
                    Op::FailSite(s) => decisions.extend(router.fail_site(s)),
                    Op::RecoverSite(s) => decisions.extend(router.recover_site(s)),
                    Op::Rebalance(th) => decisions.extend(router.rebalance(th)),
                }
            }
            (decisions, router.stats(), router.router_stats().routed_per_dtn)
        };

        let baseline = run(1);
        for shards in [2, 16] {
            let sharded = run(shards);
            assert_eq!(
                baseline.0, sharded.0,
                "decisions diverged at {shards} shards ({selector:?}, {n_sites} sites)"
            );
            assert_eq!(baseline.1, sharded.1, "stats diverged at {shards} shards");
            assert_eq!(baseline.2, sharded.2, "DTN placement diverged at {shards} shards");
        }
    });
}

/// Site-local affinity: under the default `LocalFirst` site selector a
/// transfer never crosses the WAN while the scheduling node's own site
/// still has a live data node — across random DTN kill/recover churn,
/// every decision (fresh admissions AND fail-over re-sources) whose
/// local fleet is alive lands on a local-site DTN.
#[test]
fn prop_local_first_never_crosses_wan_with_live_local_replica() {
    check("local-first-no-wan-crossing", 30, |g| {
        let n_sites = g.rng.range_usize(2, 3);
        let n_nodes = (n_sites * g.rng.range_usize(1, 2)) as u32;
        let per_site_dtns = g.rng.range_usize(1, 3);
        let n_dtns = n_sites * per_site_dtns;
        let mut router = cfg_router(
            n_nodes,
            AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            RouterPolicy::RoundRobin,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; n_dtns],
                source_selector: SourceSelector::RoundRobin,
                n_sites,
                site_selector: SiteSelector::LocalFirst,
                ..RouterConfig::default()
            },
        );
        // Checked against the router's state at decision time, so
        // fail_dtn's re-source decisions (made after the poison) are
        // held to the same standard as fresh admissions.
        let assert_local = |router: &PoolRouter, r: &Routed| {
            let local = router.site_of_node(r.node);
            let local_alive =
                (0..n_dtns).any(|d| router.site_of_dtn(d) == local && !router.is_dtn_failed(d));
            if !local_alive {
                return; // dead local fleet MAY scan outward
            }
            match r.source {
                DataSource::Dtn { dtn } => assert_eq!(
                    router.site_of_dtn(dtn),
                    local,
                    "ticket {} crossed the WAN (node site {local}, dtn {dtn}) \
                     with a live local replica",
                    r.ticket
                ),
                // A saturated-but-alive site overflows to its own
                // funnel, never to another site — with no budget here a
                // funnel placement means the whole fleet died mid-churn.
                DataSource::Funnel { node } => assert_eq!(
                    router.site_of_node(node),
                    local,
                    "ticket {} funneled off-site",
                    r.ticket
                ),
            }
        };
        let mut outstanding: Vec<u32> = Vec::new();
        let mut ticket = 0u32;
        for _ in 0..200 {
            match g.rng.range_u64(0, 9) {
                0..=4 => {
                    let owner = format!("u{}", g.rng.range_u64(0, 3));
                    let adm = router.request(TransferRequest::new(ticket, owner, 10));
                    assert_eq!(adm.len(), 1, "unthrottled: admits immediately");
                    for r in &adm {
                        assert_local(&router, r);
                    }
                    outstanding.push(ticket);
                    ticket += 1;
                }
                5..=6 => {
                    if !outstanding.is_empty() {
                        let i = g.rng.range_usize(0, outstanding.len() - 1);
                        router.complete(outstanding.swap_remove(i));
                    }
                }
                7 => {
                    let d = g.rng.range_usize(0, n_dtns - 1);
                    for r in router.fail_dtn(d) {
                        assert_local(&router, &r);
                    }
                }
                _ => {
                    let d = g.rng.range_usize(0, n_dtns - 1);
                    router.recover_dtn(d);
                }
            }
        }
    });
}

/// Batched admission is a pure batching of the single-request path: for
/// any request stream and any cycle chunking, `route_batch` emits the
/// same decisions in the same order as one `request` call per transfer,
/// and `complete_batch` likewise mirrors per-ticket `complete` calls —
/// with identical accounting afterwards.
#[test]
fn prop_route_batch_equals_single_requests() {
    check("route-batch-equals-singles", 25, |g| {
        let n_nodes = g.rng.range_u64(1, 4) as u32;
        let n_dtns = g.rng.range_usize(1, 3);
        let limit = g.rng.range_u64(1, 5) as u32;
        let policy = [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::OwnerAffinity,
        ][g.rng.range_usize(0, 2)];
        let make = || {
            cfg_router(
                n_nodes,
                AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(limit)),
                policy,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; n_dtns],
                    source_selector: SourceSelector::CacheAware,
                    ..RouterConfig::default()
                },
            )
        };
        let n_reqs = g.rng.range_u64(10, 80) as u32;
        let reqs: Vec<TransferRequest> = (0..n_reqs)
            .map(|t| {
                TransferRequest::new(
                    t,
                    format!("u{}", g.rng.range_u64(0, 4)),
                    g.rng.range_u64(1, 1_000_000),
                )
                .with_extent(ExtentId(g.rng.range_u64(0, 3)))
            })
            .collect();

        // Route: one request() per transfer vs route_batch() over random
        // cycle chunks.
        let mut single = make();
        let mut single_out: Vec<Routed> = Vec::new();
        for req in reqs.clone() {
            single_out.extend(single.request(req));
        }
        let mut batch = make();
        let mut batch_out: Vec<Routed> = Vec::new();
        let mut rest: &[TransferRequest] = &reqs;
        while !rest.is_empty() {
            let take = g.rng.range_usize(1, rest.len());
            let (cycle, tail) = rest.split_at(take);
            batch_out.extend(batch.route_batch(cycle.to_vec()));
            rest = tail;
        }
        assert_eq!(single_out, batch_out, "route_batch diverged from singles");
        assert_eq!(single.stats(), batch.stats(), "routing accounting diverged");

        // Complete: per-ticket complete() vs complete_batch() over the
        // same random chunking of a shuffled ticket order.
        let mut order: Vec<u32> = (0..n_reqs).collect();
        g.rng.shuffle(&mut order);
        let mut single_done: Vec<Routed> = Vec::new();
        for &t in &order {
            single_done.extend(single.complete(t));
        }
        let mut batch_done: Vec<Routed> = Vec::new();
        let mut rest: &[u32] = &order;
        while !rest.is_empty() {
            let take = g.rng.range_usize(1, rest.len());
            let (cycle, tail) = rest.split_at(take);
            batch_done.extend(batch.complete_batch(cycle));
            rest = tail;
        }
        assert_eq!(single_done, batch_done, "complete_batch diverged from singles");
        assert_eq!(single.stats(), batch.stats(), "completion accounting diverged");
        assert_eq!(single.active(), 0);
        assert_eq!(batch.active(), 0);
    });
}

/// Undefined-propagation: any comparison against a missing attribute is
/// UNDEFINED, and Requirements containing it never match.
#[test]
fn prop_undefined_never_matches() {
    check("undefined-requirements", 30, |g| {
        let mut job = Ad::new("Job");
        job.insert_expr(
            "Requirements",
            &format!("TARGET.MissingAttr{} > 5", g.rng.range_u64(0, 1000)),
        )
        .unwrap();
        let slot = Ad::new("Machine");
        assert_eq!(
            job.eval_with(&slot, "Requirements"),
            Value::Undefined
        );
        assert!(!matches(&job, &slot).unwrap());
    });
}

/// Sealed-stream roundtrip across random payload sizes, chunk sizes,
/// ciphers, stream versions, and sealer-thread counts: the payload
/// always comes back intact and both sides account the exact frame
/// count and wire bytes (header 20, frame head 8, zero-padded payload,
/// digest 16).
#[test]
fn prop_stream_roundtrip_exact_accounting() {
    use htcdm::runtime::engine::NativeEngine;
    use htcdm::security::Method;
    use htcdm::transfer::stream::{recv_stream, send_stream_opts, StreamOpts, V1, V2};
    check("stream-roundtrip", 40, |g| {
        let data = g.bytes(0, 300_000);
        let chunk_words = g.rng.range_usize(1, 64) * 16;
        let method = if g.rng.next_u32() % 2 == 0 {
            Method::Chacha20
        } else {
            Method::Aes256Ctr
        };
        let seal_threads = g.rng.range_usize(0, 3);
        let version = if g.rng.next_u32() % 2 == 0 { V1 } else { V2 };
        let mut key = [0u32; 8];
        let mut nonce = [0u32; 3];
        key.iter_mut().for_each(|k| *k = g.rng.next_u32());
        nonce.iter_mut().for_each(|n| *n = g.rng.next_u32());

        let opts = StreamOpts {
            chunk_words,
            seal_threads,
            version,
        };
        let mut wire = Vec::new();
        let mut tx = NativeEngine::new(method);
        let st = send_stream_opts(&mut wire, &mut tx, &key, &nonce, &data, &opts).unwrap();

        // Replay the sender's chunk math independently.
        let chunk_bytes = chunk_words * 4;
        let mut frames = 0u64;
        let mut wire_bytes = 20u64;
        let mut off = 0usize;
        while off < data.len() {
            let n = (data.len() - off).min(chunk_bytes);
            wire_bytes += 8 + (n.div_ceil(64) * 64) as u64 + 16;
            frames += 1;
            off += n;
        }
        assert_eq!(st.frames, frames, "sender frame count");
        assert_eq!(st.wire_bytes, wire_bytes, "sender wire bytes");
        assert_eq!(st.payload_bytes, data.len() as u64);
        assert_eq!(wire.len() as u64, wire_bytes, "actual bytes on the wire");

        let mut cur = std::io::Cursor::new(&wire);
        let mut rx = NativeEngine::new(method);
        let (out, rst) = recv_stream(&mut cur, &mut rx, &key, &nonce).unwrap();
        assert_eq!(out, data, "payload restored");
        assert_eq!(rst.frames, frames, "receiver frame count");
        assert_eq!(rst.wire_bytes, wire_bytes, "receiver wire bytes");
        assert_eq!(rst.payload_bytes, data.len() as u64);
    });
}
