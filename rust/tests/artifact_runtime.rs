//! Integration: the AOT artifact (Pallas kernel → JAX → HLO text → PJRT)
//! must be bit-identical to the native Rust data plane.
//!
//! This is the cross-language correctness anchor of the whole stack:
//! python/tests pin the kernel to ref.py and the RFC 7539 vectors; these
//! tests pin the *compiled artifact as executed from Rust* to the same
//! semantics. Requires `make artifacts` (skips politely otherwise).

use htcdm::runtime::engine::{Kind, NativeEngine, SealEngine, VerifyingEngine, XlaEngine};
use htcdm::runtime::{Manifest, SealRuntime};
use htcdm::security::chacha;
use htcdm::security::Method;
use htcdm::util::Prng;

/// These tests are environment-gated twice over: they need the AOT
/// artifacts on disk (`make artifacts`, which needs the Python/JAX
/// toolchain) AND a crate built with the `xla` feature (PJRT). Neither
/// holds in the offline CI environment, so they skip politely instead of
/// failing — the skip reason is printed.
fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "xla") {
        return None;
    }
    let dir = Manifest::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!(
                    "skipping: requires `make artifacts` and a build with \
                     `--features xla` (PJRT runtime)"
                );
                return;
            }
        }
    };
}

#[test]
fn artifact_matches_native_probe_geometry() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = SealRuntime::load(&manifest, &["probe"]).unwrap();

    let mut rng = Prng::new(42);
    for case in 0..8 {
        let mut key = [0u32; 8];
        let mut nonce = [0u32; 3];
        key.iter_mut().for_each(|k| *k = rng.next_u32());
        nonce.iter_mut().for_each(|n| *n = rng.next_u32());
        let counter0 = rng.next_u32() & 0xFFFF;
        let data: Vec<u32> = (0..256).map(|_| rng.next_u32()).collect();

        // Artifact seal.
        let iv = [counter0, nonce[0], nonce[1], nonce[2]];
        let (cipher_xla, dig_xla) = rt.run(Kind::Seal, "probe", &key, &iv, &data).unwrap();
        // Native seal.
        let mut cipher_nat = data.clone();
        let dig_nat = chacha::seal_chunk(&key, &nonce, counter0, &mut cipher_nat);

        assert_eq!(cipher_xla, cipher_nat, "ciphertext mismatch (case {case})");
        assert_eq!(dig_xla, dig_nat, "digest mismatch (case {case})");

        // Artifact unseal restores plaintext and re-derives the digest.
        let (plain_xla, dig_unseal) = rt
            .run(Kind::Unseal, "probe", &key, &iv, &cipher_xla)
            .unwrap();
        assert_eq!(plain_xla, data, "roundtrip plaintext (case {case})");
        assert_eq!(dig_unseal, dig_xla, "unseal digest (case {case})");
    }
}

#[test]
fn artifact_matches_native_64k_geometry() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = SealRuntime::load(&manifest, &["64k"]).unwrap();

    let mut rng = Prng::new(7);
    let mut key = [0u32; 8];
    let mut nonce = [0u32; 3];
    key.iter_mut().for_each(|k| *k = rng.next_u32());
    nonce.iter_mut().for_each(|n| *n = rng.next_u32());
    let data: Vec<u32> = (0..1024 * 16).map(|_| rng.next_u32()).collect();

    let iv = [3, nonce[0], nonce[1], nonce[2]];
    let (cipher_xla, dig_xla) = rt.run(Kind::Seal, "64k", &key, &iv, &data).unwrap();
    let mut cipher_nat = data.clone();
    let dig_nat = chacha::seal_chunk(&key, &nonce, 3, &mut cipher_nat);
    assert_eq!(cipher_xla, cipher_nat);
    assert_eq!(dig_xla, dig_nat);
}

#[test]
fn verifying_engine_xla_vs_native() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let xla = XlaEngine::new(SealRuntime::load(&manifest, &["probe", "64k"]).unwrap());
    let mut v = VerifyingEngine::new(xla, NativeEngine::new(Method::Chacha20));

    let mut rng = Prng::new(99);
    for _ in 0..4 {
        let mut key = [0u32; 8];
        let mut nonce = [0u32; 3];
        key.iter_mut().for_each(|k| *k = rng.next_u32());
        nonce.iter_mut().for_each(|n| *n = rng.next_u32());
        // Exact probe geometry.
        let mut data: Vec<u32> = (0..256).map(|_| rng.next_u32()).collect();
        let orig = data.clone();
        let d1 = v.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
        let d2 = v.process(Kind::Unseal, &key, &nonce, 0, &mut data).unwrap();
        assert_eq!(data, orig);
        assert_eq!(d1, d2);
    }
    assert_eq!(v.chunks_verified, 8);
}

#[test]
fn xla_engine_pads_odd_chunks() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let mut e = XlaEngine::new(SealRuntime::load(&manifest, &["probe"]).unwrap());
    let key = [5u32; 8];
    let nonce = [1, 2, 3];
    // 2 blocks = 32 words: smaller than the probe geometry (256 words).
    let mut data: Vec<u32> = (0..32u32).collect();
    let orig = data.clone();
    let d_seal = e.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
    let mut native = orig.clone();
    let d_native = chacha::seal_chunk(&key, &nonce, 0, &mut native);
    assert_eq!(data, native, "padded path ciphertext matches native");
    assert_eq!(d_seal, d_native, "padded path digest matches native");
    let d_unseal = e.process(Kind::Unseal, &key, &nonce, 0, &mut data).unwrap();
    assert_eq!(data, orig);
    assert_eq!(d_unseal, d_seal);
}

#[test]
fn pick_geometry_prefers_largest_fitting() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = SealRuntime::load(&manifest, &["probe", "64k"]).unwrap();
    assert_eq!(rt.pick_geometry(1024 * 16), Some("64k"));
    assert_eq!(rt.pick_geometry(256), Some("probe"));
    assert_eq!(rt.pick_geometry(10), Some("probe"), "falls back to smallest");
    assert_eq!(rt.pick_geometry(1024 * 16 + 1), Some("64k"));
}
