//! End-to-end pool tests: the real-mode loopback fabric moving actual
//! sealed bytes (native engine for speed; the artifact path is covered by
//! tests/artifact_runtime.rs and examples/quickstart.rs).

use htcdm::fabric::{run_real_pool, RealPoolConfig};
use htcdm::mover::AdmissionConfig;
use htcdm::transfer::ThrottlePolicy;

fn cfg() -> RealPoolConfig {
    RealPoolConfig {
        n_jobs: 12,
        workers: 3,
        input_bytes: 512 << 10,
        output_bytes: 2048,
        chunk_words: 4096,
        use_xla_engine: false,
        passphrase: "e2e".into(),
        shadows: 1,
        policy: AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
        ..RealPoolConfig::default()
    }
}

#[test]
fn pool_moves_all_bytes_with_integrity() {
    let r = run_real_pool(cfg()).unwrap();
    assert_eq!(r.errors, 0);
    assert_eq!(r.jobs_completed, 12);
    assert_eq!(r.total_payload_bytes, 12 * (512 << 10) as u64);
    assert!(r.gbps > 0.0);
    assert_eq!(r.transfer_secs.count(), 12);
    assert!(r.transfer_secs.median() > 0.0);
}

#[test]
fn pool_scales_with_workers() {
    let mut c1 = cfg();
    c1.workers = 1;
    c1.n_jobs = 6;
    let r1 = run_real_pool(c1).unwrap();
    let mut c4 = cfg();
    c4.workers = 4;
    c4.n_jobs = 6;
    let r4 = run_real_pool(c4).unwrap();
    assert_eq!(r1.errors + r4.errors, 0);
    // With 4 workers the same job count should not be slower by more than
    // noise; loose bound to avoid flakiness on loaded CI.
    assert!(r4.wall_secs < r1.wall_secs * 2.0);
}

#[test]
fn pool_single_job_single_worker() {
    let mut c = cfg();
    c.n_jobs = 1;
    c.workers = 1;
    let r = run_real_pool(c).unwrap();
    assert_eq!(r.jobs_completed, 1);
    assert_eq!(r.errors, 0);
}

#[test]
fn pool_sharded_with_policy_moves_all_bytes() {
    let mut c = cfg();
    c.shadows = 4;
    c.workers = 4;
    c.policy = AdmissionConfig::WeightedBySize { limit: 3 };
    let r = run_real_pool(c).unwrap();
    assert_eq!(r.errors, 0);
    assert_eq!(r.jobs_completed, 12);
    assert_eq!(r.total_payload_bytes, 12 * (512 << 10) as u64);
    assert_eq!(r.mover.admitted_per_shard.len(), 4);
    assert_eq!(r.mover.admitted_per_shard.iter().sum::<u64>(), 12);
    assert!(r.mover.peak_active <= 3, "policy limit respected");
}
