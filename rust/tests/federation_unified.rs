//! Federation acceptance tests: one `PoolRouter` partitioned into two
//! sites drives BOTH fabrics — first the virtual-time simulator, then
//! the real TCP loopback pool — with two-level (site → DTN) source
//! selection, site×site byte matrices on both legs, and whole-site
//! failure draining to the survivor with exact slot accounting
//! (mirroring `router_unified.rs`, one federation layer up).

use htcdm::coordinator::engine::{Engine, EngineSpec};
use htcdm::fabric::{run_real_pool, run_real_pool_router, RealPoolConfig};
use htcdm::mover::{
    AdmissionConfig, DataSource, FaultPlan, PoolRouter, RouterConfig, RouterPolicy, ShadowPool,
    SiteSelector, SourcePlan, SourceSelector, TransferRequest,
};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::Bytes;

/// A 2-site federation: 2 submit nodes and 4 DTNs split 1+2 per site,
/// round-robin site selection so both source rows carry traffic.
fn federated_router(admission: AdmissionConfig, selector: SiteSelector) -> PoolRouter {
    let nodes = (0..2).map(|_| ShadowPool::sim(1, admission.clone())).collect();
    PoolRouter::from_config(
        nodes,
        vec![1.0; 2],
        RouterPolicy::RoundRobin,
        RouterConfig {
            source_plan: SourcePlan::DedicatedDtn,
            dtn_capacity: vec![1.0; 4],
            source_selector: SourceSelector::RoundRobin,
            n_sites: 2,
            site_selector: selector,
            ..RouterConfig::default()
        },
    )
}

fn tiny_sim_spec(n_jobs: u32) -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers.truncate(2);
    tb.workers[0].slots = 4;
    tb.workers[1].slots = 4;
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = n_jobs;
    spec.input_bytes = Bytes(50_000_000);
    spec.runtime_median_s = 1.0;
    spec.seed = 11;
    spec
}

fn real_cfg(n_jobs: u32) -> RealPoolConfig {
    RealPoolConfig {
        n_jobs,
        workers: 2,
        input_bytes: 128 << 10,
        output_bytes: 512,
        chunk_words: 1024,
        use_xla_engine: false,
        passphrase: "federation-unified".into(),
        ..RealPoolConfig::default()
    }
}

/// One federated router object serves the simulator and then the real
/// fabric: both legs run two-level selection through the same site
/// partition, both report a 2×2 site×site matrix accounting for every
/// payload byte, and routing statistics accumulate across the two runs.
#[test]
fn same_router_object_drives_federated_sim_and_real_fabric() {
    let sim_jobs = 24u32;
    let real_jobs = 8u32;
    let router = federated_router(
        AdmissionConfig::FairShare { limit: 4 },
        SiteSelector::RoundRobin,
    );
    assert_eq!(router.n_sites(), 2);
    assert_eq!(router.site_of_node(0), 0);
    assert_eq!(router.site_of_node(1), 1);
    assert_eq!(
        (0..4).map(|d| router.site_of_dtn(d)).collect::<Vec<_>>(),
        vec![0, 0, 1, 1]
    );

    // Phase 1: the simulated fabric. `with_router` adopts the router's
    // federation shape (2 sites, DTN fleet, site selector) into the
    // testbed, so border and pair-WAN links are built to match.
    let mut spec = tiny_sim_spec(sim_jobs);
    spec.n_owners = 3;
    let result = Engine::with_router(spec, router).run().unwrap();
    assert_eq!(result.schedd.completed_count(), sim_jobs as usize);
    assert_eq!(result.mover.total_admitted, sim_jobs as u64);
    assert_eq!(result.site_matrix.len(), 2, "2×2 sim site matrix");
    assert!(result.site_matrix.iter().all(|row| row.len() == 2));
    assert_eq!(
        result.site_matrix.iter().flatten().sum::<u64>(),
        sim_jobs as u64 * 50_000_000,
        "sim matrix accounts every input byte"
    );
    for (s, row) in result.site_matrix.iter().enumerate() {
        assert!(
            row.iter().sum::<u64>() > 0,
            "round-robin left source site {s} idle: {:?}",
            result.site_matrix
        );
    }

    // Extract the very same router object from the sim schedd.
    let mut schedd = result.schedd;
    let router = schedd.take_router();
    assert_eq!(router.stats().total_admitted, sim_jobs as u64);

    // Phase 2: the real TCP fabric — one file server per submit node,
    // one DTN server per data node — drives sealed bytes through the
    // same router and the same site partition.
    let (report, router) = run_real_pool_router(&real_cfg(real_jobs), router).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.jobs_completed, real_jobs);
    assert_eq!(report.n_sites, 2);
    assert_eq!(report.site_matrix_bytes.len(), 2, "2×2 real site matrix");
    assert!(report.site_matrix_bytes.iter().all(|row| row.len() == 2));
    assert_eq!(
        report.site_matrix_bytes.iter().flatten().sum::<u64>(),
        real_jobs as u64 * (128 << 10) as u64,
        "real matrix accounts every payload byte"
    );
    for (s, row) in report.site_matrix_bytes.iter().enumerate() {
        assert!(
            row.iter().sum::<u64>() > 0,
            "round-robin left source site {s} idle: {:?}",
            report.site_matrix_bytes
        );
    }

    // The SAME router accounted for both fabrics: admissions accumulate
    // and every transfer landed on exactly one shard.
    let stats = router.stats();
    assert_eq!(stats.total_admitted, (sim_jobs + real_jobs) as u64);
    assert_eq!(stats.released_without_active, 0);
    assert_eq!(
        stats.admitted_per_shard.iter().sum::<u64>(),
        (sim_jobs + real_jobs) as u64
    );
}

/// Whole-site failure mid-burst: `fail_site` drains site 0's submit node
/// and both of its DTNs; every re-driven transfer lands on the surviving
/// site's node AND the surviving site's DTNs, slot accounting stays
/// exact throughout (no leak, no double release), and the burst drains
/// without deadlock.
#[test]
fn fail_site_mid_burst_drains_to_surviving_site() {
    let mut router = federated_router(
        AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(3)),
        SiteSelector::LocalFirst,
    );
    let n_jobs = 30u32;
    let mut admitted: Vec<u32> = Vec::new();
    for t in 0..n_jobs {
        admitted.extend(
            router
                .request(TransferRequest::new(t, "o", 1000))
                .iter()
                .map(|a| a.ticket),
        );
    }
    assert_eq!(router.active(), 6, "3 per node × 2 nodes");
    assert_eq!(
        router.active() as usize + router.waiting(),
        n_jobs as usize,
        "every ticket holds a slot or a queue entry"
    );

    // Mid-burst: complete a few, then site 0 (node 0 + DTNs 0,1) dies.
    let mut completed = 0u32;
    for _ in 0..4 {
        let t = admitted.pop().expect("admitted transfers exist");
        completed += 1;
        admitted.extend(router.complete(t).iter().map(|a| a.ticket));
    }
    let rescued = router.fail_site(0);
    assert_eq!(router.stats().shard_failed, 1, "site 0's one submit node");
    assert!(router.is_failed(0) && !router.is_failed(1));
    assert!(router.is_dtn_failed(0) && router.is_dtn_failed(1));
    for r in &rescued {
        assert_eq!(r.node, 1, "re-driven transfer scheduled off-survivor");
        if let DataSource::Dtn { dtn } = r.source {
            assert_eq!(router.site_of_dtn(dtn), 1, "re-sourced onto a dead site's DTN");
        }
    }
    // Exact slot accounting after the site kill: the dead site holds
    // nothing, the survivor is at its cap, and the outstanding burst is
    // fully conserved between slots and wait queues.
    let active = router.active_per_node();
    assert_eq!(active[0], 0, "dead site still holds submit slots");
    assert_eq!(active[1], 3, "survivor runs at its admission cap");
    let dtn_active = router.dtn_active_per_node();
    assert_eq!(dtn_active[0], 0, "dead DTN 0 still holds slots");
    assert_eq!(dtn_active[1], 0, "dead DTN 1 still holds slots");
    assert_eq!(
        router.active() as usize + router.waiting(),
        (n_jobs - completed) as usize,
        "slot+queue accounting conserved across the site kill"
    );
    admitted.retain(|&t| router.global_shard_of(t).is_some());
    admitted.extend(rescued.iter().map(|a| a.ticket));

    // Drain on the survivor: every admission stays on node 1 and every
    // DTN-sourced byte stays on site 1.
    let mut guard = 0;
    while completed < n_jobs {
        guard += 1;
        assert!(guard < 1000, "burst deadlocked after the site failure");
        let t = admitted.pop().expect("no admitted transfer while jobs remain");
        completed += 1;
        for a in router.complete(t) {
            assert_eq!(a.node, 1, "survivor serves the re-routed backlog");
            if let DataSource::Dtn { dtn } = a.source {
                assert_eq!(router.site_of_dtn(dtn), 1);
            }
            admitted.push(a.ticket);
        }
    }
    assert_eq!(completed, n_jobs, "every job finished despite the dead site");
    assert_eq!(router.active(), 0);
    assert_eq!(router.waiting(), 0);
    assert!(router.dtn_active_per_node().iter().all(|&a| a == 0));
    assert_eq!(router.stats().released_without_active, 0);
}

/// Chaos-tier e2e: a real loopback burst loses site 0 mid-flight and
/// gets it back — every job still completes, every byte is accounted in
/// the site×site matrix, and the chaos timeline records the site events.
#[test]
#[ignore = "heavier federated loopback chaos burst; run in the chaos tier"]
fn real_fabric_survives_site_kill_mid_burst() {
    let mut cfg = real_cfg(24);
    cfg.input_bytes = 256 << 10;
    cfg.n_submit_nodes = 2;
    cfg.data_nodes = 4;
    cfg.source = SourcePlan::DedicatedDtn;
    cfg.n_sites = 2;
    cfg.site_selector = SiteSelector::LocalFirst;
    cfg.faults = FaultPlan::default().kill_site(0, 0.2).recover_site(0, 1.2);
    let r = run_real_pool(cfg).unwrap();
    assert_eq!(r.errors, 0, "site kill must not surface as transfer errors");
    assert_eq!(r.jobs_completed, 24);
    assert_eq!(
        r.total_payload_bytes,
        24 * (256 << 10) as u64,
        "every byte delivered despite the site outage"
    );
    assert_eq!(r.n_sites, 2);
    assert_eq!(
        r.site_matrix_bytes.iter().flatten().sum::<u64>(),
        r.total_payload_bytes,
        "site matrix accounts the full burst"
    );
    let site_records: Vec<_> = r.chaos.records.iter().filter(|rec| rec.is_site()).collect();
    assert_eq!(site_records.len(), 2, "kill-site and recover-site recorded");
    assert!(site_records.iter().any(|rec| rec.action == "kill-site"));
    assert!(site_records.iter().any(|rec| rec.action == "recover-site"));
}
