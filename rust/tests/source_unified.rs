//! The tentpole acceptance tests for the data-source plane: one
//! `PoolRouter` carrying a `SourcePlan` (submit-funnel / dedicated-dtn
//! / hybrid) drives BOTH fabrics — first the virtual-time simulator,
//! then the real TCP loopback pool — with source placement and
//! admission statistics accumulating across the two runs (mirroring
//! `router_unified.rs`, one layer down the data plane).

use htcdm::coordinator::engine::{Engine, EngineSpec};
use htcdm::coordinator::{Experiment, Scenario};
use htcdm::fabric::{run_real_pool, run_real_pool_router, RealPoolConfig};
use htcdm::mover::{
    DataSource, FaultPlan, PoolRouter, RouterConfig, RouterPolicy, ShadowPool, SourcePlan,
    SourceSelector,
};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::{Bytes, SimTime};

fn tiny_sim_spec(n_jobs: u32) -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers.truncate(2);
    tb.workers[0].slots = 4;
    tb.workers[1].slots = 4;
    tb.monitor_bin = SimTime::from_secs(5);
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = n_jobs;
    spec.input_bytes = Bytes(50_000_000);
    spec.runtime_median_s = 1.0;
    spec.seed = 13;
    spec
}

fn real_cfg(n_jobs: u32) -> RealPoolConfig {
    RealPoolConfig {
        n_jobs,
        workers: 3,
        input_bytes: 128 << 10,
        output_bytes: 512,
        chunk_words: 1024,
        use_xla_engine: false,
        passphrase: "source-unified".into(),
        ..RealPoolConfig::default()
    }
}

/// One router object carrying a dedicated-DTN plan serves the simulator
/// and then the real fabric: in both, every payload byte is served by
/// the DTN fleet while the submit node keeps only scheduling duties.
#[test]
fn same_source_plan_drives_sim_and_real_fabric() {
    let sim_jobs = 24u32;
    let real_jobs = 8u32;
    let router = PoolRouter::from_config(
        vec![ShadowPool::sim(2, ThrottlePolicy::Disabled.into())],
        vec![1.0],
        RouterPolicy::LeastLoaded,
        RouterConfig {
            source_plan: SourcePlan::DedicatedDtn,
            dtn_capacity: vec![1.0, 1.0],
            ..RouterConfig::default()
        },
    );
    assert_eq!(router.dtn_count(), 2);

    // Phase 1: the simulated fabric routes every input flow over the
    // two monitored data-node NICs; the submit NIC stays dark.
    let result = Engine::with_router(tiny_sim_spec(sim_jobs), router)
        .run()
        .unwrap();
    assert_eq!(result.schedd.completed_count(), sim_jobs as usize);
    assert_eq!(result.dtn_monitors.len(), 2);
    let dtn_bytes: f64 = result.dtn_monitors.iter().map(|m| m.total_bytes()).sum();
    assert!(
        dtn_bytes >= sim_jobs as f64 * 50_000_000.0,
        "DTN NICs carried the sim burst: {dtn_bytes}"
    );
    assert_eq!(
        result.monitors[0].total_bytes(),
        0.0,
        "submit NIC carries no payload under dedicated-dtn"
    );
    assert_eq!(
        result.router.routed_per_dtn.iter().sum::<u64>(),
        sim_jobs as u64
    );

    // Extract the very same router object from the sim schedd.
    let mut schedd = result.schedd;
    let router = schedd.take_router();
    assert_eq!(router.source_plan(), SourcePlan::DedicatedDtn);
    assert_eq!(router.stats().total_admitted, sim_jobs as u64);

    // Phase 2: the real TCP fabric — two ServerRole::Dtn file servers
    // plus the (idle) submit funnel — moves sealed bytes through the
    // same router.
    let (report, router) = run_real_pool_router(&real_cfg(real_jobs), router).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.jobs_completed, real_jobs);
    assert_eq!(report.source_plan, "dedicated-dtn");
    assert_eq!(
        report.bytes_served_per_node,
        vec![0],
        "the submit server moved nothing"
    );
    assert_eq!(
        report.bytes_served_per_dtn.iter().sum::<u64>(),
        real_jobs as u64 * (128 << 10) as u64,
        "the DTN fleet served the whole real burst"
    );

    // The SAME router accounted for both fabrics, per-DTN.
    let rstats = router.router_stats();
    assert_eq!(
        rstats.routed_per_dtn.iter().sum::<u64>(),
        (sim_jobs + real_jobs) as u64,
        "source placements accumulated across sim and real runs"
    );
    assert_eq!(router.stats().released_without_active, 0);
}

/// A hybrid plan on the real fabric with a threshold exactly at the
/// input size: everything is "large", so everything rides the DTN —
/// the boundary is inclusive on both fabrics.
#[test]
fn hybrid_threshold_boundary_is_inclusive_on_the_real_fabric() {
    let mut cfg = real_cfg(6);
    cfg.data_nodes = 1;
    cfg.source = SourcePlan::Hybrid {
        threshold: 128 << 10, // == input_bytes
    };
    let r = run_real_pool(cfg).unwrap();
    assert_eq!(r.errors, 0);
    assert_eq!(
        r.bytes_served_per_dtn.iter().sum::<u64>(),
        6 * (128 << 10) as u64,
        "bytes == threshold goes via the DTN"
    );
    assert_eq!(r.bytes_served_per_node, vec![0]);
}

/// Chaos against the data plane on the real fabric: kill one of two
/// DTNs at t=0 — its transfers re-source to the survivor mid-burst and
/// the run still completes every job.
#[test]
fn real_dtn_kill_fails_over_to_survivor() {
    let mut cfg = real_cfg(10);
    cfg.data_nodes = 2;
    cfg.source = SourcePlan::DedicatedDtn;
    cfg.workers = 2;
    cfg.faults = FaultPlan::default().kill_dtn(0, 0.0);
    let r = run_real_pool(cfg).unwrap();
    assert_eq!(r.errors, 0, "burst survives the dead DTN");
    assert_eq!(r.jobs_completed, 10);
    assert_eq!(r.chaos.count("kill-dtn"), 1);
    assert_eq!(r.router.dtn_failed, 1);
    // The survivor ends up serving everything still outstanding.
    assert!(
        r.bytes_served_per_dtn[1] >= r.bytes_served_per_dtn[0],
        "survivor served the bulk: {:?}",
        r.bytes_served_per_dtn
    );
    assert_eq!(r.bytes_served_per_node, vec![0]);
}

/// The `dtn-offload-4` scenario runs on the simulator at smoke scale
/// (the CI bench-smoke job runs the same scenario via the CLI), and its
/// report satisfies the per-source aggregation contract.
#[test]
fn dtn_offload_4_scenario_smokes() {
    let mut spec = Scenario::DtnOffload4.spec();
    spec.n_jobs = 48;
    spec.input_bytes = Bytes(50_000_000);
    spec.testbed.monitor_bin = SimTime::from_secs(5);
    let report = Experiment::custom("dtn-offload-smoke", spec).run().unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.n_data_nodes, 4);
    assert_eq!(report.n_submit_nodes, 1);
    assert_eq!(report.source_plan, "dedicated-dtn");
    assert_eq!(report.per_dtn_series.len(), 4);
    for (d, s) in report.per_dtn_series.iter().enumerate() {
        assert!(s.total_bytes() > 0.0, "dtn {d} idle");
    }
    assert_eq!(report.per_node_series[0].total_bytes(), 0.0);
    assert_eq!(report.router.routed_per_dtn.iter().sum::<u64>(), 48);
}

/// One owner-affinity `SourceSelector` drives BOTH fabrics, including a
/// DTN-kill re-pin on the real one: the sim phase pins the benchmark
/// owner's whole burst onto one data node; the real phase then kills
/// exactly that node at burst start, the router re-pins the owner onto
/// the survivor, and every job still completes — selector state (the
/// pin) carrying across fabrics through the one router object.
#[test]
fn same_source_selector_drives_sim_and_real_fabric_with_repin() {
    let router = PoolRouter::from_config(
        vec![ShadowPool::sim(2, ThrottlePolicy::Disabled.into())],
        vec![1.0],
        RouterPolicy::LeastLoaded,
        RouterConfig {
            source_plan: SourcePlan::DedicatedDtn,
            dtn_capacity: vec![1.0, 1.0],
            source_selector: SourceSelector::OwnerAffinity,
            ..RouterConfig::default()
        },
    );

    // Phase 1 (sim): one owner, one pin — the whole burst rides a
    // single data node.
    let sim_jobs = 16u32;
    let result = Engine::with_router(tiny_sim_spec(sim_jobs), router)
        .run()
        .unwrap();
    assert_eq!(result.schedd.completed_count(), sim_jobs as usize);
    let placed = result.router.routed_per_dtn.clone();
    assert_eq!(placed.iter().sum::<u64>(), sim_jobs as u64);
    assert_eq!(
        placed.iter().filter(|&&c| c > 0).count(),
        1,
        "owner pinned to one data node: {placed:?}"
    );
    let pinned = placed.iter().position(|&c| c > 0).unwrap();

    let mut schedd = result.schedd;
    let router = schedd.take_router();
    assert_eq!(router.source_selector(), SourceSelector::OwnerAffinity);
    assert_eq!(router.dtn_pin_of("benchmark"), Some(pinned));

    // Phase 2 (real): kill the pinned node at burst start. The same
    // router re-pins the owner; the survivor serves the burst.
    let mut cfg = real_cfg(8);
    cfg.workers = 2;
    cfg.faults = FaultPlan::default().kill_dtn(pinned, 0.0);
    let (report, router) = run_real_pool_router(&cfg, router).unwrap();
    assert_eq!(report.errors, 0, "burst survives the dead pinned node");
    assert_eq!(report.jobs_completed, 8);
    assert_eq!(report.source_selector, "owner-affinity");
    assert_eq!(report.router.dtn_failed, 1);
    let survivor = 1 - pinned;
    assert_eq!(
        router.dtn_pin_of("benchmark"),
        Some(survivor),
        "the kill re-pinned the owner onto the survivor"
    );
    let served: u64 = report.bytes_served_per_dtn.iter().sum();
    assert!(
        served >= 8 * (128 << 10) as u64,
        "the fleet served the whole real burst: {served}"
    );
    assert!(
        report.bytes_served_per_dtn[survivor] >= report.bytes_served_per_dtn[pinned],
        "survivor carried the bulk: {:?}",
        report.bytes_served_per_dtn
    );
    assert_eq!(report.bytes_served_per_node, vec![0]);
}

/// Sources survive a *schedule-node* failure: with 2 submit nodes and a
/// DTN fleet, killing submit node 0 re-admits its transfers on node 1,
/// and the re-admissions pick fresh DTN sources (scheduling failover
/// composes with the data plane).
#[test]
fn schedule_node_failure_composes_with_dtn_sources() {
    use htcdm::mover::TransferRequest;
    let mut router = PoolRouter::from_config(
        (0..2)
            .map(|_| ShadowPool::sim(1, ThrottlePolicy::MaxConcurrent(2).into()))
            .collect(),
        vec![1.0; 2],
        RouterPolicy::RoundRobin,
        RouterConfig {
            source_plan: SourcePlan::DedicatedDtn,
            dtn_capacity: vec![1.0, 1.0],
            ..RouterConfig::default()
        },
    );
    for t in 0..8 {
        router.request(TransferRequest::new(t, "o", 1000));
    }
    assert_eq!(router.active(), 4, "2 per node");
    let rescued = router.fail_node(0);
    assert!(rescued.is_empty(), "survivor already at its limit");
    // Drain node 1; every admission along the way carries a DTN source.
    let mut pending: Vec<u32> = (0..8)
        .filter(|&t| router.global_shard_of(t).is_some())
        .collect();
    let mut done = 0u32;
    let mut guard = 0;
    while let Some(t) = pending.pop() {
        guard += 1;
        assert!(guard < 100, "drain deadlocked");
        done += 1;
        for a in router.complete(t) {
            assert_eq!(a.node, 1, "survivor schedules everything");
            assert!(
                matches!(a.source, DataSource::Dtn { .. }),
                "re-admissions stay on the data plane: {:?}",
                a.source
            );
            pending.push(a.ticket);
        }
    }
    assert_eq!(done, 8);
    assert!(
        router.router_stats().routed_per_dtn.iter().sum::<u64>() >= 8,
        "every admission (including re-admissions) got a DTN source"
    );
}
