//! Cross-module integration: config -> experiment wiring, submit files ->
//! engine workloads, security sessions -> sealed streams, collector ->
//! negotiator -> schedd flow.

use htcdm::classad::Ad;
use htcdm::config::Config;
use htcdm::coordinator::engine::EngineSpec;
use htcdm::coordinator::{Experiment, Scenario};
use htcdm::daemons::{Collector, Negotiator, Schedd, SlotId, Startd};
use htcdm::jobs::submit::{paper_submit_text, parse_submit};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::runtime::engine::NativeEngine;
use htcdm::security::session::{handshake, PoolKey};
use htcdm::security::Method;
use htcdm::transfer::stream::{recv_stream, send_stream};
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::{Bytes, SimTime};

/// HTCondor-style config text drives a full experiment spec.
#[test]
fn config_to_experiment() {
    let cfg = Config::parse(
        "POOL = htcdm-test\n\
         JOBS = 80\n\
         INPUT_SIZE = 50MB\n\
         FILE_TRANSFER_DISK_LOAD_THROTTLE = false\n\
         SUBMIT_NIC_GBPS = 100\n\
         NAME = bench-$(POOL)\n",
    )
    .unwrap();
    assert_eq!(cfg.get("NAME").unwrap().unwrap(), "bench-htcdm-test");
    let throttle = if cfg.get_bool("FILE_TRANSFER_DISK_LOAD_THROTTLE", true).unwrap() {
        ThrottlePolicy::htcondor_default()
    } else {
        ThrottlePolicy::Disabled
    };
    let mut spec = EngineSpec::paper(TestbedSpec::lan_paper(), throttle);
    spec.n_jobs = cfg.get_u64("JOBS", 100).unwrap() as u32;
    spec.input_bytes = Bytes(cfg.get_bytes("INPUT_SIZE", 0).unwrap());
    let report = Experiment::custom("cfg", spec).run().unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.n_jobs, 80);
}

/// A parsed submit file produces the same workload the engine generates.
#[test]
fn submit_file_matches_engine_workload() {
    let specs = parse_submit(&paper_submit_text(500), 1).unwrap();
    assert_eq!(specs.len(), 500);
    assert!(specs.iter().enumerate().all(|(i, s)| s.input_file == format!("input_{i}")));
    assert!(specs.iter().all(|s| s.input_bytes == Bytes(2_000_000_000)));
}

/// Full daemon walk: slots advertised to the collector, negotiator matches,
/// schedd drives transfers through the queue.
#[test]
fn collector_negotiator_schedd_roundtrip() {
    let mut collector = Collector::new();
    let startd = Startd::new(0, 4);
    for s in 0..4 {
        collector.advertise(&SlotId { worker: 0, slot: s }.to_string(), startd.slot_ad(s));
    }
    assert_eq!(collector.query_type("Machine").len(), 4);
    assert_eq!(
        collector
            .query("Machine", "State == \"Unclaimed\"")
            .unwrap()
            .len(),
        4
    );

    let mut schedd = Schedd::new("s", ThrottlePolicy::MaxConcurrent(2));
    schedd.submit_transaction(parse_submit(&paper_submit_text(6), 1).unwrap(), SimTime::ZERO);
    let idle = schedd.idle_jobs();
    let slots: Vec<(SlotId, Ad)> = (0..4)
        .map(|s| (SlotId { worker: 0, slot: s }, startd.slot_ad(s)))
        .collect();
    let mut neg = Negotiator::new();
    let result = neg.negotiate(&idle, &slots);
    assert_eq!(result.matches.len(), 4, "4 slots, 6 jobs");

    let mut started = Vec::new();
    for (job, _) in &result.matches {
        schedd.take_idle(job.proc);
        started.extend(schedd.job_matched(job.proc, SimTime::ZERO));
    }
    assert_eq!(started.len(), 2, "transfer queue admits only 2 of 4");
    assert_eq!(schedd.mover.waiting(), 2);
}

/// Handshake-derived session keys drive the sealed stream end to end.
#[test]
fn session_to_stream_roundtrip() {
    let key = PoolKey::from_passphrase("integration");
    let sess = handshake(
        &key,
        [1u8; 16],
        [2u8; 16],
        &[Method::Chacha20],
        &[Method::Chacha20],
    )
    .unwrap();
    let data = vec![0x42u8; 100_000];
    let mut tx = NativeEngine::new(sess.method);
    let mut rx = NativeEngine::new(sess.method);
    let mut wire = Vec::new();
    send_stream(&mut wire, &mut tx, &sess.key_words, &sess.nonce_words, &data, 1024).unwrap();
    let (out, stats) = recv_stream(
        &mut std::io::Cursor::new(wire),
        &mut rx,
        &sess.key_words,
        &sess.nonce_words,
    )
    .unwrap();
    assert_eq!(out, data);
    assert!(stats.wire_bytes > stats.payload_bytes, "framing overhead visible");
}

/// The four paper scenarios at 1/10 scale keep their qualitative ordering.
/// (Plateau-based `sustained` is noisy on sub-minute runs, so ordering is
/// checked on mean throughput = bytes/makespan.)
#[test]
fn scenario_ordering_holds_at_small_scale() {
    let run = |s: Scenario| Experiment::scenario(s).scaled(10).run().unwrap();
    let mean_gbps = |r: &htcdm::coordinator::Report| {
        r.n_jobs as f64 * 2e9 * 8.0 / r.makespan.as_secs_f64() / 1e9
    };
    let lan = run(Scenario::LanPaper);
    let wan = run(Scenario::WanPaper);
    let queue = run(Scenario::LanDefaultQueue);
    let vpn = run(Scenario::LanVpn);
    assert!(mean_gbps(&lan) > mean_gbps(&wan), "LAN > WAN");
    assert!(mean_gbps(&wan) > mean_gbps(&vpn), "WAN > VPN");
    assert!(queue.makespan > lan.makespan, "default queue is slower");
    assert!(mean_gbps(&vpn) < 27.0, "VPN ceiling ~25 Gbps");
    for r in [&lan, &wan, &queue, &vpn] {
        assert_eq!(r.errors, 0);
    }
}

/// Regression: on a long-RTT WAN path the TcpDynamic solver's slow-start
/// ramp is visible in the submit-NIC bin series — the first full bin
/// after bytes start flowing sits far below the plateau, where FairShare
/// jumps straight to its (Mathis-capped) steady rate after setup.
#[test]
fn wan_slow_start_ramp_shows_in_nic_bins() {
    use htcdm::netsim::solver::SolverKind;
    let run = |kind: SolverKind| {
        let mut tb = TestbedSpec::wan_paper();
        tb.link_rtt_ms = Some(200.0); // stretch the ramp across several bins
        tb.monitor_bin = SimTime::from_secs_f64(0.5);
        let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
        spec.n_jobs = 40;
        spec.input_bytes = Bytes(400_000_000);
        spec.output_bytes = Bytes(4_000);
        spec.runtime_median_s = 0.0;
        spec.seed = 42;
        spec.solver = kind;
        Experiment::custom("ramp", spec).run().unwrap()
    };
    let fs = run(SolverKind::FairShare);
    let tcp = run(SolverKind::TcpDynamic);
    assert_eq!(fs.errors, 0);
    assert_eq!(tcp.errors, 0);
    assert_eq!(fs.solver, "fair-share");
    assert_eq!(tcp.solver, "tcp-dynamic");

    // Rate of the second non-empty bin relative to the series peak: the
    // first non-empty bin only partially overlaps flow start, the second
    // is entirely inside the transfer.
    let early_vs_peak = |r: &htcdm::coordinator::Report| -> f64 {
        let rates: Vec<f64> = r.series.gbps_series().iter().map(|&(_, g)| g.0).collect();
        let peak = rates.iter().cloned().fold(0.0, f64::max);
        assert!(peak > 0.0, "no bytes monitored for {}", r.solver);
        let second = rates
            .iter()
            .cloned()
            .filter(|v| *v > peak * 1e-3)
            .nth(1)
            .expect("at least two non-empty bins");
        second / peak
    };
    let fs_early = early_vs_peak(&fs);
    let tcp_early = early_vs_peak(&tcp);
    assert!(
        fs_early > 0.6,
        "fair-share should start at its steady rate, got {fs_early:.3} of peak"
    );
    assert!(
        tcp_early < 0.3,
        "tcp-dynamic should still be in slow start one bin in, got {tcp_early:.3} of peak"
    );
}

/// Storage hardlink dataset + engine: the 10k-names-one-extent trick.
#[test]
fn paper_dataset_feeds_pool() {
    use htcdm::storage::{build_paper_dataset, DeviceProfile, Storage};
    let mut st = Storage::new(DeviceProfile::nvme(), 8 << 30);
    build_paper_dataset(&mut st, "input_", 2 << 30, 1000);
    assert_eq!(st.len(), 1000);
    assert_eq!(st.distinct_extents(), 1);
    // Every stream the engine would open hits the page cache.
    for i in 0..1000 {
        assert!(st.open_read(&format!("input_{i}")).unwrap().cached);
    }
}
