//! Tentpole acceptance tests for durable transfer tasks: one
//! checkpointed `TransferTask` — the named multi-file dataset object the
//! managed-transfer layer owns — drives BOTH fabrics through the same
//! `TaskRunner`, and its journal survives a coordinator "crash":
//!
//! * the virtual-time simulator runs the task as fluid flows and is
//!   killed mid-task (admissions stop, in-flight flows are abandoned,
//!   the journal keeps the last checkpoint), then
//! * a brand-new runner over the SAME journal resumes on the real TCP
//!   loopback fabric, moving real sealed bytes for ONLY the files the
//!   dead coordinator never checkpointed.
//!
//! The server-side byte counters are the proof: the resumed run serves
//! exactly `(files_total - files_resumed) × file_bytes`, and every file
//! — whichever fabric moved it — verifies against the same name-keyed
//! SHA-256.

use htcdm::coordinator::engine::{run_task_sim, run_task_sim_with_kill, EngineSpec};
use htcdm::fabric::{run_real_task, RealTaskConfig};
use htcdm::mover::{synth_file_sha256, FileState, TaskJournal, TaskRunner, TransferTask};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;

const N_FILES: usize = 6;
const FILE_BYTES: u64 = 256 << 10;

fn unified_task(name: &str) -> TransferTask {
    TransferTask::new(name, "alice").with_uniform_files("input", N_FILES, FILE_BYTES)
}

fn sim_spec() -> EngineSpec {
    EngineSpec::paper(TestbedSpec::lan_paper(), ThrottlePolicy::Disabled)
}

fn real_cfg() -> RealTaskConfig {
    RealTaskConfig {
        workers: 2,
        chunk_words: 1024,
        passphrase: "task-unified".into(),
        ..RealTaskConfig::default()
    }
}

fn temp_journal(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("htcdm-task-unified-{tag}-{}", std::process::id()))
}

/// The headline invariant: a task checkpointed by the simulated
/// coordinator resumes on the real fabric — same journal, same file
/// states, no byte re-transferred, every hash identical across fabrics.
#[test]
fn sim_checkpoint_resumes_on_real_fabric_without_retransfer() {
    let dir = temp_journal("sim2real");
    let _ = std::fs::remove_dir_all(&dir);

    // Sim coordinator, killed after 2 checkpointed files.
    let mut runner = TaskRunner::new(
        unified_task("unified"),
        TaskJournal::dir(dir.clone()).unwrap(),
    )
    .unwrap();
    let r1 = run_task_sim_with_kill(&sim_spec(), &mut runner, Some(2)).unwrap();
    assert!(r1.killed);
    let done1 = r1.progress.files_done;
    assert!((2..N_FILES).contains(&done1), "killed mid-task: {done1}");
    drop(runner); // the dead coordinator

    // Real coordinator over the same journal: only the rest moves.
    let resumed = TaskRunner::new(
        unified_task("unified"),
        TaskJournal::dir(dir.clone()).unwrap(),
    )
    .unwrap();
    assert_eq!(resumed.files_resumed(), done1);
    let (r2, runner) = run_real_task(&real_cfg(), resumed).unwrap();
    assert_eq!(r2.errors, 0);
    assert_eq!(r2.progress.files_done, N_FILES);
    assert_eq!(r2.progress.files_resumed, done1);
    assert_eq!(r2.files_transferred as usize, N_FILES - done1);
    assert_eq!(
        r2.bytes_served_per_node.iter().sum::<u64>(),
        (N_FILES - done1) as u64 * FILE_BYTES,
        "sim-checkpointed files must never hit the real wire"
    );
    // Every file — sim-moved or real-moved — carries the same
    // name-keyed hash, so the checkpoint is fabric-portable.
    for i in 0..N_FILES {
        let f = runner.file(i);
        assert_eq!(
            f.state,
            FileState::Done {
                sha256: synth_file_sha256(&f.name, f.bytes)
            },
            "file {i} hash differs across fabrics"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The reverse direction: a task the real fabric checkpointed mid-crash
/// finishes in the simulator — the journal is the contract, not the
/// fabric that wrote it.
#[test]
fn real_checkpoint_resumes_in_simulator() {
    let dir = temp_journal("real2sim");
    let _ = std::fs::remove_dir_all(&dir);

    let mut cfg = real_cfg();
    cfg.kill_after_files = Some(2);
    let runner = TaskRunner::new(
        unified_task("unified-r"),
        TaskJournal::dir(dir.clone()).unwrap(),
    )
    .unwrap();
    let (r1, _dead) = run_real_task(&cfg, runner).unwrap();
    assert!(r1.killed);
    let done1 = r1.progress.files_done;
    assert!((2..N_FILES).contains(&done1));

    let mut resumed = TaskRunner::new(
        unified_task("unified-r"),
        TaskJournal::dir(dir.clone()).unwrap(),
    )
    .unwrap();
    assert_eq!(resumed.files_resumed(), done1);
    let r2 = run_task_sim(&sim_spec(), &mut resumed).unwrap();
    assert_eq!(r2.progress.files_done, N_FILES);
    assert_eq!(r2.progress.files_resumed, done1);
    // The sim moved only the remaining files' bytes through its router.
    let routed: u64 = r2.mover.bytes_per_shard.iter().sum();
    assert_eq!(routed, (N_FILES - done1) as u64 * FILE_BYTES);
    assert!(resumed.done());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos tier (CI `--ignored` job): the full kill-the-coordinator e2e on
/// the real fabric at a heavier scale — 24 × 1 MiB files, killed after
/// 8 — restart, resume, and prove with the server byte counters that
/// nothing checkpointed was re-transferred. Writes a JSON report for the
/// CI artifact upload when `CHAOS_REPORT_DIR` is set.
#[test]
#[ignore = "chaos tier: kill/resume e2e; run with cargo test --release -- --ignored"]
fn chaos_e2e_task_kill_resume_real_fabric() {
    let dir = temp_journal("chaos");
    let _ = std::fs::remove_dir_all(&dir);
    let task = || TransferTask::new("chaos-task", "alice").with_uniform_files("input", 24, 1 << 20);

    let mut cfg = real_cfg();
    cfg.workers = 4;
    cfg.kill_after_files = Some(8);
    let runner = TaskRunner::new(task(), TaskJournal::dir(dir.clone()).unwrap()).unwrap();
    let (r1, _dead) = run_real_task(&cfg, runner).unwrap();
    assert!(r1.killed, "the coordinator kill must have fired");
    let done1 = r1.progress.files_done;
    assert!((8..24).contains(&done1), "killed mid-task: {done1}");

    cfg.kill_after_files = None;
    let runner = TaskRunner::new(task(), TaskJournal::dir(dir.clone()).unwrap()).unwrap();
    assert_eq!(runner.files_resumed(), done1);
    let (r2, runner) = run_real_task(&cfg, runner).unwrap();
    assert_eq!(r2.errors, 0);
    assert_eq!(r2.progress.files_done, 24);
    assert_eq!(r2.files_transferred as usize, 24 - done1);
    let served2: u64 = r2.bytes_served_per_node.iter().sum();
    assert_eq!(
        served2,
        (24 - done1) as u64 * (1 << 20),
        "resumed run re-served checkpointed bytes"
    );
    for i in 0..24 {
        let f = runner.file(i);
        assert_eq!(
            f.state,
            FileState::Done {
                sha256: synth_file_sha256(&f.name, f.bytes)
            }
        );
    }

    if let Ok(report_dir) = std::env::var("CHAOS_REPORT_DIR") {
        std::fs::create_dir_all(&report_dir).ok();
        let json = format!(
            "{{\"test\":\"chaos_e2e_task_kill_resume_real_fabric\",\
             \"files_total\":24,\"killed_after\":8,\
             \"files_resumed\":{},\"retransferred\":{},\
             \"bytes_served_resumed_run\":{served2},\
             \"run1_wall_secs\":{:.3},\"run2_wall_secs\":{:.3},\
             \"errors\":{}}}",
            r2.progress.files_resumed,
            r2.files_transferred,
            r1.wall_secs,
            r2.wall_secs,
            r1.errors + r2.errors,
        );
        std::fs::write(format!("{report_dir}/task_resume_e2e.json"), json)
            .expect("write chaos report");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
