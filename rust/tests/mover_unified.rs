//! The tentpole acceptance test: a single `ShadowPool` (one policy
//! object, one shard map, one statistics block) drives BOTH fabrics —
//! first the virtual-time simulator, then the real TCP loopback pool —
//! with admission statistics accumulating across the two runs.

use htcdm::coordinator::engine::{Engine, EngineSpec};
use htcdm::fabric::{run_real_pool_with, RealPoolConfig};
use htcdm::mover::{AdmissionConfig, DataMover, ShadowPool, TransferRequest};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::Bytes;

fn tiny_sim_spec(n_jobs: u32) -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers.truncate(2);
    tb.workers[0].slots = 4;
    tb.workers[1].slots = 4;
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = n_jobs;
    spec.input_bytes = Bytes(50_000_000);
    spec.runtime_median_s = 1.0;
    spec.seed = 7;
    spec
}

/// One mover object serves the simulator and then the real fabric; the
/// same admission policy gates both, and its counters accumulate.
#[test]
fn same_mover_object_drives_sim_and_real_fabric() {
    let sim_jobs = 24u32;
    let real_jobs = 8u32;
    let policy = AdmissionConfig::FairShare { limit: 3 };
    let mover = ShadowPool::sim(2, policy.clone());
    assert_eq!(mover.config(), &policy);

    // Phase 1: the simulated fabric (fluid flows over the calibrated
    // testbed) drives admission through the mover.
    let result = Engine::with_mover(tiny_sim_spec(sim_jobs), mover)
        .run()
        .unwrap();
    assert_eq!(result.schedd.completed_count(), sim_jobs as usize);
    assert_eq!(result.mover.total_admitted, sim_jobs as u64);
    assert!(result.mover.peak_active <= 3, "policy limited the sim run");

    // Extract the very same mover object from the sim schedd.
    let mut schedd = result.schedd;
    let mover = schedd.take_router().into_single().unwrap();
    assert_eq!(mover.stats().total_admitted, sim_jobs as u64);

    // Phase 2: the real TCP fabric moves sealed bytes through the same
    // mover (engines spawn on demand, admission state carries over).
    let cfg = RealPoolConfig {
        n_jobs: real_jobs,
        workers: 3,
        input_bytes: 128 << 10,
        output_bytes: 512,
        chunk_words: 1024,
        use_xla_engine: false,
        passphrase: "unified".into(),
        shadows: 2, // informational; the supplied mover's shard count wins
        policy: policy.clone(),
        ..RealPoolConfig::default()
    };
    let (report, mover) = run_real_pool_with(&cfg, mover).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.jobs_completed, real_jobs);
    assert_eq!(report.total_payload_bytes, real_jobs as u64 * (128 << 10));

    // The SAME policy object accounted for both fabrics' admissions.
    let stats = mover.stats();
    assert_eq!(
        stats.total_admitted,
        (sim_jobs + real_jobs) as u64,
        "admissions accumulated across sim and real runs"
    );
    assert_eq!(stats.released_without_active, 0);
    assert!(stats.peak_active <= 3, "one policy bounded both fabrics");
    assert_eq!(stats.admitted_per_shard.len(), 2);
    assert_eq!(
        stats.admitted_per_shard.iter().sum::<u64>(),
        (sim_jobs + real_jobs) as u64,
        "every transfer from both fabrics was routed through a shard"
    );
}

/// The DataMover trait object interface works over a ShadowPool — the
/// abstraction both fabrics program against.
#[test]
fn shadow_pool_as_dyn_data_mover() {
    let mut mover: Box<dyn DataMover> = Box::new(ShadowPool::sim(
        3,
        AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(2)),
    ));
    let a = mover.request(TransferRequest::new(1, "a", 100));
    assert_eq!(a.len(), 1);
    let b = mover.request(TransferRequest::new(2, "b", 100));
    assert_eq!(b.len(), 1);
    assert!(mover.request(TransferRequest::new(3, "c", 100)).is_empty());
    assert_eq!(mover.active(), 2);
    assert_eq!(mover.waiting(), 1);
    assert_eq!(mover.shard_count(), 3);
    assert!(mover.shard_of(1).is_some());
    let adm = mover.complete(1);
    assert_eq!(adm.len(), 1);
    assert_eq!(adm[0].ticket, 3);
    assert!(mover.describe().contains("shadow-pool"));
    assert_eq!(mover.stats().total_admitted, 3);
}
