//! The tentpole acceptance tests for multi-submit-node sharding: one
//! `PoolRouter` (N per-node `ShadowPool`s behind a routing strategy)
//! drives BOTH fabrics — first the virtual-time simulator, then the real
//! TCP loopback pool — with routing and admission statistics
//! accumulating across the two runs (mirroring `mover_unified.rs`, one
//! layer up).

use htcdm::coordinator::engine::{Engine, EngineSpec};
use htcdm::coordinator::{Experiment, Scenario};
use htcdm::fabric::{run_real_pool, run_real_pool_router, RealPoolConfig};
use htcdm::metrics::BinSeries;
use htcdm::mover::{AdmissionConfig, PoolRouter, RouterPolicy, TransferRequest};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::Bytes;

fn tiny_sim_spec(n_jobs: u32) -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers.truncate(2);
    tb.workers[0].slots = 4;
    tb.workers[1].slots = 4;
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = n_jobs;
    spec.input_bytes = Bytes(50_000_000);
    spec.runtime_median_s = 1.0;
    spec.seed = 11;
    spec
}

fn real_cfg(n_jobs: u32) -> RealPoolConfig {
    RealPoolConfig {
        n_jobs,
        workers: 3,
        input_bytes: 128 << 10,
        output_bytes: 512,
        chunk_words: 1024,
        use_xla_engine: false,
        passphrase: "router-unified".into(),
        ..RealPoolConfig::default()
    }
}

/// One router object serves the simulator and then the real fabric; the
/// same routing strategy and per-node policies gate both, every job
/// lands on exactly one node's shard, and the multi-node run moves the
/// same aggregate bytes as the single-node baseline.
#[test]
fn same_router_object_drives_sim_and_real_fabric() {
    let sim_jobs = 24u32;
    let real_jobs = 8u32;
    let policy = AdmissionConfig::FairShare { limit: 4 };
    let router = PoolRouter::sim(2, 2, policy.clone(), RouterPolicy::RoundRobin);
    assert_eq!(router.node_count(), 2);
    assert_eq!(router.shard_count(), 4, "2 nodes × 2 shards");

    // Phase 1: the simulated fabric (fluid flows over a 2-submit-NIC
    // testbed) drives routing + admission through the router.
    let mut spec = tiny_sim_spec(sim_jobs);
    spec.n_owners = 3; // fair-share has owners to rotate between
    let result = Engine::with_router(spec, router).run().unwrap();
    assert_eq!(result.schedd.completed_count(), sim_jobs as usize);
    assert_eq!(result.mover.total_admitted, sim_jobs as u64);
    assert!(result.mover.peak_active <= 8, "limit 4 per node × 2 nodes");
    assert_eq!(result.monitors.len(), 2, "one NIC monitor per submit node");
    // Every job was routed to exactly one node: per-node routing counts
    // partition the burst.
    assert_eq!(
        result.router.routed_per_node.iter().sum::<u64>(),
        sim_jobs as u64
    );
    assert_eq!(
        result.router.routed_per_node,
        vec![sim_jobs as u64 / 2; 2],
        "round-robin halves the burst"
    );

    // Extract the very same router object from the sim schedd.
    let mut schedd = result.schedd;
    let router = schedd.take_router();
    assert_eq!(router.stats().total_admitted, sim_jobs as u64);

    // Single-node baseline on the real fabric: the aggregate bytes the
    // multi-node run must match.
    let baseline = run_real_pool(real_cfg(real_jobs)).unwrap();
    assert_eq!(baseline.errors, 0);
    assert_eq!(
        baseline.total_payload_bytes,
        real_jobs as u64 * (128 << 10) as u64
    );

    // Phase 2: the real TCP fabric — one file server per submit node —
    // moves sealed bytes through the same router (engines spawn on
    // demand, routing state carries over).
    let (report, router) = run_real_pool_router(&real_cfg(real_jobs), router).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.jobs_completed, real_jobs);
    assert_eq!(
        report.total_payload_bytes, baseline.total_payload_bytes,
        "scale-out run moves exactly the single-node baseline's bytes"
    );
    assert_eq!(report.bytes_served_per_node.len(), 2);
    assert_eq!(
        report.bytes_served_per_node.iter().sum::<u64>(),
        baseline.total_payload_bytes,
        "the two file servers partition the dataset"
    );

    // The SAME router object accounted for both fabrics.
    let stats = router.stats();
    assert_eq!(
        stats.total_admitted,
        (sim_jobs + real_jobs) as u64,
        "admissions accumulated across sim and real runs"
    );
    assert_eq!(stats.released_without_active, 0);
    assert_eq!(stats.shard_failed, 0);
    // Exactly-one-shard invariant: per-shard admissions partition the
    // combined burst (no job double-routed, none lost).
    assert_eq!(stats.admitted_per_shard.len(), 4);
    assert_eq!(
        stats.admitted_per_shard.iter().sum::<u64>(),
        (sim_jobs + real_jobs) as u64,
        "every transfer from both fabrics landed on exactly one shard"
    );
    let rstats = router.router_stats();
    assert_eq!(
        rstats.routed_per_node.iter().sum::<u64>(),
        (sim_jobs + real_jobs) as u64
    );
}

/// Acceptance: an `n_submit_nodes = 4` sim scenario emits per-submit-node
/// NIC series whose element-wise sum equals the aggregate series.
#[test]
fn multi_submit_4_per_node_series_sum_to_aggregate() {
    let mut spec = Scenario::LanMultiSubmit4.spec();
    spec.n_jobs = 48;
    spec.input_bytes = Bytes(50_000_000);
    spec.testbed.monitor_bin = htcdm::util::units::SimTime::from_secs(5);
    let report = Experiment::custom("multi-submit-4-accept", spec)
        .run()
        .unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.n_submit_nodes, 4);
    assert_eq!(report.per_node_series.len(), 4);

    let summed = BinSeries::sum(&report.per_node_series);
    let agg = report.series.bins();
    let per = summed.bins();
    assert_eq!(agg.len(), per.len(), "same bin count");
    for (i, ((_, a), (_, b))) in agg.iter().zip(per.iter()).enumerate() {
        assert!(
            (a - b).abs() < 1e-6,
            "bin {i}: aggregate {a} != per-node sum {b}"
        );
    }
    // And the series carry real traffic: all input bytes crossed some
    // submit NIC.
    assert!(summed.total_bytes() >= 48.0 * 50_000_000.0);
    // Each of the 4 nodes carried a share of the burst.
    for (node, s) in report.per_node_series.iter().enumerate() {
        assert!(s.total_bytes() > 0.0, "node {node} idle");
    }
}

/// Failure path: poison one submit node mid-burst; the router re-routes
/// its waiting queue AND its in-flight transfers to the survivor, the
/// burst drains without deadlock, and the failure is counted.
#[test]
fn failed_node_drains_to_survivors_mid_burst() {
    let mut router = PoolRouter::sim(
        2,
        1,
        AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(3)),
        RouterPolicy::LeastLoaded,
    );
    let n_jobs = 30u32;
    let mut admitted: Vec<u32> = Vec::new();
    for t in 0..n_jobs {
        admitted.extend(router.request(TransferRequest::new(t, "o", 1000)).iter().map(|a| a.ticket));
    }
    assert_eq!(router.active(), 6, "3 per node");

    // Mid-burst: complete a few, then node 0 dies.
    let mut completed = 0u32;
    for _ in 0..4 {
        let t = admitted.pop().expect("admitted transfers exist");
        completed += 1;
        admitted.extend(router.complete(t).iter().map(|a| a.ticket));
    }
    let rescued = router.fail_node(0);
    // Node 0's formerly-admitted transfers are now *waiting* on node 1;
    // only tickets still holding a shard are in flight.
    admitted.retain(|&t| router.global_shard_of(t).is_some());
    admitted.extend(rescued.iter().map(|a| a.ticket));
    assert_eq!(router.stats().shard_failed, 1);

    // Drain to completion on the survivor — bounded, no deadlock.
    let mut guard = 0;
    while completed < n_jobs {
        guard += 1;
        assert!(guard < 1000, "burst deadlocked after node failure");
        let t = admitted.pop().expect("no admitted transfer while jobs remain");
        completed += 1;
        for a in router.complete(t) {
            assert_eq!(a.node, 1, "survivor serves the re-routed backlog");
            admitted.push(a.ticket);
        }
    }
    assert_eq!(completed, n_jobs, "every job finished despite the dead node");
    assert_eq!(router.active(), 0);
    assert_eq!(router.waiting(), 0);
    assert_eq!(router.stats().released_without_active, 0);
}

/// Slow scale-out e2e (CI's `--ignored` tier): sweep submit-node counts
/// on the real fabric; every width moves the identical aggregate bytes
/// and partitions the burst cleanly.
#[test]
#[ignore = "slower e2e sweep; run with cargo test --release -- --ignored"]
fn router_scaleout_e2e_sweep() {
    let total_bytes = |jobs: u32, sz: usize| jobs as u64 * sz as u64;
    let mut baseline = None;
    for nodes in [1u32, 2, 4] {
        let mut cfg = real_cfg(16);
        cfg.input_bytes = 1 << 20;
        cfg.workers = 4;
        cfg.n_submit_nodes = nodes;
        cfg.router = RouterPolicy::RoundRobin;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0, "{nodes}-node run had transfer errors");
        assert_eq!(r.jobs_completed, 16);
        assert_eq!(r.total_payload_bytes, total_bytes(16, 1 << 20));
        assert_eq!(r.router.routed_per_node.len(), nodes as usize);
        assert_eq!(r.router.routed_per_node.iter().sum::<u64>(), 16);
        let spread = r.router.routed_per_node.iter().max().unwrap()
            - r.router.routed_per_node.iter().min().unwrap();
        assert!(spread <= 1, "round-robin spread {spread} > 1");
        match baseline {
            None => baseline = Some(r.total_payload_bytes),
            Some(b) => assert_eq!(r.total_payload_bytes, b, "bytes match the 1-node baseline"),
        }
    }
}
