//! Tentpole acceptance tests for fault injection & recovery: one
//! `FaultPlan` shape — kill a submit node mid-burst, recover it later,
//! work-steal queued transfers onto it — drives BOTH fabrics to
//! equivalent drain/recover behavior:
//!
//! * the virtual-time simulator kills node 1's NIC service (in-flight
//!   flows abort and re-register on survivors) and restores it, and
//! * the real TCP loopback fabric crashes node 1's `FileServer`
//!   (in-flight connections break; workers retry through the router) and
//!   restarts it on a fresh port.
//!
//! In both: every transfer completes despite the dead node, the
//! recovered node serves bytes again, and the shared `MoverStats`
//! counters (`shard_failed`, `node_recovered`, `retried_after_fault`,
//! `stolen`) account for the churn. Event times are fabric-local
//! (virtual vs wall-clock seconds); the plan structure is identical.

use htcdm::coordinator::engine::{Engine, EngineSpec};
use htcdm::fabric::{run_real_pool_router, RealPoolConfig};
use htcdm::mover::{AdmissionConfig, FaultPlan, PoolRouter, RouterPolicy};
use htcdm::netsim::topology::TestbedSpec;
use htcdm::transfer::ThrottlePolicy;
use htcdm::util::units::{Bytes, SimTime};

/// Kill node 1, recover it later, steal work beyond the threshold — the
/// one plan shape both fabrics execute (times are fabric-local seconds).
fn kill_recover_plan(kill_at: f64, recover_at: f64) -> FaultPlan {
    FaultPlan::default()
        .kill(1, kill_at)
        .recover(1, recover_at)
        .with_steal_threshold(2)
}

const SIM_KILL_AT: f64 = 4.0;
const SIM_RECOVER_AT: f64 = 14.0;

/// A transfer-bound 4-submit-node burst: 60 slots feed 120 × 200 MB
/// sandboxes through per-node MaxConcurrent(2) admission, so every node
/// holds in-flight transfers AND a deep waiting queue when the fault
/// fires, and the burst (~25 virtual seconds) comfortably spans both
/// fault times.
fn chaos_sim_spec() -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers.truncate(2);
    tb.workers[0].slots = 30;
    tb.workers[1].slots = 30;
    tb.monitor_bin = SimTime::from_secs(2);
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::MaxConcurrent(2));
    spec.n_jobs = 120;
    spec.input_bytes = Bytes(200_000_000);
    spec.runtime_median_s = 0.6;
    spec.n_submit_nodes = 4;
    spec.router = RouterPolicy::RoundRobin;
    spec.seed = 7;
    spec.faults = kill_recover_plan(SIM_KILL_AT, SIM_RECOVER_AT);
    spec
}

/// Simulated fabric: `KillNode` mid-burst aborts node 1's in-flight
/// flows and re-routes its backlog; every job still completes via the
/// survivors; `RecoverNode` puts node 1 back to work (its NIC carries
/// bytes again) after stealing queued transfers from the survivors.
#[test]
fn sim_fault_plan_drains_and_recovers() {
    let spec = chaos_sim_spec();
    let r = Engine::new(spec).run().unwrap();

    // Drain: the dead node lost in-flight transfers, yet the whole burst
    // completed with clean accounting.
    assert_eq!(r.schedd.completed_count(), 120);
    assert_eq!(r.errors, 0);
    assert_eq!(r.mover.shard_failed, 1);
    assert!(
        r.mover.retried_after_fault >= 1,
        "node 1 held in-flight transfers at t={SIM_KILL_AT}"
    );
    assert_eq!(r.mover.released_without_active, 0);

    // Recover: the node rejoined and queued work was stolen onto it.
    assert_eq!(r.mover.node_recovered, 1);
    assert!(r.mover.stolen > 0, "survivor queues rebalanced on recovery");

    // Timeline: both events applied at their planned virtual instants,
    // and the node had served bytes before dying.
    assert_eq!(r.chaos.records.len(), 2);
    assert_eq!(r.chaos.records[0].action, "kill");
    assert_eq!(r.chaos.records[1].action, "recover");
    assert!((r.chaos.records[0].applied_s - SIM_KILL_AT).abs() < 1e-6);
    assert!((r.chaos.records[1].applied_s - SIM_RECOVER_AT).abs() < 1e-6);
    assert!(r.chaos.records[0].bytes_served_before > 0);
    assert_eq!(r.chaos.for_node(1).len(), 2);

    // The makespan really spans the fault window (precondition for the
    // NIC-series assertions below).
    assert!(
        r.finished_at.as_secs_f64() > SIM_RECOVER_AT + 2.0,
        "burst drained too early ({}) to observe the recovery",
        r.finished_at
    );

    // Node 1's monitored NIC: dark while dead, serving again afterwards.
    let node1 = &r.monitors[1];
    let mut dead_window = 0.0;
    let mut post_recover = 0.0;
    for (t, b) in node1.bins() {
        let start = t.as_secs_f64();
        if start >= SIM_KILL_AT + 2.0 && start + 2.0 <= SIM_RECOVER_AT {
            dead_window += b;
        }
        if start >= SIM_RECOVER_AT {
            post_recover += b;
        }
    }
    assert!(
        dead_window < 1.0,
        "killed node carried {dead_window} bytes while dead"
    );
    assert!(
        post_recover > 0.0,
        "recovered node's NIC never carried bytes again"
    );

    // Survivors carried the whole burst: aggregate bytes still cover all
    // inputs (aborted partial transfers only add to the total).
    assert!(r.monitor.total_bytes() >= r.total_input_bytes);
}

/// The same fault schedule is deterministic: two identical runs apply it
/// at identical virtual instants with identical accounting.
#[test]
fn sim_fault_plan_is_deterministic() {
    let a = Engine::new(chaos_sim_spec()).run().unwrap();
    let b = Engine::new(chaos_sim_spec()).run().unwrap();
    assert_eq!(a.finished_at, b.finished_at);
    assert_eq!(a.mover.retried_after_fault, b.mover.retried_after_fault);
    assert_eq!(a.mover.stolen, b.mover.stolen);
    assert_eq!(a.chaos.records.len(), b.chaos.records.len());
}

fn real_cfg(n_jobs: u32, faults: FaultPlan) -> RealPoolConfig {
    RealPoolConfig {
        n_jobs,
        workers: 3,
        input_bytes: 4 << 20,
        output_bytes: 512,
        chunk_words: 1024,
        use_xla_engine: false,
        passphrase: "chaos-unified".into(),
        policy: AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(1)),
        faults,
        ..RealPoolConfig::default()
    }
}

fn chaos_router() -> PoolRouter {
    PoolRouter::sim(
        2,
        1,
        AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(1)),
        RouterPolicy::RoundRobin,
    )
}

/// Real TCP fabric, same plan shape split across two bursts for
/// determinism on any machine: the kill fires 40 ms into a burst that is
/// guaranteed to still be moving sealed bytes (in-flight connections
/// break, workers retry via the router, zero errors), then the SAME
/// router object — node 1 still poisoned — runs a second burst whose
/// plan recovers node 1 immediately, proving the restarted `FileServer`
/// serves bytes again.
#[test]
fn real_fabric_kill_then_recover_drains_both_bursts() {
    // Burst 1: kill node 1 mid-burst.
    let plan = kill_recover_plan(0.04, 0.0);
    let kill_only = FaultPlan {
        events: vec![plan.events[0]],
        steal_threshold: plan.steal_threshold,
    };
    let (r1, router) =
        run_real_pool_router(&real_cfg(24, kill_only), chaos_router()).unwrap();
    assert_eq!(r1.errors, 0, "workers retried through the router");
    assert_eq!(r1.jobs_completed, 24);
    assert_eq!(r1.total_payload_bytes, 24 * (4 << 20) as u64);
    assert_eq!(r1.mover.shard_failed, 1);
    assert_eq!(r1.chaos.count("kill"), 1);
    // The survivors carried every byte the workers received.
    assert!(
        r1.bytes_served_per_node.iter().sum::<u64>() >= r1.total_payload_bytes,
        "served {:?} < payload {}",
        r1.bytes_served_per_node,
        r1.total_payload_bytes
    );

    // Burst 2: the carried-over router still has node 1 poisoned; the
    // plan's recover event un-poisons it at t=0 and the restarted file
    // server serves its share of a fresh burst.
    let recover_only = FaultPlan {
        events: vec![plan.events[1]],
        steal_threshold: plan.steal_threshold,
    };
    let (r2, router) = run_real_pool_router(&real_cfg(24, recover_only), router).unwrap();
    assert_eq!(r2.errors, 0);
    assert_eq!(r2.jobs_completed, 24);
    let stats = router.stats();
    assert_eq!(stats.node_recovered, 1);
    assert_eq!(r2.chaos.count("recover"), 1);
    assert!(
        r2.bytes_served_per_node[1] > 0,
        "recovered node served no bytes: {:?}",
        r2.bytes_served_per_node
    );
    assert!(
        r2.router.routed_per_node[1] > 0,
        "router never used the recovered node: {:?}",
        r2.router.routed_per_node
    );
    // Both bursts accounted on one router object.
    assert!(stats.total_admitted >= 48, "{}", stats.total_admitted);
    assert_eq!(stats.released_without_active, 0);
}

/// Chaos tier (CI `--ignored` job): the full single-burst wall-clock
/// schedule — kill node 1 at 100 ms, recover it at 400 ms — against a
/// burst long enough (~120 × 8 MiB at 1 transfer/node) that both events
/// land mid-burst. Writes a JSON report for the CI artifact upload when
/// `CHAOS_REPORT_DIR` is set.
#[test]
#[ignore = "chaos tier: wall-clock fault schedule; run with cargo test --release -- --ignored"]
fn chaos_e2e_single_plan_kill_recover_real_fabric() {
    let mut cfg = real_cfg(120, kill_recover_plan(0.10, 0.40));
    cfg.input_bytes = 8 << 20;
    cfg.workers = 4;
    let (r, router) = run_real_pool_router(&cfg, chaos_router()).unwrap();

    assert_eq!(r.errors, 0, "every killed transfer was retried to success");
    assert_eq!(r.jobs_completed, 120);
    assert_eq!(r.total_payload_bytes, 120 * (8 << 20) as u64);
    let stats = router.stats();
    assert_eq!(stats.shard_failed, 1);
    assert_eq!(stats.node_recovered, 1);
    assert!(
        stats.retried_after_fault >= 1,
        "node 1 was mid-transfer at the kill"
    );
    assert!(stats.stolen >= 1, "recovery rebalanced the survivor's queue");
    assert_eq!(r.chaos.count("kill"), 1);
    assert_eq!(r.chaos.count("recover"), 1);
    // The recovered node served bytes AFTER recovery: its cumulative
    // total exceeds what it had served when recovered.
    let recover_rec = r
        .chaos
        .records
        .iter()
        .find(|rec| rec.action == "recover")
        .expect("recover record");
    assert!(
        r.bytes_served_per_node[1] > recover_rec.bytes_served_before,
        "node 1 total {} never grew past its at-recovery total {}",
        r.bytes_served_per_node[1],
        recover_rec.bytes_served_before
    );

    if let Ok(dir) = std::env::var("CHAOS_REPORT_DIR") {
        std::fs::create_dir_all(&dir).ok();
        let json = format!(
            "{{\"test\":\"chaos_e2e_single_plan_kill_recover_real_fabric\",\
             \"jobs\":{},\"errors\":{},\"wall_secs\":{:.3},\"gbps\":{:.4},\
             \"shard_failed\":{},\"node_recovered\":{},\
             \"retried_after_fault\":{},\"stolen\":{},\
             \"bytes_served_per_node\":{:?},\"timeline\":\"{}\"}}",
            r.jobs_completed,
            r.errors,
            r.wall_secs,
            r.gbps,
            stats.shard_failed,
            stats.node_recovered,
            stats.retried_after_fault,
            stats.stolen,
            r.bytes_served_per_node,
            r.chaos.render().replace('\n', "; "),
        );
        std::fs::write(format!("{dir}/kill_recover_e2e.json"), json)
            .expect("write chaos report");
    }
}
