//! The schedd's file-transfer queue: admission control for concurrent
//! sandbox transfers through the submit node.
//!
//! HTCondor's queue exists to protect the submit node's storage: with a
//! spinning disk, hundreds of concurrent readers thrash seeks and
//! *aggregate* throughput collapses. The shipped default
//! (`FILE_TRANSFER_DISK_LOAD_THROTTLE = 2.0`) sizes concurrency for a
//! spinning disk's I/O capacity. The paper's storage was a page-cached
//! single extent, so the throttle only *hurt*: disabling it doubled
//! throughput (§III). Both policies are implemented here and benchmarked
//! in `benches/queue_ablation.rs`.

use crate::storage::DeviceProfile;
use std::collections::VecDeque;

/// Admission policy for the upload (input-sandbox) side.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ThrottlePolicy {
    /// No limit — the paper's tuned configuration.
    Disabled,
    /// HTCondor's disk-load throttle: admit while the *estimated* disk
    /// load stays under `threshold` disk-equivalents. The estimate uses a
    /// conservative per-stream rate assumption (the schedd cannot know the
    /// data is page-cached), which is exactly why it over-throttles on the
    /// paper's setup.
    DiskLoad {
        threshold: f64,
        /// Assumed per-stream draw on the device, bytes/sec.
        est_stream_bps: f64,
        device: DeviceProfile,
    },
    /// Fixed concurrency cap (operator override).
    MaxConcurrent(u32),
}

impl ThrottlePolicy {
    /// HTCondor 9.0.1 shipping default.
    pub fn htcondor_default() -> ThrottlePolicy {
        ThrottlePolicy::DiskLoad {
            threshold: 2.0,
            est_stream_bps: 10e6, // ~10 MB/s per stream, the classic tuning
            device: DeviceProfile::spinning(),
        }
    }

    /// Maximum concurrent transfers this policy admits.
    pub fn limit(&self) -> u32 {
        match self {
            ThrottlePolicy::Disabled => u32::MAX,
            ThrottlePolicy::MaxConcurrent(n) => *n,
            ThrottlePolicy::DiskLoad {
                threshold,
                est_stream_bps,
                device,
            } => {
                // Admit streams while est_load = n·est_bps / device_bw stays
                // under threshold → n ≤ threshold · device_bw / est_bps.
                ((threshold * device.bandwidth_bps / est_stream_bps).floor() as u32).max(1)
            }
        }
    }
}

/// A FIFO transfer queue with admission control. Generic over the ticket
/// type `T` (the engine uses job ids).
#[derive(Debug)]
pub struct TransferQueue<T> {
    policy: ThrottlePolicy,
    waiting: VecDeque<T>,
    active: u32,
    /// Totals for the report.
    pub peak_active: u32,
    pub total_admitted: u64,
    /// Releases that arrived with no active transfer. The old behavior
    /// was a `debug_assert!` that silently underflow-saturated in release
    /// builds; now every spurious release is counted so operators can see
    /// double-release bugs instead of a wedged queue.
    pub released_without_active: u64,
}

impl<T> TransferQueue<T> {
    pub fn new(policy: ThrottlePolicy) -> TransferQueue<T> {
        TransferQueue {
            policy,
            waiting: VecDeque::new(),
            active: 0,
            peak_active: 0,
            total_admitted: 0,
            released_without_active: 0,
        }
    }

    pub fn policy(&self) -> ThrottlePolicy {
        self.policy
    }

    pub fn active(&self) -> u32 {
        self.active
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Enqueue a transfer request; returns the tickets that may start NOW
    /// (possibly including this one).
    pub fn enqueue(&mut self, ticket: T) -> Vec<T> {
        self.waiting.push_back(ticket);
        self.admit()
    }

    /// A transfer finished; returns newly admitted tickets. A release
    /// with nothing active is counted in `released_without_active`
    /// (saturating — never underflows, in debug or release builds).
    pub fn release(&mut self) -> Vec<T> {
        if self.active == 0 {
            self.released_without_active += 1;
        } else {
            self.active -= 1;
        }
        self.admit()
    }

    fn admit(&mut self) -> Vec<T> {
        let limit = self.policy.limit();
        let mut started = Vec::new();
        while self.active < limit {
            match self.waiting.pop_front() {
                Some(t) => {
                    self.active += 1;
                    self.total_admitted += 1;
                    self.peak_active = self.peak_active.max(self.active);
                    started.push(t);
                }
                None => break,
            }
        }
        started
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_admits_everything() {
        let mut q = TransferQueue::new(ThrottlePolicy::Disabled);
        for i in 0..500 {
            let started = q.enqueue(i);
            assert_eq!(started, vec![i], "every request starts immediately");
        }
        assert_eq!(q.active(), 500);
        assert_eq!(q.waiting(), 0);
        assert_eq!(q.peak_active, 500);
    }

    #[test]
    fn default_throttle_limit_is_spinning_disk_sized() {
        let limit = ThrottlePolicy::htcondor_default().limit();
        // 2.0 × 180 MB/s ÷ 10 MB/s = 36 concurrent.
        assert_eq!(limit, 36);
    }

    #[test]
    fn max_concurrent_respected_fifo() {
        let mut q = TransferQueue::new(ThrottlePolicy::MaxConcurrent(2));
        assert_eq!(q.enqueue("a"), vec!["a"]);
        assert_eq!(q.enqueue("b"), vec!["b"]);
        assert_eq!(q.enqueue("c"), Vec::<&str>::new(), "third waits");
        assert_eq!(q.enqueue("d"), Vec::<&str>::new());
        assert_eq!(q.active(), 2);
        assert_eq!(q.waiting(), 2);
        assert_eq!(q.release(), vec!["c"], "FIFO order");
        assert_eq!(q.release(), vec!["d"]);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn release_admits_multiple_after_policy_change_scenario() {
        // Start with cap 1, three waiting; each release admits exactly one.
        let mut q = TransferQueue::new(ThrottlePolicy::MaxConcurrent(1));
        q.enqueue(1);
        q.enqueue(2);
        q.enqueue(3);
        assert_eq!(q.active(), 1);
        assert_eq!(q.release(), vec![2]);
        assert_eq!(q.release(), vec![3]);
        assert_eq!(q.release(), Vec::<i32>::new());
        assert_eq!(q.active(), 0, "all three finished");
        assert_eq!(q.total_admitted, 3);
    }

    #[test]
    fn spurious_release_counts_instead_of_underflowing() {
        let mut q: TransferQueue<u32> = TransferQueue::new(ThrottlePolicy::MaxConcurrent(2));
        assert_eq!(q.release(), Vec::<u32>::new());
        assert_eq!(q.active(), 0, "no u32 underflow");
        assert_eq!(q.released_without_active, 1);
        // The queue still admits normally afterwards.
        assert_eq!(q.enqueue(7), vec![7]);
        assert_eq!(q.active(), 1);
        q.release();
        q.release();
        assert_eq!(q.released_without_active, 2);
        assert_eq!(q.active(), 0);
    }

    #[test]
    fn property_active_never_exceeds_limit() {
        crate::util::testkit::check("queue-limit", 50, |g| {
            let cap = g.rng.range_u64(1, 20) as u32;
            let mut q = TransferQueue::new(ThrottlePolicy::MaxConcurrent(cap));
            let mut active = 0i64;
            for step in 0..200 {
                if g.rng.next_f64() < 0.6 {
                    active += q.enqueue(step).len() as i64;
                } else if q.active() > 0 {
                    active -= 1;
                    active += q.release().len() as i64;
                }
                assert!(q.active() <= cap);
                assert_eq!(q.active() as i64, active);
            }
        });
    }
}
