//! Framed sealed chunk stream — the real-mode wire format.
//!
//! A file is transmitted as a header frame followed by sealed data frames:
//!
//! ```text
//! header:  magic "HTCF" | u32 version | u64 file_bytes | u32 chunk_words
//! frame:   u32 counter0 | u32 n_words | n_words×u32 ciphertext | 4×u32 digest
//! ```
//!
//! All integers little-endian. Each frame is sealed by a
//! [`SealEngine`](crate::runtime::engine::SealEngine) — ChaCha20+poly16
//! through the PJRT artifact on the submit side, verified and decrypted on
//! the worker side. `counter0` advances by the number of 64-byte blocks
//! consumed, so the keystream never repeats within a session and chunking
//! is transparent (see the counter-continuity tests in `security::chacha`).

use crate::runtime::engine::{Kind, SealEngine};
use crate::security::chacha::bytes_to_words;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC: &[u8; 4] = b"HTCF";
pub const VERSION: u32 = 1;

/// Default chunk: 64 KiB of payload = 1024 blocks = 16384 words (matches
/// the `64k` artifact geometry).
pub const DEFAULT_CHUNK_WORDS: usize = 1024 * 16;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u32")
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u64")
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("read u64")?;
    Ok(u64::from_le_bytes(b))
}

/// Statistics from one side of a transfer.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub frames: u64,
}

/// Send `data` as a sealed stream. `session` provides key+nonce; the
/// engine seals each chunk with an advancing block counter.
pub fn send_stream(
    w: &mut impl Write,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
    data: &[u8],
    chunk_words: usize,
) -> Result<StreamStats> {
    assert!(chunk_words % 16 == 0 && chunk_words > 0);
    let mut stats = StreamStats::default();

    w.write_all(MAGIC)?;
    write_u32(w, VERSION)?;
    write_u64(w, data.len() as u64)?;
    write_u32(w, chunk_words as u32)?;
    stats.wire_bytes += 4 + 4 + 8 + 4;

    let words = bytes_to_words(data);
    let mut counter0: u32 = 0;
    let mut frame_buf: Vec<u8> = Vec::with_capacity(chunk_words * 4 + 32);
    for chunk in words.chunks(chunk_words) {
        let mut buf = chunk.to_vec();
        // Tail chunks are padded to whole blocks by bytes_to_words already;
        // pad further to a multiple of 16 words is guaranteed. Seal.
        let digest = engine.process(Kind::Seal, key, nonce, counter0, &mut buf)?;
        // One buffered write per frame: serializing word-by-word costs a
        // write call per 4 bytes and was the top loopback bottleneck
        // (see EXPERIMENTS.md §Perf).
        frame_buf.clear();
        frame_buf.extend_from_slice(&counter0.to_le_bytes());
        frame_buf.extend_from_slice(&(buf.len() as u32).to_le_bytes());
        for word in &buf {
            frame_buf.extend_from_slice(&word.to_le_bytes());
        }
        for d in &digest {
            frame_buf.extend_from_slice(&d.to_le_bytes());
        }
        w.write_all(&frame_buf)?;
        stats.wire_bytes += 8 + buf.len() as u64 * 4 + 16;
        stats.frames += 1;
        counter0 = counter0.wrapping_add((buf.len() / 16) as u32);
    }
    stats.payload_bytes = data.len() as u64;
    w.flush()?;
    Ok(stats)
}

/// Receive a sealed stream, verifying every frame's digest before
/// trusting its plaintext. Returns the payload bytes.
pub fn recv_stream(
    r: &mut impl Read,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
) -> Result<(Vec<u8>, StreamStats)> {
    let mut stats = StreamStats::default();
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("bad stream magic {magic:?}");
    }
    let version = read_u32(r)?;
    if version != VERSION {
        bail!("unsupported stream version {version}");
    }
    let file_bytes = read_u64(r)? as usize;
    let chunk_words = read_u32(r)? as usize;
    if chunk_words == 0 || chunk_words % 16 != 0 || chunk_words > (1 << 24) {
        bail!("bad chunk_words {chunk_words}");
    }
    stats.wire_bytes += 4 + 4 + 8 + 4;

    let total_words = file_bytes.div_ceil(64) * 16;
    // Hot path: one reusable word scratch per stream (not a fresh
    // collect() per frame), and plaintext bytes appended frame by frame
    // (no whole-payload words_to_bytes copy at the end).
    let mut bytes: Vec<u8> = Vec::with_capacity(total_words * 4);
    let mut received_words = 0usize;
    let mut expect_counter: u32 = 0;
    let mut byte_buf: Vec<u8> = Vec::new();
    let mut frame_words: Vec<u32> = Vec::new();
    while received_words < total_words {
        let counter0 = read_u32(r)?;
        if counter0 != expect_counter {
            bail!("frame counter {counter0} != expected {expect_counter} (reorder/replay?)");
        }
        let n_words = read_u32(r)? as usize;
        if n_words == 0 || n_words % 16 != 0 || n_words > chunk_words {
            bail!("bad frame n_words {n_words}");
        }
        byte_buf.resize(n_words * 4, 0);
        r.read_exact(&mut byte_buf).context("read frame payload")?;
        frame_words.clear();
        frame_words.extend(
            byte_buf
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap())),
        );
        let mut digest = [0u32; 4];
        for d in digest.iter_mut() {
            *d = read_u32(r)?;
        }
        let computed = engine.process(Kind::Unseal, key, nonce, counter0, &mut frame_words)?;
        if computed != digest {
            bail!(
                "integrity failure in frame at counter {counter0}: {computed:08x?} != {digest:08x?}"
            );
        }
        stats.wire_bytes += 8 + n_words as u64 * 4 + 16;
        stats.frames += 1;
        expect_counter = expect_counter.wrapping_add((n_words / 16) as u32);
        received_words += n_words;
        for w in &frame_words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
    }
    bytes.truncate(file_bytes);
    stats.payload_bytes = file_bytes as u64;
    Ok((bytes, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::NativeEngine;
    use crate::security::Method;
    use crate::util::Prng;

    fn roundtrip(data: &[u8], chunk_words: usize) -> (Vec<u8>, StreamStats, StreamStats) {
        let key = [3u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut rx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        let tx_stats = send_stream(&mut wire, &mut tx, &key, &nonce, data, chunk_words).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let (out, rx_stats) = recv_stream(&mut cursor, &mut rx, &key, &nonce).unwrap();
        (out, tx_stats, rx_stats)
    }

    #[test]
    fn roundtrip_small() {
        let data = b"hello sealed world".to_vec();
        let (out, tx, rx) = roundtrip(&data, 16);
        assert_eq!(out, data);
        assert_eq!(tx.frames, 1);
        assert_eq!(rx.frames, 1);
        assert_eq!(tx.wire_bytes, rx.wire_bytes);
    }

    #[test]
    fn roundtrip_multi_frame_sizes() {
        let mut rng = Prng::new(5);
        for n in [0usize, 1, 63, 64, 65, 1024, 4096, 70_000] {
            let mut data = vec![0u8; n];
            rng.fill_bytes(&mut data);
            let (out, tx, _) = roundtrip(&data, 256);
            assert_eq!(out, data, "payload size {n}");
            if n > 0 {
                let expected_frames = n.div_ceil(64).div_ceil(16) as u64;
                assert_eq!(tx.frames, expected_frames, "size {n}");
            }
        }
    }

    #[test]
    fn tampered_payload_detected() {
        let key = [3u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[0xAB; 256], 16).unwrap();
        // Flip one ciphertext byte (past the 20-byte header).
        wire[30] ^= 0x01;
        let mut rx = NativeEngine::new(Method::Chacha20);
        let err = recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce)
            .unwrap_err()
            .to_string();
        assert!(err.contains("integrity failure"), "{err}");
    }

    #[test]
    fn wrong_key_fails_integrity_or_garbles() {
        let key = [3u32; 8];
        let bad_key = [4u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[7u8; 128], 16).unwrap();
        let mut rx = NativeEngine::new(Method::Chacha20);
        // Digest is over ciphertext, so it still verifies — but plaintext
        // differs (confidentiality vs integrity separation).
        let (out, _) =
            recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &bad_key, &nonce).unwrap();
        assert_ne!(out, vec![7u8; 128]);
    }

    #[test]
    fn replayed_frame_rejected() {
        let key = [1u32; 8];
        let nonce = [1, 1, 1];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[5u8; 2048], 16).unwrap();
        // Duplicate the first data frame right after itself.
        let header = 20;
        let frame = 8 + 16 * 4 + 16;
        let dup: Vec<u8> = [
            &wire[..header + frame],
            &wire[header..header + frame],
            &wire[header + frame..],
        ]
        .concat();
        let mut rx = NativeEngine::new(Method::Chacha20);
        let err = recv_stream(&mut std::io::Cursor::new(dup), &mut rx, &key, &nonce).unwrap_err();
        assert!(err.to_string().contains("counter"), "{err}");
    }

    #[test]
    fn truncated_stream_errors() {
        let key = [1u32; 8];
        let nonce = [1, 1, 1];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[5u8; 1024], 16).unwrap();
        wire.truncate(wire.len() - 10);
        let mut rx = NativeEngine::new(Method::Chacha20);
        assert!(recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut rx = NativeEngine::new(Method::Chacha20);
        let wire = b"NOPE\0\0\0\0".to_vec();
        assert!(recv_stream(
            &mut std::io::Cursor::new(wire),
            &mut rx,
            &[0; 8],
            &[0; 3]
        )
        .is_err());
    }

    #[test]
    fn aes_engine_interoperates() {
        let key = [2u32; 8];
        let nonce = [4, 5, 6];
        let mut tx = NativeEngine::new(Method::Aes256Ctr);
        let mut rx = NativeEngine::new(Method::Aes256Ctr);
        let data = vec![0x5Au8; 4096];
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &data, 64).unwrap();
        let (out, _) = recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce).unwrap();
        assert_eq!(out, data);
    }
}
