//! Framed sealed chunk stream — the real-mode wire format.
//!
//! A file is transmitted as a header frame followed by sealed data frames:
//!
//! ```text
//! header:  magic "HTCF" | u32 version | u64 file_bytes | u32 chunk_words
//! frame:   u32 counter0 | u32 n_words | n_words×u32 ciphertext | 4×u32 digest
//! ```
//!
//! All integers little-endian. Versions [`V1`] and [`V2`] share this
//! exact layout; v2 is stamped by peers that negotiated `chunk_words` at
//! connection setup (see [`crate::fabric::tcp`]), letting the chunk knob
//! move per connection while v1 peers interoperate untouched. Each frame
//! is sealed by a [`SealEngine`](crate::runtime::engine::SealEngine) —
//! ChaCha20+poly16 through the PJRT artifact on the submit side,
//! verified and decrypted on the worker side. `counter0` advances by the
//! number of 64-byte blocks consumed, so the keystream never repeats
//! within a session and chunking is transparent (see the
//! counter-continuity tests in `security::chacha`).
//!
//! The hot path is zero-copy: payloads stay bytes end to end
//! (`SealEngine::process_bytes` seals one reusable buffer in place),
//! frames go out as one vectored write of head+payload+digest, and the
//! `SEAL_THREADS` knob enables a small sealer pool so frame N+1 is
//! sealed while frame N is on the socket. Receivers can consume frames
//! as they are verified via [`recv_stream_with`] instead of buffering
//! the whole payload. See docs/ARCHITECTURE.md §Data-path performance.

use crate::runtime::engine::{Kind, SealEngine};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{IoSlice, Read, Write};
use std::sync::mpsc;

pub const MAGIC: &[u8; 4] = b"HTCF";

/// Original wire-format version.
pub const V1: u32 = 1;
/// Chunk-negotiated wire-format version (same frame layout as v1; the
/// version stamp records that `chunk_words` was agreed at handshake).
pub const V2: u32 = 2;

/// Default chunk: 64 KiB of payload = 1024 blocks = 16384 words (matches
/// the `64k` artifact geometry).
pub const DEFAULT_CHUNK_WORDS: usize = 1024 * 16;

/// Largest `chunk_words` either side accepts (bounds per-frame buffers).
pub const MAX_WIRE_CHUNK_WORDS: usize = 1 << 24;

/// Cap on the receiver's upfront buffer reservation: a forged
/// `file_bytes` header can no longer trigger an unbounded allocation;
/// honest large streams grow amortized as verified frames arrive.
pub const MAX_RECV_PREALLOC: usize = 16 << 20;

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

/// Statistics from one side of a transfer.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub frames: u64,
}

/// Tuning for [`send_stream_opts`]. The plain [`send_stream`] wrapper
/// uses v1 with inline sealing, which is the pre-negotiation behavior.
#[derive(Debug, Clone, Copy)]
pub struct StreamOpts {
    /// Words per frame: positive multiple of 16, at most
    /// [`MAX_WIRE_CHUNK_WORDS`].
    pub chunk_words: usize,
    /// Sealer threads overlapping sealing with socket writes (capped at
    /// 8); 0 seals inline, the right default for single-core hosts. See
    /// docs/KNOBS.md.
    pub seal_threads: usize,
    /// Wire version to stamp ([`V1`] or [`V2`]).
    pub version: u32,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            chunk_words: DEFAULT_CHUNK_WORDS,
            seal_threads: 0,
            version: V1,
        }
    }
}

/// The `SEAL_THREADS` knob: sealer threads per sending stream (0 =
/// inline, the default). See docs/KNOBS.md.
pub fn seal_threads_from_env() -> usize {
    std::env::var("SEAL_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.min(8))
        .unwrap_or(0)
}

/// Send `data` as a sealed v1 stream with inline sealing (the
/// historical signature; see [`send_stream_opts`] for the tunable
/// form). `key`+`nonce` come from the session; the engine seals each
/// chunk with an advancing block counter.
pub fn send_stream(
    w: &mut impl Write,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
    data: &[u8],
    chunk_words: usize,
) -> Result<StreamStats> {
    let opts = StreamOpts {
        chunk_words,
        ..StreamOpts::default()
    };
    send_stream_opts(w, engine, key, nonce, data, &opts)
}

/// Send `data` as a sealed stream under explicit [`StreamOpts`].
pub fn send_stream_opts(
    w: &mut impl Write,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
    data: &[u8],
    opts: &StreamOpts,
) -> Result<StreamStats> {
    let chunk_words = opts.chunk_words;
    if chunk_words == 0 || chunk_words % 16 != 0 || chunk_words > MAX_WIRE_CHUNK_WORDS {
        bail!("bad chunk_words {chunk_words} (positive multiple of 16, <= {MAX_WIRE_CHUNK_WORDS})");
    }
    if opts.version != V1 && opts.version != V2 {
        bail!("unsupported stream version {}", opts.version);
    }
    let mut stats = StreamStats::default();
    let mut header = [0u8; 20];
    header[..4].copy_from_slice(MAGIC);
    header[4..8].copy_from_slice(&opts.version.to_le_bytes());
    header[8..16].copy_from_slice(&(data.len() as u64).to_le_bytes());
    header[16..20].copy_from_slice(&(chunk_words as u32).to_le_bytes());
    w.write_all(&header).context("write header")?;
    stats.wire_bytes += 20;

    let chunk_bytes = chunk_words * 4;
    let n_frames = data.len().div_ceil(chunk_bytes);
    // Pipelining needs at least two frames in flight and an engine that
    // can fork; otherwise seal inline.
    let sealers = if n_frames > 1 {
        opts.seal_threads.min(8).min(n_frames)
    } else {
        0
    };
    let forks = if sealers > 0 {
        collect_forks(engine, sealers)
    } else {
        None
    };
    if let Some(forks) = forks {
        send_frames_pipelined(w, forks, key, nonce, data, chunk_bytes, &mut stats)?;
        stats.payload_bytes = data.len() as u64;
        w.flush()?;
        return Ok(stats);
    }

    // Serial path: one reusable payload buffer, sealed in place.
    let mut payload: Vec<u8> = Vec::new();
    let mut counter0: u32 = 0;
    for chunk in data.chunks(chunk_bytes) {
        let padded = chunk.len().div_ceil(64) * 64;
        payload.clear();
        payload.resize(padded, 0);
        payload[..chunk.len()].copy_from_slice(chunk);
        let digest = engine.process_bytes(Kind::Seal, key, nonce, counter0, &mut payload)?;
        write_frame(w, counter0, &payload, &digest, &mut stats)?;
        counter0 = counter0.wrapping_add((padded / 64) as u32);
    }
    stats.payload_bytes = data.len() as u64;
    w.flush()?;
    Ok(stats)
}

fn collect_forks(engine: &mut dyn SealEngine, n: usize) -> Option<Vec<Box<dyn SealEngine + Send>>> {
    let mut forks = Vec::with_capacity(n);
    for _ in 0..n {
        forks.push(engine.fork()?);
    }
    Some(forks)
}

/// Double-buffered sealer pool: frame i is sealed by fork `i % s` and
/// collected in order, so sealing overlaps the socket write while the
/// wire bytes stay identical to the serial path. Buffers are recycled
/// (at most `2 * s` live), and dropping the work senders on any error
/// shuts the pool down cleanly.
fn send_frames_pipelined(
    w: &mut impl Write,
    forks: Vec<Box<dyn SealEngine + Send>>,
    key: &[u32; 8],
    nonce: &[u32; 3],
    data: &[u8],
    chunk_bytes: usize,
    stats: &mut StreamStats,
) -> Result<()> {
    struct Work {
        buf: Vec<u8>,
        counter0: u32,
    }
    struct Sealed {
        buf: Vec<u8>,
        counter0: u32,
        digest: [u32; 4],
    }
    let s = forks.len();
    let key = *key;
    let nonce = *nonce;
    std::thread::scope(|scope| -> Result<()> {
        let mut work_txs = Vec::with_capacity(s);
        let mut res_rxs = Vec::with_capacity(s);
        for mut eng in forks {
            let (wtx, wrx) = mpsc::channel::<Work>();
            let (rtx, rrx) = mpsc::channel::<Result<Sealed>>();
            work_txs.push(wtx);
            res_rxs.push(rrx);
            scope.spawn(move || {
                while let Ok(mut wk) = wrx.recv() {
                    let r = eng
                        .process_bytes(Kind::Seal, &key, &nonce, wk.counter0, &mut wk.buf)
                        .map(|digest| Sealed {
                            buf: wk.buf,
                            counter0: wk.counter0,
                            digest,
                        });
                    if rtx.send(r).is_err() {
                        break;
                    }
                }
            });
        }
        let n_frames = data.len().div_ceil(chunk_bytes);
        let max_inflight = 2 * s;
        let mut free: Vec<Vec<u8>> = Vec::new();
        let mut chunks = data.chunks(chunk_bytes);
        let mut counter0: u32 = 0;
        let mut dispatched = 0usize;
        let mut collected = 0usize;
        while collected < n_frames {
            while dispatched < n_frames && dispatched - collected < max_inflight {
                let chunk = chunks.next().expect("chunk count matches frame count");
                let padded = chunk.len().div_ceil(64) * 64;
                let mut buf = free.pop().unwrap_or_default();
                buf.clear();
                buf.resize(padded, 0);
                buf[..chunk.len()].copy_from_slice(chunk);
                work_txs[dispatched % s]
                    .send(Work { buf, counter0 })
                    .map_err(|_| anyhow!("sealer thread exited early"))?;
                counter0 = counter0.wrapping_add((padded / 64) as u32);
                dispatched += 1;
            }
            let sealed = res_rxs[collected % s]
                .recv()
                .map_err(|_| anyhow!("sealer thread died"))??;
            write_frame(w, sealed.counter0, &sealed.buf, &sealed.digest, stats)?;
            free.push(sealed.buf);
            collected += 1;
        }
        drop(work_txs);
        Ok(())
    })
}

/// One vectored write of [8-byte head][sealed payload][16-byte digest]:
/// no frame-assembly copy, no per-word appends.
fn write_frame(
    w: &mut impl Write,
    counter0: u32,
    payload: &[u8],
    digest: &[u32; 4],
    stats: &mut StreamStats,
) -> Result<()> {
    let mut head = [0u8; 8];
    head[..4].copy_from_slice(&counter0.to_le_bytes());
    head[4..].copy_from_slice(&((payload.len() / 4) as u32).to_le_bytes());
    let mut dig = [0u8; 16];
    for (i, d) in digest.iter().enumerate() {
        dig[i * 4..i * 4 + 4].copy_from_slice(&d.to_le_bytes());
    }
    let mut bufs = [IoSlice::new(&head), IoSlice::new(payload), IoSlice::new(&dig)];
    let mut slices: &mut [IoSlice<'_>] = &mut bufs;
    while !slices.is_empty() {
        match w.write_vectored(slices) {
            Ok(0) => bail!("write_vectored wrote 0 bytes (peer closed?)"),
            Ok(n) => IoSlice::advance_slices(&mut slices, n),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("write frame"),
        }
    }
    stats.wire_bytes += 8 + payload.len() as u64 + 16;
    stats.frames += 1;
    Ok(())
}

/// Parsed stream header, handed to the [`recv_stream_with`] sink.
#[derive(Debug, Clone, Copy)]
pub struct StreamHeader {
    pub version: u32,
    pub file_bytes: u64,
    pub chunk_words: usize,
}

/// Receive a sealed stream, verifying every frame's digest before
/// trusting its plaintext. The sink is called once per verified frame
/// with the parsed header and that frame's payload slice (padding
/// already stripped), so consumers can hash or persist incrementally
/// without buffering the whole file.
pub fn recv_stream_with<R, S>(
    r: &mut R,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
    mut sink: S,
) -> Result<StreamStats>
where
    R: Read,
    S: FnMut(&StreamHeader, &[u8]) -> Result<()>,
{
    let mut stats = StreamStats::default();
    let mut hdr = [0u8; 20];
    r.read_exact(&mut hdr).context("read header")?;
    if &hdr[..4] != MAGIC {
        bail!("bad stream magic {:?}", &hdr[..4]);
    }
    let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    if version != V1 && version != V2 {
        bail!("unsupported stream version {version}");
    }
    let file_bytes = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    let chunk_words = u32::from_le_bytes(hdr[16..20].try_into().unwrap()) as usize;
    if chunk_words == 0 || chunk_words % 16 != 0 || chunk_words > MAX_WIRE_CHUNK_WORDS {
        bail!("bad chunk_words {chunk_words}");
    }
    stats.wire_bytes += 20;
    let header = StreamHeader {
        version,
        file_bytes,
        chunk_words,
    };

    let total_words: u64 = file_bytes.div_ceil(64) * 16;
    let mut received_words: u64 = 0;
    let mut delivered: u64 = 0;
    let mut expect_counter: u32 = 0;
    // One reusable frame buffer, bounded by the validated chunk_words —
    // never by the peer's file_bytes claim.
    let mut buf: Vec<u8> = Vec::new();
    while received_words < total_words {
        let counter0 = read_u32(r)?;
        if counter0 != expect_counter {
            bail!("frame counter {counter0} != expected {expect_counter} (reorder/replay?)");
        }
        let n_words = read_u32(r)? as usize;
        if n_words == 0 || n_words % 16 != 0 || n_words > chunk_words {
            bail!("bad frame n_words {n_words}");
        }
        buf.clear();
        buf.resize(n_words * 4, 0);
        r.read_exact(&mut buf).context("read frame payload")?;
        let mut dig = [0u8; 16];
        r.read_exact(&mut dig).context("read frame digest")?;
        let mut digest = [0u32; 4];
        for (i, d) in digest.iter_mut().enumerate() {
            *d = u32::from_le_bytes(dig[i * 4..i * 4 + 4].try_into().unwrap());
        }
        let computed = engine.process_bytes(Kind::Unseal, key, nonce, counter0, &mut buf)?;
        if computed != digest {
            bail!(
                "integrity failure in frame at counter {counter0}: {computed:08x?} != {digest:08x?}"
            );
        }
        stats.wire_bytes += 8 + n_words as u64 * 4 + 16;
        stats.frames += 1;
        expect_counter = expect_counter.wrapping_add((n_words / 16) as u32);
        received_words += n_words as u64;
        let take = ((n_words as u64 * 4).min(file_bytes - delivered)) as usize;
        sink(&header, &buf[..take])?;
        delivered += take as u64;
    }
    stats.payload_bytes = file_bytes;
    Ok(stats)
}

/// Receive a sealed stream into a buffer (see [`recv_stream_with`] for
/// the streaming form). The upfront reservation is capped at
/// [`MAX_RECV_PREALLOC`] so a forged header cannot force an unbounded
/// allocation.
pub fn recv_stream(
    r: &mut impl Read,
    engine: &mut dyn SealEngine,
    key: &[u32; 8],
    nonce: &[u32; 3],
) -> Result<(Vec<u8>, StreamStats)> {
    let mut out: Vec<u8> = Vec::new();
    let stats = recv_stream_with(r, engine, key, nonce, |h: &StreamHeader, chunk: &[u8]| {
        if out.capacity() == 0 {
            out.reserve(h.file_bytes.min(MAX_RECV_PREALLOC as u64) as usize);
        }
        out.extend_from_slice(chunk);
        Ok(())
    })?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::NativeEngine;
    use crate::security::chacha::{bytes_to_words, seal_chunk, words_to_bytes};
    use crate::security::Method;
    use crate::util::Prng;

    fn roundtrip(data: &[u8], chunk_words: usize) -> (Vec<u8>, StreamStats, StreamStats) {
        let key = [3u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut rx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        let tx_stats = send_stream(&mut wire, &mut tx, &key, &nonce, data, chunk_words).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let (out, rx_stats) = recv_stream(&mut cursor, &mut rx, &key, &nonce).unwrap();
        (out, tx_stats, rx_stats)
    }

    #[test]
    fn roundtrip_small() {
        let data = b"hello sealed world".to_vec();
        let (out, tx, rx) = roundtrip(&data, 16);
        assert_eq!(out, data);
        assert_eq!(tx.frames, 1);
        assert_eq!(rx.frames, 1);
        assert_eq!(tx.wire_bytes, rx.wire_bytes);
    }

    #[test]
    fn roundtrip_multi_frame_sizes() {
        let mut rng = Prng::new(5);
        for n in [0usize, 1, 63, 64, 65, 1024, 4096, 70_000] {
            let mut data = vec![0u8; n];
            rng.fill_bytes(&mut data);
            let (out, tx, _) = roundtrip(&data, 256);
            assert_eq!(out, data, "payload size {n}");
            if n > 0 {
                let expected_frames = n.div_ceil(64).div_ceil(16) as u64;
                assert_eq!(tx.frames, expected_frames, "size {n}");
            }
        }
    }

    #[test]
    fn golden_v2_frame_layout() {
        // Pin the v2 wire layout byte for byte: header fields, frame
        // head/digest serialization, tail zero-padding, and counter
        // advance. The expected bytes are reconstructed from the scalar
        // word-path primitives, independently of the byte/SIMD path the
        // sender uses.
        let key = [0x0101_0101u32; 8];
        let nonce = [0xAA, 0xBB, 0xCC];
        let data: Vec<u8> = (0..80u8).collect();
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        let opts = StreamOpts {
            chunk_words: 16,
            seal_threads: 0,
            version: V2,
        };
        send_stream_opts(&mut wire, &mut tx, &key, &nonce, &data, &opts).unwrap();

        let mut expected = Vec::new();
        expected.extend_from_slice(b"HTCF");
        expected.extend_from_slice(&2u32.to_le_bytes());
        expected.extend_from_slice(&80u64.to_le_bytes());
        expected.extend_from_slice(&16u32.to_le_bytes());
        // Frame 0: bytes 0..64, counter0 = 0.
        let mut blk0 = bytes_to_words(&data[..64]);
        let d0 = seal_chunk(&key, &nonce, 0, &mut blk0);
        expected.extend_from_slice(&0u32.to_le_bytes());
        expected.extend_from_slice(&16u32.to_le_bytes());
        expected.extend_from_slice(&words_to_bytes(&blk0));
        for d in &d0 {
            expected.extend_from_slice(&d.to_le_bytes());
        }
        // Frame 1: tail 16 bytes zero-padded to one block, counter0 = 1.
        let mut blk1 = bytes_to_words(&data[64..]);
        let d1 = seal_chunk(&key, &nonce, 1, &mut blk1);
        expected.extend_from_slice(&1u32.to_le_bytes());
        expected.extend_from_slice(&16u32.to_le_bytes());
        expected.extend_from_slice(&words_to_bytes(&blk1));
        for d in &d1 {
            expected.extend_from_slice(&d.to_le_bytes());
        }
        assert_eq!(wire, expected, "v2 wire layout is pinned");

        // And a v2 stream decodes like any other.
        let mut rx = NativeEngine::new(Method::Chacha20);
        let mut cur = std::io::Cursor::new(wire);
        let (out, stats) = recv_stream(&mut cur, &mut rx, &key, &nonce).unwrap();
        assert_eq!(out, data);
        assert_eq!(stats.frames, 2);
    }

    #[test]
    fn pipelined_send_matches_serial_bytes() {
        let key = [5u32; 8];
        let nonce = [1, 2, 3];
        let mut rng = Prng::new(11);
        let mut data = vec![0u8; 300_000];
        rng.fill_bytes(&mut data);
        let mut serial_wire = Vec::new();
        let mut piped_wire = Vec::new();
        let mut e1 = NativeEngine::new(Method::Chacha20);
        let mut e2 = NativeEngine::new(Method::Chacha20);
        let base = StreamOpts {
            chunk_words: 256,
            seal_threads: 0,
            version: V2,
        };
        let s = send_stream_opts(&mut serial_wire, &mut e1, &key, &nonce, &data, &base).unwrap();
        let piped = StreamOpts {
            seal_threads: 3,
            ..base
        };
        let p = send_stream_opts(&mut piped_wire, &mut e2, &key, &nonce, &data, &piped).unwrap();
        assert_eq!(serial_wire, piped_wire, "pipelined sealing is bit-identical");
        assert_eq!(s.wire_bytes, p.wire_bytes);
        assert_eq!(s.frames, p.frames);
        let mut rx = NativeEngine::new(Method::Chacha20);
        let mut cur = std::io::Cursor::new(piped_wire);
        let (out, _) = recv_stream(&mut cur, &mut rx, &key, &nonce).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn recv_stream_with_streams_frames() {
        let key = [3u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        let data: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        send_stream(&mut wire, &mut tx, &key, &nonce, &data, 256).unwrap();
        let mut rx = NativeEngine::new(Method::Chacha20);
        let mut seen = Vec::new();
        let mut calls = 0u64;
        let stats = recv_stream_with(
            &mut std::io::Cursor::new(wire),
            &mut rx,
            &key,
            &nonce,
            |h: &StreamHeader, chunk: &[u8]| {
                assert_eq!(h.version, V1);
                assert_eq!(h.file_bytes, 100_000);
                seen.extend_from_slice(chunk);
                calls += 1;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, data);
        assert_eq!(calls, stats.frames);
    }

    #[test]
    fn bad_chunk_words_is_err_not_panic() {
        let mut e = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        for bad in [0usize, 8, 100] {
            let err = send_stream(&mut wire, &mut e, &[0; 8], &[0; 3], b"x", bad).unwrap_err();
            assert!(err.to_string().contains("chunk_words"), "{err}");
        }
        let over = MAX_WIRE_CHUNK_WORDS + 16;
        assert!(send_stream(&mut wire, &mut e, &[0; 8], &[0; 3], b"x", over).is_err());
    }

    #[test]
    fn forged_huge_file_bytes_does_not_preallocate() {
        // Header claims 2^60 payload bytes, then the stream ends. The
        // receiver must fail on the missing frame — it must not reserve
        // a buffer sized from the hostile header.
        let mut wire = Vec::new();
        wire.extend_from_slice(MAGIC);
        wire.extend_from_slice(&V1.to_le_bytes());
        wire.extend_from_slice(&(1u64 << 60).to_le_bytes());
        wire.extend_from_slice(&16u32.to_le_bytes());
        let mut rx = NativeEngine::new(Method::Chacha20);
        let r = recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &[0; 8], &[0; 3]);
        assert!(r.is_err());
    }

    #[test]
    fn tampered_payload_detected() {
        let key = [3u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[0xAB; 256], 16).unwrap();
        // Flip one ciphertext byte (past the 20-byte header).
        wire[30] ^= 0x01;
        let mut rx = NativeEngine::new(Method::Chacha20);
        let err = recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce)
            .unwrap_err()
            .to_string();
        assert!(err.contains("integrity failure"), "{err}");
    }

    #[test]
    fn wrong_key_fails_integrity_or_garbles() {
        let key = [3u32; 8];
        let bad_key = [4u32; 8];
        let nonce = [9, 8, 7];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[7u8; 128], 16).unwrap();
        let mut rx = NativeEngine::new(Method::Chacha20);
        // Digest is over ciphertext, so it still verifies — but plaintext
        // differs (confidentiality vs integrity separation).
        let (out, _) =
            recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &bad_key, &nonce).unwrap();
        assert_ne!(out, vec![7u8; 128]);
    }

    #[test]
    fn replayed_frame_rejected() {
        let key = [1u32; 8];
        let nonce = [1, 1, 1];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[5u8; 2048], 16).unwrap();
        // Duplicate the first data frame right after itself.
        let header = 20;
        let frame = 8 + 16 * 4 + 16;
        let dup: Vec<u8> = [
            &wire[..header + frame],
            &wire[header..header + frame],
            &wire[header + frame..],
        ]
        .concat();
        let mut rx = NativeEngine::new(Method::Chacha20);
        let err = recv_stream(&mut std::io::Cursor::new(dup), &mut rx, &key, &nonce).unwrap_err();
        assert!(err.to_string().contains("counter"), "{err}");
    }

    #[test]
    fn truncated_stream_errors() {
        let key = [1u32; 8];
        let nonce = [1, 1, 1];
        let mut tx = NativeEngine::new(Method::Chacha20);
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &[5u8; 1024], 16).unwrap();
        wire.truncate(wire.len() - 10);
        let mut rx = NativeEngine::new(Method::Chacha20);
        assert!(recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut rx = NativeEngine::new(Method::Chacha20);
        let wire = b"NOPE\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0".to_vec();
        assert!(recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &[0; 8], &[0; 3]).is_err());
    }

    #[test]
    fn aes_engine_interoperates() {
        let key = [2u32; 8];
        let nonce = [4, 5, 6];
        let mut tx = NativeEngine::new(Method::Aes256Ctr);
        let mut rx = NativeEngine::new(Method::Aes256Ctr);
        let data = vec![0x5Au8; 4096];
        let mut wire = Vec::new();
        send_stream(&mut wire, &mut tx, &key, &nonce, &data, 64).unwrap();
        let (out, _) = recv_stream(&mut std::io::Cursor::new(wire), &mut rx, &key, &nonce).unwrap();
        assert_eq!(out, data);
    }
}
