//! The transfer subsystem — the paper's subject.
//!
//! In a default HTCondor setup every job's input and output sandbox flows
//! through the submit node. Two pieces live here:
//!
//! * [`queue`] — the classic FIFO file-transfer queue and the
//!   [`ThrottlePolicy`] knob. HTCondor ships a disk-load throttle tuned
//!   for spinning disks; the paper had to *disable* it to reach 90 Gbps
//!   (§III: default settings took 64 min instead of 32). The schedd now
//!   delegates admission to the policy-driven
//!   [`crate::mover`] subsystem; `TransferQueue` remains as the minimal
//!   standalone primitive (and the reference semantics for the mover's
//!   FIFO policies).
//! * [`stream`] — the framed, sealed (encrypted + integrity-checked) chunk
//!   stream used by real mode, running over any `Read`/`Write` transport
//!   with the [`crate::runtime::engine::SealEngine`] doing the data-plane
//!   work.

pub mod queue;
pub mod stream;

pub use queue::{ThrottlePolicy, TransferQueue};
