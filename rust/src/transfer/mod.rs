//! The transfer subsystem — the paper's subject.
//!
//! In a default HTCondor setup every job's input and output sandbox flows
//! through the submit node. Two pieces live here:
//!
//! * [`queue`] — the schedd's file-transfer queue: admission control over
//!   concurrent sandbox transfers. HTCondor ships a disk-load throttle
//!   tuned for spinning disks; the paper had to *disable* it to reach
//!   90 Gbps (§III: default settings took 64 min instead of 32).
//! * [`stream`] — the framed, sealed (encrypted + integrity-checked) chunk
//!   stream used by real mode, running over any `Read`/`Write` transport
//!   with the [`crate::runtime::engine::SealEngine`] doing the data-plane
//!   work.

pub mod queue;
pub mod stream;

pub use queue::{ThrottlePolicy, TransferQueue};
