//! Workload generators and pool-sizing arithmetic.
//!
//! The paper's §II sizes its benchmark from steady-state pool arithmetic:
//! *"approximately 200 slots that need file transfer at any point in time,
//! which is what one would expect in a pool with 20k slots serving jobs
//! lasting 6 hours, each spending 3 minutes in file transfer."* That
//! arithmetic (a Little's-law argument) lives here, along with generators
//! for the benchmark burst and spiky arrival patterns.

use crate::jobs::{JobId, JobSpec};
use crate::util::units::Bytes;
use crate::util::Prng;

/// Steady-state pool sizing (§II): expected number of slots in file
/// transfer at any instant.
///
/// With `pool_slots` busy slots, each job holding a slot for
/// `job_duration_s` of which `transfer_s` is file transfer, the expected
/// number of concurrently-transferring slots is
/// `pool_slots × transfer_s / job_duration_s`.
pub fn concurrent_transfers(pool_slots: u32, job_duration_s: f64, transfer_s: f64) -> f64 {
    assert!(job_duration_s > 0.0);
    pool_slots as f64 * (transfer_s / job_duration_s)
}

/// The paper's sizing example: 20k slots, 6 h jobs, 3 min transfers.
pub fn paper_sizing() -> f64 {
    concurrent_transfers(20_000, 6.0 * 3600.0, 3.0 * 60.0)
}

/// The §III/§IV benchmark burst: `n` jobs with unique hard-linked input
/// names, identical sizes, trivial runtime.
pub fn benchmark_burst(n: u32, input_bytes: Bytes, output_bytes: Bytes) -> Vec<JobSpec> {
    (0..n)
        .map(|p| JobSpec {
            id: JobId { cluster: 1, proc: p },
            owner: "benchmark".into(),
            input_file: format!("input_{p}"),
            // Every benchmark name hard-links the same single extent.
            input_extent: Some(crate::storage::ExtentId(0)),
            input_bytes,
            output_bytes,
            runtime_median_s: 5.0,
        })
        .collect()
}

/// A spiky workload: `waves` bursts of `wave_size` jobs with varying input
/// sizes (lognormal around `median_bytes`) — the "very spiky workload
/// patterns" the paper's intro warns about. Returns (arrival_s, spec).
pub fn spiky_workload(
    waves: u32,
    wave_size: u32,
    wave_gap_s: f64,
    median_bytes: u64,
    seed: u64,
) -> Vec<(f64, JobSpec)> {
    let mut rng = Prng::new(seed);
    let mut out = Vec::with_capacity((waves * wave_size) as usize);
    let mut proc_ = 0u32;
    for w in 0..waves {
        let arrival = w as f64 * wave_gap_s;
        for _ in 0..wave_size {
            let bytes = rng.lognormal(median_bytes as f64, 0.5).max(1e6) as u64;
            out.push((
                arrival,
                JobSpec {
                    id: JobId { cluster: 2, proc: proc_ },
                    owner: "spiky".into(),
                    input_file: format!("spiky_{proc_}"),
                    input_extent: None,
                    input_bytes: Bytes(bytes),
                    output_bytes: Bytes(4_000),
                    runtime_median_s: 30.0,
                },
            ));
            proc_ += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizing_is_200() {
        // 20000 × (180 s / 21600 s) ≈ 166.7 — "approximately 200" in the
        // paper's rounding; assert the Little's-law value.
        let v = paper_sizing();
        assert!((v - 166.67).abs() < 0.1, "got {v}");
        // And the paper's chosen benchmark concurrency (200) is within 25%.
        assert!((200.0 - v) / v < 0.25);
    }

    #[test]
    fn concurrent_transfers_scales_linearly() {
        assert_eq!(concurrent_transfers(100, 100.0, 10.0), 10.0);
        assert_eq!(concurrent_transfers(200, 100.0, 10.0), 20.0);
        assert_eq!(concurrent_transfers(200, 200.0, 10.0), 10.0);
    }

    #[test]
    fn burst_has_unique_inputs() {
        let specs = benchmark_burst(1000, Bytes(2_000_000_000), Bytes(4_000));
        assert_eq!(specs.len(), 1000);
        let mut names: Vec<&str> = specs.iter().map(|s| s.input_file.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 1000, "unique hard-linked names");
    }

    #[test]
    fn spiky_waves_arrive_in_gaps() {
        let w = spiky_workload(3, 50, 600.0, 1_000_000_000, 7);
        assert_eq!(w.len(), 150);
        assert_eq!(w[0].0, 0.0);
        assert_eq!(w[50].0, 600.0);
        assert_eq!(w[100].0, 1200.0);
        // Sizes vary.
        let sizes: Vec<u64> = w.iter().map(|(_, s)| s.input_bytes.0).collect();
        assert!(sizes.iter().any(|&b| b != sizes[0]));
        // Deterministic.
        let w2 = spiky_workload(3, 50, 600.0, 1_000_000_000, 7);
        assert_eq!(w[17].1.input_bytes, w2[17].1.input_bytes);
    }
}
