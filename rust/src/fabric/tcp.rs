//! TCP loopback fabric: a submit-node file server and worker clients
//! moving real sealed bytes through the full protocol stack:
//!
//! ```text
//! worker                          submit file server
//!   | ---- ClientHello ------------> |        (16B nonce + methods)
//!   | <--- ServerHello ------------- |        (nonce, method, MAC)
//!   | ---- client MAC -------------> |        (mutual auth done)
//!   | ---- file request -----------> |        (u32 len + name)
//!   | <--- sealed input stream ----- |        (transfer::stream)
//!   | ---- sealed output stream ---> |        (job output sandbox)
//! ```
//!
//! Transfer admission and sealing both go through the unified
//! [`PoolRouter`]/[`ShadowPool`] data mover: jobs are routed to a submit
//! node and admitted under that node's configured [`AdmissionConfig`]
//! policy (the same objects the simulator drives), and each admitted
//! transfer is sealed by its assigned shadow shard's dedicated
//! crypto-service thread. With one node and one shard this reproduces
//! the paper's single-funnel submit node; with N shards sealing
//! parallelizes, and with N submit nodes (`n_submit_nodes > 1`) each
//! node runs its *own* [`FileServer`] — its own listener, dataset view
//! and per-shard engines — behind the router (see
//! `benches/queue_ablation.rs` for both sweeps).

use crate::jobs::JobSpec;
use crate::mover::chaos::{apply_to_router, ChaosTimeline, FaultEvent, FaultPlan};
use crate::mover::task::{synth_file_bytes, TaskProgress, TaskRunner, TunerSample};
use crate::mover::{
    AdmissionConfig, DataSource, MoverStats, PoolRouter, Routed, RouterConfig, RouterPolicy,
    RouterStats, ShadowPool, SiteSelector, SourcePlan, SourceSelector, TransferRequest,
};
use crate::runtime::engine::{NativeEngine, SealEngine};
use crate::runtime::service::{EngineHandle, EngineService};
use crate::security::session::{self, PoolKey};
use crate::security::sha256::Sha256;
use crate::security::Method;
use crate::transfer::stream::{
    recv_stream, recv_stream_with, seal_threads_from_env, send_stream, send_stream_opts,
    StreamOpts, StreamStats, MAX_WIRE_CHUNK_WORDS, V1, V2,
};
use crate::transfer::ThrottlePolicy;
use crate::util::{site_of_member, OnlineStats, Prng};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u32")
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

fn method_code(m: Method) -> u8 {
    match m {
        Method::Chacha20 => 1,
        Method::Aes256Ctr => 2,
        Method::Plain => 3,
    }
}

fn method_from(code: u8) -> Option<Method> {
    match code {
        1 => Some(Method::Chacha20),
        2 => Some(Method::Aes256Ctr),
        3 => Some(Method::Plain),
        _ => None,
    }
}

/// Server side of the wire handshake. Returns the session.
fn server_handshake(sock: &mut TcpStream, key: &PoolKey, rng: &mut Prng) -> Result<session::Session> {
    let mut client_nonce = [0u8; 16];
    sock.read_exact(&mut client_nonce)?;
    let n_methods = read_u32(sock)? as usize;
    if n_methods == 0 || n_methods > 8 {
        bail!("bad method count {n_methods}");
    }
    let mut methods = Vec::new();
    for _ in 0..n_methods {
        let mut b = [0u8; 1];
        sock.read_exact(&mut b)?;
        methods.push(method_from(b[0]).ok_or_else(|| anyhow!("unknown method {}", b[0]))?);
    }
    let hello = session::client_hello(client_nonce, &methods);

    let mut server_nonce = [0u8; 16];
    rng.fill_bytes(&mut server_nonce);
    let reply = session::server_respond(key, &hello, server_nonce, &[Method::Chacha20, Method::Aes256Ctr])?;
    sock.write_all(&reply.server_nonce)?;
    sock.write_all(&[method_code(reply.method)])?;
    sock.write_all(&reply.server_mac)?;

    let mut client_mac = [0u8; 32];
    sock.read_exact(&mut client_mac)?;
    Ok(session::server_finish(key, &hello, &reply, &client_mac)?)
}

/// Client side of the wire handshake.
fn client_handshake(
    sock: &mut TcpStream,
    key: &PoolKey,
    rng: &mut Prng,
    methods: &[Method],
) -> Result<session::Session> {
    let mut client_nonce = [0u8; 16];
    rng.fill_bytes(&mut client_nonce);
    let hello = session::client_hello(client_nonce, methods);
    sock.write_all(&client_nonce)?;
    write_u32(sock, methods.len() as u32)?;
    for m in methods {
        sock.write_all(&[method_code(*m)])?;
    }

    let mut server_nonce = [0u8; 16];
    sock.read_exact(&mut server_nonce)?;
    let mut mb = [0u8; 1];
    sock.read_exact(&mut mb)?;
    let method = method_from(mb[0]).ok_or_else(|| anyhow!("bad method byte"))?;
    let mut server_mac = [0u8; 32];
    sock.read_exact(&mut server_mac)?;
    let reply = session::ServerHello {
        server_nonce,
        method,
        server_mac,
    };
    let (mac, sess) = session::client_finish(key, &hello, &reply)?;
    sock.write_all(&mac)?;
    Ok(sess)
}

/// The role a [`FileServer`] plays in the pool: the scheduling node's
/// own funnel (the paper baseline) or a dedicated data-transfer node.
/// Same server type, same wire protocol — the role only names the
/// endpoint in thread names and logs, which is the point: a DTN *is* a
/// submit-node file server minus the scheduling duties.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerRole {
    Funnel,
    Dtn,
}

impl ServerRole {
    fn thread_name(&self) -> &'static str {
        match self {
            ServerRole::Funnel => "htcdm-fileserver",
            ServerRole::Dtn => "htcdm-dtn",
        }
    }
}

/// A pool file server: serves named in-memory files (the paper's
/// hard-linked dataset) over sealed streams; receives output sandboxes.
/// Backs both the submit-funnel and the DTN role (see [`ServerRole`]).
pub struct FileServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    pub bytes_served: Arc<AtomicU64>,
    /// Wire bytes this server put on (and accepted from) its sockets:
    /// payload plus stream headers, frame heads and digests. The
    /// payload/wire split is what the reports surface as framing
    /// overhead.
    pub wire_bytes_served: Arc<AtomicU64>,
    pub outputs_received: Arc<AtomicU64>,
    /// Live connection sockets (keyed by connection sequence, removed
    /// when their serving thread finishes); [`FileServer::stop`] shuts
    /// them down so a chaos kill looks like a node crash (mid-transfer
    /// socket errors) rather than a graceful drain.
    conns: Arc<Mutex<Vec<(u64, TcpStream)>>>,
}

impl FileServer {
    /// Start serving in the submit-funnel role. `files` maps name ->
    /// content (hardlinks = shared `Arc<Vec<u8>>`). `engines` holds one
    /// server-side crypto-service handle per shadow shard; each
    /// connection announces its assigned shard and is sealed by that
    /// shard's engine.
    pub fn start(
        files: HashMap<String, Arc<Vec<u8>>>,
        pool_key: PoolKey,
        engines: Vec<EngineHandle>,
        chunk_words: usize,
    ) -> Result<FileServer> {
        FileServer::start_with_role(ServerRole::Funnel, files, pool_key, engines, chunk_words)
    }

    /// [`FileServer::start`] with an explicit [`ServerRole`] (the DTN
    /// fleet uses [`ServerRole::Dtn`]).
    pub fn start_with_role(
        role: ServerRole,
        files: HashMap<String, Arc<Vec<u8>>>,
        pool_key: PoolKey,
        engines: Vec<EngineHandle>,
        chunk_words: usize,
    ) -> Result<FileServer> {
        if engines.is_empty() {
            bail!("file server needs at least one seal-engine handle");
        }
        let listener = TcpListener::bind("127.0.0.1:0").context("bind file server")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_served = Arc::new(AtomicU64::new(0));
        let wire_bytes_served = Arc::new(AtomicU64::new(0));
        let outputs_received = Arc::new(AtomicU64::new(0));
        let conns: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));

        let stop2 = stop.clone();
        let bytes2 = bytes_served.clone();
        let wire2 = wire_bytes_served.clone();
        let outputs2 = outputs_received.clone();
        let conns2 = conns.clone();
        let thread = std::thread::Builder::new()
            .name(role.thread_name().into())
            .spawn(move || {
                let mut conn_seq: u64 = 0;
                let mut threads = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            conn_seq += 1;
                            if let Ok(dup) = sock.try_clone() {
                                conns2.lock().unwrap().push((conn_seq, dup));
                            }
                            let files = files.clone();
                            let key = pool_key.clone();
                            let engines = engines.clone();
                            let bytes3 = bytes2.clone();
                            let wire3 = wire2.clone();
                            let outputs3 = outputs2.clone();
                            let conns3 = conns2.clone();
                            let seq = conn_seq;
                            threads.push(std::thread::spawn(move || {
                                let mut rng = Prng::new(0xF11E_5E17 ^ seq);
                                if let Err(e) = serve_one(
                                    sock, &files, &key, &engines, &mut rng, chunk_words, &bytes3,
                                    &wire3, &outputs3,
                                ) {
                                    log::warn!("connection {seq}: {e:#}");
                                }
                                // Done serving: drop this connection's
                                // kill handle so long bursts don't
                                // accumulate open fds.
                                conns3.lock().unwrap().retain(|(s, _)| *s != seq);
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(e) => {
                            log::warn!("accept: {e}");
                            break;
                        }
                    }
                }
                for t in threads {
                    let _ = t.join();
                }
            })
            .context("spawn file server")?;
        Ok(FileServer {
            addr,
            stop,
            thread: Some(thread),
            bytes_served,
            wire_bytes_served,
            outputs_received,
            conns,
        })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Break in-flight connections so stopping mid-burst behaves like
        // a node crash; at a normal end of run every socket is already
        // drained and the list is empty.
        for (_, c) in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FileServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// High bit of the shard-announcement word: set by v2 clients to open a
/// chunk negotiation (a `u32` proposal follows; the server echoes the
/// agreed value). Unflagged announcements get the exact v1 protocol and
/// the server's configured chunk, so v1 peers interoperate untouched.
pub const NEGOTIATE_FLAG: u32 = 0x8000_0000;

/// The client's chunk-size stance for one connection (wire format v2
/// negotiation; see [`NEGOTIATE_FLAG`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkProposal {
    /// Pre-negotiation v1 protocol: announce only the shard.
    Legacy,
    /// Negotiate, but let the server pick its configured chunk.
    ServerDefault,
    /// Negotiate this many words per frame. The server validates and
    /// falls back to its configured chunk on a bad value.
    Words(usize),
}

/// Server side of the chunk negotiation: validate the client's proposal
/// and pick the connection's chunk (0 = "server default").
fn negotiate_chunk_words(proposed: u32, configured: usize) -> usize {
    let p = proposed as usize;
    if p == 0 || p % 16 != 0 || p > MAX_WIRE_CHUNK_WORDS {
        configured
    } else {
        p
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_one(
    mut sock: TcpStream,
    files: &HashMap<String, Arc<Vec<u8>>>,
    key: &PoolKey,
    engines: &[EngineHandle],
    rng: &mut Prng,
    chunk_words: usize,
    bytes_served: &AtomicU64,
    wire_bytes_served: &AtomicU64,
    outputs_received: &AtomicU64,
) -> Result<()> {
    sock.set_nodelay(true).ok();
    let sess = server_handshake(&mut sock, key, rng)?;

    // Shadow-shard announcement: the mover assigned this transfer a
    // shard at admission; its engine seals this connection. A v2 client
    // sets the high bit and follows with its chunk proposal.
    let shard_word = read_u32(&mut sock)?;
    let (shard, chunk, version) = if shard_word & NEGOTIATE_FLAG != 0 {
        let proposed = read_u32(&mut sock)?;
        let agreed = negotiate_chunk_words(proposed, chunk_words);
        write_u32(&mut sock, agreed as u32)?;
        ((shard_word & !NEGOTIATE_FLAG) as usize, agreed, V2)
    } else {
        (shard_word as usize, chunk_words, V1)
    };
    let mut engine = engines[shard % engines.len()].clone();

    // File request.
    let name_len = read_u32(&mut sock)? as usize;
    if name_len > 4096 {
        bail!("file name too long");
    }
    let mut name_buf = vec![0u8; name_len];
    sock.read_exact(&mut name_buf)?;
    let name = String::from_utf8(name_buf).context("file name utf8")?;
    let content = files
        .get(&name)
        .ok_or_else(|| anyhow!("no such input file '{name}'"))?
        .clone();

    let opts = StreamOpts {
        chunk_words: chunk,
        seal_threads: seal_threads_from_env(),
        version,
    };
    let stats = send_stream_opts(
        &mut sock,
        &mut engine,
        &sess.key_words,
        &sess.nonce_words,
        &content,
        &opts,
    )?;
    bytes_served.fetch_add(stats.payload_bytes, Ordering::Relaxed);
    wire_bytes_served.fetch_add(stats.wire_bytes, Ordering::Relaxed);

    // Output sandbox comes back on the same session. The output stream's
    // counters continue after the input's (no keystream reuse).
    let mut rx_engine = NativeEngine::new(sess.method);
    let (_output, ostats) = recv_stream(
        &mut sock,
        &mut rx_engine,
        &sess.key_words,
        &sess.nonce_words,
    )?;
    wire_bytes_served.fetch_add(ostats.wire_bytes, Ordering::Relaxed);
    outputs_received.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// One worker job cycle against the server: handshake, announce the
/// mover-assigned shard (negotiating the server's default chunk),
/// fetch input, validate, send output. Returns (input stats, wall
/// seconds).
pub fn run_job(
    addr: std::net::SocketAddr,
    pool_key: &PoolKey,
    spec_input: &str,
    output: &[u8],
    shard: usize,
    rng: &mut Prng,
) -> Result<(StreamStats, f64)> {
    let proposal = ChunkProposal::ServerDefault;
    let (_input, stats, secs) =
        run_job_fetch(addr, pool_key, spec_input, output, shard, proposal, rng)?;
    Ok((stats, secs))
}

/// [`run_job`] that also returns the fetched input payload, for callers
/// that verify content end-to-end. Callers that only need the payload's
/// hash should prefer [`run_job_fetch_digest`], which folds SHA-256
/// into the receive loop instead of buffering and re-reading the file.
pub fn run_job_fetch(
    addr: std::net::SocketAddr,
    pool_key: &PoolKey,
    spec_input: &str,
    output: &[u8],
    shard: usize,
    proposal: ChunkProposal,
    rng: &mut Prng,
) -> Result<(Vec<u8>, StreamStats, f64)> {
    let mut input = Vec::new();
    let (stats, secs) = run_job_sink(
        addr,
        pool_key,
        spec_input,
        output,
        shard,
        proposal,
        rng,
        |chunk| input.extend_from_slice(chunk),
    )?;
    Ok((input, stats, secs))
}

/// [`run_job_fetch`] for the durable-task layer: the fetched file is
/// hashed with the in-crate SHA-256 *as frames arrive* — each frame's
/// payload is already integrity-verified before the sink sees it — so
/// checkpoint verification costs no second pass over the buffered file.
/// Returns the lowercase hex digest in place of the payload.
#[allow(clippy::too_many_arguments)]
pub fn run_job_fetch_digest(
    addr: std::net::SocketAddr,
    pool_key: &PoolKey,
    spec_input: &str,
    output: &[u8],
    shard: usize,
    proposal: ChunkProposal,
    rng: &mut Prng,
) -> Result<(String, StreamStats, f64)> {
    let mut hasher = Sha256::new();
    let (stats, secs) = run_job_sink(
        addr,
        pool_key,
        spec_input,
        output,
        shard,
        proposal,
        rng,
        |chunk| hasher.update(chunk),
    )?;
    let mut hex = String::with_capacity(64);
    for b in hasher.finalize() {
        hex.push_str(&format!("{b:02x}"));
    }
    Ok((hex, stats, secs))
}

/// The shared client cycle: handshake, shard announcement (with v2
/// chunk negotiation unless [`ChunkProposal::Legacy`]), streamed input
/// delivery into `sink`, then the output sandbox send.
#[allow(clippy::too_many_arguments)]
fn run_job_sink(
    addr: std::net::SocketAddr,
    pool_key: &PoolKey,
    spec_input: &str,
    output: &[u8],
    shard: usize,
    proposal: ChunkProposal,
    rng: &mut Prng,
    mut sink: impl FnMut(&[u8]),
) -> Result<(StreamStats, f64)> {
    let t0 = std::time::Instant::now();
    let mut sock = TcpStream::connect(addr).context("connect to submit")?;
    sock.set_nodelay(true).ok();
    let sess = client_handshake(&mut sock, pool_key, rng, &[Method::Chacha20, Method::Aes256Ctr])?;

    match proposal {
        ChunkProposal::Legacy => write_u32(&mut sock, shard as u32)?,
        ChunkProposal::ServerDefault | ChunkProposal::Words(_) => {
            write_u32(&mut sock, NEGOTIATE_FLAG | shard as u32)?;
            let words = match proposal {
                ChunkProposal::Words(w) => w as u32,
                _ => 0,
            };
            write_u32(&mut sock, words)?;
            let _agreed = read_u32(&mut sock)?;
        }
    }
    write_u32(&mut sock, spec_input.len() as u32)?;
    sock.write_all(spec_input.as_bytes())?;

    let mut engine = NativeEngine::new(sess.method);
    let stats = recv_stream_with(
        &mut sock,
        &mut engine,
        &sess.key_words,
        &sess.nonce_words,
        |_h, chunk| {
            sink(chunk);
            Ok(())
        },
    )?;

    // "Run" the validation script: the data is already integrity-checked
    // frame by frame; job output is tiny, as in the paper.
    let mut tx_engine = NativeEngine::new(sess.method);
    send_stream(
        &mut sock,
        &mut tx_engine,
        &sess.key_words,
        &sess.nonce_words,
        output,
        256,
    )?;
    Ok((stats, t0.elapsed().as_secs_f64()))
}

/// Configuration for a real-mode pool run.
#[derive(Debug, Clone)]
pub struct RealPoolConfig {
    pub n_jobs: u32,
    pub workers: u32,
    pub input_bytes: usize,
    pub output_bytes: usize,
    pub chunk_words: usize,
    /// Use the PJRT artifact engine on the submit side (requires
    /// `make artifacts`); falls back to native if unavailable.
    pub use_xla_engine: bool,
    pub passphrase: String,
    /// Shadow-pool shard count per submit node: each shard gets its own
    /// seal-engine thread. 1 = the paper's single crypto funnel.
    pub shadows: u32,
    /// Transfer-admission policy (the same knob the simulator takes);
    /// every submit node runs its own copy.
    pub policy: AdmissionConfig,
    /// Submit-node count: one [`FileServer`] (own listener + per-shard
    /// engines) per node, fed by the pool router.
    pub n_submit_nodes: u32,
    /// Pool-level routing strategy across submit nodes.
    pub router: RouterPolicy,
    /// Relative per-node NIC budgets for weighted-by-capacity routing
    /// (e.g. `[100.0, 25.0]`). Empty = uniform; otherwise must have
    /// `n_submit_nodes` entries.
    pub node_capacities: Vec<f64>,
    /// Dedicated data-transfer-node fleet size: one [`ServerRole::Dtn`]
    /// file server per data node, serving bytes under `source` while
    /// the submit node keeps only scheduling (admission) duties.
    pub data_nodes: u32,
    /// Data-source plan choosing funnel vs DTN per admitted transfer.
    pub source: SourcePlan,
    /// Which-DTN selection strategy within the fleet (the same knob the
    /// simulator takes: round-robin / cache-aware / owner-affinity /
    /// weighted-by-capacity).
    pub source_selector: SourceSelector,
    /// Federation sites (1 = unfederated): the submit fleet, DTN fleet
    /// and workers partition into `n_sites` contiguous blocks
    /// ([`site_of_member`], the same partition the simulator builds),
    /// and routing goes two-level — a [`SiteSelector`] picks the source
    /// site, then `source_selector` picks the endpoint within it.
    pub n_sites: usize,
    /// Which-site selection strategy (the `SITE_SELECTOR` knob:
    /// local-first / cache-aware / round-robin).
    pub site_selector: SiteSelector,
    /// Per-DTN admission budget: max concurrent transfers one data node
    /// serves (0 = unlimited). A saturated DTN defers placements to its
    /// peers and overflows to the funnel when the whole fleet is full.
    pub dtn_slots: u32,
    /// Per-DTN bounded wait-queue depth (0 = disabled): with queues on,
    /// a budget-full fleet parks transfers on a data node's queue
    /// instead of overflowing to the funnel.
    pub dtn_queue_depth: u32,
    /// Router state shards (`ROUTER_SHARDS`): how many lock shards the
    /// router's ticket/owner maps split into. Decisions are identical
    /// for every value; more shards cut worker-side lock contention.
    pub router_shards: usize,
    /// Admission-cycle batch size (`CYCLE_SIZE`): requests handed to the
    /// router per `route_batch` call when a gate holder drains the
    /// combining buffer (0 = drain everything in one batch).
    pub cycle_size: usize,
    /// Fault-injection schedule (wall-clock seconds from burst start):
    /// `KillNode` crashes the node's file server mid-burst (in-flight
    /// connections break; workers retry through the router),
    /// `RecoverNode` restarts it on a fresh port and rebalances queued
    /// work onto it, `DegradeNic` re-rates its routing weight. Empty =
    /// fault-free.
    pub faults: FaultPlan,
}

impl Default for RealPoolConfig {
    fn default() -> Self {
        RealPoolConfig {
            n_jobs: 40,
            workers: 4,
            input_bytes: 4 << 20,
            output_bytes: 4096,
            chunk_words: crate::transfer::stream::DEFAULT_CHUNK_WORDS,
            use_xla_engine: true,
            passphrase: "htcdm-pool".into(),
            shadows: 1,
            policy: AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            n_submit_nodes: 1,
            router: RouterPolicy::LeastLoaded,
            node_capacities: Vec::new(),
            data_nodes: 0,
            source: SourcePlan::SubmitFunnel,
            source_selector: SourceSelector::RoundRobin,
            n_sites: 1,
            site_selector: SiteSelector::LocalFirst,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            router_shards: crate::mover::DEFAULT_ROUTER_SHARDS,
            cycle_size: 0,
            faults: FaultPlan::default(),
        }
    }
}

/// Results of a real-mode pool run.
#[derive(Debug)]
pub struct RealPoolReport {
    pub jobs_completed: u32,
    pub total_payload_bytes: u64,
    /// Wire bytes workers received fetching inputs: payload plus stream
    /// headers, frame heads and digests. `total_wire_bytes -
    /// total_payload_bytes` is the framing overhead the goodput gap
    /// comes from.
    pub total_wire_bytes: u64,
    pub wall_secs: f64,
    pub gbps: f64,
    pub transfer_secs: OnlineStats,
    pub engine_desc: String,
    pub errors: u32,
    /// Aggregate data-mover accounting (per-shard routing node-major,
    /// admission totals).
    pub mover: MoverStats,
    /// Per-submit-node router accounting.
    pub router: RouterStats,
    /// Payload bytes each submit node's file servers put on the wire
    /// (index = node; accumulated across a killed node's generations,
    /// so it keeps growing after a recovery; sums to roughly
    /// `total_payload_bytes` plus re-served partial transfers).
    pub bytes_served_per_node: Vec<u64>,
    /// Payload bytes each data node's file servers put on the wire
    /// (index = dtn; same generation-accumulation rule; empty with no
    /// DTN fleet). Under `SourcePlan::DedicatedDtn` these carry the
    /// whole burst while `bytes_served_per_node` stays ~0.
    pub bytes_served_per_dtn: Vec<u64>,
    /// Wire bytes each submit node's file servers moved (payload plus
    /// framing, both directions; same indexing and generation rules as
    /// `bytes_served_per_node`).
    pub wire_bytes_per_node: Vec<u64>,
    /// Wire bytes each data node's file servers moved (see
    /// `wire_bytes_per_node`).
    pub wire_bytes_per_dtn: Vec<u64>,
    /// Data-source plan label the run executed with.
    pub source_plan: String,
    /// Which-DTN selection-strategy label the run executed with.
    pub source_selector: String,
    /// Federation sites the run executed with (1 = unfederated).
    pub n_sites: usize,
    /// Site×site goodput matrix: `site_matrix_bytes[src][dst]` is the
    /// verified payload bytes a site-`src` endpoint (funnel or DTN)
    /// served to a site-`dst` worker — the same matrix the simulator's
    /// `Report` carries, measured from real sockets. Always
    /// `n_sites × n_sites`; a 1×1 total on unfederated runs.
    pub site_matrix_bytes: Vec<Vec<u64>>,
    /// Flow-solver label for sim-vs-real joins: the real fabric always
    /// moves bytes over the kernel's actual TCP stack, so this is the
    /// constant `real-tcp` — the calibration harness
    /// (`fabric::calibrate`) compares it against sim reports labelled
    /// `fair-share` or `tcp-dynamic`.
    pub solver: String,
    /// Per-node fault timeline (empty for fault-free runs).
    pub chaos: ChaosTimeline,
}

/// Seal-engine factory for one shadow shard: the PJRT artifact when
/// requested and available, native ChaCha20 otherwise.
fn shard_engine_factory(use_xla: bool) -> impl Fn(usize) -> Result<Box<dyn SealEngine>> + Send + Clone + 'static
{
    move |shard: usize| {
        if use_xla {
            let dir = crate::runtime::Manifest::default_dir();
            match crate::runtime::Manifest::load(&dir)
                .and_then(|m| crate::runtime::SealRuntime::load(&m, &["64k"]))
            {
                Ok(rt) => {
                    return Ok(Box::new(crate::runtime::engine::XlaEngine::new(rt))
                        as Box<dyn SealEngine>)
                }
                Err(e) => log::warn!("xla engine unavailable on shard {shard} ({e:#}); using native"),
            }
        }
        Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
    }
}

/// Admission gate shared between worker threads: the router (the policy
/// object) plus the set of admitted-but-not-yet-claimed tickets, mapped
/// to their full routing decision (schedule node, shard, data source).
/// A chaos re-source overwrites a ticket's entry with its new source.
struct GateState {
    router: PoolRouter,
    ready: HashMap<u32, Routed>,
}

/// Chaos kill, server side: crash one endpoint's file server (funnel or
/// DTN — same protocol), accumulate its served bytes into the
/// cross-generation total, and return them.
fn crash_server(
    servers: &Mutex<Vec<Option<FileServer>>>,
    totals: &[AtomicU64],
    wire_totals: &[AtomicU64],
    node: usize,
) -> u64 {
    match servers.lock().unwrap()[node].take() {
        Some(mut server) => {
            server.stop();
            let b = server.bytes_served.load(Ordering::Relaxed);
            totals[node].fetch_add(b, Ordering::Relaxed);
            let w = server.wire_bytes_served.load(Ordering::Relaxed);
            wire_totals[node].fetch_add(w, Ordering::Relaxed);
            b
        }
        None => 0,
    }
}

/// End-of-run shutdown: stop every live server in a fleet (funnel or
/// DTN) and fold its served bytes into the cross-generation totals —
/// the same stop-and-accumulate contract as [`crash_server`].
fn stop_fleet(
    servers: &Mutex<Vec<Option<FileServer>>>,
    totals: &[AtomicU64],
    wire_totals: &[AtomicU64],
) {
    let mut servers = servers.lock().unwrap();
    for (node, slot) in servers.iter_mut().enumerate() {
        if let Some(server) = slot.as_mut() {
            server.stop();
            totals[node]
                .fetch_add(server.bytes_served.load(Ordering::Relaxed), Ordering::Relaxed);
            let w = server.wire_bytes_served.load(Ordering::Relaxed);
            wire_totals[node].fetch_add(w, Ordering::Relaxed);
        }
        *slot = None;
    }
}

/// Chaos recovery, server side: restart one endpoint's file server on a
/// fresh port and publish the new address — shared by the funnel and
/// DTN roles so the restart-before-unpoison protocol lives in one
/// place. Returns false when the rebind failed (the event is skipped).
#[allow(clippy::too_many_arguments)]
fn restart_server(
    role: ServerRole,
    files: &HashMap<String, Arc<Vec<u8>>>,
    key: &PoolKey,
    handles: Vec<EngineHandle>,
    chunk_words: usize,
    addrs: &Mutex<Vec<std::net::SocketAddr>>,
    servers: &Mutex<Vec<Option<FileServer>>>,
    node: usize,
) -> bool {
    match FileServer::start_with_role(role, files.clone(), key.clone(), handles, chunk_words) {
        Ok(server) => {
            addrs.lock().unwrap()[node] = server.addr;
            servers.lock().unwrap()[node] = Some(server);
            true
        }
        Err(e) => {
            log::error!(
                "chaos: {role:?} {node} recovery failed to restart its file server: {e:#}"
            );
            false
        }
    }
}

/// Run a full real-mode pool on loopback: one submit file server per
/// submit node with the hard-linked dataset and `workers` worker threads
/// pulling jobs, all routing and admission driven by a router built from
/// the config.
pub fn run_real_pool(cfg: RealPoolConfig) -> Result<RealPoolReport> {
    let n_nodes = cfg.n_submit_nodes.max(1) as usize;
    let nodes: Vec<ShadowPool> = (0..n_nodes)
        .map(|_| ShadowPool::sim(cfg.shadows.max(1), cfg.policy.clone()))
        .collect();
    let capacities = if cfg.node_capacities.is_empty() {
        vec![1.0; n_nodes]
    } else if cfg.node_capacities.len() == n_nodes {
        cfg.node_capacities.clone()
    } else {
        bail!(
            "node_capacities has {} entries for {} submit nodes",
            cfg.node_capacities.len(),
            n_nodes
        );
    };
    let router = PoolRouter::from_config(
        nodes,
        capacities,
        cfg.router,
        RouterConfig {
            source_plan: cfg.source,
            dtn_capacity: vec![1.0; cfg.data_nodes as usize],
            source_selector: cfg.source_selector,
            n_sites: cfg.n_sites.max(1),
            site_selector: cfg.site_selector,
            dtn_slots: cfg.dtn_slots,
            dtn_queue_depth: cfg.dtn_queue_depth,
            state_shards: cfg.router_shards,
            recovery_ramp: cfg.faults.recovery_ramp.unwrap_or(0),
        },
    );
    let (report, _router) = run_real_pool_router(&cfg, router)?;
    Ok(report)
}

/// Like [`run_real_pool`] but driving a caller-supplied single-node
/// mover — the same policy object can first drive the simulator and then
/// this fabric (`tests/mover_unified.rs`). Engines are spawned on demand
/// if the mover arrived from sim mode; admission statistics accumulate
/// across both. Returns the report and the mover (with its accumulated
/// state).
pub fn run_real_pool_with(
    cfg: &RealPoolConfig,
    mover: ShadowPool,
) -> Result<(RealPoolReport, ShadowPool)> {
    let (report, router) = run_real_pool_router(cfg, PoolRouter::single(mover))?;
    let mover = router
        .into_single()
        .map_err(|_| anyhow!("single-node router came back multi-node"))?;
    Ok((report, mover))
}

/// The multi-submit-node core both entry points share: drive a
/// caller-supplied [`PoolRouter`] (N nodes → N file servers) through a
/// real loopback burst. The same router object can first drive the
/// simulator (`tests/router_unified.rs`); engines spawn on demand and
/// statistics accumulate. Returns the report and the router.
pub fn run_real_pool_router(
    cfg: &RealPoolConfig,
    mut router: PoolRouter,
) -> Result<(RealPoolReport, PoolRouter)> {
    let pool_key = PoolKey::from_passphrase(&cfg.passphrase);
    router.ensure_engines(shard_engine_factory(cfg.use_xla_engine));
    if let Err(e) = cfg.faults.validate(router.node_count(), router.dtn_count(), router.n_sites())
    {
        bail!("invalid fault plan: {e}");
    }
    if let Err(e) = router.source_plan().validate(router.dtn_count()) {
        bail!("invalid source plan: {e}");
    }
    if let Some(ramp) = cfg.faults.recovery_ramp {
        router.set_ramp_decisions(ramp);
    }
    for node in 0..router.node_count() {
        if router.node_config(node).limit() == 0 {
            bail!(
                "node {node}'s admission policy admits nothing (limit 0) — the pool would \
                 deadlock"
            );
        }
    }
    // A carried-over router must be quiescent: stale in-flight tickets
    // would hold admission slots no worker here will ever complete (and
    // could collide with this run's job procs), wedging the pool.
    if router.active() > 0 || router.waiting() > 0 {
        bail!(
            "router still has {} active / {} waiting transfers — complete the previous run \
             before driving the real fabric with it",
            router.active(),
            router.waiting()
        );
    }

    // The paper's dataset trick: one extent, many names. Every submit
    // node serves the same hard-linked dataset (shared `Arc`s, so the
    // extent exists once regardless of node count).
    let mut extent = vec![0u8; cfg.input_bytes];
    Prng::new(2021).fill_bytes(&mut extent);
    let extent = Arc::new(extent);
    let mut files = HashMap::new();
    for p in 0..cfg.n_jobs {
        files.insert(format!("input_{p}"), extent.clone());
    }

    let first_handles = router.handles(0);
    let engine_desc = format!(
        "{} x{}{}",
        first_handles
            .first()
            .map(|h| h.describe())
            .unwrap_or_else(|| "none".into()),
        first_handles.len(),
        if router.node_count() > 1 {
            format!(" x{} nodes", router.node_count())
        } else {
            String::new()
        }
    );

    // One file server per submit node. Chaos can crash and restart a
    // node's server mid-burst, so servers live in shared slots and the
    // address table is re-read by workers on every (re)connection.
    let n_nodes = router.node_count();
    let mut server_vec: Vec<Option<FileServer>> = Vec::with_capacity(n_nodes);
    for node in 0..n_nodes {
        server_vec.push(Some(FileServer::start(
            files.clone(),
            pool_key.clone(),
            router.handles(node),
            cfg.chunk_words,
        )?));
    }
    let addrs: Arc<Mutex<Vec<std::net::SocketAddr>>> = Arc::new(Mutex::new(
        server_vec
            .iter()
            .map(|s| s.as_ref().expect("just started").addr)
            .collect(),
    ));
    let servers: Arc<Mutex<Vec<Option<FileServer>>>> = Arc::new(Mutex::new(server_vec));
    // Bytes served per node, accumulated across server generations
    // (a killed node's total carries over into its recovered server).
    // Wire totals ride alongside under the same rules.
    let served_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_nodes).map(|_| AtomicU64::new(0)).collect());
    let wire_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_nodes).map(|_| AtomicU64::new(0)).collect());

    // The DTN fleet: one ServerRole::Dtn file server per data node, each
    // with its own seal-engine services (the same dataset view — every
    // endpoint serves the shared hard-linked extents). The services
    // outlive server generations so a chaos kill/recover restarts the
    // listener without respawning engines. A fleet no code path can
    // reach — a SubmitFunnel plan with no DTN-addressed faults — is not
    // spawned at all (no idle listeners or crypto threads).
    let fleet_reachable = router.source_plan().uses_dtns()
        || cfg
            .faults
            .events
            .iter()
            .any(|e| e.is_dtn() || e.is_site());
    let n_dtns = if fleet_reachable { router.dtn_count() } else { 0 };
    let mut dtn_services: Vec<EngineService> = Vec::new();
    let mut dtn_handles: Vec<Vec<EngineHandle>> = Vec::with_capacity(n_dtns);
    for _ in 0..n_dtns {
        let mut handles = Vec::with_capacity(cfg.shadows.max(1) as usize);
        for _ in 0..cfg.shadows.max(1) {
            let svc = EngineService::spawn({
                let f = shard_engine_factory(cfg.use_xla_engine);
                move || f(0)
            });
            handles.push(svc.handle());
            dtn_services.push(svc);
        }
        dtn_handles.push(handles);
    }
    let mut dtn_server_vec: Vec<Option<FileServer>> = Vec::with_capacity(n_dtns);
    for handles in &dtn_handles {
        dtn_server_vec.push(Some(FileServer::start_with_role(
            ServerRole::Dtn,
            files.clone(),
            pool_key.clone(),
            handles.clone(),
            cfg.chunk_words,
        )?));
    }
    let dtn_addrs: Arc<Mutex<Vec<std::net::SocketAddr>>> = Arc::new(Mutex::new(
        dtn_server_vec
            .iter()
            .map(|s| s.as_ref().expect("just started").addr)
            .collect(),
    ));
    let dtn_servers: Arc<Mutex<Vec<Option<FileServer>>>> = Arc::new(Mutex::new(dtn_server_vec));
    let dtn_served_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_dtns).map(|_| AtomicU64::new(0)).collect());
    let dtn_wire_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_dtns).map(|_| AtomicU64::new(0)).collect());

    let queue: Arc<Mutex<Vec<JobSpec>>> = Arc::new(Mutex::new(
        crate::workload::benchmark_burst(
            cfg.n_jobs,
            crate::util::units::Bytes(cfg.input_bytes as u64),
            crate::util::units::Bytes(cfg.output_bytes as u64),
        )
        .into_iter()
        .rev()
        .collect(),
    ));

    // Read-side handle onto the router's sharded ticket state: workers
    // answer "where is my ticket now?" probes through one shard lock
    // each instead of re-deriving everything from the router object.
    let state = router.state_handle();
    // The federation partition, shared with the router and the sim
    // engine: endpoint i of a fleet of `count` lives in
    // `site_of_member(i, count, n_sites)`.
    let n_sites = router.n_sites();
    let gate = Arc::new((
        Mutex::new(GateState {
            router,
            ready: HashMap::new(),
        }),
        Condvar::new(),
    ));
    // The admission combining buffer: workers park their requests here,
    // and whoever takes the gate next routes the whole backlog as ONE
    // negotiator-style admission cycle (`route_batch` in `cycle_size`
    // chunks) — the gate is taken once per cycle, not once per request.
    // Lock order: gate, then pending; never the reverse.
    let pending: Arc<Mutex<Vec<TransferRequest>>> = Arc::new(Mutex::new(Vec::new()));

    let t0 = std::time::Instant::now();
    let chaos_log: Arc<Mutex<ChaosTimeline>> = Arc::new(Mutex::new(ChaosTimeline::default()));
    let burst_done = Arc::new(AtomicBool::new(false));
    let chaos_thread = if cfg.faults.is_empty() {
        None
    } else {
        let events = cfg.faults.sorted();
        let threshold = cfg.faults.steal_threshold;
        let gate = gate.clone();
        let servers = servers.clone();
        let addrs = addrs.clone();
        let served_totals = served_totals.clone();
        let wire_totals = wire_totals.clone();
        let dtn_servers = dtn_servers.clone();
        let dtn_addrs = dtn_addrs.clone();
        let dtn_served_totals = dtn_served_totals.clone();
        let dtn_wire_totals = dtn_wire_totals.clone();
        let dtn_handles = dtn_handles.clone();
        let chaos_log = chaos_log.clone();
        let burst_done = burst_done.clone();
        let files = files.clone();
        let key = pool_key.clone();
        let chunk_words = cfg.chunk_words;
        Some(
            std::thread::Builder::new()
                .name("htcdm-chaos".into())
                .spawn(move || {
                    // A site event fans out over the site's contiguous
                    // member block in every fleet.
                    let site_nodes = |site: usize| {
                        (0..n_nodes)
                            .filter(move |&n| site_of_member(n, n_nodes, n_sites) == site)
                    };
                    let site_dtns = |site: usize| {
                        (0..n_dtns)
                            .filter(move |&d| site_of_member(d, n_dtns, n_sites) == site)
                    };
                    for ev in events {
                        // Wait for the event's wall-clock instant; give
                        // up only on events still in the future when the
                        // burst drains (an event whose time has arrived
                        // always applies, so t=0 events never race the
                        // workers).
                        while t0.elapsed().as_secs_f64() < ev.at() {
                            if burst_done.load(Ordering::Relaxed) {
                                return;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        let node = ev.node();
                        let mut bytes_before = if ev.is_site() {
                            // A whole site's served total: its funnel
                            // members plus its DTN members.
                            let funnel: u64 = site_nodes(node)
                                .map(|n| served_totals[n].load(Ordering::Relaxed))
                                .sum();
                            let dtns: u64 = site_dtns(node)
                                .map(|d| dtn_served_totals[d].load(Ordering::Relaxed))
                                .sum();
                            funnel + dtns
                        } else if ev.is_dtn() {
                            dtn_served_totals[node].load(Ordering::Relaxed)
                        } else {
                            served_totals[node].load(Ordering::Relaxed)
                        };
                        // A recovering node's fresh file server must be
                        // listening BEFORE the router routes to it again.
                        // Recovering a node that never died is a no-op on
                        // the router side — don't crash its healthy
                        // server by replacing it.
                        if matches!(ev, FaultEvent::RecoverNode { .. }) {
                            let (handles, was_failed) = {
                                let (lock, _) = &*gate;
                                let g = lock.lock().unwrap();
                                (g.router.handles(node), g.router.is_failed(node))
                            };
                            if was_failed
                                && !restart_server(
                                    ServerRole::Funnel,
                                    &files,
                                    &key,
                                    handles,
                                    chunk_words,
                                    &addrs,
                                    &servers,
                                    node,
                                )
                            {
                                continue;
                            }
                        }
                        // Same rule for a recovering data node.
                        if matches!(ev, FaultEvent::RecoverDtn { .. }) {
                            let was_failed = {
                                let (lock, _) = &*gate;
                                lock.lock().unwrap().router.is_dtn_failed(node)
                            };
                            if was_failed
                                && !restart_server(
                                    ServerRole::Dtn,
                                    &files,
                                    &key,
                                    dtn_handles[node].clone(),
                                    chunk_words,
                                    &dtn_addrs,
                                    &dtn_servers,
                                    node,
                                )
                            {
                                continue;
                            }
                        }
                        // A recovering site restarts every dead member
                        // server — funnel nodes and DTNs — BEFORE the
                        // router un-poisons the site and routes to it
                        // again (same restart-before-unpoison protocol
                        // as the single-endpoint recoveries above).
                        if matches!(ev, FaultEvent::RecoverSite { .. }) {
                            let mut ok = true;
                            for n in site_nodes(node) {
                                let (handles, was_failed) = {
                                    let (lock, _) = &*gate;
                                    let g = lock.lock().unwrap();
                                    (g.router.handles(n), g.router.is_failed(n))
                                };
                                if was_failed
                                    && !restart_server(
                                        ServerRole::Funnel,
                                        &files,
                                        &key,
                                        handles,
                                        chunk_words,
                                        &addrs,
                                        &servers,
                                        n,
                                    )
                                {
                                    ok = false;
                                }
                            }
                            for d in site_dtns(node) {
                                let was_failed = {
                                    let (lock, _) = &*gate;
                                    lock.lock().unwrap().router.is_dtn_failed(d)
                                };
                                if was_failed
                                    && !restart_server(
                                        ServerRole::Dtn,
                                        &files,
                                        &key,
                                        dtn_handles[d].clone(),
                                        chunk_words,
                                        &dtn_addrs,
                                        &dtn_servers,
                                        d,
                                    )
                                {
                                    ok = false;
                                }
                            }
                            if !ok {
                                continue;
                            }
                        }
                        // Router-side half, shared verbatim with the sim
                        // engine: poison/drain/re-source, un-poison, or
                        // re-rate, plus threshold work-stealing.
                        let admitted = {
                            let (lock, cv) = &*gate;
                            let mut g = lock.lock().unwrap();
                            let admitted = apply_to_router(&ev, &mut g.router, threshold);
                            for a in &admitted {
                                g.ready.insert(a.ticket, *a);
                            }
                            cv.notify_all();
                            admitted.len()
                        };
                        // A killed node's server crashes AFTER the router
                        // is poisoned, so failing workers find their
                        // tickets already re-routed when they retry; a
                        // killed data node likewise, with its tickets
                        // already re-sourced.
                        if matches!(ev, FaultEvent::KillNode { .. }) {
                            bytes_before +=
                                crash_server(&servers, &served_totals, &wire_totals, node);
                        }
                        if matches!(ev, FaultEvent::KillDtn { .. }) {
                            bytes_before += crash_server(
                                &dtn_servers,
                                &dtn_served_totals,
                                &dtn_wire_totals,
                                node,
                            );
                        }
                        // A killed site crashes every member server,
                        // DTNs first (they carry the payload), after the
                        // router has already poisoned the whole site and
                        // re-sourced its in-flight tickets.
                        if matches!(ev, FaultEvent::KillSite { .. }) {
                            for d in site_dtns(node) {
                                bytes_before += crash_server(
                                    &dtn_servers,
                                    &dtn_served_totals,
                                    &dtn_wire_totals,
                                    d,
                                );
                            }
                            for n in site_nodes(node) {
                                bytes_before +=
                                    crash_server(&servers, &served_totals, &wire_totals, n);
                            }
                        }
                        chaos_log.lock().unwrap().record(
                            node,
                            ev.label(),
                            ev.at(),
                            t0.elapsed().as_secs_f64(),
                            admitted,
                            bytes_before,
                        );
                    }
                })
                .context("spawn chaos controller")?,
        )
    };

    // (times, payload bytes, wire bytes, errors)
    let stats = Arc::new(Mutex::new((OnlineStats::new(), 0u64, 0u64, 0u32)));
    // The site×site goodput matrix, flat row-major (src × n_sites +
    // dst), accumulated lock-free by workers as their transfers verify.
    let site_matrix: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_sites * n_sites).map(|_| AtomicU64::new(0)).collect());
    let n_workers = cfg.workers.max(1) as usize;
    let mut worker_threads = Vec::new();
    for w in 0..cfg.workers {
        let queue = queue.clone();
        let stats = stats.clone();
        let key = pool_key.clone();
        let gate = gate.clone();
        let state = state.clone();
        let pending = pending.clone();
        let cycle_size = cfg.cycle_size;
        let addrs = addrs.clone();
        let dtn_addrs = dtn_addrs.clone();
        let out_bytes = cfg.output_bytes;
        let site_matrix = site_matrix.clone();
        // The worker fleet partitions into sites exactly like the
        // endpoint fleets: worker w is site_of_member(w, workers, sites)
        // — the destination row of every byte it pulls.
        let worker_site = site_of_member(w as usize, n_workers, n_sites);
        worker_threads.push(std::thread::spawn(move || {
            let mut rng = Prng::new(0xBEEF_0000 + w as u64);
            let output = vec![0xA5u8; out_bytes];
            loop {
                let job = queue.lock().unwrap().pop();
                let Some(job) = job else { break };
                let ticket = job.id.proc;

                // Routing + admission: request, then wait until some
                // node's policy admits this ticket (it may admit other
                // tickets first). A ticket stranded with every node dead
                // gives up after ~30 s instead of wedging the pool —
                // same backstop as the mid-transfer retry path below.
                let (lock, cv) = &*gate;
                let mut req = TransferRequest::new(ticket, job.owner.clone(), job.input_bytes.0);
                req.extent = job.input_extent;
                // Park the request; the gate holder below drains the
                // whole buffer (this request plus any other workers'
                // parked ones) through the batched cycle API.
                pending.lock().unwrap().push(req);
                let admission = {
                    let mut g = lock.lock().unwrap();
                    let backlog: Vec<TransferRequest> =
                        pending.lock().unwrap().drain(..).collect();
                    if !backlog.is_empty() {
                        let chunk = if cycle_size == 0 {
                            backlog.len()
                        } else {
                            cycle_size.max(1)
                        };
                        for cycle in backlog.chunks(chunk) {
                            for a in g.router.route_batch(cycle.to_vec()) {
                                g.ready.insert(a.ticket, a);
                            }
                        }
                        cv.notify_all();
                    }
                    let mut strand_waits = 0u32;
                    loop {
                        if let Some(ns) = g.ready.remove(&ticket) {
                            break Some(ns);
                        }
                        if state.node_of(ticket).is_some() {
                            // Queued on a live node: the admission will
                            // be signalled as the pool drains.
                            strand_waits = 0;
                            g = cv.wait(g).unwrap();
                        } else {
                            strand_waits += 1;
                            if strand_waits >= 600 {
                                break None; // stranded ~30 s
                            }
                            let (g2, _) = cv
                                .wait_timeout(
                                    g,
                                    std::time::Duration::from_millis(50),
                                )
                                .unwrap();
                            g = g2;
                        }
                    }
                };
                let Some(mut routed) = admission else {
                    // Every node dead and nothing recovered: fail the
                    // job and cancel its stranded request.
                    {
                        let mut g = lock.lock().unwrap();
                        for a in g.router.complete(ticket) {
                            g.ready.insert(a.ticket, a);
                        }
                        cv.notify_all();
                    }
                    log::error!("job {} stranded: every submit node is down", job.id);
                    stats.lock().unwrap().3 += 1;
                    continue;
                };

                // Run the job against its data source, retrying through
                // the router when the serving endpoint — the scheduling
                // node's funnel OR its data node — is killed
                // mid-transfer: the failure shows up as a socket error,
                // the router has already re-routed / re-sourced the
                // ticket, and the worker waits for its new placement and
                // reconnects there.
                let mut attempts = 0u32;
                let result = loop {
                    let addr = match routed.source {
                        DataSource::Funnel { node } => addrs.lock().unwrap()[node],
                        DataSource::Dtn { dtn } => dtn_addrs.lock().unwrap()[dtn],
                    };
                    match run_job(addr, &key, &job.input_file, &output, routed.shard, &mut rng)
                    {
                        Ok(ok) => break Ok(ok),
                        Err(e) => {
                            attempts += 1;
                            let mut g = lock.lock().unwrap();
                            // The failure is retryable when the router
                            // moved this ticket off the endpoint we just
                            // failed against (its node or DTN died —
                            // even if it has since recovered).
                            // Probes go through the sharded state handle
                            // (one shard lock each); holding the gate
                            // keeps them serialized with the chaos
                            // thread, exactly like the old router reads.
                            let rerouted = g.ready.contains_key(&ticket)
                                || match routed.source {
                                    DataSource::Funnel { node } => {
                                        state.is_node_down(node)
                                            || state
                                                .node_of(ticket)
                                                .is_some_and(|n| n != node)
                                    }
                                    DataSource::Dtn { dtn } => {
                                        state.is_dtn_down(dtn)
                                            || state
                                                .source_of(ticket)
                                                .is_some_and(|s| s != routed.source)
                                    }
                                };
                            if attempts >= 5 || !rerouted {
                                // Not a node failure (or too many): final.
                                break Err(e);
                            }
                            // Wait for the re-admission. A ticket still
                            // queued on a live node WILL be admitted as
                            // the pool drains, so only a stranded ticket
                            // (every node dead, no recovery in ~30 s) —
                            // or a pathological wedge — gives up.
                            let mut total_waits = 0u32;
                            let mut strand_waits = 0u32;
                            let next = loop {
                                if let Some(ns) = g.ready.remove(&ticket) {
                                    break Some(ns);
                                }
                                if state.node_of(ticket).is_some() {
                                    strand_waits = 0;
                                } else {
                                    strand_waits += 1;
                                    if strand_waits >= 600 {
                                        break None; // stranded ~30 s
                                    }
                                }
                                total_waits += 1;
                                if total_waits >= 36_000 {
                                    break None; // 30 min anti-wedge backstop
                                }
                                let (g2, _) = cv
                                    .wait_timeout(
                                        g,
                                        std::time::Duration::from_millis(50),
                                    )
                                    .unwrap();
                                g = g2;
                            };
                            drop(g);
                            match next {
                                Some(r2) => routed = r2,
                                None => break Err(e),
                            }
                        }
                    }
                };

                {
                    let mut g = lock.lock().unwrap();
                    // Scrub any re-source that raced this completion so
                    // it can't sit in `ready` forever.
                    g.ready.remove(&ticket);
                    for a in g.router.complete(ticket) {
                        g.ready.insert(a.ticket, a);
                    }
                    cv.notify_all();
                }

                match result {
                    Ok((st, secs)) => {
                        // `routed` is the placement the successful
                        // attempt actually fetched from (retries update
                        // it), so its source names the serving site.
                        let src_site = match routed.source {
                            DataSource::Funnel { node } => {
                                site_of_member(node, n_nodes, n_sites)
                            }
                            DataSource::Dtn { dtn } => site_of_member(dtn, n_dtns, n_sites),
                        };
                        site_matrix[src_site * n_sites + worker_site]
                            .fetch_add(st.payload_bytes, Ordering::Relaxed);
                        let mut s = stats.lock().unwrap();
                        s.0.push(secs);
                        s.1 += st.payload_bytes;
                        s.2 += st.wire_bytes;
                    }
                    Err(e) => {
                        log::error!("job {} failed: {e:#}", job.id);
                        stats.lock().unwrap().3 += 1;
                    }
                }
            }
        }));
    }
    for t in worker_threads {
        t.join().map_err(|_| anyhow!("worker thread panicked"))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    burst_done.store(true, Ordering::Relaxed);
    if let Some(t) = chaos_thread {
        t.join().map_err(|_| anyhow!("chaos thread panicked"))?;
    }
    stop_fleet(&servers, &served_totals, &wire_totals);
    stop_fleet(&dtn_servers, &dtn_served_totals, &dtn_wire_totals);
    let load_all = |v: &[AtomicU64]| -> Vec<u64> {
        v.iter().map(|t| t.load(Ordering::Relaxed)).collect()
    };
    let bytes_served_per_node = load_all(&served_totals);
    let bytes_served_per_dtn = load_all(&dtn_served_totals);
    let wire_bytes_per_node = load_all(&wire_totals);
    let wire_bytes_per_dtn = load_all(&dtn_wire_totals);

    let (times, bytes, wire, errors) = {
        let s = stats.lock().unwrap();
        (s.0.clone(), s.1, s.2, s.3)
    };
    let router = Arc::try_unwrap(gate)
        .map_err(|_| anyhow!("admission gate still referenced after join"))?
        .0
        .into_inner()
        .map_err(|_| anyhow!("admission gate poisoned"))?
        .router;
    let chaos = Arc::try_unwrap(chaos_log)
        .map_err(|_| anyhow!("chaos timeline still referenced after join"))?
        .into_inner()
        .map_err(|_| anyhow!("chaos timeline poisoned"))?;
    let report = RealPoolReport {
        jobs_completed: cfg.n_jobs - errors,
        total_payload_bytes: bytes,
        total_wire_bytes: wire,
        wall_secs: wall,
        gbps: bytes as f64 * 8.0 / wall / 1e9,
        transfer_secs: times,
        engine_desc,
        errors,
        mover: router.stats(),
        source_plan: router.source_plan().label(),
        source_selector: router.source_selector().label().to_string(),
        n_sites,
        site_matrix_bytes: (0..n_sites)
            .map(|s| {
                (0..n_sites)
                    .map(|d| site_matrix[s * n_sites + d].load(Ordering::Relaxed))
                    .collect()
            })
            .collect(),
        solver: "real-tcp".to_string(),
        router: router.router_stats(),
        bytes_served_per_node,
        bytes_served_per_dtn,
        wire_bytes_per_node,
        wire_bytes_per_dtn,
        chaos,
    };
    Ok((report, router))
}

/// Knobs for a real-fabric durable-task run ([`run_real_task`]).
///
/// Deliberately smaller than [`RealPoolConfig`]: the dataset comes from
/// the task itself (one deterministic synthetic file per
/// [`FileEntry`](crate::mover::task::FileEntry), not a shared
/// hard-linked extent), pacing/deadline/concurrency come from the
/// [`TaskRunner`], and the chaos hook is a coordinator kill rather than
/// a fault schedule.
#[derive(Debug, Clone)]
pub struct RealTaskConfig {
    /// Worker threads pulling admitted files. Effective transfer
    /// parallelism is `min(workers, task concurrency)` — the runner's
    /// admission cap is the binding knob; workers are just executors.
    pub workers: u32,
    /// Server-side default send chunking (words). Workers negotiate a
    /// per-connection chunk at the shard announcement (wire format v2),
    /// proposing the [`TaskRunner`]'s current `chunk_words` — so the
    /// auto-tuner's chunk moves apply on the real fabric too, and this
    /// value only serves v1 peers and invalid proposals.
    pub chunk_words: usize,
    /// Use the PJRT artifact engine for sealing (falls back to native).
    pub use_xla_engine: bool,
    pub passphrase: String,
    /// Shadow shards per endpoint (funnel node and DTN alike).
    pub shadows: u32,
    pub n_submit_nodes: u32,
    pub router: RouterPolicy,
    /// Data-transfer-node fleet size (0 = funnel-only).
    pub data_nodes: u32,
    pub source: SourcePlan,
    pub source_selector: SourceSelector,
    pub dtn_slots: u32,
    pub dtn_queue_depth: u32,
    /// Chaos hook: kill the coordinator after this many files complete
    /// *this run* — workers stop immediately, in-flight transfers are
    /// abandoned uncheckpointed and the fleet shuts down. A fresh
    /// [`TaskRunner`] over the same journal resumes from the last
    /// checkpoint without re-transferring completed files.
    pub kill_after_files: Option<usize>,
}

impl Default for RealTaskConfig {
    fn default() -> Self {
        RealTaskConfig {
            workers: 4,
            chunk_words: crate::transfer::stream::DEFAULT_CHUNK_WORDS,
            use_xla_engine: false,
            passphrase: "htcdm-task".into(),
            shadows: 1,
            n_submit_nodes: 1,
            router: RouterPolicy::LeastLoaded,
            data_nodes: 0,
            source: SourcePlan::SubmitFunnel,
            source_selector: SourceSelector::RoundRobin,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            kill_after_files: None,
        }
    }
}

/// Results of one real-fabric task run — one coordinator lifetime. A
/// killed run reports how far it got; the resumed run's
/// `bytes_served_per_node` / `bytes_served_per_dtn` totals prove that
/// checkpointed files were never re-transferred (only the remaining
/// files' bytes hit the wire).
#[derive(Debug)]
pub struct RealTaskReport {
    /// Task progress at shutdown (includes files resumed from the
    /// journal, which this run never moved).
    pub progress: TaskProgress,
    /// Auto-tuner trajectory (empty without `AUTOTUNE`).
    pub tuner: Vec<TunerSample>,
    pub wall_secs: f64,
    pub errors: u32,
    /// Files completed AND checkpointed this run (excludes resumed).
    pub files_transferred: u32,
    /// Payload bytes received and verified by workers this run.
    pub payload_bytes: u64,
    /// Wire bytes workers received fetching those payloads (payload
    /// plus stream headers, frame heads and digests).
    pub wire_bytes: u64,
    pub mover: MoverStats,
    pub router: RouterStats,
    pub bytes_served_per_node: Vec<u64>,
    pub bytes_served_per_dtn: Vec<u64>,
    /// Per-endpoint wire bytes (payload plus framing, both directions;
    /// same indexing as the `bytes_served_*` fields).
    pub wire_bytes_per_node: Vec<u64>,
    pub wire_bytes_per_dtn: Vec<u64>,
    /// True when `kill_after_files` fired — the run ended as a
    /// simulated coordinator crash, not by draining the task.
    pub killed: bool,
}

/// Drive a [`TaskRunner`] through the real TCP loopback fabric: the
/// same durable-task object the simulator runs
/// (`coordinator::engine::run_task_sim`), here moving real sealed
/// bytes. Each admitted file is routed through the pool router, fetched
/// with [`run_job_fetch_digest`] — which negotiates the tuner's current
/// chunk size onto the wire and folds the in-crate SHA-256 over each
/// verified frame as it arrives — and only then checkpointed done, so a
/// resumed task re-verifies nothing and re-transfers nothing that
/// already landed.
///
/// Returns the report and the runner (whose journal holds the final
/// checkpoint) so callers can resume, inspect or re-run it.
pub fn run_real_task(
    cfg: &RealTaskConfig,
    runner: TaskRunner,
) -> Result<(RealTaskReport, TaskRunner)> {
    let pool_key = PoolKey::from_passphrase(&cfg.passphrase);
    let n_nodes = cfg.n_submit_nodes.max(1) as usize;
    let nodes: Vec<ShadowPool> = (0..n_nodes)
        .map(|_| {
            ShadowPool::sim(
                cfg.shadows.max(1),
                AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            )
        })
        .collect();
    let mut router = PoolRouter::from_config(
        nodes,
        vec![1.0; n_nodes],
        cfg.router,
        RouterConfig {
            source_plan: cfg.source,
            dtn_capacity: vec![1.0; cfg.data_nodes as usize],
            source_selector: cfg.source_selector,
            dtn_slots: cfg.dtn_slots,
            dtn_queue_depth: cfg.dtn_queue_depth,
            ..RouterConfig::default()
        },
    );
    router.ensure_engines(shard_engine_factory(cfg.use_xla_engine));
    if let Err(e) = router.source_plan().validate(router.dtn_count()) {
        bail!("invalid source plan: {e}");
    }

    // The task's dataset: one deterministic synthetic file per entry,
    // content keyed by file name so both fabrics (and both sides of a
    // kill/resume boundary) agree on every file's bytes and hash.
    let owner = runner.task().owner.clone();
    let file_meta: Vec<(String, u64, Option<crate::storage::ExtentId>)> = runner
        .task()
        .files
        .iter()
        .map(|f| (f.name.clone(), f.bytes, f.extent))
        .collect();
    let mut files: HashMap<String, Arc<Vec<u8>>> = HashMap::new();
    for (name, bytes, _) in &file_meta {
        files.insert(name.clone(), Arc::new(synth_file_bytes(name, *bytes)));
    }

    let mut server_vec: Vec<Option<FileServer>> = Vec::with_capacity(n_nodes);
    for node in 0..n_nodes {
        server_vec.push(Some(FileServer::start(
            files.clone(),
            pool_key.clone(),
            router.handles(node),
            cfg.chunk_words,
        )?));
    }
    let addrs: Arc<Mutex<Vec<std::net::SocketAddr>>> = Arc::new(Mutex::new(
        server_vec
            .iter()
            .map(|s| s.as_ref().expect("just started").addr)
            .collect(),
    ));
    let servers: Arc<Mutex<Vec<Option<FileServer>>>> = Arc::new(Mutex::new(server_vec));
    let served_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_nodes).map(|_| AtomicU64::new(0)).collect());
    let wire_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_nodes).map(|_| AtomicU64::new(0)).collect());

    // DTN fleet, only when the plan can reach it (no fault schedule
    // here — the task layer's chaos hook is the coordinator kill).
    let n_dtns = if router.source_plan().uses_dtns() {
        router.dtn_count()
    } else {
        0
    };
    let mut dtn_services: Vec<EngineService> = Vec::new();
    let mut dtn_handles: Vec<Vec<EngineHandle>> = Vec::with_capacity(n_dtns);
    for _ in 0..n_dtns {
        let mut handles = Vec::with_capacity(cfg.shadows.max(1) as usize);
        for _ in 0..cfg.shadows.max(1) {
            let svc = EngineService::spawn({
                let f = shard_engine_factory(cfg.use_xla_engine);
                move || f(0)
            });
            handles.push(svc.handle());
            dtn_services.push(svc);
        }
        dtn_handles.push(handles);
    }
    let mut dtn_server_vec: Vec<Option<FileServer>> = Vec::with_capacity(n_dtns);
    for handles in &dtn_handles {
        dtn_server_vec.push(Some(FileServer::start_with_role(
            ServerRole::Dtn,
            files.clone(),
            pool_key.clone(),
            handles.clone(),
            cfg.chunk_words,
        )?));
    }
    let dtn_addrs: Arc<Mutex<Vec<std::net::SocketAddr>>> = Arc::new(Mutex::new(
        dtn_server_vec
            .iter()
            .map(|s| s.as_ref().expect("just started").addr)
            .collect(),
    ));
    let dtn_servers: Arc<Mutex<Vec<Option<FileServer>>>> = Arc::new(Mutex::new(dtn_server_vec));
    let dtn_served_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_dtns).map(|_| AtomicU64::new(0)).collect());
    let dtn_wire_totals: Arc<Vec<AtomicU64>> =
        Arc::new((0..n_dtns).map(|_| AtomicU64::new(0)).collect());

    let gate = Arc::new((
        Mutex::new(GateState {
            router,
            ready: HashMap::new(),
        }),
        Condvar::new(),
    ));
    // The coordinator state every worker shares: the runner (admission
    // pacing, checkpoints, tuner), the admitted-but-unclaimed file
    // queue, and the kill switch. Lock order: gate, then runner; a
    // worker never holds both (admission uses the gate, checkpointing
    // uses the runner).
    let runner = Arc::new(Mutex::new(runner));
    let admitted: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let done_this_run = Arc::new(AtomicU64::new(0));
    let payload_total = Arc::new(AtomicU64::new(0));
    let wire_total = Arc::new(AtomicU64::new(0));
    let errors_total = Arc::new(AtomicU64::new(0));
    let t0 = std::time::Instant::now();

    let mut worker_threads = Vec::new();
    for w in 0..cfg.workers.max(1) {
        let runner = runner.clone();
        let admitted = admitted.clone();
        let stop = stop.clone();
        let done_this_run = done_this_run.clone();
        let payload_total = payload_total.clone();
        let wire_total = wire_total.clone();
        let errors_total = errors_total.clone();
        let gate = gate.clone();
        let addrs = addrs.clone();
        let dtn_addrs = dtn_addrs.clone();
        let key = pool_key.clone();
        let owner = owner.clone();
        let file_meta = file_meta.clone();
        let kill_after = cfg.kill_after_files;
        worker_threads.push(std::thread::spawn(move || {
            let mut rng = Prng::new(0x7A53_0000 + w as u64);
            // Tiny fixed "job output" — the task layer moves input
            // sandboxes; the return stream is just the protocol's ack.
            let output = vec![0x5Au8; 64];
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let now = t0.elapsed().as_secs_f64();
                let next = admitted.lock().unwrap().pop();
                let idx = match next {
                    Some(i) => i,
                    None => {
                        let (fresh, finished) = {
                            let mut r = runner.lock().unwrap();
                            r.observe_window(now);
                            let fresh = r.next_files(now);
                            (fresh, r.done() || r.deadline_exceeded())
                        };
                        if fresh.is_empty() {
                            if finished {
                                break;
                            }
                            // Rate-paced, or peers hold the in-flight
                            // files: wait for admission or a retry.
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            continue;
                        }
                        let mut q = admitted.lock().unwrap();
                        q.extend(fresh);
                        match q.pop() {
                            Some(i) => i,
                            None => continue,
                        }
                    }
                };
                let (name, bytes, extent) = file_meta[idx].clone();
                let ticket = idx as u32;

                // Route + wait for admission. No fault schedule runs
                // here, so a ticket unadmitted after ~30 s is stranded:
                // cancel it and send the file back to pending.
                let (lock, cv) = &*gate;
                let admission = {
                    let mut g = lock.lock().unwrap();
                    let mut req = TransferRequest::new(ticket, owner.clone(), bytes);
                    req.extent = extent;
                    for a in g.router.route_batch(vec![req]) {
                        g.ready.insert(a.ticket, a);
                    }
                    cv.notify_all();
                    let mut waits = 0u32;
                    loop {
                        if let Some(r) = g.ready.remove(&ticket) {
                            break Some(r);
                        }
                        if stop.load(Ordering::Relaxed) || waits >= 600 {
                            break None;
                        }
                        waits += 1;
                        let (g2, _) = cv
                            .wait_timeout(g, std::time::Duration::from_millis(50))
                            .unwrap();
                        g = g2;
                    }
                };
                let Some(routed) = admission else {
                    {
                        let mut g = lock.lock().unwrap();
                        g.ready.remove(&ticket);
                        for a in g.router.complete(ticket) {
                            g.ready.insert(a.ticket, a);
                        }
                        cv.notify_all();
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    log::error!("task file {name} stranded waiting for admission");
                    errors_total.fetch_add(1, Ordering::Relaxed);
                    let _ = runner.lock().unwrap().file_failed(idx);
                    continue;
                };

                let addr = match routed.source {
                    DataSource::Funnel { node } => addrs.lock().unwrap()[node],
                    DataSource::Dtn { dtn } => dtn_addrs.lock().unwrap()[dtn],
                };
                // Propose the runner's *current* chunk size: the tuner's
                // chunk moves reach the wire through v2 negotiation.
                let chunk = runner.lock().unwrap().chunk_words();
                let result = run_job_fetch_digest(
                    addr,
                    &key,
                    &name,
                    &output,
                    routed.shard,
                    ChunkProposal::Words(chunk),
                    &mut rng,
                );
                {
                    let mut g = lock.lock().unwrap();
                    g.ready.remove(&ticket);
                    for a in g.router.complete(ticket) {
                        g.ready.insert(a.ticket, a);
                    }
                    cv.notify_all();
                }
                if stop.load(Ordering::Relaxed) {
                    // Coordinator killed while this transfer was on the
                    // wire: abandon it uncheckpointed — the resumed run
                    // re-transfers it (never the checkpointed ones).
                    break;
                }
                match result {
                    Ok((digest, st, _secs)) => {
                        // The digest was folded in frame by frame during
                        // the receive — no second pass over the payload.
                        let now = t0.elapsed().as_secs_f64();
                        let done = runner.lock().unwrap().file_done(idx, &digest, now);
                        match done {
                            Ok(()) => {
                                payload_total.fetch_add(st.payload_bytes, Ordering::Relaxed);
                                wire_total.fetch_add(st.wire_bytes, Ordering::Relaxed);
                                let n = done_this_run.fetch_add(1, Ordering::Relaxed) + 1;
                                if kill_after == Some(n as usize) {
                                    stop.store(true, Ordering::Relaxed);
                                    cv.notify_all();
                                }
                            }
                            Err(e) => {
                                log::error!("task file {name} checkpoint failed: {e:#}");
                                errors_total.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Err(e) => {
                        log::error!("task file {name} transfer failed: {e:#}");
                        errors_total.fetch_add(1, Ordering::Relaxed);
                        let _ = runner.lock().unwrap().file_failed(idx);
                    }
                }
            }
        }));
    }
    for t in worker_threads {
        t.join().map_err(|_| anyhow!("task worker thread panicked"))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    stop_fleet(&servers, &served_totals, &wire_totals);
    stop_fleet(&dtn_servers, &dtn_served_totals, &dtn_wire_totals);
    drop(dtn_services);
    let load_all = |v: &[AtomicU64]| -> Vec<u64> {
        v.iter().map(|t| t.load(Ordering::Relaxed)).collect()
    };
    let bytes_served_per_node = load_all(&served_totals);
    let bytes_served_per_dtn = load_all(&dtn_served_totals);
    let wire_bytes_per_node = load_all(&wire_totals);
    let wire_bytes_per_dtn = load_all(&dtn_wire_totals);

    let router = Arc::try_unwrap(gate)
        .map_err(|_| anyhow!("admission gate still referenced after join"))?
        .0
        .into_inner()
        .map_err(|_| anyhow!("admission gate poisoned"))?
        .router;
    let runner = Arc::try_unwrap(runner)
        .map_err(|_| anyhow!("task runner still referenced after join"))?
        .into_inner()
        .map_err(|_| anyhow!("task runner poisoned"))?;
    let report = RealTaskReport {
        progress: runner.progress(),
        tuner: runner.tuner_trajectory().to_vec(),
        wall_secs: wall,
        errors: errors_total.load(Ordering::Relaxed) as u32,
        files_transferred: done_this_run.load(Ordering::Relaxed) as u32,
        payload_bytes: payload_total.load(Ordering::Relaxed),
        wire_bytes: wire_total.load(Ordering::Relaxed),
        mover: router.stats(),
        router: router.router_stats(),
        bytes_served_per_node,
        bytes_served_per_dtn,
        wire_bytes_per_node,
        wire_bytes_per_dtn,
        killed: stop.load(Ordering::Relaxed),
    };
    Ok((report, runner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::service::EngineService;

    fn base_cfg() -> RealPoolConfig {
        RealPoolConfig {
            n_jobs: 8,
            workers: 2,
            input_bytes: 256 << 10,
            output_bytes: 1024,
            chunk_words: 1024, // 4 KiB frames keep the test quick
            use_xla_engine: false,
            passphrase: "test".into(),
            shadows: 1,
            policy: AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            n_submit_nodes: 1,
            router: RouterPolicy::LeastLoaded,
            node_capacities: Vec::new(),
            data_nodes: 0,
            source: SourcePlan::SubmitFunnel,
            source_selector: SourceSelector::RoundRobin,
            n_sites: 1,
            site_selector: SiteSelector::LocalFirst,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            router_shards: crate::mover::DEFAULT_ROUTER_SHARDS,
            cycle_size: 0,
            faults: FaultPlan::default(),
        }
    }

    #[test]
    fn real_pool_native_roundtrip() {
        let r = run_real_pool(base_cfg()).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.total_payload_bytes, 8 * (256 << 10) as u64);
        assert!(
            r.total_wire_bytes > r.total_payload_bytes,
            "wire bytes include framing: {} vs {}",
            r.total_wire_bytes,
            r.total_payload_bytes
        );
        let node_wire: u64 = r.wire_bytes_per_node.iter().sum();
        assert!(
            node_wire >= r.total_wire_bytes,
            "server wire ({node_wire}) covers at least the input streams"
        );
        assert!(r.gbps > 0.0);
        assert_eq!(r.transfer_secs.count(), 8);
        assert_eq!(r.mover.total_admitted, 8);
        assert_eq!(r.mover.released_without_active, 0);
        // Unfederated: the matrix collapses to a 1×1 total.
        assert_eq!(r.n_sites, 1);
        assert_eq!(r.site_matrix_bytes, vec![vec![8 * (256 << 10) as u64]]);
    }

    #[test]
    fn real_pool_federated_site_matrix_accounts_every_byte() {
        // 2 sites × (1 submit node + 1 DTN), round-robin site selection:
        // each site sources half the burst, and every verified payload
        // byte lands in exactly one site×site cell.
        let mut cfg = base_cfg();
        cfg.n_submit_nodes = 2;
        cfg.data_nodes = 2;
        cfg.source = SourcePlan::DedicatedDtn;
        cfg.n_sites = 2;
        cfg.site_selector = SiteSelector::RoundRobin;
        cfg.workers = 2;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.n_sites, 2);
        assert_eq!(r.site_matrix_bytes.len(), 2);
        assert!(r.site_matrix_bytes.iter().all(|row| row.len() == 2));
        let total: u64 = r.site_matrix_bytes.iter().flatten().sum();
        assert_eq!(total, 8 * (256 << 10) as u64, "every byte in some cell");
        for s in 0..2 {
            assert!(
                r.site_matrix_bytes[s].iter().sum::<u64>() > 0,
                "site {s} sourced nothing under round-robin: {:?}",
                r.site_matrix_bytes
            );
        }
    }

    #[test]
    fn real_pool_multi_shard_routes_across_engines() {
        let mut cfg = base_cfg();
        cfg.shadows = 3;
        cfg.workers = 3;
        cfg.n_jobs = 9;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 9);
        assert_eq!(r.mover.admitted_per_shard.len(), 3);
        let total: u64 = r.mover.admitted_per_shard.iter().sum();
        assert_eq!(total, 9, "every job routed through some shard");
        assert!(r.engine_desc.contains("x3"), "{}", r.engine_desc);
    }

    #[test]
    fn real_pool_multi_submit_nodes_round_robin() {
        let mut cfg = base_cfg();
        cfg.n_submit_nodes = 2;
        cfg.router = RouterPolicy::RoundRobin;
        cfg.workers = 4;
        cfg.n_jobs = 8;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.router.routed_per_node, vec![4, 4], "±0 split of 8 jobs");
        assert_eq!(r.bytes_served_per_node.len(), 2);
        // Each node's file server really moved its share of the bytes.
        let served: u64 = r.bytes_served_per_node.iter().sum();
        assert_eq!(served, 8 * (256 << 10) as u64);
        for (node, &b) in r.bytes_served_per_node.iter().enumerate() {
            assert_eq!(b, 4 * (256 << 10) as u64, "node {node} served its half");
        }
        assert!(r.engine_desc.contains("x2 nodes"), "{}", r.engine_desc);
        assert_eq!(r.mover.shard_failed, 0);
    }

    #[test]
    fn real_pool_weighted_by_capacity_splits_3_to_1() {
        let mut cfg = base_cfg();
        cfg.n_submit_nodes = 2;
        cfg.router = RouterPolicy::WeightedByCapacity;
        cfg.node_capacities = vec![3.0, 1.0];
        cfg.workers = 4;
        cfg.n_jobs = 8;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(
            r.router.routed_per_node,
            vec![6, 2],
            "deficit round-robin tracks the 3:1 budget"
        );
    }

    #[test]
    fn real_pool_rejects_mismatched_capacities() {
        let mut cfg = base_cfg();
        cfg.n_submit_nodes = 2;
        cfg.node_capacities = vec![1.0, 2.0, 3.0];
        assert!(run_real_pool(cfg).is_err());
    }

    #[test]
    fn real_pool_enforces_admission_limit() {
        let mut cfg = base_cfg();
        cfg.workers = 4;
        cfg.policy = AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(2));
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert!(
            r.mover.peak_active <= 2,
            "policy capped concurrency: peak {}",
            r.mover.peak_active
        );
    }

    #[test]
    fn real_pool_fair_share_policy_runs_clean() {
        let mut cfg = base_cfg();
        cfg.policy = AdmissionConfig::FairShare { limit: 2 };
        cfg.shadows = 2;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert!(r.mover.peak_active <= 2);
    }

    #[test]
    fn real_pool_dedicated_dtn_offloads_the_submit_server() {
        let mut cfg = base_cfg();
        cfg.data_nodes = 2;
        cfg.source = SourcePlan::DedicatedDtn;
        cfg.workers = 4;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.source_plan, "dedicated-dtn");
        // The submit node's server carried no payload — the fleet did.
        assert_eq!(r.bytes_served_per_node, vec![0]);
        assert_eq!(r.bytes_served_per_dtn.len(), 2);
        let dtn_served: u64 = r.bytes_served_per_dtn.iter().sum();
        assert_eq!(dtn_served, 8 * (256 << 10) as u64);
        // Round-robin placement across the fleet.
        assert_eq!(r.router.routed_per_dtn, vec![4, 4]);
        assert_eq!(r.router.dtn_failed, 0);
    }

    #[test]
    fn real_pool_hybrid_splits_by_size() {
        // 256 KiB inputs against a 1-byte threshold: everything rides
        // the DTN; against a huge threshold: everything rides the
        // funnel. (Uniform sizes: the boundary property lives in
        // tests/props.rs.)
        for (threshold, via_dtn) in [(1u64, true), (u64::MAX, false)] {
            let mut cfg = base_cfg();
            cfg.data_nodes = 1;
            cfg.source = SourcePlan::Hybrid { threshold };
            let r = run_real_pool(cfg).unwrap();
            assert_eq!(r.errors, 0, "threshold {threshold}");
            let dtn_served: u64 = r.bytes_served_per_dtn.iter().sum();
            let funnel_served: u64 = r.bytes_served_per_node.iter().sum();
            if via_dtn {
                assert_eq!(dtn_served, 8 * (256 << 10) as u64);
                assert_eq!(funnel_served, 0);
            } else {
                assert_eq!(dtn_served, 0);
                assert_eq!(funnel_served, 8 * (256 << 10) as u64);
            }
        }
    }

    #[test]
    fn real_pool_cache_aware_selector_homes_the_shared_extent() {
        // benchmark_burst hard-links every input name to ONE extent:
        // the first placement homes it on a data node and every later
        // transfer affines to the same node.
        let mut cfg = base_cfg();
        cfg.data_nodes = 2;
        cfg.source = SourcePlan::DedicatedDtn;
        cfg.source_selector = SourceSelector::CacheAware;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.source_selector, "cache-aware");
        assert_eq!(r.router.routed_per_dtn.iter().sum::<u64>(), 8);
        assert_eq!(
            r.router.routed_per_dtn.iter().filter(|&&c| c > 0).count(),
            1,
            "one extent, one home: {:?}",
            r.router.routed_per_dtn
        );
    }

    #[test]
    fn real_pool_dtn_budget_overflows_to_funnel() {
        // 4 workers pop their first jobs near-simultaneously against a
        // single 1-slot data node: the budget pushes the overlap onto
        // the funnel, whose server demonstrably serves payload.
        let mut cfg = base_cfg();
        cfg.data_nodes = 1;
        cfg.source = SourcePlan::DedicatedDtn;
        cfg.dtn_slots = 1;
        cfg.workers = 4;
        cfg.input_bytes = 2 << 20;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert!(
            r.mover.dtn_overflow_to_funnel > 0,
            "a 4-wide burst against one slot must overflow"
        );
        let funnel: u64 = r.bytes_served_per_node.iter().sum();
        let dtns: u64 = r.bytes_served_per_dtn.iter().sum();
        assert!(funnel > 0, "overflowed transfers rode the funnel");
        assert_eq!(funnel + dtns, 8 * (2 << 20) as u64, "nothing lost");
    }

    #[test]
    fn real_pool_rejects_dtn_plan_without_data_nodes() {
        let mut cfg = base_cfg();
        cfg.source = SourcePlan::DedicatedDtn;
        assert!(run_real_pool(cfg).is_err());
    }

    #[test]
    fn real_pool_dtn_degrade_records_timeline() {
        let mut cfg = base_cfg();
        cfg.data_nodes = 2;
        cfg.source = SourcePlan::DedicatedDtn;
        cfg.faults = FaultPlan::default().degrade_dtn(1, 0.0, 25.0);
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.chaos.count("degrade-dtn"), 1);
        assert_eq!(r.chaos.records[0].node, 1);
    }

    #[test]
    fn real_pool_rejects_out_of_range_fault_plan() {
        let mut cfg = base_cfg();
        cfg.faults = FaultPlan::default().kill(3, 0.1);
        let err = run_real_pool(cfg);
        assert!(err.is_err(), "node 3 does not exist in a 1-node pool");
    }

    #[test]
    fn real_pool_degrade_event_records_timeline() {
        // Degrade is the lightest chaos event (no server crash), so it
        // exercises the controller thread deterministically: it always
        // applies (at t=0) and always lands in the report's timeline.
        let mut cfg = base_cfg();
        cfg.n_submit_nodes = 2;
        cfg.router = RouterPolicy::WeightedByCapacity;
        cfg.node_capacities = vec![100.0, 100.0];
        cfg.faults = FaultPlan::default().degrade(1, 0.0, 25.0);
        cfg.workers = 2;
        cfg.n_jobs = 8;
        let r = run_real_pool(cfg).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.jobs_completed, 8);
        assert_eq!(r.chaos.count("degrade"), 1);
        assert_eq!(r.chaos.records[0].node, 1);
    }

    #[test]
    fn wrong_passphrase_fails_auth() {
        let key_good = PoolKey::from_passphrase("right");
        let files: HashMap<String, Arc<Vec<u8>>> =
            [("f".to_string(), Arc::new(vec![1u8; 1024]))].into();
        let svc = EngineService::spawn(|| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let mut server = FileServer::start(files, key_good, vec![svc.handle()], 256).unwrap();
        let bad = PoolKey::from_passphrase("wrong");
        let mut rng = Prng::new(1);
        let err = run_job(server.addr, &bad, "f", &[0u8; 16], 0, &mut rng);
        assert!(err.is_err(), "bad pool key must fail the handshake");
        server.stop();
    }

    #[test]
    fn chunk_negotiation_serves_v1_and_v2_clients() {
        // One server configured at 1024 words (4 KiB frames) serving a
        // 256 KiB file: the negotiated chunk is observable as the frame
        // count of the client's received stream.
        let key = PoolKey::from_passphrase("nego");
        let files: HashMap<String, Arc<Vec<u8>>> =
            [("f".to_string(), Arc::new(vec![7u8; 256 << 10]))].into();
        let svc = EngineService::spawn(|| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let mut server = FileServer::start(files, key.clone(), vec![svc.handle()], 1024).unwrap();
        let mut rng = Prng::new(7);
        let cases = [
            // v1 client: no negotiation, configured chunk.
            (ChunkProposal::Legacy, 1024usize),
            // v2 client deferring to the server: configured chunk.
            (ChunkProposal::ServerDefault, 1024),
            // v2 client proposing its own chunk: honored.
            (ChunkProposal::Words(256), 256),
            (ChunkProposal::Words(4096), 4096),
            // Invalid proposal (not a multiple of 16): server default.
            (ChunkProposal::Words(100), 1024),
        ];
        for (proposal, chunk) in cases {
            let (input, st, _) =
                run_job_fetch(server.addr, &key, "f", &[0u8; 16], 0, proposal, &mut rng).unwrap();
            assert_eq!(input.len(), 256 << 10, "{proposal:?}");
            let frames = ((256 << 10) / (chunk * 4)) as u64;
            assert_eq!(st.frames, frames, "{proposal:?} → {chunk} words");
            // Exact wire accounting: header + per-frame head and digest.
            let wire = 20 + frames * (8 + chunk as u64 * 4 + 16);
            assert_eq!(st.wire_bytes, wire, "{proposal:?}");
        }
        assert!(server.wire_bytes_served.load(Ordering::Relaxed) > 0);
        server.stop();
    }

    use crate::mover::task::{synth_file_sha256, TaskJournal, TransferTask};

    const TASK_FILE_BYTES: u64 = 256 << 10;

    fn task_cfg() -> RealTaskConfig {
        RealTaskConfig {
            workers: 2,
            chunk_words: 1024, // 4 KiB frames keep the test quick
            passphrase: "test".into(),
            ..RealTaskConfig::default()
        }
    }

    fn six_file_task(name: &str) -> TransferTask {
        TransferTask::new(name, "alice").with_uniform_files("input", 6, TASK_FILE_BYTES)
    }

    #[test]
    fn real_task_completes_and_verifies_every_file() {
        let runner =
            TaskRunner::new(six_file_task("tcp-task"), TaskJournal::memory()).unwrap();
        let (r, runner) = run_real_task(&task_cfg(), runner).unwrap();
        assert_eq!(r.errors, 0);
        assert!(!r.killed);
        assert!(runner.done());
        assert_eq!(r.progress.files_done, 6);
        assert_eq!(r.files_transferred, 6);
        assert_eq!(r.payload_bytes, 6 * TASK_FILE_BYTES);
        assert!(
            r.wire_bytes > r.payload_bytes,
            "wire bytes include framing: {} vs {}",
            r.wire_bytes,
            r.payload_bytes
        );
        assert_eq!(r.bytes_served_per_node.iter().sum::<u64>(), 6 * TASK_FILE_BYTES);
        assert!(r.wire_bytes_per_node.iter().sum::<u64>() >= r.wire_bytes);
        for i in 0..6 {
            let f = runner.file(i);
            assert_eq!(
                f.state,
                crate::mover::task::FileState::Done {
                    sha256: synth_file_sha256(&f.name, f.bytes)
                },
                "file {i} must verify against its deterministic content"
            );
        }
    }

    #[test]
    fn real_task_routes_bytes_through_dtn_fleet() {
        let mut cfg = task_cfg();
        cfg.data_nodes = 2;
        cfg.source = SourcePlan::DedicatedDtn;
        let runner =
            TaskRunner::new(six_file_task("tcp-task-dtn"), TaskJournal::memory()).unwrap();
        let (r, _runner) = run_real_task(&cfg, runner).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.progress.files_done, 6);
        assert_eq!(r.bytes_served_per_node.iter().sum::<u64>(), 0);
        assert_eq!(r.bytes_served_per_dtn.iter().sum::<u64>(), 6 * TASK_FILE_BYTES);
    }

    #[test]
    fn real_task_kill_and_resume_skips_checkpointed_files() {
        let dir = std::env::temp_dir().join(format!("htcdm-tcp-task-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut cfg = task_cfg();
        cfg.kill_after_files = Some(2);
        let runner = TaskRunner::new(
            six_file_task("tcp-resume"),
            TaskJournal::dir(dir.clone()).unwrap(),
        )
        .unwrap();
        let (r1, _dead) = run_real_task(&cfg, runner).unwrap();
        assert!(r1.killed, "the kill switch must have fired");
        let done1 = r1.progress.files_done;
        assert!((2..6).contains(&done1), "killed mid-task: {done1} done");

        // A brand-new coordinator over the same journal: resumes the
        // checkpointed files and moves ONLY the remaining ones — the
        // server-side byte counter is the proof.
        cfg.kill_after_files = None;
        let runner = TaskRunner::new(
            six_file_task("tcp-resume"),
            TaskJournal::dir(dir.clone()).unwrap(),
        )
        .unwrap();
        assert_eq!(runner.files_resumed(), done1);
        let (r2, runner) = run_real_task(&cfg, runner).unwrap();
        assert_eq!(r2.errors, 0);
        assert!(!r2.killed);
        assert_eq!(r2.progress.files_done, 6);
        assert_eq!(r2.progress.files_resumed, done1);
        assert_eq!(r2.files_transferred as usize, 6 - done1);
        assert_eq!(
            r2.bytes_served_per_node.iter().sum::<u64>(),
            (6 - done1) as u64 * TASK_FILE_BYTES,
            "checkpointed files must not hit the wire again"
        );
        for i in 0..6 {
            let f = runner.file(i);
            assert!(f.is_done(), "file {i} incomplete after resume");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
