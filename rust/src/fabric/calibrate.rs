//! Sim-vs-real calibration harness: one measured loopback burst, the
//! same burst replayed through the virtual-time engine under every
//! flow solver, and the goodput ratios between them.
//!
//! The real run is the ground truth: `run_real_pool` moves sealed
//! bytes over the kernel's actual TCP stack and reports aggregate
//! goodput plus the median per-stream transfer time. The harness then
//! builds an [`EngineSpec`] that mirrors the burst — same job count,
//! same payload size, one worker node with as many slots as the real
//! pool had worker threads, zero job runtime (pure transfer burst) —
//! and pins the sim's per-stream endpoint ceiling to the measured
//! loopback rate. Replaying that spec under [`SolverKind::FairShare`]
//! and [`SolverKind::TcpDynamic`] yields one [`SolverPoint`] per
//! solver whose `ratio` answers the calibration question directly:
//! how far is each solver's predicted goodput from the wire?
//!
//! ## Tolerance band
//!
//! The documented acceptance band is a **factor of two** in aggregate
//! goodput (`0.5 <= ratio <= 2.0`). The sim inherits the measured
//! per-stream rate, so the residual error is scheduling shape — ramp-up
//! and drain tails at the burst edges, admission serialization, and
//! (under TcpDynamic) the modelled slow-start allowance — none of
//! which should cost more than 2× on a burst of at least a few jobs
//! per worker. CI enforces the band in `calibration_within_band`
//! (tier 1, small burst) and `calibration_within_band_heavy`
//! (`--ignored` chaos tier, paper-shaped burst).
//!
//! ## Site×site matrix calibration
//!
//! [`run_site_calibration`] extends the harness to the federation
//! layer: the real leg runs a federated loopback pool (one submit node
//! + one DTN + equal workers per site, round-robin site selection) and
//! the sim leg mirrors it with zero-cost WAN links — loopback has no
//! real WAN, so the comparison isolates the *routing and accounting*
//! path, not propagation. Both legs report the same site×site goodput
//! matrix shape; the band applies to aggregate goodput and to each
//! source site's row sum (tier 1), and per-pair cells in the chaos
//! tier (`site_calibration_per_pair_within_band`).

use anyhow::{ensure, Result};

use super::{run_real_pool, RealPoolConfig};
use crate::coordinator::engine::{Engine, EngineSpec};
use crate::mover::{AdmissionConfig, SiteSelector, SourcePlan};
use crate::netsim::solver::SolverKind;
use crate::netsim::topology::{TestbedSpec, WorkerSpec};
use crate::transfer::ThrottlePolicy;
use crate::util::units::{Bytes, SimTime};

/// Shape of one calibration burst (shared by the real and sim legs).
#[derive(Debug, Clone)]
pub struct CalibrationConfig {
    /// Jobs in the burst; keep it a multiple of `workers` so the real
    /// pool runs full rounds and the edge tails stay small.
    pub n_jobs: u32,
    /// Input payload per job in bytes.
    pub input_bytes: usize,
    /// Real-pool worker threads == sim execute slots (the burst's
    /// transfer concurrency).
    pub workers: u32,
    /// Seal through the PJRT artifact on the real leg (falls back to
    /// native when the artifact is absent). Calibration defaults to
    /// native so the measurement does not depend on `make artifacts`.
    pub use_xla_engine: bool,
    /// Sim engine seed (the real leg is wall-clock, not seeded).
    pub seed: u64,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        CalibrationConfig {
            n_jobs: 12,
            input_bytes: 1 << 20,
            workers: 3,
            use_xla_engine: false,
            seed: 11,
        }
    }
}

/// One solver's replay of the measured burst.
#[derive(Debug, Clone)]
pub struct SolverPoint {
    /// Solver label as stamped in sim reports (`fair-share` /
    /// `tcp-dynamic`).
    pub solver: String,
    /// Aggregate sim goodput: burst bytes over the sim makespan.
    pub sim_gbps: f64,
    /// `sim_gbps / real_gbps` — 1.0 is a perfect prediction; the
    /// acceptance band is [0.5, 2.0].
    pub ratio: f64,
}

/// The full sim-vs-real comparison for one burst.
#[derive(Debug, Clone)]
pub struct SolverCalibration {
    pub n_jobs: u32,
    pub input_bytes: u64,
    pub workers: u32,
    /// Label of the ground-truth leg (always `real-tcp`, from
    /// [`super::RealPoolReport::solver`]).
    pub real_solver: String,
    /// Measured aggregate loopback goodput in Gbps.
    pub real_gbps: f64,
    /// Measured per-stream loopback rate in bytes/sec (payload bytes
    /// over the median full-job time) — the endpoint ceiling the sim
    /// legs are pinned to, same unit as [`TestbedSpec`]'s
    /// `endpoint_bps`.
    pub real_stream_bps: f64,
    /// One point per solver, in [`SolverKind`] declaration order.
    pub points: Vec<SolverPoint>,
}

impl SolverCalibration {
    /// The point for one solver, if that solver was replayed.
    pub fn point(&self, kind: SolverKind) -> Option<&SolverPoint> {
        self.points.iter().find(|p| p.solver == kind.label())
    }

    /// True when every replayed solver landed inside the documented
    /// factor-`band` goodput band around the real measurement.
    pub fn within_band(&self, band: f64) -> bool {
        !self.points.is_empty()
            && self
                .points
                .iter()
                .all(|p| p.ratio >= 1.0 / band && p.ratio <= band)
    }

    /// Machine-readable record for CI artifacts (no serde in tree, so
    /// the object is assembled by hand).
    pub fn to_json(&self) -> String {
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"solver\":\"{}\",\"gbps\":{:.6},\"ratio\":{:.6}}}",
                    p.solver, p.sim_gbps, p.ratio
                )
            })
            .collect();
        format!(
            "{{\"burst\":{{\"jobs\":{},\"input_bytes\":{},\"workers\":{}}},\
             \"real\":{{\"solver\":\"{}\",\"gbps\":{:.6},\"stream_bytes_per_sec\":{:.1}}},\
             \"sim\":[{}]}}",
            self.n_jobs,
            self.input_bytes,
            self.workers,
            self.real_solver,
            self.real_gbps,
            self.real_stream_bps,
            points.join(",")
        )
    }
}

/// The sim mirror of a measured burst: one worker node whose slot
/// count equals the real pool's worker-thread count, per-stream
/// endpoint ceiling pinned to the measured loopback rate, NICs left at
/// the paper's 100 Gbps so only the endpoint cap binds, and zero job
/// runtime so the makespan is pure data movement.
fn sim_spec(cfg: &CalibrationConfig, real_stream_bps: f64, kind: SolverKind) -> EngineSpec {
    let mut tb = TestbedSpec::lan_paper();
    tb.workers = vec![WorkerSpec {
        nic_gbps: 100.0,
        slots: cfg.workers.max(1),
    }];
    tb.monitor_bin = SimTime::from_secs(1);
    tb.endpoint_bps = Some(real_stream_bps);
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = cfg.n_jobs;
    spec.input_bytes = Bytes(cfg.input_bytes as u64);
    spec.output_bytes = Bytes(512); // matches the real leg's tiny result upload
    spec.runtime_median_s = 0.0;
    spec.seed = cfg.seed;
    spec.solver = kind;
    spec
}

/// Replay one already-measured burst through the sim under `kind` and
/// return its point. Exposed for the bench harness, which reuses one
/// real measurement across many sim replays.
pub fn replay_sim(
    cfg: &CalibrationConfig,
    real_gbps: f64,
    real_stream_bps: f64,
    kind: SolverKind,
) -> Result<SolverPoint> {
    let result = Engine::new(sim_spec(cfg, real_stream_bps, kind)).run()?;
    ensure!(
        result.schedd.completed_count() == cfg.n_jobs as usize,
        "sim replay under {} completed {}/{} jobs",
        kind.label(),
        result.schedd.completed_count(),
        cfg.n_jobs
    );
    let makespan_s = result
        .schedd
        .makespan()
        .unwrap_or(SimTime::ZERO)
        .as_secs_f64()
        .max(1e-9);
    let sim_gbps = cfg.n_jobs as f64 * cfg.input_bytes as f64 * 8.0 / makespan_s / 1e9;
    Ok(SolverPoint {
        solver: kind.label().to_string(),
        sim_gbps,
        ratio: sim_gbps / real_gbps.max(1e-9),
    })
}

/// Run the full harness: measure one real loopback burst, replay it
/// under both solvers, and return the comparison.
pub fn run_calibration(cfg: &CalibrationConfig) -> Result<SolverCalibration> {
    let real = run_real_pool(RealPoolConfig {
        n_jobs: cfg.n_jobs,
        workers: cfg.workers.max(1),
        input_bytes: cfg.input_bytes,
        output_bytes: 512,
        use_xla_engine: cfg.use_xla_engine,
        passphrase: "calibrate".into(),
        policy: AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
        ..RealPoolConfig::default()
    })?;
    ensure!(
        real.errors == 0 && real.jobs_completed == cfg.n_jobs,
        "real calibration burst failed: {}/{} jobs, {} errors",
        real.jobs_completed,
        cfg.n_jobs,
        real.errors
    );
    let median_s = real.transfer_secs.median().max(1e-9);
    // Bytes/sec to match `TestbedSpec::endpoint_bps` — the median covers
    // the full job cycle (connect, handshake, sealed fetch, output), so
    // the pinned ceiling carries the real leg's crypto cost too.
    let real_stream_bps = cfg.input_bytes as f64 / median_s;
    let mut points = Vec::new();
    for kind in [SolverKind::FairShare, SolverKind::TcpDynamic] {
        points.push(replay_sim(cfg, real.gbps, real_stream_bps, kind)?);
    }
    Ok(SolverCalibration {
        n_jobs: cfg.n_jobs,
        input_bytes: cfg.input_bytes as u64,
        workers: cfg.workers,
        real_solver: real.solver,
        real_gbps: real.gbps,
        real_stream_bps,
        points,
    })
}

/// The federated sim-vs-real comparison: one measured federated
/// loopback burst and its sim mirror, each reporting the same
/// site×site goodput matrix.
#[derive(Debug, Clone)]
pub struct SiteCalibration {
    pub n_sites: usize,
    pub n_jobs: u32,
    pub input_bytes: u64,
    /// Measured aggregate loopback goodput in Gbps.
    pub real_gbps: f64,
    /// Sim-mirror aggregate goodput in Gbps.
    pub sim_gbps: f64,
    /// `sim_gbps / real_gbps` — the aggregate band check.
    pub ratio: f64,
    /// The real leg's site×site payload matrix
    /// ([`super::RealPoolReport::site_matrix_bytes`]).
    pub real_matrix: Vec<Vec<u64>>,
    /// The sim leg's site×site payload matrix
    /// (`EngineResult::site_matrix`).
    pub sim_matrix: Vec<Vec<u64>>,
}

impl SiteCalibration {
    /// Per-source-site row-sum ratios, sim over real: how similarly the
    /// two fabrics split the burst across source sites. Both totals are
    /// the same burst, so 1.0 is a perfect split match.
    pub fn row_ratios(&self) -> Vec<f64> {
        (0..self.n_sites)
            .map(|s| {
                let real: u64 = self.real_matrix[s].iter().sum();
                let sim: u64 = self.sim_matrix[s].iter().sum();
                sim as f64 / (real as f64).max(1e-9)
            })
            .collect()
    }

    /// Per-pair cell ratios, sim over real, row-major. Cells empty on
    /// BOTH legs ratio to exactly 1.0; a cell empty on one leg only is
    /// an infinite/zero ratio and fails any band.
    pub fn pair_ratios(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n_sites * self.n_sites);
        for s in 0..self.n_sites {
            for d in 0..self.n_sites {
                let real = self.real_matrix[s][d];
                let sim = self.sim_matrix[s][d];
                if real == 0 && sim == 0 {
                    out.push(1.0);
                } else {
                    out.push(sim as f64 / (real as f64).max(1e-9));
                }
            }
        }
        out
    }

    /// True when the aggregate goodput ratio AND every source site's
    /// row-sum ratio land inside the factor-`band` band.
    pub fn within_band(&self, band: f64) -> bool {
        let ok = |r: f64| r >= 1.0 / band && r <= band;
        ok(self.ratio) && self.row_ratios().iter().all(|&r| ok(r))
    }

    /// Machine-readable record for CI artifacts (hand-assembled — no
    /// serde in tree). Schema documented in docs/REPORTS.md.
    pub fn to_json(&self) -> String {
        let matrix = |m: &[Vec<u64>]| -> String {
            let rows: Vec<String> = m
                .iter()
                .map(|row| {
                    let cells: Vec<String> = row.iter().map(|b| b.to_string()).collect();
                    format!("[{}]", cells.join(","))
                })
                .collect();
            format!("[{}]", rows.join(","))
        };
        let rows: Vec<String> = self.row_ratios().iter().map(|r| format!("{r:.6}")).collect();
        format!(
            "{{\"n_sites\":{},\"burst\":{{\"jobs\":{},\"input_bytes\":{}}},\
             \"real\":{{\"gbps\":{:.6},\"matrix_bytes\":{}}},\
             \"sim\":{{\"gbps\":{:.6},\"ratio\":{:.6},\"matrix_bytes\":{}}},\
             \"row_ratios\":[{}]}}",
            self.n_sites,
            self.n_jobs,
            self.input_bytes,
            self.real_gbps,
            matrix(&self.real_matrix),
            self.sim_gbps,
            self.ratio,
            matrix(&self.sim_matrix),
            rows.join(",")
        )
    }
}

/// Run the federated harness over `n_sites` sites (each with one
/// submit node, one DTN and an equal worker share): measure one real
/// federated loopback burst, replay its sim mirror, and return both
/// site×site matrices with their ratios.
pub fn run_site_calibration(cfg: &CalibrationConfig, n_sites: usize) -> Result<SiteCalibration> {
    ensure!(n_sites >= 2, "site calibration needs a federation (n_sites >= 2)");
    let n_sites_u = n_sites as u32;
    // Round workers up to a multiple of the site count so every site
    // hosts the same number of destination threads.
    let workers = cfg.workers.max(1).div_ceil(n_sites_u) * n_sites_u;
    let real = run_real_pool(RealPoolConfig {
        n_jobs: cfg.n_jobs,
        workers,
        input_bytes: cfg.input_bytes,
        output_bytes: 512,
        use_xla_engine: cfg.use_xla_engine,
        passphrase: "calibrate-sites".into(),
        policy: AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
        n_submit_nodes: n_sites_u,
        data_nodes: n_sites_u,
        source: SourcePlan::DedicatedDtn,
        n_sites,
        // Round-robin fills every source row deterministically — the
        // transfer-matrix shape of the Petascale DTN benchmark.
        site_selector: SiteSelector::RoundRobin,
        ..RealPoolConfig::default()
    })?;
    ensure!(
        real.errors == 0 && real.jobs_completed == cfg.n_jobs,
        "real federated burst failed: {}/{} jobs, {} errors",
        real.jobs_completed,
        cfg.n_jobs,
        real.errors
    );
    let median_s = real.transfer_secs.median().max(1e-9);
    let real_stream_bps = cfg.input_bytes as f64 / median_s;

    // The sim mirror: same federation shape, endpoint ceiling pinned to
    // the measured loopback rate, and FREE WAN links (zero RTT, no
    // loss, full rate) because the real leg's "WAN" is the same
    // loopback device — the matrix comparison calibrates routing and
    // accounting, not propagation.
    let mut tb = TestbedSpec::lan_paper();
    tb.n_sites = n_sites_u;
    tb.site_wan_gbps = 100.0;
    tb.site_wan_rtt_ms = 0.0;
    tb.site_wan_loss = 0.0;
    tb.workers = (0..n_sites)
        .map(|_| WorkerSpec {
            nic_gbps: 100.0,
            slots: workers / n_sites_u,
        })
        .collect();
    tb.monitor_bin = SimTime::from_secs(1);
    tb.endpoint_bps = Some(real_stream_bps);
    let mut spec = EngineSpec::paper(tb, ThrottlePolicy::Disabled);
    spec.n_jobs = cfg.n_jobs;
    spec.input_bytes = Bytes(cfg.input_bytes as u64);
    spec.output_bytes = Bytes(512);
    spec.runtime_median_s = 0.0;
    spec.seed = cfg.seed;
    spec.n_submit_nodes = n_sites_u;
    spec.n_data_nodes = n_sites_u;
    spec.source = SourcePlan::DedicatedDtn;
    spec.site_selector = SiteSelector::RoundRobin;
    let result = Engine::new(spec).run()?;
    ensure!(
        result.schedd.completed_count() == cfg.n_jobs as usize,
        "sim mirror completed {}/{} jobs",
        result.schedd.completed_count(),
        cfg.n_jobs
    );
    let makespan_s = result
        .schedd
        .makespan()
        .unwrap_or(SimTime::ZERO)
        .as_secs_f64()
        .max(1e-9);
    let sim_gbps = cfg.n_jobs as f64 * cfg.input_bytes as f64 * 8.0 / makespan_s / 1e9;
    Ok(SiteCalibration {
        n_sites,
        n_jobs: cfg.n_jobs,
        input_bytes: cfg.input_bytes as u64,
        real_gbps: real.gbps,
        sim_gbps,
        ratio: sim_gbps / real.gbps.max(1e-9),
        real_matrix: real.site_matrix_bytes,
        sim_matrix: result.site_matrix,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tier-1 capstone: a small measured loopback burst and both sim
    /// solvers land within the documented factor-2 goodput band.
    #[test]
    fn calibration_within_band() {
        let cfg = CalibrationConfig {
            n_jobs: 8,
            input_bytes: 1 << 20,
            workers: 2,
            use_xla_engine: false,
            seed: 5,
        };
        let cal = run_calibration(&cfg).unwrap();
        assert_eq!(cal.points.len(), 2);
        assert_eq!(cal.real_solver, "real-tcp");
        assert!(cal.real_gbps > 0.0 && cal.real_stream_bps > 0.0);
        for p in &cal.points {
            assert!(
                p.ratio >= 0.5 && p.ratio <= 2.0,
                "{} predicted {:.3} Gbps vs real {:.3} Gbps (ratio {:.3}) — \
                 outside the factor-2 calibration band",
                p.solver,
                p.sim_gbps,
                cal.real_gbps,
                p.ratio
            );
        }
        assert!(cal.within_band(2.0));
        let json = cal.to_json();
        assert!(json.contains("\"fair-share\"") && json.contains("\"tcp-dynamic\""));
        assert!(json.contains("\"real-tcp\""));
    }

    /// Chaos-tier variant: a paper-shaped burst (more jobs, bigger
    /// payloads, more workers) under the same band.
    #[test]
    #[ignore = "heavier loopback burst; run in the chaos tier"]
    fn calibration_within_band_heavy() {
        let cfg = CalibrationConfig {
            n_jobs: 48,
            input_bytes: 4 << 20,
            workers: 4,
            use_xla_engine: false,
            seed: 7,
        };
        let cal = run_calibration(&cfg).unwrap();
        assert!(
            cal.within_band(2.0),
            "calibration out of band: {}",
            cal.to_json()
        );
    }

    /// Tier-1 federation capstone: a small 2-site federated loopback
    /// burst and its sim mirror report same-shape site×site matrices
    /// that account for every payload byte, with aggregate goodput and
    /// every source site's row sum inside the factor-2 band.
    #[test]
    fn site_calibration_matrices_within_band() {
        let cfg = CalibrationConfig {
            n_jobs: 8,
            input_bytes: 1 << 20,
            workers: 2,
            use_xla_engine: false,
            seed: 13,
        };
        let cal = run_site_calibration(&cfg, 2).unwrap();
        assert_eq!(cal.n_sites, 2);
        // Same shape on both legs...
        assert_eq!(cal.real_matrix.len(), 2);
        assert!(cal.real_matrix.iter().all(|row| row.len() == 2));
        assert_eq!(cal.sim_matrix.len(), 2);
        assert!(cal.sim_matrix.iter().all(|row| row.len() == 2));
        // ...both accounting for every payload byte of the burst.
        let burst = 8u64 * (1 << 20);
        assert_eq!(cal.real_matrix.iter().flatten().sum::<u64>(), burst);
        assert_eq!(cal.sim_matrix.iter().flatten().sum::<u64>(), burst);
        // Round-robin splits sources exactly in half on both fabrics,
        // so each row-sum ratio is exactly 1.0 — well inside the band.
        for (s, r) in cal.row_ratios().iter().enumerate() {
            assert!(
                (0.5..=2.0).contains(r),
                "source site {s} row-sum ratio {r:.3} out of band\nreal {:?}\nsim {:?}",
                cal.real_matrix,
                cal.sim_matrix
            );
        }
        assert!(
            cal.ratio >= 0.5 && cal.ratio <= 2.0,
            "aggregate ratio {:.3} out of band (sim {:.3} vs real {:.3} Gbps)",
            cal.ratio,
            cal.sim_gbps,
            cal.real_gbps
        );
        assert!(cal.within_band(2.0));
        let json = cal.to_json();
        assert!(json.contains("\"n_sites\":2"));
        assert!(json.contains("\"row_ratios\""));
        assert!(json.contains("\"matrix_bytes\""));
    }

    /// Chaos-tier variant: a bigger federated burst where every
    /// site×site cell carries bytes on both legs, asserted per pair.
    #[test]
    #[ignore = "heavier federated loopback burst; run in the chaos tier"]
    fn site_calibration_per_pair_within_band() {
        let cfg = CalibrationConfig {
            n_jobs: 96,
            input_bytes: 2 << 20,
            workers: 4,
            use_xla_engine: false,
            seed: 17,
        };
        let cal = run_site_calibration(&cfg, 2).unwrap();
        assert!(
            cal.real_matrix.iter().flatten().all(|&b| b > 0),
            "real leg left a matrix cell empty: {:?}",
            cal.real_matrix
        );
        assert!(
            cal.sim_matrix.iter().flatten().all(|&b| b > 0),
            "sim leg left a matrix cell empty: {:?}",
            cal.sim_matrix
        );
        for (i, r) in cal.pair_ratios().iter().enumerate() {
            assert!(
                (0.5..=2.0).contains(r),
                "pair cell {i} ratio {r:.3} out of band\nreal {:?}\nsim {:?}",
                cal.real_matrix,
                cal.sim_matrix
            );
        }
        assert!(cal.within_band(2.0), "out of band: {}", cal.to_json());
    }

    /// Both solver points are addressable by kind, and the TcpDynamic
    /// replay of a LAN burst stays close to FairShare. The two model
    /// the slow-start ramp differently — FairShare as a static setup
    /// allowance, TcpDynamic in-band through the window — so either
    /// may edge out the other depending on the measured loopback rate,
    /// but on a sub-millisecond-RTT path the gap stays small.
    #[test]
    fn replay_points_addressable_by_kind() {
        let cfg = CalibrationConfig {
            n_jobs: 6,
            input_bytes: 256 << 10,
            workers: 2,
            use_xla_engine: false,
            seed: 3,
        };
        let cal = run_calibration(&cfg).unwrap();
        let fs = cal.point(SolverKind::FairShare).unwrap();
        let tcp = cal.point(SolverKind::TcpDynamic).unwrap();
        assert!(fs.sim_gbps > 0.0 && tcp.sim_gbps > 0.0);
        let rel = (tcp.sim_gbps - fs.sim_gbps).abs() / fs.sim_gbps;
        assert!(
            rel < 0.2,
            "LAN replays of the same burst diverged: tcp-dynamic \
             {:.3} Gbps vs fair-share {:.3} Gbps",
            tcp.sim_gbps,
            fs.sim_gbps
        );
    }
}
