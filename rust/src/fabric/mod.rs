//! Real-mode fabric: actual sealed bytes over TCP loopback.
//!
//! The same transfer architecture as the simulator — all sandbox data
//! flowing through the submit node's file server, authenticated and
//! sealed end-to-end — but with real sockets, real crypto (through the
//! PJRT artifact when requested), and wall-clock time. Used by
//! `examples/quickstart.rs` and the end-to-end tests; this is the proof
//! that all three layers compose.

pub mod calibrate;
pub mod tcp;

pub use calibrate::{
    run_calibration, run_site_calibration, CalibrationConfig, SiteCalibration, SolverCalibration,
    SolverPoint,
};
pub use tcp::{
    run_real_pool, run_real_pool_router, run_real_pool_with, run_real_task, ChunkProposal,
    FileServer, RealPoolConfig, RealPoolReport, RealTaskConfig, RealTaskReport, ServerRole,
};
