//! ClassAd expression engine: lexer, recursive-descent parser, evaluator.
//!
//! Implements the "old ClassAds" expression dialect used for matchmaking:
//! three-valued logic (UNDEFINED/ERROR propagate), `MY.`/`TARGET.` scoped
//! attribute references with unqualified fallback (MY then TARGET),
//! case-insensitive string equality for `==` and the `=?=`/`=!=` identity
//! operators, the ternary operator, lists, and the builtin function set the
//! daemons rely on.

use super::{Ad, Value};
use std::fmt;

// ---------------------------------------------------------------------------
// AST
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,
    And,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Is,    // =?= identity
    Isnt,  // =!= non-identity
    Add,
    Sub,
    Mul,
    Div,
    Mod,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Not,
    Neg,
    Plus,
}

#[derive(Debug, Clone)]
pub enum Expr {
    Lit(Value),
    /// Unqualified attribute reference.
    Attr(String),
    /// MY.attr
    My(String),
    /// TARGET.attr
    Target(String),
    Un(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Ternary(Box<Expr>, Box<Expr>, Box<Expr>),
    Call(String, Vec<Expr>),
    List(Vec<Expr>),
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Lit(v) => write!(f, "{v}"),
            Expr::Attr(a) => write!(f, "{a}"),
            Expr::My(a) => write!(f, "MY.{a}"),
            Expr::Target(a) => write!(f, "TARGET.{a}"),
            Expr::Un(op, e) => {
                let s = match op {
                    UnOp::Not => "!",
                    UnOp::Neg => "-",
                    UnOp::Plus => "+",
                };
                write!(f, "{s}({e})")
            }
            Expr::Bin(op, l, r) => {
                let s = match op {
                    BinOp::Or => "||",
                    BinOp::And => "&&",
                    BinOp::Eq => "==",
                    BinOp::Ne => "!=",
                    BinOp::Lt => "<",
                    BinOp::Le => "<=",
                    BinOp::Gt => ">",
                    BinOp::Ge => ">=",
                    BinOp::Is => "=?=",
                    BinOp::Isnt => "=!=",
                    BinOp::Add => "+",
                    BinOp::Sub => "-",
                    BinOp::Mul => "*",
                    BinOp::Div => "/",
                    BinOp::Mod => "%",
                };
                write!(f, "({l} {s} {r})")
            }
            Expr::Ternary(c, t, e) => write!(f, "({c} ? {t} : {e})"),
            Expr::Call(name, args) => {
                write!(f, "{name}(")?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
            Expr::List(xs) => {
                write!(f, "{{")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Int(i64),
    Real(f64),
    Str(String),
    Ident(String),
    Op(&'static str),
    LParen,
    RParen,
    LBrace,
    RBrace,
    Comma,
    Question,
    Colon,
    Dot,
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "classad parse error at {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

fn lex(text: &str) -> Result<Vec<(usize, Tok)>, ParseError> {
    let b = text.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                toks.push((i, Tok::LParen));
                i += 1;
            }
            b')' => {
                toks.push((i, Tok::RParen));
                i += 1;
            }
            b'{' => {
                toks.push((i, Tok::LBrace));
                i += 1;
            }
            b'}' => {
                toks.push((i, Tok::RBrace));
                i += 1;
            }
            b',' => {
                toks.push((i, Tok::Comma));
                i += 1;
            }
            b'?' => {
                toks.push((i, Tok::Question));
                i += 1;
            }
            b':' => {
                toks.push((i, Tok::Colon));
                i += 1;
            }
            b'.' if i + 1 < b.len() && !b[i + 1].is_ascii_digit() => {
                toks.push((i, Tok::Dot));
                i += 1;
            }
            b'"' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                loop {
                    if i >= b.len() {
                        return Err(ParseError {
                            pos: start,
                            msg: "unterminated string".into(),
                        });
                    }
                    match b[i] {
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\\' if i + 1 < b.len() => {
                            s.push(match b[i + 1] {
                                b'n' => '\n',
                                b't' => '\t',
                                other => other as char,
                            });
                            i += 2;
                        }
                        other => {
                            s.push(other as char);
                            i += 1;
                        }
                    }
                }
                toks.push((start, Tok::Str(s)));
            }
            b'=' => {
                if b[i..].starts_with(b"=?=") {
                    toks.push((i, Tok::Op("=?=")));
                    i += 3;
                } else if b[i..].starts_with(b"=!=") {
                    toks.push((i, Tok::Op("=!=")));
                    i += 3;
                } else if b[i..].starts_with(b"==") {
                    toks.push((i, Tok::Op("==")));
                    i += 2;
                } else {
                    return Err(ParseError {
                        pos: i,
                        msg: "bare '=' (assignment) not valid in expression".into(),
                    });
                }
            }
            b'!' => {
                if b[i..].starts_with(b"!=") {
                    toks.push((i, Tok::Op("!=")));
                    i += 2;
                } else {
                    toks.push((i, Tok::Op("!")));
                    i += 1;
                }
            }
            b'<' => {
                if b[i..].starts_with(b"<=") {
                    toks.push((i, Tok::Op("<=")));
                    i += 2;
                } else {
                    toks.push((i, Tok::Op("<")));
                    i += 1;
                }
            }
            b'>' => {
                if b[i..].starts_with(b">=") {
                    toks.push((i, Tok::Op(">=")));
                    i += 2;
                } else {
                    toks.push((i, Tok::Op(">")));
                    i += 1;
                }
            }
            b'&' => {
                if b[i..].starts_with(b"&&") {
                    toks.push((i, Tok::Op("&&")));
                    i += 2;
                } else {
                    return Err(ParseError {
                        pos: i,
                        msg: "single '&'".into(),
                    });
                }
            }
            b'|' => {
                if b[i..].starts_with(b"||") {
                    toks.push((i, Tok::Op("||")));
                    i += 2;
                } else {
                    return Err(ParseError {
                        pos: i,
                        msg: "single '|'".into(),
                    });
                }
            }
            b'+' => {
                toks.push((i, Tok::Op("+")));
                i += 1;
            }
            b'-' => {
                toks.push((i, Tok::Op("-")));
                i += 1;
            }
            b'*' => {
                toks.push((i, Tok::Op("*")));
                i += 1;
            }
            b'/' => {
                toks.push((i, Tok::Op("/")));
                i += 1;
            }
            b'%' => {
                toks.push((i, Tok::Op("%")));
                i += 1;
            }
            b'0'..=b'9' | b'.' => {
                let start = i;
                let mut is_real = false;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == b'.'
                        || b[i] == b'e'
                        || b[i] == b'E'
                        || ((b[i] == b'+' || b[i] == b'-')
                            && i > start
                            && (b[i - 1] == b'e' || b[i - 1] == b'E')))
                {
                    if b[i] == b'.' || b[i] == b'e' || b[i] == b'E' {
                        is_real = true;
                    }
                    i += 1;
                }
                let text = std::str::from_utf8(&b[start..i]).unwrap();
                if is_real {
                    let v = text.parse::<f64>().map_err(|_| ParseError {
                        pos: start,
                        msg: format!("bad real '{text}'"),
                    })?;
                    toks.push((start, Tok::Real(v)));
                } else {
                    let v = text.parse::<i64>().map_err(|_| ParseError {
                        pos: start,
                        msg: format!("bad int '{text}'"),
                    })?;
                    toks.push((start, Tok::Int(v)));
                }
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                let ident = std::str::from_utf8(&b[start..i]).unwrap().to_string();
                toks.push((start, Tok::Ident(ident)));
            }
            other => {
                return Err(ParseError {
                    pos: i,
                    msg: format!("unexpected character '{}'", other as char),
                })
            }
        }
    }
    Ok(toks)
}

// ---------------------------------------------------------------------------
// Parser (precedence climbing)
// ---------------------------------------------------------------------------

struct Parser {
    toks: Vec<(usize, Tok)>,
    i: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i).map(|(_, t)| t)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.i).map(|(_, t)| t.clone());
        self.i += 1;
        t
    }

    fn err(&self, msg: &str) -> ParseError {
        let pos = self.toks.get(self.i).map(|(p, _)| *p).unwrap_or(usize::MAX);
        ParseError {
            pos,
            msg: msg.to_string(),
        }
    }

    fn expr(&mut self) -> Result<Expr, ParseError> {
        let cond = self.or_expr()?;
        if self.peek() == Some(&Tok::Question) {
            self.bump();
            let then = self.expr()?;
            match self.bump() {
                Some(Tok::Colon) => {}
                _ => return Err(self.err("expected ':' in ternary")),
            }
            let els = self.expr()?;
            return Ok(Expr::Ternary(Box::new(cond), Box::new(then), Box::new(els)));
        }
        Ok(cond)
    }

    fn or_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while self.peek() == Some(&Tok::Op("||")) {
            self.bump();
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.cmp_expr()?;
        while self.peek() == Some(&Tok::Op("&&")) {
            self.bump();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.add_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Op("==")) => BinOp::Eq,
                Some(Tok::Op("!=")) => BinOp::Ne,
                Some(Tok::Op("<")) => BinOp::Lt,
                Some(Tok::Op("<=")) => BinOp::Le,
                Some(Tok::Op(">")) => BinOp::Gt,
                Some(Tok::Op(">=")) => BinOp::Ge,
                Some(Tok::Op("=?=")) => BinOp::Is,
                Some(Tok::Op("=!=")) => BinOp::Isnt,
                _ => break,
            };
            self.bump();
            let rhs = self.add_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn add_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Op("+")) => BinOp::Add,
                Some(Tok::Op("-")) => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Op("*")) => BinOp::Mul,
                Some(Tok::Op("/")) => BinOp::Div,
                Some(Tok::Op("%")) => BinOp::Mod,
                _ => break,
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr, ParseError> {
        match self.peek() {
            Some(Tok::Op("!")) => {
                self.bump();
                Ok(Expr::Un(UnOp::Not, Box::new(self.unary_expr()?)))
            }
            Some(Tok::Op("-")) => {
                self.bump();
                Ok(Expr::Un(UnOp::Neg, Box::new(self.unary_expr()?)))
            }
            Some(Tok::Op("+")) => {
                self.bump();
                Ok(Expr::Un(UnOp::Plus, Box::new(self.unary_expr()?)))
            }
            _ => self.primary(),
        }
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        match self.bump() {
            Some(Tok::Int(v)) => Ok(Expr::Lit(Value::Int(v))),
            Some(Tok::Real(v)) => Ok(Expr::Lit(Value::Real(v))),
            Some(Tok::Str(s)) => Ok(Expr::Lit(Value::Str(s))),
            Some(Tok::LParen) => {
                let e = self.expr()?;
                match self.bump() {
                    Some(Tok::RParen) => Ok(e),
                    _ => Err(self.err("expected ')'")),
                }
            }
            Some(Tok::LBrace) => {
                let mut xs = Vec::new();
                if self.peek() == Some(&Tok::RBrace) {
                    self.bump();
                    return Ok(Expr::List(xs));
                }
                loop {
                    xs.push(self.expr()?);
                    match self.bump() {
                        Some(Tok::Comma) => continue,
                        Some(Tok::RBrace) => return Ok(Expr::List(xs)),
                        _ => return Err(self.err("expected ',' or '}' in list")),
                    }
                }
            }
            Some(Tok::Ident(id)) => {
                let lower = id.to_ascii_lowercase();
                match lower.as_str() {
                    "true" => return Ok(Expr::Lit(Value::Bool(true))),
                    "false" => return Ok(Expr::Lit(Value::Bool(false))),
                    "undefined" => return Ok(Expr::Lit(Value::Undefined)),
                    "error" => return Ok(Expr::Lit(Value::Error)),
                    _ => {}
                }
                // MY.attr / TARGET.attr scoping.
                if (lower == "my" || lower == "target") && self.peek() == Some(&Tok::Dot) {
                    self.bump();
                    let attr = match self.bump() {
                        Some(Tok::Ident(a)) => a,
                        _ => return Err(self.err("expected attribute after scope")),
                    };
                    return Ok(if lower == "my" {
                        Expr::My(attr.to_ascii_lowercase())
                    } else {
                        Expr::Target(attr.to_ascii_lowercase())
                    });
                }
                // Function call.
                if self.peek() == Some(&Tok::LParen) {
                    self.bump();
                    let mut args = Vec::new();
                    if self.peek() == Some(&Tok::RParen) {
                        self.bump();
                        return Ok(Expr::Call(lower, args));
                    }
                    loop {
                        args.push(self.expr()?);
                        match self.bump() {
                            Some(Tok::Comma) => continue,
                            Some(Tok::RParen) => return Ok(Expr::Call(lower, args)),
                            _ => return Err(self.err("expected ',' or ')' in call")),
                        }
                    }
                }
                Ok(Expr::Attr(lower))
            }
            _ => Err(self.err("expected expression")),
        }
    }
}

/// Parse a ClassAd expression from text.
pub fn parse_expr(text: &str) -> Result<Expr, ParseError> {
    let toks = lex(text)?;
    let mut p = Parser { toks, i: 0 };
    let e = p.expr()?;
    if p.i != p.toks.len() {
        return Err(p.err("trailing tokens"));
    }
    Ok(e)
}

// ---------------------------------------------------------------------------
// Evaluator
// ---------------------------------------------------------------------------

struct Ctx<'a> {
    my: &'a Ad,
    target: Option<&'a Ad>,
    depth: u32,
}

/// Evaluate attribute `name` of `my`, with optional `target` in scope.
pub fn eval_attr(my: &Ad, target: Option<&Ad>, name: &str) -> Value {
    let mut ctx = Ctx {
        my,
        target,
        depth: 0,
    };
    lookup(&mut ctx, name, false)
}

fn lookup(ctx: &mut Ctx, name: &str, target_scope: bool) -> Value {
    if ctx.depth > 64 {
        return Value::Error; // cyclic attribute definitions
    }
    let (ad, other) = if target_scope {
        match ctx.target {
            Some(t) => (t, Some(ctx.my)),
            None => return Value::Undefined,
        }
    } else {
        (ctx.my, ctx.target)
    };
    match ad.get_expr(name) {
        Some(e) => {
            let mut sub = Ctx {
                my: ad,
                target: other,
                depth: ctx.depth + 1,
            };
            eval(&mut sub, &e.clone())
        }
        None => Value::Undefined,
    }
}

fn eval(ctx: &mut Ctx, e: &Expr) -> Value {
    match e {
        Expr::Lit(v) => v.clone(),
        Expr::My(a) => lookup(ctx, a, false),
        Expr::Target(a) => lookup(ctx, a, true),
        Expr::Attr(a) => {
            // Unqualified: MY scope first, then TARGET (old-ClassAd fallback).
            let v = lookup(ctx, a, false);
            if v.is_undefined() && ctx.target.is_some() {
                lookup(ctx, a, true)
            } else {
                v
            }
        }
        Expr::Un(op, inner) => {
            let v = eval(ctx, inner);
            eval_unop(*op, v)
        }
        Expr::Bin(op, l, r) => eval_binop(ctx, *op, l, r),
        Expr::Ternary(c, t, f) => match eval(ctx, c) {
            Value::Bool(true) => eval(ctx, t),
            Value::Bool(false) => eval(ctx, f),
            Value::Undefined => Value::Undefined,
            _ => Value::Error,
        },
        Expr::Call(name, args) => eval_call(ctx, name, args),
        Expr::List(xs) => Value::List(xs.iter().map(|x| eval(ctx, x)).collect()),
    }
}

fn eval_unop(op: UnOp, v: Value) -> Value {
    match (op, v) {
        (_, Value::Error) => Value::Error,
        (UnOp::Not, Value::Undefined) => Value::Undefined,
        (UnOp::Not, Value::Bool(b)) => Value::Bool(!b),
        (UnOp::Not, _) => Value::Error,
        (_, Value::Undefined) => Value::Undefined,
        (UnOp::Neg, Value::Int(i)) => Value::Int(-i),
        (UnOp::Neg, Value::Real(r)) => Value::Real(-r),
        (UnOp::Plus, v @ (Value::Int(_) | Value::Real(_))) => v,
        _ => Value::Error,
    }
}

fn eval_binop(ctx: &mut Ctx, op: BinOp, l: &Expr, r: &Expr) -> Value {
    // Short-circuiting three-valued logic first.
    match op {
        BinOp::And => {
            let lv = eval(ctx, l);
            return match lv {
                Value::Bool(false) => Value::Bool(false),
                Value::Error => Value::Error,
                Value::Bool(true) | Value::Undefined => {
                    let rv = eval(ctx, r);
                    match (lv, rv) {
                        (_, Value::Bool(false)) => Value::Bool(false),
                        (_, Value::Error) => Value::Error,
                        (Value::Undefined, _) | (_, Value::Undefined) => Value::Undefined,
                        (_, Value::Bool(true)) => Value::Bool(true),
                        _ => Value::Error,
                    }
                }
                _ => Value::Error,
            };
        }
        BinOp::Or => {
            let lv = eval(ctx, l);
            return match lv {
                Value::Bool(true) => Value::Bool(true),
                Value::Error => Value::Error,
                Value::Bool(false) | Value::Undefined => {
                    let rv = eval(ctx, r);
                    match (lv, rv) {
                        (_, Value::Bool(true)) => Value::Bool(true),
                        (_, Value::Error) => Value::Error,
                        (Value::Undefined, _) | (_, Value::Undefined) => Value::Undefined,
                        (_, Value::Bool(false)) => Value::Bool(false),
                        _ => Value::Error,
                    }
                }
                _ => Value::Error,
            };
        }
        _ => {}
    }

    let lv = eval(ctx, l);
    let rv = eval(ctx, r);

    // Identity operators never yield UNDEFINED/ERROR.
    if op == BinOp::Is || op == BinOp::Isnt {
        let same = values_identical(&lv, &rv);
        return Value::Bool(if op == BinOp::Is { same } else { !same });
    }

    if lv.is_error() || rv.is_error() {
        return Value::Error;
    }
    if lv.is_undefined() || rv.is_undefined() {
        return Value::Undefined;
    }

    match op {
        BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => {
            compare(op, &lv, &rv)
        }
        BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Mod => {
            arithmetic(op, &lv, &rv)
        }
        BinOp::And | BinOp::Or | BinOp::Is | BinOp::Isnt => unreachable!(),
    }
}

fn values_identical(a: &Value, b: &Value) -> bool {
    match (a, b) {
        (Value::Undefined, Value::Undefined) => true,
        (Value::Error, Value::Error) => true,
        (Value::Str(x), Value::Str(y)) => x == y, // case-SENSITIVE for =?=
        (Value::Int(x), Value::Int(y)) => x == y,
        (Value::Real(x), Value::Real(y)) => x == y,
        (Value::Bool(x), Value::Bool(y)) => x == y,
        (Value::List(x), Value::List(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(a, b)| values_identical(a, b))
        }
        _ => false,
    }
}

fn compare(op: BinOp, l: &Value, r: &Value) -> Value {
    use std::cmp::Ordering;
    let ord: Option<Ordering> = match (l, r) {
        (Value::Str(a), Value::Str(b)) => {
            // ClassAd '=='/'<' on strings is case-insensitive.
            Some(a.to_ascii_lowercase().cmp(&b.to_ascii_lowercase()))
        }
        (Value::Bool(a), Value::Bool(b)) => Some(a.cmp(b)),
        _ => {
            let (a, b) = (l.as_real(), r.as_real());
            match (a, b) {
                (Some(a), Some(b)) => a.partial_cmp(&b),
                _ => None,
            }
        }
    };
    let Some(ord) = ord else {
        return Value::Error;
    };
    let b = match op {
        BinOp::Eq => ord == std::cmp::Ordering::Equal,
        BinOp::Ne => ord != std::cmp::Ordering::Equal,
        BinOp::Lt => ord == std::cmp::Ordering::Less,
        BinOp::Le => ord != std::cmp::Ordering::Greater,
        BinOp::Gt => ord == std::cmp::Ordering::Greater,
        BinOp::Ge => ord != std::cmp::Ordering::Less,
        _ => unreachable!(),
    };
    Value::Bool(b)
}

fn arithmetic(op: BinOp, l: &Value, r: &Value) -> Value {
    // String concatenation via '+'.
    if op == BinOp::Add {
        if let (Value::Str(a), Value::Str(b)) = (l, r) {
            return Value::Str(format!("{a}{b}"));
        }
    }
    let both_int = matches!((l, r), (Value::Int(_) | Value::Bool(_), Value::Int(_) | Value::Bool(_)));
    let (Some(a), Some(b)) = (l.as_real(), r.as_real()) else {
        return Value::Error;
    };
    if both_int {
        let (ai, bi) = (l.as_int().unwrap(), r.as_int().unwrap());
        return match op {
            BinOp::Add => Value::Int(ai.wrapping_add(bi)),
            BinOp::Sub => Value::Int(ai.wrapping_sub(bi)),
            BinOp::Mul => Value::Int(ai.wrapping_mul(bi)),
            BinOp::Div => {
                if bi == 0 {
                    Value::Error
                } else {
                    Value::Int(ai.wrapping_div(bi))
                }
            }
            BinOp::Mod => {
                if bi == 0 {
                    Value::Error
                } else {
                    Value::Int(ai.wrapping_rem(bi))
                }
            }
            _ => unreachable!(),
        };
    }
    match op {
        BinOp::Add => Value::Real(a + b),
        BinOp::Sub => Value::Real(a - b),
        BinOp::Mul => Value::Real(a * b),
        BinOp::Div => {
            if b == 0.0 {
                Value::Error
            } else {
                Value::Real(a / b)
            }
        }
        BinOp::Mod => {
            if b == 0.0 {
                Value::Error
            } else {
                Value::Real(a % b)
            }
        }
        _ => unreachable!(),
    }
}

fn eval_call(ctx: &mut Ctx, name: &str, args: &[Expr]) -> Value {
    let vals: Vec<Value> = args.iter().map(|a| eval(ctx, a)).collect();
    // isUndefined/isError inspect, never propagate.
    match name {
        "isundefined" => {
            return match vals.as_slice() {
                [v] => Value::Bool(v.is_undefined()),
                _ => Value::Error,
            }
        }
        "iserror" => {
            return match vals.as_slice() {
                [v] => Value::Bool(v.is_error()),
                _ => Value::Error,
            }
        }
        "ifthenelse" => {
            return match vals.as_slice() {
                [c, t, f] => match c {
                    Value::Bool(true) => t.clone(),
                    Value::Bool(false) => f.clone(),
                    Value::Undefined => f.clone(),
                    _ => Value::Error,
                },
                _ => Value::Error,
            }
        }
        _ => {}
    }
    if vals.iter().any(|v| v.is_error()) {
        return Value::Error;
    }
    if vals.iter().any(|v| v.is_undefined()) {
        return Value::Undefined;
    }
    match (name, vals.as_slice()) {
        ("strcat", vs) => {
            let mut s = String::new();
            for v in vs {
                match v {
                    Value::Str(x) => s.push_str(x),
                    Value::Int(i) => s.push_str(&i.to_string()),
                    Value::Real(r) => s.push_str(&r.to_string()),
                    Value::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
                    _ => return Value::Error,
                }
            }
            Value::Str(s)
        }
        ("size", [Value::Str(s)]) => Value::Int(s.len() as i64),
        ("size", [Value::List(l)]) => Value::Int(l.len() as i64),
        ("toupper", [Value::Str(s)]) => Value::Str(s.to_ascii_uppercase()),
        ("tolower", [Value::Str(s)]) => Value::Str(s.to_ascii_lowercase()),
        ("int", [v]) => v.as_int().map(Value::Int).unwrap_or(Value::Error),
        ("real", [v]) => v.as_real().map(Value::Real).unwrap_or(Value::Error),
        ("string", [v]) => Value::Str(match v {
            Value::Str(s) => s.clone(),
            other => other.to_string(),
        }),
        ("floor", [v]) => v.as_real().map(|r| Value::Int(r.floor() as i64)).unwrap_or(Value::Error),
        ("ceiling", [v]) => v.as_real().map(|r| Value::Int(r.ceil() as i64)).unwrap_or(Value::Error),
        ("round", [v]) => v.as_real().map(|r| Value::Int(r.round() as i64)).unwrap_or(Value::Error),
        ("abs", [Value::Int(i)]) => Value::Int(i.abs()),
        ("abs", [v]) => v.as_real().map(|r| Value::Real(r.abs())).unwrap_or(Value::Error),
        ("min", [Value::List(l)]) => fold_real(l, f64::min),
        ("max", [Value::List(l)]) => fold_real(l, f64::max),
        ("member", [x, Value::List(l)]) => {
            Value::Bool(l.iter().any(|v| values_identical(v, x)))
        }
        ("stringlistmember", [Value::Str(x), Value::Str(list)]) => {
            Value::Bool(list.split(',').any(|t| t.trim().eq_ignore_ascii_case(x)))
        }
        ("stringlistsize", [Value::Str(list)]) => {
            Value::Int(list.split(',').filter(|t| !t.trim().is_empty()).count() as i64)
        }
        _ => Value::Error,
    }
}

fn fold_real(l: &[Value], f: impl Fn(f64, f64) -> f64) -> Value {
    let mut acc: Option<f64> = None;
    for v in l {
        match v.as_real() {
            Some(r) => acc = Some(acc.map_or(r, |a| f(a, r))),
            None => return Value::Error,
        }
    }
    acc.map(Value::Real).unwrap_or(Value::Undefined)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval_str(s: &str) -> Value {
        let ad = Ad::new("Test");
        let mut ctx = Ctx {
            my: &ad,
            target: None,
            depth: 0,
        };
        eval(&mut ctx, &parse_expr(s).unwrap())
    }

    #[test]
    fn arithmetic_int_and_real() {
        assert_eq!(eval_str("1 + 2 * 3"), Value::Int(7));
        assert_eq!(eval_str("(1 + 2) * 3"), Value::Int(9));
        assert_eq!(eval_str("7 / 2"), Value::Int(3));
        assert_eq!(eval_str("7.0 / 2"), Value::Real(3.5));
        assert_eq!(eval_str("7 % 3"), Value::Int(1));
        assert_eq!(eval_str("-3 + 1"), Value::Int(-2));
        assert_eq!(eval_str("2.5e2"), Value::Real(250.0));
    }

    #[test]
    fn division_by_zero_is_error() {
        assert_eq!(eval_str("1 / 0"), Value::Error);
        assert_eq!(eval_str("1 % 0"), Value::Error);
        assert_eq!(eval_str("1.0 / 0.0"), Value::Error);
    }

    #[test]
    fn comparisons() {
        assert_eq!(eval_str("3 > 2"), Value::Bool(true));
        assert_eq!(eval_str("3 <= 2"), Value::Bool(false));
        assert_eq!(eval_str("2 == 2.0"), Value::Bool(true));
        assert_eq!(eval_str("\"ABC\" == \"abc\""), Value::Bool(true), "case-insensitive ==");
        assert_eq!(eval_str("\"ABC\" =?= \"abc\""), Value::Bool(false), "case-sensitive =?=");
        assert_eq!(eval_str("\"a\" < \"B\""), Value::Bool(true));
    }

    #[test]
    fn three_valued_logic() {
        assert_eq!(eval_str("undefined && false"), Value::Bool(false));
        assert_eq!(eval_str("undefined && true"), Value::Undefined);
        assert_eq!(eval_str("undefined || true"), Value::Bool(true));
        assert_eq!(eval_str("undefined || false"), Value::Undefined);
        assert_eq!(eval_str("!undefined"), Value::Undefined);
        assert_eq!(eval_str("error || true"), Value::Error);
        assert_eq!(eval_str("undefined + 1"), Value::Undefined);
        assert_eq!(eval_str("error + 1"), Value::Error);
    }

    #[test]
    fn identity_operators() {
        assert_eq!(eval_str("undefined =?= undefined"), Value::Bool(true));
        assert_eq!(eval_str("undefined =?= 1"), Value::Bool(false));
        assert_eq!(eval_str("undefined =!= 1"), Value::Bool(true));
        assert_eq!(eval_str("error =?= error"), Value::Bool(true));
    }

    #[test]
    fn ternary() {
        assert_eq!(eval_str("true ? 1 : 2"), Value::Int(1));
        assert_eq!(eval_str("false ? 1 : 2"), Value::Int(2));
        assert_eq!(eval_str("undefined ? 1 : 2"), Value::Undefined);
        assert_eq!(eval_str("3 ? 1 : 2"), Value::Error);
    }

    #[test]
    fn builtins() {
        assert_eq!(eval_str("strcat(\"a\", 1, \"b\")"), Value::Str("a1b".into()));
        assert_eq!(eval_str("size(\"hello\")"), Value::Int(5));
        assert_eq!(eval_str("toupper(\"aBc\")"), Value::Str("ABC".into()));
        assert_eq!(eval_str("floor(2.7)"), Value::Int(2));
        assert_eq!(eval_str("ceiling(2.1)"), Value::Int(3));
        assert_eq!(eval_str("round(2.5)"), Value::Int(3));
        assert_eq!(eval_str("abs(-4)"), Value::Int(4));
        assert_eq!(eval_str("min({3, 1, 2})"), Value::Real(1.0));
        assert_eq!(eval_str("max({3, 1, 2})"), Value::Real(3.0));
        assert_eq!(eval_str("member(2, {1, 2, 3})"), Value::Bool(true));
        assert_eq!(
            eval_str("stringListMember(\"b\", \"a, B, c\")"),
            Value::Bool(true)
        );
        assert_eq!(eval_str("stringListSize(\"a, b, c\")"), Value::Int(3));
        assert_eq!(eval_str("isUndefined(undefined)"), Value::Bool(true));
        assert_eq!(eval_str("isError(1/0)"), Value::Bool(true));
        assert_eq!(eval_str("ifThenElse(true, 1, 2)"), Value::Int(1));
        assert_eq!(eval_str("ifThenElse(undefined, 1, 2)"), Value::Int(2));
        assert_eq!(eval_str("nosuchfn(1)"), Value::Error);
    }

    #[test]
    fn string_concat_plus() {
        assert_eq!(eval_str("\"a\" + \"b\""), Value::Str("ab".into()));
    }

    #[test]
    fn undefined_attr_lookup() {
        assert_eq!(eval_str("NoSuchAttr"), Value::Undefined);
        assert_eq!(eval_str("NoSuchAttr > 5"), Value::Undefined);
    }

    #[test]
    fn lists() {
        assert_eq!(
            eval_str("{1, 2+3}"),
            Value::List(vec![Value::Int(1), Value::Int(5)])
        );
        assert_eq!(eval_str("size({1, 2})"), Value::Int(2));
    }

    #[test]
    fn parse_errors() {
        assert!(parse_expr("1 +").is_err());
        assert!(parse_expr("(1").is_err());
        assert!(parse_expr("a = b").is_err());
        assert!(parse_expr("\"unterminated").is_err());
        assert!(parse_expr("1 & 2").is_err());
        assert!(parse_expr("1 2").is_err());
        assert!(parse_expr("{1,").is_err());
    }

    #[test]
    fn my_target_scoping() {
        let mut my = Ad::new("Job");
        my.insert("X", 1i64);
        my.insert_expr("UsesTarget", "TARGET.Y + MY.X").unwrap();
        let mut target = Ad::new("Machine");
        target.insert("Y", 10i64);
        assert_eq!(eval_attr(&my, Some(&target), "UsesTarget"), Value::Int(11));
        // Without a target, TARGET.* is undefined.
        assert_eq!(eval_attr(&my, None, "UsesTarget"), Value::Undefined);
    }

    #[test]
    fn unqualified_falls_back_to_target() {
        let mut my = Ad::new("Job");
        my.insert_expr("R", "Memory >= 100").unwrap();
        let mut target = Ad::new("Machine");
        target.insert("Memory", 200i64);
        assert_eq!(eval_attr(&my, Some(&target), "R"), Value::Bool(true));
    }

    #[test]
    fn cyclic_attrs_are_error() {
        let mut ad = Ad::new("Job");
        ad.insert_expr("A", "B").unwrap();
        ad.insert_expr("B", "A").unwrap();
        assert_eq!(eval_attr(&ad, None, "A"), Value::Error);
    }

    #[test]
    fn deep_expression_display_roundtrip() {
        let src = "(TARGET.Memory >= MY.RequestMemory) && (KFlops > 1000 || Disk * 2 >= 10)";
        let e = parse_expr(src).unwrap();
        let printed = e.to_string();
        // Round-trip: printing then reparsing yields an equal tree shape.
        let e2 = parse_expr(&printed).unwrap();
        assert_eq!(printed, e2.to_string());
    }
}
