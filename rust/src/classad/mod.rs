//! ClassAds: HTCondor's attribute/expression language and bilateral
//! matchmaking — the substrate every daemon speaks.
//!
//! Implemented here: the "old ClassAds" dialect HTCondor pools actually
//! run on — flat attribute ads whose values are lazily-evaluated
//! expressions with three-valued logic (`UNDEFINED` / `ERROR` propagate),
//! `MY.`/`TARGET.` scoping, and the `Requirements`/`Rank` bilateral match
//! used by the negotiator.
//!
//! ```no_run
//! use htcdm::classad::{Ad, matches};
//!
//! let mut job = Ad::new("Job");
//! job.insert_expr("Requirements", "TARGET.Memory >= 2048 && TARGET.Arch == \"X86_64\"").unwrap();
//! job.insert("RequestMemory", 2048i64);
//!
//! let mut slot = Ad::new("Machine");
//! slot.insert("Memory", 4096i64);
//! slot.insert("Arch", "X86_64");
//! slot.insert_expr("Requirements", "TARGET.RequestMemory <= MY.Memory").unwrap();
//!
//! assert!(matches(&job, &slot).unwrap());
//! ```

mod expr;

pub use expr::{parse_expr, BinOp, Expr, ParseError, UnOp};

use std::collections::BTreeMap;
use std::fmt;

/// A ClassAd value (the result of evaluating an expression).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Undefined,
    Error,
    Bool(bool),
    Int(i64),
    Real(f64),
    Str(String),
    List(Vec<Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric coercion used by arithmetic.
    pub fn as_real(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Real(r) => Some(*r),
            Value::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Real(r) => Some(*r as i64),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_undefined(&self) -> bool {
        matches!(self, Value::Undefined)
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Value::Error)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Undefined => write!(f, "undefined"),
            Value::Error => write!(f, "error"),
            Value::Bool(b) => write!(f, "{}", if *b { "true" } else { "false" }),
            Value::Int(i) => write!(f, "{i}"),
            Value::Real(r) => {
                if r.fract() == 0.0 && r.is_finite() {
                    write!(f, "{r:.1}")
                } else {
                    write!(f, "{r}")
                }
            }
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::List(xs) => {
                write!(f, "{{")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Value {
        Value::Int(i)
    }
}
impl From<u64> for Value {
    fn from(i: u64) -> Value {
        Value::Int(i as i64)
    }
}
impl From<f64> for Value {
    fn from(r: f64) -> Value {
        Value::Real(r)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

/// An attribute ad: name -> expression (stored unevaluated, as HTCondor
/// does, so `Rank = TARGET.KFlops` re-evaluates per candidate).
#[derive(Debug, Clone)]
pub struct Ad {
    /// MyType: "Job", "Machine", "Scheduler", ...
    pub my_type: String,
    attrs: BTreeMap<String, Expr>,
}

impl Ad {
    pub fn new(my_type: &str) -> Ad {
        Ad {
            my_type: my_type.to_string(),
            attrs: BTreeMap::new(),
        }
    }

    /// Insert a literal value.
    pub fn insert(&mut self, name: &str, value: impl Into<Value>) {
        self.attrs
            .insert(name.to_ascii_lowercase(), Expr::Lit(value.into()));
    }

    /// Insert an expression (parsed from ClassAd syntax).
    pub fn insert_expr(&mut self, name: &str, text: &str) -> Result<(), ParseError> {
        let e = parse_expr(text)?;
        self.attrs.insert(name.to_ascii_lowercase(), e);
        Ok(())
    }

    pub fn get_expr(&self, name: &str) -> Option<&Expr> {
        self.attrs.get(&name.to_ascii_lowercase())
    }

    pub fn remove(&mut self, name: &str) -> Option<Expr> {
        self.attrs.remove(&name.to_ascii_lowercase())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.attrs.contains_key(&name.to_ascii_lowercase())
    }

    pub fn len(&self) -> usize {
        self.attrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.attrs.is_empty()
    }

    pub fn attr_names(&self) -> impl Iterator<Item = &str> {
        self.attrs.keys().map(|s| s.as_str())
    }

    /// Evaluate an attribute in this ad's scope (no TARGET).
    pub fn eval(&self, name: &str) -> Value {
        expr::eval_attr(self, None, name)
    }

    /// Evaluate an attribute with a TARGET ad in scope.
    pub fn eval_with(&self, target: &Ad, name: &str) -> Value {
        expr::eval_attr(self, Some(target), name)
    }

    /// Convenience typed getters (evaluated without TARGET).
    pub fn get_int(&self, name: &str) -> Option<i64> {
        self.eval(name).as_int()
    }
    pub fn get_real(&self, name: &str) -> Option<f64> {
        self.eval(name).as_real()
    }
    pub fn get_str(&self, name: &str) -> Option<String> {
        match self.eval(name) {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn get_bool(&self, name: &str) -> Option<bool> {
        self.eval(name).as_bool()
    }
}

impl fmt::Display for Ad {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "MyType = \"{}\"", self.my_type)?;
        for (k, v) in &self.attrs {
            writeln!(f, "{k} = {v}")?;
        }
        Ok(())
    }
}

/// Bilateral match: both ads' `Requirements` must evaluate to true with the
/// other ad as TARGET. `UNDEFINED`/`ERROR` requirements are a non-match
/// (HTCondor semantics). A missing `Requirements` is treated as `true`.
pub fn matches(left: &Ad, right: &Ad) -> Result<bool, ParseError> {
    Ok(half_match(left, right) && half_match(right, left))
}

fn half_match(ad: &Ad, target: &Ad) -> bool {
    if !ad.contains("requirements") {
        return true;
    }
    matches!(ad.eval_with(target, "requirements"), Value::Bool(true))
}

/// Evaluate `Rank` of `ad` against a candidate; non-numeric ranks count as
/// 0.0 (HTCondor semantics).
pub fn rank(ad: &Ad, target: &Ad) -> f64 {
    ad.eval_with(target, "rank").as_real().unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job_and_slot() -> (Ad, Ad) {
        let mut job = Ad::new("Job");
        job.insert("RequestMemory", 2048i64);
        job.insert("RequestCpus", 1i64);
        job.insert("Owner", "alice");
        job.insert_expr(
            "Requirements",
            "TARGET.Memory >= MY.RequestMemory && TARGET.Cpus >= MY.RequestCpus",
        )
        .unwrap();
        let mut slot = Ad::new("Machine");
        slot.insert("Memory", 4096i64);
        slot.insert("Cpus", 8i64);
        slot.insert("KFlops", 1_000_000i64);
        slot.insert_expr("Requirements", "TARGET.RequestMemory <= MY.Memory")
            .unwrap();
        (job, slot)
    }

    #[test]
    fn bilateral_match() {
        let (job, slot) = job_and_slot();
        assert!(matches(&job, &slot).unwrap());
    }

    #[test]
    fn match_fails_when_resources_insufficient() {
        let (mut job, slot) = job_and_slot();
        job.insert("RequestMemory", 8192i64);
        assert!(!matches(&job, &slot).unwrap());
    }

    #[test]
    fn missing_requirements_is_true() {
        let mut a = Ad::new("Job");
        a.insert("X", 1i64);
        let b = Ad::new("Machine");
        assert!(matches(&a, &b).unwrap());
    }

    #[test]
    fn undefined_requirements_is_no_match() {
        let mut job = Ad::new("Job");
        job.insert_expr("Requirements", "TARGET.NoSuchAttr > 5").unwrap();
        let slot = Ad::new("Machine");
        assert!(!matches(&job, &slot).unwrap());
    }

    #[test]
    fn rank_orders_candidates() {
        let mut job = Ad::new("Job");
        job.insert_expr("Rank", "TARGET.KFlops").unwrap();
        let mut fast = Ad::new("Machine");
        fast.insert("KFlops", 100i64);
        let mut slow = Ad::new("Machine");
        slow.insert("KFlops", 10i64);
        assert!(rank(&job, &fast) > rank(&job, &slow));
        // Missing rank -> 0
        let norank = Ad::new("Job");
        assert_eq!(rank(&norank, &fast), 0.0);
    }

    #[test]
    fn attr_names_case_insensitive() {
        let mut ad = Ad::new("Job");
        ad.insert("FooBar", 1i64);
        assert!(ad.contains("foobar"));
        assert!(ad.contains("FOOBAR"));
        assert_eq!(ad.get_int("fooBAR"), Some(1));
    }

    #[test]
    fn display_roundtrips_through_parser() {
        let (job, _) = job_and_slot();
        let text = job.to_string();
        assert!(text.contains("requirements ="));
        // Every displayed attr line parses back.
        for line in text.lines().skip(1) {
            let (_, rhs) = line.split_once('=').unwrap();
            parse_expr(rhs.trim()).unwrap();
        }
    }

    #[test]
    fn self_referencing_attr() {
        let mut ad = Ad::new("Machine");
        ad.insert("Base", 10i64);
        ad.insert_expr("Total", "Base * 2").unwrap();
        assert_eq!(ad.get_int("Total"), Some(20));
    }
}
