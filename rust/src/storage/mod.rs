//! Storage substrate: file catalog with hardlinks, page cache, and device
//! profiles.
//!
//! The paper's §III trick — one 2 GB extent hard-linked under 10k names so
//! "10k independent files" are served from the page cache — is modeled
//! faithfully: the catalog distinguishes *names* from *extents*, and the
//! cache tracks extents, so the 10k-job workload touches a single cached
//! extent and the storage subsystem never bottlenecks (exactly the
//! experimental design intent).
//!
//! The device profiles also feed the transfer queue's disk-load throttle
//! (HTCondor's `FILE_TRANSFER_DISK_LOAD_THROTTLE` is tuned for spinning
//! disks; the paper had to disable it to reach 90 Gbps).

use crate::netsim::calib;
use std::collections::{BTreeMap, HashMap};

/// Identifier of a physical data extent (an inode, roughly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExtentId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// NVMe flash: fast, concurrency-tolerant.
    Nvme,
    /// Spinning disk: seek-bound under concurrency.
    Spinning,
}

/// A storage device with a simple concurrency-degradation model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub kind: DeviceKind,
    /// Aggregate sequential bandwidth, bytes/sec.
    pub bandwidth_bps: f64,
    /// Fractional throughput loss per additional concurrent stream
    /// (seek amplification). 0 for flash.
    pub seek_penalty: f64,
}

impl DeviceProfile {
    pub fn nvme() -> DeviceProfile {
        DeviceProfile {
            kind: DeviceKind::Nvme,
            bandwidth_bps: calib::NVME_DISK_BPS,
            seek_penalty: 0.0,
        }
    }

    pub fn spinning() -> DeviceProfile {
        DeviceProfile {
            kind: DeviceKind::Spinning,
            bandwidth_bps: calib::SPINNING_DISK_BPS,
            seek_penalty: 0.15,
        }
    }

    /// Aggregate read bandwidth with `n` concurrent streams.
    pub fn aggregate_bps(&self, n: u32) -> f64 {
        if n == 0 {
            return self.bandwidth_bps;
        }
        self.bandwidth_bps / (1.0 + self.seek_penalty * (n as f64 - 1.0))
    }
}

#[derive(Debug, Clone)]
struct FileMeta {
    extent: ExtentId,
    bytes: u64,
}

/// File catalog + page cache for one node's storage.
#[derive(Debug)]
pub struct Storage {
    device: DeviceProfile,
    files: BTreeMap<String, FileMeta>,
    extents: HashMap<ExtentId, u64>,
    next_extent: u64,
    /// Cached extents (bytes resident), LRU by insertion order.
    cache: BTreeMap<ExtentId, u64>,
    cache_capacity: u64,
    cache_used: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl Storage {
    pub fn new(device: DeviceProfile, cache_capacity: u64) -> Storage {
        Storage {
            device,
            files: BTreeMap::new(),
            extents: HashMap::new(),
            next_extent: 0,
            cache: BTreeMap::new(),
            cache_capacity,
            cache_used: 0,
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    pub fn device(&self) -> DeviceProfile {
        self.device
    }

    /// Create a new file with fresh data.
    pub fn create(&mut self, name: &str, bytes: u64) -> ExtentId {
        let ext = ExtentId(self.next_extent);
        self.next_extent += 1;
        self.extents.insert(ext, bytes);
        self.files.insert(
            name.to_string(),
            FileMeta { extent: ext, bytes },
        );
        ext
    }

    /// Create a hard link: a new name sharing an existing file's extent —
    /// the paper's "10k unique file names hard linking" setup.
    pub fn hardlink(&mut self, existing: &str, new_name: &str) -> Option<ExtentId> {
        let meta = self.files.get(existing)?.clone();
        self.files.insert(new_name.to_string(), meta.clone());
        Some(meta.extent)
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn file_bytes(&self, name: &str) -> Option<u64> {
        self.files.get(name).map(|m| m.bytes)
    }

    pub fn file_extent(&self, name: &str) -> Option<ExtentId> {
        self.files.get(name).map(|m| m.extent)
    }

    /// Number of distinct extents behind all names (the paper: 10k names,
    /// 1 extent).
    pub fn distinct_extents(&self) -> usize {
        let mut set: Vec<ExtentId> = self.files.values().map(|m| m.extent).collect();
        set.sort();
        set.dedup();
        set.len()
    }

    /// Open a file for reading; returns the effective source bandwidth for
    /// this stream's data (cache vs device) and updates cache state.
    pub fn open_read(&mut self, name: &str) -> Option<ReadSource> {
        let meta = self.files.get(name)?.clone();
        if self.cache.contains_key(&meta.extent) {
            self.cache_hits += 1;
            Some(ReadSource {
                cached: true,
                bps: calib::PAGE_CACHE_BPS,
            })
        } else {
            self.cache_misses += 1;
            self.admit(meta.extent, meta.bytes);
            Some(ReadSource {
                cached: false,
                bps: self.device.bandwidth_bps,
            })
        }
    }

    fn admit(&mut self, ext: ExtentId, bytes: u64) {
        if bytes > self.cache_capacity {
            return; // uncacheable
        }
        while self.cache_used + bytes > self.cache_capacity {
            // Evict oldest (BTreeMap first key ~ FIFO approximation of LRU
            // at the granularity we need).
            let Some((&victim, &vb)) = self.cache.iter().next() else {
                break;
            };
            self.cache.remove(&victim);
            self.cache_used -= vb;
        }
        self.cache.insert(ext, bytes);
        self.cache_used += bytes;
    }

    /// Pre-warm an extent into cache (the paper's setup read the file once).
    pub fn warm(&mut self, name: &str) -> bool {
        let Some(meta) = self.files.get(name).map(|m| m.clone()) else {
            return false;
        };
        self.admit(meta.extent, meta.bytes);
        self.cache.contains_key(&meta.extent)
    }

    pub fn cached_bytes(&self) -> u64 {
        self.cache_used
    }

    /// Is the extent currently resident in the page cache?
    pub fn contains_extent(&self, ext: ExtentId) -> bool {
        self.cache.contains_key(&ext)
    }

    /// Extents currently resident in the page cache, in id order (the
    /// truth the router's cache-aware residency view is re-synced from).
    pub fn cached_extents(&self) -> Vec<ExtentId> {
        self.cache.keys().copied().collect()
    }

    /// Drop the entire page cache — a crashed node's cache dies with it
    /// (fault injection: the recovered node starts cold).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
        self.cache_used = 0;
    }

    /// Aggregate source bandwidth with `n` concurrent readers, assuming
    /// `cached_fraction` of streams hit cache.
    pub fn aggregate_read_bps(&self, n: u32, cached_fraction: f64) -> f64 {
        let cached = calib::PAGE_CACHE_BPS * cached_fraction;
        let disk = self.device.aggregate_bps(n) * (1.0 - cached_fraction);
        cached + disk
    }
}

/// Result of opening a file for read.
#[derive(Debug, Clone, Copy)]
pub struct ReadSource {
    pub cached: bool,
    pub bps: f64,
}

/// Build the paper's §III dataset: one `bytes` extent with `names` hard
/// links named `prefix0000..`.
pub fn build_paper_dataset(storage: &mut Storage, prefix: &str, bytes: u64, names: usize) {
    let first = format!("{prefix}0");
    storage.create(&first, bytes);
    storage.warm(&first);
    for i in 1..names {
        storage
            .hardlink(&first, &format!("{prefix}{i}"))
            .expect("hardlink source exists");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardlinks_share_extent() {
        let mut s = Storage::new(DeviceProfile::nvme(), 8 << 30);
        s.create("data0", 2 << 30);
        s.hardlink("data0", "data1").unwrap();
        s.hardlink("data0", "data2").unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.distinct_extents(), 1);
        assert_eq!(s.file_extent("data0"), s.file_extent("data2"));
        assert!(s.hardlink("missing", "x").is_none());
    }

    #[test]
    fn paper_dataset_shape() {
        let mut s = Storage::new(DeviceProfile::nvme(), 8 << 30);
        build_paper_dataset(&mut s, "input_", 2 << 30, 10_000);
        assert_eq!(s.len(), 10_000);
        assert_eq!(s.distinct_extents(), 1);
        assert_eq!(s.cached_bytes(), 2 << 30, "the single extent is cached");
    }

    #[test]
    fn cached_reads_hit_page_cache() {
        let mut s = Storage::new(DeviceProfile::spinning(), 8 << 30);
        build_paper_dataset(&mut s, "f", 1 << 30, 100);
        for i in 0..100 {
            let src = s.open_read(&format!("f{i}")).unwrap();
            assert!(src.cached, "all hardlinked reads hit cache");
            assert_eq!(src.bps, calib::PAGE_CACHE_BPS);
        }
        assert_eq!(s.cache_hits, 100);
        assert_eq!(s.cache_misses, 0);
    }

    #[test]
    fn distinct_files_miss_then_hit() {
        let mut s = Storage::new(DeviceProfile::nvme(), 8 << 30);
        s.create("a", 1 << 30);
        s.create("b", 1 << 30);
        assert!(!s.open_read("a").unwrap().cached);
        assert!(s.open_read("a").unwrap().cached);
        assert!(!s.open_read("b").unwrap().cached);
        assert_eq!(s.cache_misses, 2);
        assert_eq!(s.cache_hits, 1);
    }

    #[test]
    fn cache_eviction() {
        let mut s = Storage::new(DeviceProfile::nvme(), 2 << 30);
        s.create("a", 1 << 30);
        s.create("b", 1 << 30);
        s.create("c", 1 << 30);
        s.open_read("a");
        s.open_read("b");
        s.open_read("c"); // evicts something
        assert!(s.cached_bytes() <= 2 << 30);
    }

    #[test]
    fn residency_view_tracks_cache_and_clears_on_crash() {
        let mut s = Storage::new(DeviceProfile::nvme(), 4 << 30);
        let a = s.create("a", 1 << 30);
        let b = s.create("b", 1 << 30);
        s.open_read("a");
        assert!(s.contains_extent(a));
        assert!(!s.contains_extent(b));
        assert_eq!(s.cached_extents(), vec![a]);
        s.open_read("b");
        assert_eq!(s.cached_extents(), vec![a, b]);
        s.clear_cache();
        assert_eq!(s.cached_bytes(), 0);
        assert!(s.cached_extents().is_empty());
        assert!(!s.open_read("a").unwrap().cached, "cold after the crash");
    }

    #[test]
    fn uncacheable_when_larger_than_cache() {
        let mut s = Storage::new(DeviceProfile::nvme(), 1 << 20);
        s.create("huge", 1 << 30);
        assert!(!s.open_read("huge").unwrap().cached);
        assert!(!s.open_read("huge").unwrap().cached, "never cached");
    }

    #[test]
    fn spinning_degrades_with_concurrency() {
        let d = DeviceProfile::spinning();
        assert!(d.aggregate_bps(1) > d.aggregate_bps(10));
        assert!(d.aggregate_bps(10) > d.aggregate_bps(100));
        let flash = DeviceProfile::nvme();
        assert_eq!(flash.aggregate_bps(1), flash.aggregate_bps(100));
    }

    #[test]
    fn aggregate_read_mixes_cache_and_disk() {
        let s = Storage::new(DeviceProfile::spinning(), 8 << 30);
        let all_cache = s.aggregate_read_bps(50, 1.0);
        let all_disk = s.aggregate_read_bps(50, 0.0);
        assert!(all_cache > all_disk * 10.0);
        let mixed = s.aggregate_read_bps(50, 0.5);
        assert!(mixed < all_cache && mixed > all_disk);
    }
}
