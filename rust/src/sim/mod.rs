//! Discrete-event simulation core: a deterministic event queue over
//! virtual [`SimTime`].
//!
//! Ties are broken by insertion sequence so runs are exactly reproducible.
//! The experiment engine (`coordinator::engine`) drives everything through
//! this queue: job lifecycle events, negotiation cycles, network
//! re-solves, background-traffic updates.

use crate::util::units::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A deterministic time-ordered event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(SimTime, u64)>>,
    payload: std::collections::HashMap<u64, E>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            payload: std::collections::HashMap::new(),
            seq: 0,
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `t`. Returns a token that can be
    /// used to cancel the event.
    pub fn push(&mut self, t: SimTime, event: E) -> u64 {
        let tok = self.seq;
        self.seq += 1;
        self.heap.push(Reverse((t, tok)));
        self.payload.insert(tok, event);
        tok
    }

    /// Cancel a scheduled event by token. Returns the payload if it had not
    /// fired yet.
    pub fn cancel(&mut self, token: u64) -> Option<E> {
        self.payload.remove(&token)
    }

    /// Pop the earliest live event.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        while let Some(Reverse((t, tok))) = self.heap.pop() {
            if let Some(e) = self.payload.remove(&tok) {
                return Some((t, e));
            }
            // cancelled — skip
        }
        None
    }

    /// Time of the earliest live event without popping it.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        while let Some(Reverse((t, tok))) = self.heap.peek().copied() {
            if self.payload.contains_key(&tok) {
                return Some(t);
            }
            self.heap.pop();
        }
        None
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    pub fn len(&self) -> usize {
        self.payload.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_by_time_then_insertion() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(5), "c");
        q.push(SimTime::from_secs(1), "a1");
        q.push(SimTime::from_secs(1), "a2");
        q.push(SimTime::from_secs(3), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a1", "a2", "b", "c"]);
    }

    #[test]
    fn cancel_suppresses_event() {
        let mut q = EventQueue::new();
        let t1 = q.push(SimTime::from_secs(1), 1);
        q.push(SimTime::from_secs(2), 2);
        assert_eq!(q.cancel(t1), Some(1));
        assert_eq!(q.cancel(t1), None, "double-cancel is None");
        assert_eq!(q.pop(), Some((SimTime::from_secs(2), 2)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_skips_cancelled() {
        let mut q = EventQueue::new();
        let t = q.push(SimTime::from_secs(1), "x");
        q.push(SimTime::from_secs(4), "y");
        q.cancel(t);
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(4)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn empty_queue() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        assert!(q.peek_time().is_none());
    }
}
