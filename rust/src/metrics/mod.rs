//! Metrics: binned throughput timeseries (the paper's Fig. 1/2 are 5-min
//! binned network monitor plots) and ASCII rendering for the CLI/benches.

use crate::util::units::{Gbps, SimTime};

/// A time-binned byte counter: bytes carried per fixed-width bin.
#[derive(Debug, Clone)]
pub struct BinSeries {
    bin: SimTime,
    bins: Vec<f64>,
}

impl BinSeries {
    pub fn new(bin: SimTime) -> BinSeries {
        assert!(bin.0 > 0);
        BinSeries {
            bin,
            bins: Vec::new(),
        }
    }

    pub fn bin_width(&self) -> SimTime {
        self.bin
    }

    fn ensure(&mut self, idx: usize) {
        if idx >= self.bins.len() {
            self.bins.resize(idx + 1, 0.0);
        }
    }

    /// Add `bytes` carried uniformly over [t0, t1), spreading across bins.
    pub fn add_spread(&mut self, t0: SimTime, t1: SimTime, bytes: f64) {
        if bytes <= 0.0 {
            return;
        }
        if t1 <= t0 {
            let idx = (t0.0 / self.bin.0) as usize;
            self.ensure(idx);
            self.bins[idx] += bytes;
            return;
        }
        let span = (t1.0 - t0.0) as f64;
        let first = t0.0 / self.bin.0;
        let last = (t1.0.saturating_sub(1)) / self.bin.0;
        self.ensure(last as usize);
        for b in first..=last {
            let bin_start = b * self.bin.0;
            let bin_end = bin_start + self.bin.0;
            let lo = bin_start.max(t0.0);
            let hi = bin_end.min(t1.0);
            let frac = (hi.saturating_sub(lo)) as f64 / span;
            self.bins[b as usize] += bytes * frac;
        }
    }

    /// Add all bytes at instant `t`.
    pub fn add_at(&mut self, t: SimTime, bytes: f64) {
        self.add_spread(t, t, bytes);
    }

    /// (bin start time, bytes) pairs.
    pub fn bins(&self) -> Vec<(SimTime, f64)> {
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &b)| (SimTime(i as u64 * self.bin.0), b))
            .collect()
    }

    /// Mean throughput per bin, in Gbps (the figure's y-axis).
    pub fn gbps_series(&self) -> Vec<(SimTime, Gbps)> {
        let secs = self.bin.as_secs_f64();
        self.bins()
            .into_iter()
            .map(|(t, b)| (t, Gbps::from_bytes_per_sec(b / secs)))
            .collect()
    }

    /// Re-bin into a coarser width (must be a multiple of the current one).
    pub fn rebin(&self, new_bin: SimTime) -> BinSeries {
        assert!(new_bin.0 >= self.bin.0 && new_bin.0 % self.bin.0 == 0);
        let k = (new_bin.0 / self.bin.0) as usize;
        let mut out = BinSeries::new(new_bin);
        out.bins = self
            .bins
            .chunks(k)
            .map(|c| c.iter().sum::<f64>())
            .collect();
        out
    }

    pub fn total_bytes(&self) -> f64 {
        self.bins.iter().sum()
    }

    /// Element-wise add another series (same bin width) into this one —
    /// how per-submit-node NIC monitors aggregate into the pool series.
    pub fn merge(&mut self, other: &BinSeries) {
        assert_eq!(
            self.bin, other.bin,
            "can only merge series with equal bin widths"
        );
        if !other.bins.is_empty() {
            self.ensure(other.bins.len() - 1);
        }
        for (i, b) in other.bins.iter().enumerate() {
            self.bins[i] += b;
        }
    }

    /// Element-wise sum of several series with equal bin widths (at least
    /// one required).
    pub fn sum(series: &[BinSeries]) -> BinSeries {
        let first = series.first().expect("sum needs at least one series");
        let mut out = BinSeries::new(first.bin_width());
        for s in series {
            out.merge(s);
        }
        out
    }

    /// Peak bin throughput in Gbps.
    pub fn peak_gbps(&self) -> Gbps {
        let secs = self.bin.as_secs_f64();
        let peak = self.bins.iter().cloned().fold(0.0, f64::max);
        Gbps::from_bytes_per_sec(peak / secs)
    }

    /// Sustained throughput: mean of bins above `frac` of the peak — the
    /// number one reads off the paper's monitoring screenshots (plateau
    /// height, ignoring ramp-up/drain bins).
    pub fn sustained_gbps(&self, frac: f64) -> Gbps {
        let secs = self.bin.as_secs_f64();
        let peak = self.bins.iter().cloned().fold(0.0, f64::max);
        let plateau: Vec<f64> = self
            .bins
            .iter()
            .cloned()
            .filter(|&b| b >= peak * frac)
            .collect();
        if plateau.is_empty() {
            return Gbps(0.0);
        }
        let mean = plateau.iter().sum::<f64>() / plateau.len() as f64;
        Gbps::from_bytes_per_sec(mean / secs)
    }

    /// Render the series as an ASCII chart like the paper's monitoring
    /// page (one row per bin).
    pub fn ascii_chart(&self, width: usize, cap: Gbps) -> String {
        let secs = self.bin.as_secs_f64();
        let mut out = String::new();
        out.push_str(&format!(
            "{:>8} | {:<width$} | Gbps\n",
            "t",
            format!("0 .. {cap}"),
            width = width
        ));
        for (t, b) in self.bins() {
            let gbps = b / secs * 8.0 / 1e9;
            let n = ((gbps / cap.0) * width as f64).round().clamp(0.0, width as f64) as usize;
            out.push_str(&format!(
                "{:>8} | {:<width$} | {:6.1}\n",
                format!("{:.0}m", t.as_mins_f64()),
                "█".repeat(n),
                gbps,
                width = width
            ));
        }
        out
    }
}

/// CSV export of a gbps series ("minute,gbps" rows) for plotting.
pub fn to_csv(series: &BinSeries) -> String {
    let mut s = String::from("minute,gbps\n");
    for (t, g) in series.gbps_series() {
        s.push_str(&format!("{:.2},{:.3}\n", t.as_mins_f64(), g.0));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_across_bins() {
        let mut s = BinSeries::new(SimTime::from_secs(10));
        // 100 bytes over [5s, 25s): 25% in bin0, 50% in bin1, 25% in bin2.
        s.add_spread(SimTime::from_secs(5), SimTime::from_secs(25), 100.0);
        let bins = s.bins();
        assert_eq!(bins.len(), 3);
        assert!((bins[0].1 - 25.0).abs() < 1e-9);
        assert!((bins[1].1 - 50.0).abs() < 1e-9);
        assert!((bins[2].1 - 25.0).abs() < 1e-9);
        assert!((s.total_bytes() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn instant_add() {
        let mut s = BinSeries::new(SimTime::from_secs(10));
        s.add_at(SimTime::from_secs(15), 7.0);
        assert_eq!(s.bins().len(), 2);
        assert!((s.bins()[1].1 - 7.0).abs() < 1e-12);
    }

    #[test]
    fn gbps_conversion() {
        let mut s = BinSeries::new(SimTime::from_secs(1));
        s.add_spread(SimTime::ZERO, SimTime::from_secs(1), 12.5e9); // 100 Gb in 1s
        let g = s.gbps_series();
        assert!((g[0].1 .0 - 100.0).abs() < 1e-9);
        assert!((s.peak_gbps().0 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rebin_preserves_total() {
        let mut s = BinSeries::new(SimTime::from_secs(60));
        for i in 0..10 {
            s.add_at(SimTime::from_secs(i * 60 + 1), i as f64);
        }
        let coarse = s.rebin(SimTime::from_secs(300));
        assert_eq!(coarse.bins().len(), 2);
        assert!((coarse.total_bytes() - s.total_bytes()).abs() < 1e-9);
        assert!((coarse.bins()[0].1 - (0.0 + 1.0 + 2.0 + 3.0 + 4.0)).abs() < 1e-9);
    }

    #[test]
    fn sustained_ignores_ramp() {
        let mut s = BinSeries::new(SimTime::from_secs(1));
        // ramp 10, plateau 100 ×4, drain 5
        for (i, v) in [10.0, 100.0, 100.0, 100.0, 100.0, 5.0].iter().enumerate() {
            s.add_at(SimTime::from_secs(i as u64), v * 1e9 / 8.0);
        }
        let sus = s.sustained_gbps(0.5);
        assert!((sus.0 - 100.0).abs() < 1e-6, "got {sus}");
        assert!(s.peak_gbps().0 >= sus.0);
    }

    #[test]
    fn ascii_chart_shape() {
        let mut s = BinSeries::new(SimTime::from_secs(60));
        s.add_at(SimTime::from_secs(30), 60e9 / 8.0 * 60.0);
        let art = s.ascii_chart(40, Gbps(100.0));
        assert!(art.contains('█'));
        assert!(art.lines().count() >= 2);
    }

    #[test]
    fn merge_and_sum_are_elementwise() {
        let mut a = BinSeries::new(SimTime::from_secs(10));
        a.add_at(SimTime::from_secs(5), 10.0);
        let mut b = BinSeries::new(SimTime::from_secs(10));
        b.add_at(SimTime::from_secs(25), 4.0);
        let total = BinSeries::sum(&[a.clone(), b.clone()]);
        assert_eq!(total.bins().len(), 3);
        assert!((total.bins()[0].1 - 10.0).abs() < 1e-12);
        assert!((total.bins()[2].1 - 4.0).abs() < 1e-12);
        assert!((total.total_bytes() - 14.0).abs() < 1e-12);
        a.merge(&b);
        assert!((a.total_bytes() - 14.0).abs() < 1e-12);
    }

    #[test]
    fn csv_format() {
        let mut s = BinSeries::new(SimTime::from_secs(60));
        s.add_at(SimTime::ZERO, 1e9);
        let csv = to_csv(&s);
        assert!(csv.starts_with("minute,gbps\n"));
        assert_eq!(csv.lines().count(), 2);
    }
}
