//! The negotiator: periodic matchmaking cycles pairing idle jobs with
//! unclaimed slots via bilateral ClassAd matching, with autocluster
//! optimization (identical jobs are matched once per cycle, which is what
//! lets HTCondor negotiate 10k-job submissions in seconds).

use crate::classad::{matches, rank, Ad};
use crate::jobs::{autocluster_signature, JobId};
use std::collections::HashMap;

use super::startd::SlotId;

/// Result of one negotiation cycle.
#[derive(Debug, Default)]
pub struct CycleResult {
    pub matches: Vec<(JobId, SlotId)>,
    pub autoclusters: usize,
    pub considered_slots: usize,
}

#[derive(Debug, Default)]
pub struct Negotiator {
    pub cycles: u64,
}

impl Negotiator {
    pub fn new() -> Negotiator {
        Negotiator::default()
    }

    /// One cycle: greedily hand each idle job (grouped by autocluster) the
    /// best-ranked matching unclaimed slot. `idle_jobs` are (id, ad) in
    /// queue order; `slots` are (id, ad) of unclaimed slots.
    pub fn negotiate(
        &mut self,
        idle_jobs: &[(JobId, &Ad)],
        slots: &[(SlotId, Ad)],
    ) -> CycleResult {
        self.cycles += 1;
        let mut result = CycleResult {
            considered_slots: slots.len(),
            ..Default::default()
        };
        if idle_jobs.is_empty() || slots.is_empty() {
            return result;
        }

        // Group jobs by autocluster; candidate slot set is computed once
        // per autocluster against a representative ad.
        let mut cluster_of: HashMap<String, Vec<usize>> = HashMap::new();
        let mut order: Vec<String> = Vec::new();
        for (i, (_, ad)) in idle_jobs.iter().enumerate() {
            let sig = autocluster_signature(ad);
            if !cluster_of.contains_key(&sig) {
                order.push(sig.clone());
            }
            cluster_of.entry(sig).or_default().push(i);
        }
        result.autoclusters = order.len();

        let mut slot_free: Vec<bool> = vec![true; slots.len()];
        for sig in order {
            let members = &cluster_of[&sig];
            let rep_ad = idle_jobs[members[0]].1;
            // Rank all matching free slots once for the representative.
            let mut candidates: Vec<(usize, f64)> = slots
                .iter()
                .enumerate()
                .filter(|(si, _)| slot_free[*si])
                .filter(|(_, (_, slot_ad))| matches(rep_ad, slot_ad).unwrap_or(false))
                .map(|(si, (_, slot_ad))| (si, rank(rep_ad, slot_ad)))
                .collect();
            // Best rank first; stable by slot order for determinism.
            candidates.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
            for (&job_idx, &(slot_idx, _)) in members.iter().zip(candidates.iter()) {
                slot_free[slot_idx] = false;
                result
                    .matches
                    .push((idle_jobs[job_idx].0, slots[slot_idx].0));
            }
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::{build_job_ad, JobSpec};
    use crate::util::units::Bytes;

    fn jspec(p: u32) -> JobSpec {
        JobSpec {
            id: JobId { cluster: 1, proc: p },
            owner: "a".into(),
            input_file: format!("f{p}"),
            input_extent: None,
            input_bytes: Bytes::gib(2),
            output_bytes: Bytes::kib(4),
            runtime_median_s: 5.0,
        }
    }

    fn slot_ad(mem: i64, kflops: i64) -> Ad {
        let mut ad = Ad::new("Machine");
        ad.insert("Cpus", 1i64);
        ad.insert("Memory", mem);
        ad.insert("KFlops", kflops);
        ad.insert("HasFileTransfer", true);
        ad
    }

    fn sid(w: u32, s: u32) -> SlotId {
        SlotId { worker: w, slot: s }
    }

    #[test]
    fn matches_up_to_slot_count() {
        let ads: Vec<Ad> = (0..3).map(|p| build_job_ad(&jspec(p))).collect();
        let jobs: Vec<(JobId, &Ad)> = ads
            .iter()
            .enumerate()
            .map(|(p, ad)| (JobId { cluster: 1, proc: p as u32 }, ad))
            .collect();
        let slots = vec![(sid(0, 0), slot_ad(4096, 1)), (sid(0, 1), slot_ad(4096, 1))];
        let mut neg = Negotiator::new();
        let r = neg.negotiate(&jobs, &slots);
        assert_eq!(r.matches.len(), 2, "two slots, three jobs");
        assert_eq!(r.autoclusters, 1, "identical jobs share one autocluster");
        // Distinct slots assigned.
        assert_ne!(r.matches[0].1, r.matches[1].1);
    }

    #[test]
    fn no_match_when_requirements_fail() {
        let ad = build_job_ad(&jspec(0));
        let jobs = vec![(JobId { cluster: 1, proc: 0 }, &ad)];
        let mut bad_slot = slot_ad(4096, 1);
        bad_slot.insert("HasFileTransfer", false);
        let mut neg = Negotiator::new();
        let r = neg.negotiate(&jobs, &[(sid(0, 0), bad_slot)]);
        assert!(r.matches.is_empty());
    }

    #[test]
    fn rank_prefers_better_slot() {
        let mut ad = build_job_ad(&jspec(0));
        ad.insert_expr("Rank", "TARGET.KFlops").unwrap();
        let jobs = vec![(JobId { cluster: 1, proc: 0 }, &ad)];
        let slots = vec![
            (sid(0, 0), slot_ad(4096, 10)),
            (sid(1, 0), slot_ad(4096, 1000)),
            (sid(2, 0), slot_ad(4096, 100)),
        ];
        let mut neg = Negotiator::new();
        let r = neg.negotiate(&jobs, &slots);
        assert_eq!(r.matches, vec![(JobId { cluster: 1, proc: 0 }, sid(1, 0))]);
    }

    #[test]
    fn empty_inputs() {
        let mut neg = Negotiator::new();
        let r = neg.negotiate(&[], &[]);
        assert!(r.matches.is_empty());
        assert_eq!(neg.cycles, 1);
    }

    #[test]
    fn scales_to_10k_jobs_quickly() {
        // The autocluster path must handle the paper's 10k-job transaction
        // without 10k × 200 bilateral evaluations.
        let ads: Vec<Ad> = (0..10_000).map(|p| build_job_ad(&jspec(p))).collect();
        let jobs: Vec<(JobId, &Ad)> = ads
            .iter()
            .enumerate()
            .map(|(p, ad)| (JobId { cluster: 1, proc: p as u32 }, ad))
            .collect();
        let slots: Vec<(SlotId, Ad)> = (0..200)
            .map(|s| (sid(s / 34, s % 34), slot_ad(4096, 1)))
            .collect();
        let t0 = std::time::Instant::now();
        let mut neg = Negotiator::new();
        let r = neg.negotiate(&jobs, &slots);
        assert_eq!(r.matches.len(), 200);
        assert_eq!(r.autoclusters, 1);
        assert!(
            t0.elapsed().as_secs_f64() < 2.0,
            "negotiation took {:?}",
            t0.elapsed()
        );
    }
}
