//! The schedd: the submit-side daemon owning the job queue, the user log,
//! and (in a default HTCondor setup) *all* sandbox data movement — which
//! is exactly why the paper benchmarks it as the potential bottleneck.
//! Data movement itself is delegated to a [`crate::mover::PoolRouter`]
//! over per-submit-node [`crate::mover::ShadowPool`]s: the schedd tracks
//! job lifecycle, the router owns node routing, admission and shard
//! assignment (a single-node router is exactly the paper's one submit
//! node).

use crate::jobs::log::{EventKind, UserLog};
use crate::jobs::{Job, JobId, JobSpec, JobState};
use crate::mover::task::TransferTask;
use crate::mover::{PoolRouter, Routed, ShadowPool, TransferRequest};
use crate::transfer::ThrottlePolicy;
use crate::util::units::{Bytes, SimTime};
use std::collections::VecDeque;

#[derive(Debug)]
pub struct Schedd {
    pub name: String,
    pub jobs: Vec<Job>,
    /// Procs waiting for a match, in submission order.
    idle: VecDeque<u32>,
    pub log: UserLog,
    /// Upload (input sandbox) data movement — node routing and admission
    /// mechanics are fully delegated to the pool router.
    pub mover: PoolRouter,
}

impl Schedd {
    /// A schedd with a single-node, single-shard mover running the given
    /// classic throttle (the paper's configuration space).
    pub fn new(name: &str, policy: ThrottlePolicy) -> Schedd {
        Schedd::with_router(name, PoolRouter::single(ShadowPool::sim(1, policy.into())))
    }

    /// A schedd delegating sandbox movement to a multi-node pool router
    /// (wrap a single [`ShadowPool`] with [`PoolRouter::single`] for the
    /// paper's one-submit-node shape).
    pub fn with_router(name: &str, router: PoolRouter) -> Schedd {
        Schedd {
            name: name.to_string(),
            jobs: Vec::new(),
            idle: VecDeque::new(),
            log: UserLog::new(),
            mover: router,
        }
    }

    /// Extract the router (e.g. to hand the same policy object to the
    /// real fabric after a simulated run); leaves a fresh single-node
    /// unthrottled router behind.
    pub fn take_router(&mut self) -> PoolRouter {
        std::mem::replace(
            &mut self.mover,
            PoolRouter::single(ShadowPool::sim(1, ThrottlePolicy::Disabled.into())),
        )
    }

    /// One submit transaction (the paper queued all 10k jobs in one).
    pub fn submit_transaction(&mut self, specs: Vec<JobSpec>, t: SimTime) {
        for spec in specs {
            let id = spec.id;
            debug_assert_eq!(id.proc as usize, self.jobs.len());
            self.log.record(t, id, EventKind::Submitted);
            self.idle.push_back(id.proc);
            self.jobs.push(Job::new(spec, t));
        }
    }

    /// Submit a durable transfer task's *remaining* work as jobs: every
    /// file the task's checkpoint does not record as done becomes one
    /// job (input = the file, no compute, no output) in a single submit
    /// transaction. Returns `(proc, file index)` pairs so the driving
    /// fabric can report completions back to the
    /// [`TaskRunner`](crate::mover::task::TaskRunner) that owns the
    /// checkpoint. Already-done files are skipped entirely — on a
    /// resumed task they never re-enter the queue, which is what the
    /// byte counters in `tests/task_unified.rs` prove.
    pub fn submit_task(&mut self, task: &TransferTask, t: SimTime) -> Vec<(u32, usize)> {
        let base = self.jobs.len() as u32;
        let mut mapping = Vec::new();
        let mut specs = Vec::new();
        for (idx, f) in task.files.iter().enumerate() {
            if f.is_done() {
                continue;
            }
            let proc_ = base + specs.len() as u32;
            specs.push(JobSpec {
                id: JobId {
                    cluster: 1,
                    proc: proc_,
                },
                owner: task.owner.clone(),
                input_file: f.name.clone(),
                input_extent: f.extent,
                input_bytes: Bytes(f.bytes),
                output_bytes: Bytes(0),
                runtime_median_s: 0.0,
            });
            mapping.push((proc_, idx));
        }
        self.submit_transaction(specs, t);
        mapping
    }

    pub fn job(&self, proc_: u32) -> &Job {
        &self.jobs[proc_ as usize]
    }

    pub fn job_mut(&mut self, proc_: u32) -> &mut Job {
        &mut self.jobs[proc_ as usize]
    }

    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    /// Idle (unmatched) jobs for the negotiator, in queue order.
    pub fn idle_jobs(&self) -> Vec<(JobId, &crate::classad::Ad)> {
        self.idle
            .iter()
            .map(|&p| (self.jobs[p as usize].spec.id, &self.jobs[p as usize].ad))
            .collect()
    }

    /// Pop the next idle job (claim-reuse path: a freed slot takes the
    /// next queued job directly, no negotiation round-trip).
    pub fn take_next_idle(&mut self) -> Option<u32> {
        self.idle.pop_front()
    }

    /// Remove a specific proc from the idle queue (it was matched by the
    /// negotiator).
    pub fn take_idle(&mut self, proc_: u32) -> bool {
        if let Some(pos) = self.idle.iter().position(|&p| p == proc_) {
            self.idle.remove(pos);
            true
        } else {
            false
        }
    }

    /// Job matched to a slot → its input transfer enters the mover.
    /// Returns routed transfers that may START now (ticket = proc, plus
    /// the submit node and shadow shard serving it).
    pub fn job_matched(&mut self, proc_: u32, t: SimTime) -> Vec<Routed> {
        self.job_matched_batch(&[proc_], t)
    }

    /// One admission cycle's worth of matches: every job's lifecycle
    /// bookkeeping runs first, then the whole slice enters the mover in
    /// one `route_batch` call — equivalent to per-proc
    /// [`Schedd::job_matched`] calls in order, with the router's
    /// per-call plumbing (and, on the real fabric, the gate lock)
    /// amortized across the cycle.
    pub fn job_matched_batch(&mut self, procs: &[u32], t: SimTime) -> Vec<Routed> {
        let mut reqs = Vec::with_capacity(procs.len());
        for &proc_ in procs {
            let job = &mut self.jobs[proc_ as usize];
            debug_assert_eq!(job.state, JobState::Idle);
            job.state = JobState::TransferQueued;
            job.t_matched = Some(t);
            job.t_transfer_queued = Some(t);
            let id = job.spec.id;
            let mut req =
                TransferRequest::new(proc_, job.spec.owner.clone(), job.spec.input_bytes.0);
            req.extent = job.spec.input_extent;
            self.log.record(t, id, EventKind::TransferInputQueued);
            reqs.push(req);
        }
        self.mover.route_batch(reqs)
    }

    /// Admitted transfer goes on the wire.
    pub fn input_started(&mut self, proc_: u32, t: SimTime) {
        let job = &mut self.jobs[proc_ as usize];
        debug_assert_eq!(job.state, JobState::TransferQueued);
        job.state = JobState::TransferringInput;
        job.t_input_started = Some(t);
        let id = job.spec.id;
        self.log.record(t, id, EventKind::TransferInputBegan);
    }

    /// The input transfer died with its submit node (fault injection):
    /// the job returns to the transfer queue; the router re-admits it on
    /// a survivor (the mover side is handled by `fail_node`, which sees
    /// the ticket as in-flight and re-routes it).
    pub fn input_aborted(&mut self, proc_: u32, t: SimTime) {
        let job = &mut self.jobs[proc_ as usize];
        debug_assert_eq!(job.state, JobState::TransferringInput);
        job.state = JobState::TransferQueued;
        let id = job.spec.id;
        self.log.record(t, id, EventKind::TransferInputAborted);
    }

    /// Transfer finished → job executes; frees a mover slot.
    /// Returns routed transfers that may START now.
    pub fn input_done(&mut self, proc_: u32, t: SimTime) -> Vec<Routed> {
        let job = &mut self.jobs[proc_ as usize];
        debug_assert_eq!(job.state, JobState::TransferringInput);
        job.state = JobState::Running;
        job.t_input_done = Some(t);
        let id = job.spec.id;
        self.log.record(t, id, EventKind::TransferInputDone);
        self.log.record(t, id, EventKind::Executing);
        self.mover.complete(proc_)
    }

    pub fn run_done(&mut self, proc_: u32, t: SimTime) {
        let job = &mut self.jobs[proc_ as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.state = JobState::TransferringOutput;
        job.t_run_done = Some(t);
        let id = job.spec.id;
        self.log.record(t, id, EventKind::TransferOutputBegan);
    }

    pub fn job_completed(&mut self, proc_: u32, t: SimTime) {
        let job = &mut self.jobs[proc_ as usize];
        debug_assert_eq!(job.state, JobState::TransferringOutput);
        job.state = JobState::Completed;
        job.t_completed = Some(t);
        let id = job.spec.id;
        self.log.record(t, id, EventKind::TransferOutputDone);
        self.log.record(t, id, EventKind::Terminated);
    }

    pub fn completed_count(&self) -> usize {
        self.jobs
            .iter()
            .filter(|j| j.state == JobState::Completed)
            .count()
    }

    pub fn all_completed(&self) -> bool {
        self.jobs.iter().all(|j| j.state == JobState::Completed)
    }

    /// Makespan: submission of the first job to completion of the last.
    pub fn makespan(&self) -> Option<SimTime> {
        let start = self.jobs.iter().map(|j| j.t_submitted).min()?;
        let end = self.jobs.iter().map(|j| j.t_completed).max()??;
        Some(end.since(start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bytes;

    fn tickets(v: &[Routed]) -> Vec<u32> {
        v.iter().map(|r| r.ticket).collect()
    }

    fn specs(n: u32) -> Vec<JobSpec> {
        (0..n)
            .map(|p| JobSpec {
                id: JobId { cluster: 1, proc: p },
                owner: "a".into(),
                input_file: format!("f{p}"),
                input_extent: None,
                input_bytes: Bytes::mib(1),
                output_bytes: Bytes::kib(1),
                runtime_median_s: 5.0,
            })
            .collect()
    }

    #[test]
    fn submit_transaction_queues_all() {
        let mut s = Schedd::new("schedd", ThrottlePolicy::Disabled);
        s.submit_transaction(specs(100), SimTime::ZERO);
        assert_eq!(s.jobs.len(), 100);
        assert_eq!(s.idle_count(), 100);
        assert_eq!(s.log.count(EventKind::Submitted), 100);
    }

    #[test]
    fn full_lifecycle_updates_state_and_log() {
        let mut s = Schedd::new("schedd", ThrottlePolicy::Disabled);
        s.submit_transaction(specs(1), SimTime::ZERO);
        assert!(s.take_idle(0));
        let started = s.job_matched(0, SimTime::from_secs(1));
        assert_eq!(tickets(&started), vec![0], "unthrottled: starts immediately");
        assert_eq!(started[0].node, 0, "single-node router");
        s.input_started(0, SimTime::from_secs(1));
        s.input_done(0, SimTime::from_secs(31));
        s.run_done(0, SimTime::from_secs(36));
        s.job_completed(0, SimTime::from_secs(37));
        let j = s.job(0);
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.input_transfer_duration(), Some(SimTime::from_secs(30)));
        assert_eq!(s.makespan(), Some(SimTime::from_secs(37)));
        assert!(s.all_completed());
    }

    #[test]
    fn throttled_transfers_wait() {
        let mut s = Schedd::new("schedd", ThrottlePolicy::MaxConcurrent(1));
        s.submit_transaction(specs(3), SimTime::ZERO);
        for p in 0..3 {
            s.take_idle(p);
        }
        assert_eq!(tickets(&s.job_matched(0, SimTime::ZERO)), vec![0]);
        assert!(s.job_matched(1, SimTime::ZERO).is_empty(), "queued");
        assert!(s.job_matched(2, SimTime::ZERO).is_empty());
        s.input_started(0, SimTime::ZERO);
        let next = s.input_done(0, SimTime::from_secs(10));
        assert_eq!(tickets(&next), vec![1], "release admits next");
    }

    #[test]
    fn claim_reuse_order() {
        let mut s = Schedd::new("schedd", ThrottlePolicy::Disabled);
        s.submit_transaction(specs(3), SimTime::ZERO);
        assert_eq!(s.take_next_idle(), Some(0));
        assert_eq!(s.take_next_idle(), Some(1));
        assert!(s.take_idle(2));
        assert_eq!(s.take_next_idle(), None);
    }

    #[test]
    fn submit_task_skips_done_files_and_maps_procs() {
        use crate::mover::task::FileState;
        let mut task = TransferTask::new("t", "alice").with_uniform_files("input", 4, 1000);
        task.files[1].state = FileState::Done {
            sha256: "00".repeat(32),
        };
        let mut s = Schedd::new("schedd", ThrottlePolicy::Disabled);
        let mapping = s.submit_task(&task, SimTime::ZERO);
        assert_eq!(mapping, vec![(0, 0), (1, 2), (2, 3)], "done file skipped");
        assert_eq!(s.jobs.len(), 3);
        assert_eq!(s.job(1).spec.input_file, "input_2");
        assert_eq!(s.job(1).spec.owner, "alice");
        assert_eq!(s.job(1).spec.input_bytes, Bytes(1000));
    }

    #[test]
    fn makespan_none_until_done() {
        let mut s = Schedd::new("schedd", ThrottlePolicy::Disabled);
        s.submit_transaction(specs(1), SimTime::ZERO);
        assert!(s.makespan().is_none());
    }

    #[test]
    fn schedd_delegates_to_custom_mover() {
        use crate::mover::{AdmissionConfig, ShadowPool};
        let mover = ShadowPool::sim(2, AdmissionConfig::WeightedBySize { limit: 1 });
        let mut s = Schedd::with_router("schedd", PoolRouter::single(mover));
        // Three jobs with distinct sizes; proc 2 is the smallest.
        let mut sp = specs(3);
        sp[0].input_bytes = Bytes::mib(100);
        sp[1].input_bytes = Bytes::mib(50);
        sp[2].input_bytes = Bytes::mib(1);
        s.submit_transaction(sp, SimTime::ZERO);
        for p in 0..3 {
            s.take_idle(p);
        }
        assert_eq!(
            tickets(&s.job_matched(0, SimTime::ZERO)),
            vec![0],
            "capacity free"
        );
        assert!(s.job_matched(1, SimTime::ZERO).is_empty());
        assert!(s.job_matched(2, SimTime::ZERO).is_empty());
        s.input_started(0, SimTime::ZERO);
        let next = s.input_done(0, SimTime::from_secs(5));
        assert_eq!(tickets(&next), vec![2], "weighted-by-size admits the smallest");
        assert_eq!(s.mover.stats().total_admitted, 2);
        let taken = s.take_router().into_single().unwrap();
        assert_eq!(taken.stats().total_admitted, 2, "mover state travels");
    }
}
