//! The collector: the pool's ad registry. Every daemon advertises a
//! ClassAd under a unique name; queries filter by `MyType` and an optional
//! constraint expression.

use crate::classad::{parse_expr, Ad, Value};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Collector {
    ads: BTreeMap<String, Ad>,
}

impl Collector {
    pub fn new() -> Collector {
        Collector::default()
    }

    /// Advertise (insert or replace) an ad under `name`.
    pub fn advertise(&mut self, name: &str, ad: Ad) {
        self.ads.insert(name.to_string(), ad);
    }

    /// Remove an ad (daemon shutdown).
    pub fn invalidate(&mut self, name: &str) -> bool {
        self.ads.remove(name).is_some()
    }

    pub fn get(&self, name: &str) -> Option<&Ad> {
        self.ads.get(name)
    }

    pub fn len(&self) -> usize {
        self.ads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ads.is_empty()
    }

    /// All ads of a type, with names.
    pub fn query_type(&self, my_type: &str) -> Vec<(&str, &Ad)> {
        self.ads
            .iter()
            .filter(|(_, ad)| ad.my_type.eq_ignore_ascii_case(my_type))
            .map(|(n, ad)| (n.as_str(), ad))
            .collect()
    }

    /// Ads of a type satisfying a constraint expression (evaluated in the
    /// ad's own scope), e.g. `State == "Unclaimed" && Memory > 1024`.
    pub fn query(&self, my_type: &str, constraint: &str) -> Result<Vec<(&str, &Ad)>, String> {
        let expr = parse_expr(constraint).map_err(|e| e.to_string())?;
        let mut out = Vec::new();
        for (name, ad) in self.query_type(my_type) {
            let mut probe = ad.clone();
            probe.remove("__constraint");
            let mut tmp = probe.clone();
            // Evaluate the constraint as a transient attribute of the ad.
            tmp.insert_expr("__constraint", &expr.to_string())
                .map_err(|e| e.to_string())?;
            if tmp.eval("__constraint") == Value::Bool(true) {
                out.push((name, ad));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine(name: &str, mem: i64, state: &str) -> Ad {
        let mut ad = Ad::new("Machine");
        ad.insert("Name", name);
        ad.insert("Memory", mem);
        ad.insert("State", state);
        ad
    }

    #[test]
    fn advertise_replace_invalidate() {
        let mut c = Collector::new();
        c.advertise("slot1@w0", machine("slot1@w0", 1024, "Unclaimed"));
        c.advertise("slot1@w0", machine("slot1@w0", 2048, "Unclaimed"));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("slot1@w0").unwrap().get_int("Memory"), Some(2048));
        assert!(c.invalidate("slot1@w0"));
        assert!(!c.invalidate("slot1@w0"));
        assert!(c.is_empty());
    }

    #[test]
    fn query_by_type() {
        let mut c = Collector::new();
        c.advertise("m1", machine("m1", 1024, "Unclaimed"));
        let mut sched = Ad::new("Scheduler");
        sched.insert("Name", "schedd@submit");
        c.advertise("schedd", sched);
        assert_eq!(c.query_type("Machine").len(), 1);
        assert_eq!(c.query_type("Scheduler").len(), 1);
        assert_eq!(c.query_type("Negotiator").len(), 0);
    }

    #[test]
    fn query_with_constraint() {
        let mut c = Collector::new();
        c.advertise("m1", machine("m1", 1024, "Unclaimed"));
        c.advertise("m2", machine("m2", 8192, "Claimed"));
        c.advertise("m3", machine("m3", 8192, "Unclaimed"));
        let hits = c
            .query("Machine", "State == \"Unclaimed\" && Memory >= 2048")
            .unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0, "m3");
    }

    #[test]
    fn bad_constraint_is_error() {
        let c = Collector::new();
        assert!(c.query("Machine", "Memory >=").is_err());
    }

    #[test]
    fn constraint_undefined_attr_excludes() {
        let mut c = Collector::new();
        c.advertise("m1", machine("m1", 512, "Unclaimed"));
        let hits = c.query("Machine", "NoSuchAttr > 1").unwrap();
        assert!(hits.is_empty(), "undefined constraint is not a match");
    }
}
