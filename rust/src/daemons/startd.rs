//! The startd: one per worker node, advertising execute slots and running
//! starters. In the paper's tests the workers were Kubernetes pods
//! providing 200 single-core slots in total.

use crate::classad::Ad;
use crate::jobs::JobId;

/// Pool-unique slot identifier: (worker index, slot index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SlotId {
    pub worker: u32,
    pub slot: u32,
}

impl std::fmt::Display for SlotId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "slot{}@worker{}", self.slot, self.worker)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    /// Advertising, no claim.
    Unclaimed,
    /// Claimed by the schedd; idle between jobs (claim reuse).
    ClaimedIdle,
    /// A starter is processing a job (transfer or execution).
    ClaimedBusy,
}

#[derive(Debug, Clone)]
pub struct Slot {
    pub id: SlotId,
    pub state: SlotState,
    pub job: Option<JobId>,
}

/// One worker node's startd.
#[derive(Debug)]
pub struct Startd {
    pub worker: u32,
    pub slots: Vec<Slot>,
    /// Node attributes advertised in every slot ad.
    cpus_per_slot: i64,
    memory_per_slot: i64,
}

impl Startd {
    pub fn new(worker: u32, n_slots: u32) -> Startd {
        Startd {
            worker,
            slots: (0..n_slots)
                .map(|slot| Slot {
                    id: SlotId { worker, slot },
                    state: SlotState::Unclaimed,
                    job: None,
                })
                .collect(),
            cpus_per_slot: 1,
            memory_per_slot: 4096,
        }
    }

    /// The ClassAd a slot advertises to the collector.
    pub fn slot_ad(&self, slot: u32) -> Ad {
        let s = &self.slots[slot as usize];
        let mut ad = Ad::new("Machine");
        ad.insert("Name", s.id.to_string());
        ad.insert("SlotID", slot as i64 + 1);
        ad.insert("Cpus", self.cpus_per_slot);
        ad.insert("Memory", self.memory_per_slot);
        ad.insert("HasFileTransfer", true);
        ad.insert("Arch", "X86_64");
        ad.insert("OpSys", "LINUX");
        ad.insert(
            "State",
            match s.state {
                SlotState::Unclaimed => "Unclaimed",
                SlotState::ClaimedIdle | SlotState::ClaimedBusy => "Claimed",
            },
        );
        ad.insert_expr("Requirements", "TARGET.RequestMemory <= MY.Memory")
            .expect("static slot requirements");
        ad
    }

    pub fn claim(&mut self, slot: u32) -> bool {
        let s = &mut self.slots[slot as usize];
        if s.state == SlotState::Unclaimed {
            s.state = SlotState::ClaimedIdle;
            true
        } else {
            false
        }
    }

    pub fn activate(&mut self, slot: u32, job: JobId) -> bool {
        let s = &mut self.slots[slot as usize];
        if s.state == SlotState::ClaimedIdle {
            s.state = SlotState::ClaimedBusy;
            s.job = Some(job);
            true
        } else {
            false
        }
    }

    /// Starter finished; claim is retained for the next job (HTCondor
    /// claim reuse — crucial for back-to-back transfer scheduling).
    pub fn deactivate(&mut self, slot: u32) {
        let s = &mut self.slots[slot as usize];
        debug_assert_eq!(s.state, SlotState::ClaimedBusy);
        s.state = SlotState::ClaimedIdle;
        s.job = None;
    }

    pub fn release(&mut self, slot: u32) {
        let s = &mut self.slots[slot as usize];
        s.state = SlotState::Unclaimed;
        s.job = None;
    }

    pub fn count(&self, state: SlotState) -> usize {
        self.slots.iter().filter(|s| s.state == state).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jid() -> JobId {
        JobId { cluster: 1, proc: 0 }
    }

    #[test]
    fn slot_lifecycle() {
        let mut sd = Startd::new(0, 2);
        assert_eq!(sd.count(SlotState::Unclaimed), 2);
        assert!(sd.claim(0));
        assert!(!sd.claim(0), "double claim refused");
        assert!(sd.activate(0, jid()));
        assert!(!sd.activate(0, jid()), "busy slot refuses");
        assert_eq!(sd.count(SlotState::ClaimedBusy), 1);
        sd.deactivate(0);
        assert_eq!(sd.slots[0].state, SlotState::ClaimedIdle, "claim reused");
        assert!(sd.slots[0].job.is_none());
        sd.release(0);
        assert_eq!(sd.count(SlotState::Unclaimed), 2);
    }

    #[test]
    fn activate_requires_claim() {
        let mut sd = Startd::new(0, 1);
        assert!(!sd.activate(0, jid()));
    }

    #[test]
    fn slot_ad_shape() {
        let sd = Startd::new(3, 1);
        let ad = sd.slot_ad(0);
        assert_eq!(ad.get_str("Name").unwrap(), "slot0@worker3");
        assert_eq!(ad.get_bool("HasFileTransfer"), Some(true));
        assert_eq!(ad.get_str("State").unwrap(), "Unclaimed");
        // A matching job matches the ad bilaterally.
        let job = crate::jobs::build_job_ad(&crate::jobs::JobSpec {
            id: jid(),
            owner: "a".into(),
            input_file: "f".into(),
            input_extent: None,
            input_bytes: crate::util::units::Bytes::gib(2),
            output_bytes: crate::util::units::Bytes::kib(4),
            runtime_median_s: 5.0,
        });
        assert!(crate::classad::matches(&job, &ad).unwrap());
    }

    #[test]
    fn claimed_ad_state() {
        let mut sd = Startd::new(0, 1);
        sd.claim(0);
        assert_eq!(sd.slot_ad(0).get_str("State").unwrap(), "Claimed");
    }
}
