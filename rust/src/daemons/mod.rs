//! The HTCondor-shaped daemons: collector (ad registry), negotiator
//! (matchmaking), schedd (job queue + shadows + transfer queue), startd
//! (execute slots). The simulation engine (`coordinator::engine`) and the
//! real-mode fabric both drive pools built from these pieces.

pub mod collector;
pub mod negotiator;
pub mod schedd;
pub mod startd;

pub use collector::Collector;
pub use negotiator::Negotiator;
pub use schedd::Schedd;
pub use startd::{SlotId, SlotState, Startd};
