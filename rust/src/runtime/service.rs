//! Engine service: a dedicated thread owning a [`SealEngine`] and serving
//! seal/unseal requests over channels.
//!
//! The PJRT client is not `Send`, so the XLA engine cannot hop threads.
//! Real-mode pools instead run one crypto-service thread per node (just as
//! the paper's submit node funneled all transfer crypto through its CPU),
//! and every connection thread talks to it through a cloneable handle that
//! itself implements [`SealEngine`].

use super::engine::{Kind, SealEngine};
use anyhow::{anyhow, Result};
use std::sync::mpsc;

enum Req {
    Process {
        kind: Kind,
        key: [u32; 8],
        nonce: [u32; 3],
        counter0: u32,
        data: Vec<u32>,
        reply: mpsc::Sender<Result<(Vec<u32>, [u32; 4])>>,
    },
    ProcessBytes {
        kind: Kind,
        key: [u32; 8],
        nonce: [u32; 3],
        counter0: u32,
        data: Vec<u8>,
        reply: mpsc::Sender<Result<(Vec<u8>, [u32; 4])>>,
    },
    Describe {
        reply: mpsc::Sender<String>,
    },
}

/// Cloneable handle to a crypto-service thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Req>,
}

/// The service: joinable thread + handle factory.
pub struct EngineService {
    handle: EngineHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl EngineService {
    /// Spawn a service thread; the engine is constructed *inside* the
    /// thread by `factory` (so non-Send engines work).
    pub fn spawn<F>(factory: F) -> EngineService
    where
        F: FnOnce() -> Result<Box<dyn SealEngine>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Req>();
        let thread = std::thread::Builder::new()
            .name("htcdm-crypto".into())
            .spawn(move || {
                let mut engine = match factory() {
                    Ok(e) => e,
                    Err(e) => {
                        // Drain requests with the construction error.
                        while let Ok(req) = rx.recv() {
                            match req {
                                Req::Process { reply, .. } => {
                                    let _ = reply.send(Err(anyhow!("engine init failed: {e}")));
                                }
                                Req::ProcessBytes { reply, .. } => {
                                    let _ = reply.send(Err(anyhow!("engine init failed: {e}")));
                                }
                                Req::Describe { reply } => {
                                    let _ = reply.send(format!("failed: {e}"));
                                }
                            }
                        }
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Process {
                            kind,
                            key,
                            nonce,
                            counter0,
                            mut data,
                            reply,
                        } => {
                            let r = engine
                                .process(kind, &key, &nonce, counter0, &mut data)
                                .map(|digest| (data, digest));
                            let _ = reply.send(r);
                        }
                        Req::ProcessBytes {
                            kind,
                            key,
                            nonce,
                            counter0,
                            mut data,
                            reply,
                        } => {
                            let r = engine
                                .process_bytes(kind, &key, &nonce, counter0, &mut data)
                                .map(|digest| (data, digest));
                            let _ = reply.send(r);
                        }
                        Req::Describe { reply } => {
                            let _ = reply.send(engine.describe());
                        }
                    }
                }
            })
            .expect("spawn crypto thread");
        EngineService {
            handle: EngineHandle { tx },
            thread: Some(thread),
        }
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for EngineService {
    fn drop(&mut self) {
        // Closing our handle's sender isn't enough if clones are live; the
        // thread exits when the last handle drops. Detach politely.
        if let Some(t) = self.thread.take() {
            drop(std::mem::replace(
                &mut self.handle,
                EngineHandle {
                    tx: {
                        let (tx, _rx) = mpsc::channel();
                        tx
                    },
                },
            ));
            let _ = t.join();
        }
    }
}

impl SealEngine for EngineHandle {
    fn process(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u32],
    ) -> Result<[u32; 4]> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Req::Process {
                kind,
                key: *key,
                nonce: *nonce,
                counter0,
                data: data.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("crypto service gone"))?;
        let (out, digest) = reply_rx
            .recv()
            .map_err(|_| anyhow!("crypto service dropped reply"))??;
        data.copy_from_slice(&out);
        Ok(digest)
    }

    fn process_bytes(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u8],
    ) -> Result<[u32; 4]> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Req::ProcessBytes {
                kind,
                key: *key,
                nonce: *nonce,
                counter0,
                data: data.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("crypto service gone"))?;
        let (out, digest) = reply_rx
            .recv()
            .map_err(|_| anyhow!("crypto service dropped reply"))??;
        data.copy_from_slice(&out);
        Ok(digest)
    }

    /// Handles fork freely: clones serialize through the same service
    /// thread, so a sealer pool over one service overlaps sealing with
    /// socket writes without extra crypto parallelism.
    fn fork(&self) -> Option<Box<dyn SealEngine + Send>> {
        Some(Box::new(self.clone()))
    }

    fn describe(&self) -> String {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Req::Describe { reply: reply_tx }).is_err() {
            return "service(gone)".into();
        }
        reply_rx
            .recv()
            .map(|d| format!("service[{d}]"))
            .unwrap_or_else(|_| "service(gone)".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::NativeEngine;
    use crate::security::{chacha, Method};

    #[test]
    fn service_matches_direct_engine() {
        let svc = EngineService::spawn(|| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let mut h = svc.handle();
        let key = [1u32; 8];
        let nonce = [2, 3, 4];
        let mut data: Vec<u32> = (0..64u32).collect();
        let mut expect = data.clone();
        let d_expect = chacha::seal_chunk(&key, &nonce, 0, &mut expect);
        let d = h.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
        assert_eq!(data, expect);
        assert_eq!(d, d_expect);
        assert!(h.describe().contains("native/CHACHA20"));
    }

    #[test]
    fn service_shared_across_threads() {
        let svc = EngineService::spawn(|| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let mut handles = Vec::new();
        for i in 0..4u32 {
            let mut h = svc.handle();
            handles.push(std::thread::spawn(move || {
                let key = [i; 8];
                let nonce = [0, 0, i];
                let mut data: Vec<u32> = (0..32u32).map(|x| x ^ i).collect();
                let orig = data.clone();
                let d1 = h.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
                let d2 = h.process(Kind::Unseal, &key, &nonce, 0, &mut data).unwrap();
                assert_eq!(data, orig);
                assert_eq!(d1, d2);
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
    }

    #[test]
    fn service_byte_path_matches_direct_engine() {
        let svc = EngineService::spawn(|| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let mut h = svc.handle();
        let key = [1u32; 8];
        let nonce = [2, 3, 4];
        let mut data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        let mut expect = data.clone();
        let d_expect = chacha::seal_chunk_bytes(&key, &nonce, 9, &mut expect);
        let d = h.process_bytes(Kind::Seal, &key, &nonce, 9, &mut data).unwrap();
        assert_eq!(data, expect);
        assert_eq!(d, d_expect);
        let mut f = h.fork().expect("handles fork");
        let d2 = f.process_bytes(Kind::Unseal, &key, &nonce, 9, &mut data).unwrap();
        assert_eq!(d2, d_expect, "forked handle serves the same engine");
    }

    #[test]
    fn failed_factory_reports_error() {
        let svc = EngineService::spawn(|| Err(anyhow!("nope")));
        let mut h = svc.handle();
        let mut data = vec![0u32; 16];
        let err = h
            .process(Kind::Seal, &[0; 8], &[0; 3], 0, &mut data)
            .unwrap_err();
        assert!(err.to_string().contains("engine init failed"));
    }
}
