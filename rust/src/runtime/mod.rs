//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them from the transfer hot
//! path. Python never runs here — the HLO text is compiled once by the
//! PJRT CPU client at startup and executed as native code thereafter.
//!
//! * [`Manifest`] — the artifact ABI description (`manifest.json`).
//! * [`SealRuntime`] — one compiled executable per (kind, chunk geometry).
//! * [`engine`] — the [`engine::SealEngine`] trait with three impls:
//!   native Rust, XLA artifact, and a cross-verifying wrapper.

pub mod engine;
pub mod service;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Supported chunk geometry names, smallest to largest.
pub const GEOMETRIES: &[&str] = &["probe", "64k", "256k", "1m"];

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub name: String,
    pub file: String,
    pub n_blocks: usize,
    pub tile: usize,
    pub chunk_bytes: usize,
    pub sha256: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub abi_version: u64,
    pub entries: Vec<ManifestEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let abi_version = v
            .get("abi_version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest missing abi_version"))?;
        if abi_version != 1 {
            bail!("unsupported artifact ABI version {abi_version}");
        }
        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let gets = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing {k}"))?
                    .to_string())
            };
            let getn = |k: &str| -> Result<usize> {
                Ok(e.get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("entry missing {k}"))? as usize)
            };
            entries.push(ManifestEntry {
                kind: gets("kind")?,
                name: gets("name")?,
                file: gets("file")?,
                n_blocks: getn("n_blocks")?,
                tile: getn("tile")?,
                chunk_bytes: getn("chunk_bytes")?,
                sha256: gets("sha256")?,
            });
        }
        Ok(Manifest {
            abi_version,
            entries,
            dir,
        })
    }

    pub fn entry(&self, kind: &str, name: &str) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.name == name)
    }

    /// Default artifact directory: `$HTCDM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HTCDM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

/// A compiled seal/unseal executable pair for one chunk geometry.
#[cfg(feature = "xla")]
struct CompiledGeometry {
    n_blocks: usize,
    seal: xla::PjRtLoadedExecutable,
    unseal: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed seal runtime: client + compiled executables.
#[cfg(feature = "xla")]
pub struct SealRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    geometries: HashMap<String, CompiledGeometry>,
}

#[cfg(feature = "xla")]
impl std::fmt::Debug for SealRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SealRuntime({} geometries)", self.geometries.len())
    }
}

#[cfg(feature = "xla")]
impl SealRuntime {
    /// Load and compile artifacts for the given geometry names (compile
    /// everything in [`GEOMETRIES`] when `names` is empty).
    pub fn load(manifest: &Manifest, names: &[&str]) -> Result<SealRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut geometries = HashMap::new();
        let wanted: Vec<&str> = if names.is_empty() {
            GEOMETRIES.to_vec()
        } else {
            names.to_vec()
        };
        for name in wanted {
            let seal_e = manifest
                .entry("seal", name)
                .ok_or_else(|| anyhow!("manifest has no seal/{name}"))?;
            let unseal_e = manifest
                .entry("unseal", name)
                .ok_or_else(|| anyhow!("manifest has no unseal/{name}"))?;
            let seal = Self::compile_one(&client, &manifest.dir.join(&seal_e.file))?;
            let unseal = Self::compile_one(&client, &manifest.dir.join(&unseal_e.file))?;
            geometries.insert(
                name.to_string(),
                CompiledGeometry {
                    n_blocks: seal_e.n_blocks,
                    seal,
                    unseal,
                },
            );
        }
        Ok(SealRuntime { client, geometries })
    }

    fn compile_one(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    pub fn has_geometry(&self, name: &str) -> bool {
        self.geometries.contains_key(name)
    }

    pub fn n_blocks(&self, name: &str) -> Option<usize> {
        self.geometries.get(name).map(|g| g.n_blocks)
    }

    /// Largest loaded geometry whose chunk fits `words` words, else the
    /// smallest loaded geometry.
    pub fn pick_geometry(&self, words: usize) -> Option<&str> {
        let mut best: Option<(&str, usize)> = None;
        let mut smallest: Option<(&str, usize)> = None;
        for (name, g) in &self.geometries {
            let w = g.n_blocks * 16;
            if smallest.is_none_or(|(_, sw)| w < sw) {
                smallest = Some((name.as_str(), w));
            }
            if w <= words && best.is_none_or(|(_, bw)| w > bw) {
                best = Some((name.as_str(), w));
            }
        }
        best.or(smallest).map(|(n, _)| n)
    }

    /// Execute seal/unseal on one chunk. `data` must be exactly
    /// `n_blocks*16` words. Returns (payload words, digest4).
    pub fn run(
        &self,
        kind: engine::Kind,
        name: &str,
        key: &[u32; 8],
        iv: &[u32; 4],
        data: &[u32],
    ) -> Result<(Vec<u32>, [u32; 4])> {
        let g = self
            .geometries
            .get(name)
            .ok_or_else(|| anyhow!("geometry {name} not loaded"))?;
        if data.len() != g.n_blocks * 16 {
            bail!(
                "chunk size mismatch: {} words != {}",
                data.len(),
                g.n_blocks * 16
            );
        }
        let exe = match kind {
            engine::Kind::Seal => &g.seal,
            engine::Kind::Unseal => &g.unseal,
        };
        let key_lit = xla::Literal::vec1(&key[..]);
        let iv_lit = xla::Literal::vec1(&iv[..]);
        let data_lit = xla::Literal::vec1(data).reshape(&[g.n_blocks as i64, 16])?;
        let result = exe.execute::<xla::Literal>(&[key_lit, iv_lit, data_lit])?[0][0]
            .to_literal_sync()?;
        // return_tuple=True ABI: a 2-tuple (payload, digest).
        let parts = result.to_tuple()?;
        if parts.len() != 2 {
            bail!("artifact returned {}-tuple, expected 2", parts.len());
        }
        let payload = parts[0].to_vec::<u32>()?;
        let dig_vec = parts[1].to_vec::<u32>()?;
        if dig_vec.len() != 4 {
            bail!("digest length {} != 4", dig_vec.len());
        }
        Ok((payload, [dig_vec[0], dig_vec[1], dig_vec[2], dig_vec[3]]))
    }
}

/// Stub seal runtime used when the crate is built without the `xla`
/// feature (the default in offline environments): loading always fails
/// with a clear message and the engine layer falls back to the native
/// data plane. The API surface matches the real runtime so callers
/// compile unchanged.
#[cfg(not(feature = "xla"))]
pub struct SealRuntime {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl std::fmt::Debug for SealRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SealRuntime(stub: built without `xla`)")
    }
}

#[cfg(not(feature = "xla"))]
impl SealRuntime {
    pub fn load(_manifest: &Manifest, _names: &[&str]) -> Result<SealRuntime> {
        bail!(
            "PJRT runtime unavailable: htcdm was built without the `xla` \
             feature; rebuild with `--features xla` (and an xla crate \
             provided by the environment) or use the native engine"
        )
    }

    pub fn has_geometry(&self, _name: &str) -> bool {
        false
    }

    pub fn n_blocks(&self, _name: &str) -> Option<usize> {
        None
    }

    pub fn pick_geometry(&self, _words: usize) -> Option<&str> {
        None
    }

    pub fn run(
        &self,
        _kind: engine::Kind,
        _name: &str,
        _key: &[u32; 8],
        _iv: &[u32; 4],
        _data: &[u32],
    ) -> Result<(Vec<u32>, [u32; 4])> {
        bail!("PJRT runtime unavailable (built without the `xla` feature)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_from_fixture() {
        let dir = std::env::temp_dir().join(format!("htcdm-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"abi_version": 1, "entries": [
                {"kind":"seal","name":"probe","file":"seal_probe.hlo.txt",
                 "n_blocks":16,"tile":16,"chunk_bytes":1024,
                 "args":[],"outputs":[],"sha256":"x"}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.abi_version, 1);
        assert_eq!(m.entries.len(), 1);
        let e = m.entry("seal", "probe").unwrap();
        assert_eq!(e.n_blocks, 16);
        assert!(m.entry("unseal", "probe").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_bad_abi() {
        let dir = std::env::temp_dir().join(format!("htcdm-badabi-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"abi_version": 99, "entries": []}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/htcdm").is_err());
    }
}
