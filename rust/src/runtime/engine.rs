//! The seal engine abstraction: the one object the transfer hot path
//! calls to encrypt+digest (or digest+decrypt) a chunk of words.
//!
//! Three implementations:
//! * [`NativeEngine`] — pure Rust ([`crate::security::chacha`] /
//!   [`crate::security::aesctr`]); always available, used by sim mode and
//!   as the verification oracle.
//! * [`XlaEngine`] — the AOT Pallas/JAX artifact executed via PJRT; the
//!   paper-architecture hot path (L1/L2 compute, L3 orchestration).
//! * [`VerifyingEngine`] — runs both and asserts bit-identical results
//!   (used at startup and in tests; catches ABI drift instantly).

use crate::security::{aesctr, chacha, Method};
use anyhow::{bail, Result};

/// Seal = encrypt-then-digest (sender); Unseal = digest-then-decrypt
/// (receiver). Digest is always over the ciphertext.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Seal,
    Unseal,
}

/// A data-plane engine processing whole chunks of u32 words in place.
pub trait SealEngine {
    /// Process `data` (whole 64-byte blocks) in place; returns the 4-word
    /// transfer digest. `counter0` is the chunk's absolute block offset.
    fn process(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u32],
    ) -> Result<[u32; 4]>;

    /// Byte-slice variant of [`SealEngine::process`] for the zero-copy
    /// wire path: `data.len()` must be a multiple of 64 (whole blocks,
    /// little-endian words). The default implementation round-trips
    /// through words so every engine stays correct; engines with a
    /// native byte path ([`NativeEngine`], the service handle) override
    /// it to skip the copies. See docs/ARCHITECTURE.md §Data-path
    /// performance.
    fn process_bytes(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u8],
    ) -> Result<[u32; 4]> {
        if data.len() % 64 != 0 {
            bail!("chunk must be whole 64-byte blocks, got {} bytes", data.len());
        }
        let mut words = chacha::bytes_to_words(data);
        let digest = self.process(kind, key, nonce, counter0, &mut words)?;
        for (b, w) in data.chunks_exact_mut(4).zip(words.iter()) {
            b.copy_from_slice(&w.to_le_bytes());
        }
        Ok(digest)
    }

    /// A second, independent engine for the same configuration, used by
    /// the pipelined stream sealer to run frames in parallel. Engines
    /// that hold exclusive resources (the PJRT runtime) return `None`
    /// and the stream layer falls back to serial sealing.
    fn fork(&self) -> Option<Box<dyn SealEngine + Send>> {
        None
    }

    /// Human-readable engine description for logs/reports.
    fn describe(&self) -> String;
}

/// Pure-Rust engine (ChaCha20 or AES-256-CTR + poly16).
#[derive(Debug, Clone)]
pub struct NativeEngine {
    pub method: Method,
}

impl NativeEngine {
    pub fn new(method: Method) -> NativeEngine {
        NativeEngine { method }
    }
}

impl SealEngine for NativeEngine {
    fn process(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u32],
    ) -> Result<[u32; 4]> {
        if data.len() % 16 != 0 {
            bail!("chunk must be whole 64-byte blocks, got {} words", data.len());
        }
        Ok(match (self.method, kind) {
            (Method::Chacha20, Kind::Seal) => chacha::seal_chunk(key, nonce, counter0, data),
            (Method::Chacha20, Kind::Unseal) => chacha::unseal_chunk(key, nonce, counter0, data),
            (Method::Aes256Ctr, Kind::Seal) => aesctr::seal_chunk(key, nonce, counter0, data),
            (Method::Aes256Ctr, Kind::Unseal) => aesctr::unseal_chunk(key, nonce, counter0, data),
            (Method::Plain, _) => {
                // Integrity only: digest the payload as-is.
                let lane = chacha::poly16_digest(data, counter0);
                chacha::digest_finalize(&lane, data.len() as u32, nonce)
            }
        })
    }

    fn process_bytes(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u8],
    ) -> Result<[u32; 4]> {
        if data.len() % 64 != 0 {
            bail!("chunk must be whole 64-byte blocks, got {} bytes", data.len());
        }
        Ok(match (self.method, kind) {
            (Method::Chacha20, Kind::Seal) => chacha::seal_chunk_bytes(key, nonce, counter0, data),
            (Method::Chacha20, Kind::Unseal) => {
                chacha::unseal_chunk_bytes(key, nonce, counter0, data)
            }
            (Method::Aes256Ctr, Kind::Seal) => aesctr::seal_chunk_bytes(key, nonce, counter0, data),
            (Method::Aes256Ctr, Kind::Unseal) => {
                aesctr::unseal_chunk_bytes(key, nonce, counter0, data)
            }
            (Method::Plain, _) => {
                let lane = chacha::poly16_digest_bytes(data, counter0);
                chacha::digest_finalize(&lane, (data.len() / 4) as u32, nonce)
            }
        })
    }

    fn fork(&self) -> Option<Box<dyn SealEngine + Send>> {
        Some(Box::new(self.clone()))
    }

    fn describe(&self) -> String {
        format!("native/{}", self.method.name())
    }
}

/// PJRT artifact engine: ChaCha20+poly16 compiled from the Pallas kernel.
pub struct XlaEngine {
    runtime: super::SealRuntime,
    /// Scratch buffer for padding odd-sized chunks to a geometry.
    scratch: Vec<u32>,
}

impl XlaEngine {
    pub fn new(runtime: super::SealRuntime) -> XlaEngine {
        XlaEngine {
            runtime,
            scratch: Vec::new(),
        }
    }

    /// Load the default artifacts (all geometries) from `dir`.
    pub fn load_default(dir: impl AsRef<std::path::Path>) -> Result<XlaEngine> {
        let manifest = super::Manifest::load(dir)?;
        Ok(XlaEngine::new(super::SealRuntime::load(&manifest, &[])?))
    }

    pub fn runtime(&self) -> &super::SealRuntime {
        &self.runtime
    }
}

impl SealEngine for XlaEngine {
    fn process(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u32],
    ) -> Result<[u32; 4]> {
        if data.len() % 16 != 0 {
            bail!("chunk must be whole 64-byte blocks, got {} words", data.len());
        }
        // The artifact ABI is fixed-shape per geometry; a chunk is processed
        // as a sequence of geometry-sized sub-chunks with advancing counter.
        // Digests of sub-chunks are XOR-combined via the lane-digest
        // decomposition property... but the artifact returns the *final*
        // digest, so chunks must be geometry-aligned: the stream layer
        // always sends geometry-sized chunks. Here we require exact fit of
        // a single geometry and process it in one call.
        let words = data.len();
        let Some(geom) = self.runtime.pick_geometry(words) else {
            bail!("no geometry loaded");
        };
        let gwords = self.runtime.n_blocks(geom).unwrap() * 16;
        if gwords == words {
            let iv = [counter0, nonce[0], nonce[1], nonce[2]];
            let (out, digest) = self.runtime.run(kind, geom, key, &iv, data)?;
            data.copy_from_slice(&out);
            return Ok(digest);
        }
        // Not an exact geometry: pad into scratch using the smallest
        // geometry that fits, then recompute the true digest natively over
        // the unpadded ciphertext (rare path — tiny tail chunks only).
        let mut padded = self.runtime.pick_geometry(usize::MAX).unwrap(); // smallest
        for (name, _) in GEOM_SIZES {
            if let Some(nb) = self.runtime.n_blocks(name) {
                if nb * 16 >= words {
                    padded = name;
                    break;
                }
            }
        }
        let pwords = self.runtime.n_blocks(padded).unwrap() * 16;
        if pwords < words {
            bail!("chunk of {words} words exceeds largest loaded geometry ({pwords})");
        }
        self.scratch.clear();
        self.scratch.resize(pwords, 0);
        self.scratch[..words].copy_from_slice(data);
        let iv = [counter0, nonce[0], nonce[1], nonce[2]];
        let scratch = std::mem::take(&mut self.scratch);
        let (out, _) = self.runtime.run(kind, padded, key, &iv, &scratch)?;
        self.scratch = scratch;
        data.copy_from_slice(&out[..words]);
        // True digest over the actual (unpadded) ciphertext.
        let cipher: &[u32] = match kind {
            Kind::Seal => data,
            Kind::Unseal => &self.scratch[..words],
        };
        let lane = chacha::poly16_digest(cipher, counter0);
        Ok(chacha::digest_finalize(&lane, words as u32, nonce))
    }

    fn describe(&self) -> String {
        format!("xla-pjrt/CHACHA20 ({:?})", self.runtime)
    }
}

/// Geometry names ordered smallest-to-largest (mirrors super::GEOMETRIES
/// with word sizes for the padding path).
const GEOM_SIZES: &[(&str, usize)] = &[
    ("probe", 16 * 16),
    ("64k", 1024 * 16),
    ("256k", 4096 * 16),
    ("1m", 16384 * 16),
];

/// Runs a primary and a reference engine and asserts identical results.
pub struct VerifyingEngine<A: SealEngine, B: SealEngine> {
    pub primary: A,
    pub reference: B,
    pub chunks_verified: u64,
}

impl<A: SealEngine, B: SealEngine> VerifyingEngine<A, B> {
    pub fn new(primary: A, reference: B) -> Self {
        VerifyingEngine {
            primary,
            reference,
            chunks_verified: 0,
        }
    }
}

impl<A: SealEngine, B: SealEngine> SealEngine for VerifyingEngine<A, B> {
    fn process(
        &mut self,
        kind: Kind,
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u32],
    ) -> Result<[u32; 4]> {
        let mut copy = data.to_vec();
        let d1 = self.primary.process(kind, key, nonce, counter0, data)?;
        let d2 = self
            .reference
            .process(kind, key, nonce, counter0, &mut copy)?;
        if d1 != d2 || data != &copy[..] {
            bail!(
                "engine mismatch: {} vs {} (digest {:08x?} vs {:08x?})",
                self.primary.describe(),
                self.reference.describe(),
                d1,
                d2
            );
        }
        self.chunks_verified += 1;
        Ok(d1)
    }

    fn describe(&self) -> String {
        format!(
            "verify[{} == {}]",
            self.primary.describe(),
            self.reference.describe()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_roundtrip_chacha() {
        let mut e = NativeEngine::new(Method::Chacha20);
        let key = [7u32; 8];
        let nonce = [1, 2, 3];
        let mut data: Vec<u32> = (0..256u32).collect();
        let orig = data.clone();
        let d1 = e.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
        assert_ne!(data, orig);
        let d2 = e.process(Kind::Unseal, &key, &nonce, 0, &mut data).unwrap();
        assert_eq!(data, orig);
        assert_eq!(d1, d2);
    }

    #[test]
    fn native_roundtrip_aes() {
        let mut e = NativeEngine::new(Method::Aes256Ctr);
        let key = [7u32; 8];
        let nonce = [1, 2, 3];
        let mut data: Vec<u32> = (0..64u32).collect();
        let orig = data.clone();
        let d1 = e.process(Kind::Seal, &key, &nonce, 4, &mut data).unwrap();
        let d2 = e.process(Kind::Unseal, &key, &nonce, 4, &mut data).unwrap();
        assert_eq!(data, orig);
        assert_eq!(d1, d2);
    }

    #[test]
    fn plain_leaves_data_but_digests() {
        let mut e = NativeEngine::new(Method::Plain);
        let key = [0u32; 8];
        let nonce = [0, 0, 0];
        let mut data: Vec<u32> = (0..16u32).collect();
        let orig = data.clone();
        let d = e.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
        assert_eq!(data, orig, "plain method does not encrypt");
        assert_ne!(d, [0u32; 4]);
    }

    #[test]
    fn process_bytes_matches_process() {
        for method in [Method::Chacha20, Method::Aes256Ctr, Method::Plain] {
            let mut e = NativeEngine::new(method);
            let key = [3u32; 8];
            let nonce = [4, 5, 6];
            let bytes: Vec<u8> = (0..192).map(|i| i as u8).collect();
            let mut words = chacha::bytes_to_words(&bytes);
            let mut b = bytes.clone();
            let dw = e.process(Kind::Seal, &key, &nonce, 2, &mut words).unwrap();
            let db = e.process_bytes(Kind::Seal, &key, &nonce, 2, &mut b).unwrap();
            assert_eq!(dw, db, "digest parity for {method:?}");
            assert_eq!(chacha::words_to_bytes(&words), b, "ciphertext parity");
        }
    }

    #[test]
    fn default_process_bytes_roundtrips_through_words() {
        // An engine relying on the trait-default byte path must agree
        // with the native override bit for bit.
        struct WordOnly(NativeEngine);
        impl SealEngine for WordOnly {
            fn process(
                &mut self,
                kind: Kind,
                key: &[u32; 8],
                nonce: &[u32; 3],
                counter0: u32,
                data: &mut [u32],
            ) -> Result<[u32; 4]> {
                self.0.process(kind, key, nonce, counter0, data)
            }
            fn describe(&self) -> String {
                "word-only".into()
            }
        }
        let mut w = WordOnly(NativeEngine::new(Method::Chacha20));
        let mut n = NativeEngine::new(Method::Chacha20);
        let mut a: Vec<u8> = (0..128).map(|i| (i * 3) as u8).collect();
        let mut b = a.clone();
        let da = w.process_bytes(Kind::Seal, &[1; 8], &[2; 3], 0, &mut a).unwrap();
        let db = n.process_bytes(Kind::Seal, &[1; 8], &[2; 3], 0, &mut b).unwrap();
        assert_eq!(da, db);
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_partial_blocks() {
        let mut e = NativeEngine::new(Method::Chacha20);
        let mut data = vec![0u32; 15];
        assert!(e
            .process(Kind::Seal, &[0; 8], &[0; 3], 0, &mut data)
            .is_err());
    }

    #[test]
    fn verifying_engine_agrees_native_native() {
        let mut v = VerifyingEngine::new(
            NativeEngine::new(Method::Chacha20),
            NativeEngine::new(Method::Chacha20),
        );
        let mut data: Vec<u32> = (0..32u32).collect();
        v.process(Kind::Seal, &[1; 8], &[2; 3], 0, &mut data).unwrap();
        assert_eq!(v.chunks_verified, 1);
    }

    #[test]
    fn verifying_engine_detects_mismatch() {
        // ChaCha vs AES produce different ciphertexts -> must error.
        let mut v = VerifyingEngine::new(
            NativeEngine::new(Method::Chacha20),
            NativeEngine::new(Method::Aes256Ctr),
        );
        let mut data: Vec<u32> = (0..32u32).collect();
        assert!(v.process(Kind::Seal, &[1; 8], &[2; 3], 0, &mut data).is_err());
    }
}
