//! Small shared substrates: units, PRNG, statistics, JSON, test kit.

pub mod json;
pub mod prng;
pub mod stats;
pub mod testkit;
pub mod units;

pub use prng::Prng;
pub use stats::{Histogram, OnlineStats};
pub use units::{Bytes, Gbps, SimTime};
