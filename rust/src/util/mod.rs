//! Small shared substrates: units, PRNG, statistics, JSON, test kit.

pub mod json;
pub mod prng;
pub mod stats;
pub mod testkit;
pub mod units;

pub use prng::Prng;
pub use stats::{Histogram, OnlineStats};
pub use units::{Bytes, Gbps, SimTime};

/// Canonical member→site partition for the multi-site federation layer:
/// member `idx` of a fleet of `count` (submit nodes, DTNs, or workers)
/// belongs to site `idx * n_sites / count` — contiguous blocks, the same
/// rule everywhere (topology paths, router placement, fault scoping,
/// report matrices), so no layer can disagree about which site an
/// endpoint lives in. With `n_sites <= 1` (or an empty fleet) everything
/// is site 0.
pub fn site_of_member(idx: usize, count: usize, n_sites: usize) -> usize {
    if n_sites <= 1 || count == 0 {
        return 0;
    }
    (idx.min(count - 1)) * n_sites / count
}

#[cfg(test)]
mod site_tests {
    use super::site_of_member;

    #[test]
    fn site_partition_is_contiguous_and_covers_every_site() {
        // 6 members over 3 sites: blocks of 2.
        let sites: Vec<usize> = (0..6).map(|i| site_of_member(i, 6, 3)).collect();
        assert_eq!(sites, vec![0, 0, 1, 1, 2, 2]);
        // Uneven split stays monotone and hits every site.
        let sites: Vec<usize> = (0..5).map(|i| site_of_member(i, 5, 2)).collect();
        assert_eq!(sites, vec![0, 0, 0, 1, 1]);
        // Degenerate shapes collapse to site 0.
        assert_eq!(site_of_member(3, 4, 1), 0);
        assert_eq!(site_of_member(0, 0, 4), 0);
    }
}
