//! Mini property-testing kit (the vendor set has no proptest).
//!
//! `check(name, cases, f)` runs `f` against `cases` deterministic random
//! inputs drawn from a [`Gen`]; on failure it reports the case seed so the
//! exact input can be replayed with `replay(seed, f)`. No shrinking — the
//! generators are kept small enough that raw counterexamples are readable.

use super::prng::Prng;

/// A deterministic generator handle passed to property bodies.
pub struct Gen {
    pub rng: Prng,
    pub case: usize,
}

impl Gen {
    /// A vector of length in [lo, hi] filled by `f`.
    pub fn vec_of<T>(&mut self, lo: usize, hi: usize, mut f: impl FnMut(&mut Prng) -> T) -> Vec<T> {
        let n = self.rng.range_usize(lo, hi);
        (0..n).map(|_| f(&mut self.rng)).collect()
    }

    pub fn bytes(&mut self, lo: usize, hi: usize) -> Vec<u8> {
        let n = self.rng.range_usize(lo, hi);
        let mut v = vec![0u8; n];
        self.rng.fill_bytes(&mut v);
        v
    }
}

/// Run a property over `cases` generated inputs. Panics with the failing
/// case seed on the first violation.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = 0x5EED_0000_0000 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Prng::new(seed),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed (for debugging a failure printed by check).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen {
        rng: Prng::new(seed),
        case: 0,
    };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("trivial", 50, |g| {
            let v = g.vec_of(0, 10, |r| r.next_u32());
            assert!(v.len() <= 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed")]
    fn reports_failing_case() {
        check("failing", 10, |g| {
            let b = g.bytes(1, 4);
            assert!(b.len() > 4, "too short");
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen1 = Vec::new();
        check("collect1", 5, |g| seen1.push(g.rng.next_u64()));
        let mut seen2 = Vec::new();
        check("collect2", 5, |g| seen2.push(g.rng.next_u64()));
        assert_eq!(seen1, seen2);
    }
}
