//! Deterministic PRNG for the simulator and the property-test kit.
//!
//! splitmix64 seeding + xoshiro256++ generation — small, fast, and good
//! enough for workload sampling and randomized tests. Every simulation
//! component derives its own stream from (seed, component-id), so runs are
//! reproducible regardless of event interleaving.

/// xoshiro256++ with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Prng {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream for a named component.
    pub fn derive(&self, tag: &str) -> Prng {
        let mut h: u64 = 0xCBF29CE484222325;
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001B3);
        }
        Prng::new(self.s[0] ^ h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        lo + self.next_u64() % span
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with given median and sigma (of the underlying normal).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn derive_independent() {
        let root = Prng::new(7);
        let mut a = root.derive("netsim");
        let mut b = root.derive("storage");
        assert_ne!(a.next_u64(), b.next_u64());
        // Re-derivation is stable.
        let mut a2 = root.derive("netsim");
        assert_eq!(a2.next_u64(), Prng::new(7).derive("netsim").next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(3);
        for _ in 0..10_000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut p = Prng::new(4);
        for _ in 0..10_000 {
            let v = p.range_u64(10, 20);
            assert!((10..=20).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(5);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = p.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut p = Prng::new(6);
        let n = 50_000;
        let mean = (0..n).map(|_| p.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut p = Prng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn fill_bytes_covers_partial_chunks() {
        let mut p = Prng::new(9);
        let mut buf = [0u8; 13];
        p.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
