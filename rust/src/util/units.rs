//! Units for the simulator and the reports: bytes, rates, virtual time.
//!
//! Virtual time is kept in integer nanoseconds for deterministic event
//! ordering; rates are `f64` bytes/second (the fluid solver is numeric
//! anyway). Formatting helpers render the paper's units (Gbps, GB, min).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Virtual time in nanoseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> SimTime {
        debug_assert!(s >= 0.0, "negative time: {s}");
        SimTime((s * 1e9).round() as u64)
    }
    pub fn from_secs(s: u64) -> SimTime {
        SimTime(s * 1_000_000_000)
    }
    pub fn from_millis(ms: u64) -> SimTime {
        SimTime(ms * 1_000_000)
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_mins_f64(self) -> f64 {
        self.as_secs_f64() / 60.0
    }
    /// Saturating difference.
    pub fn since(self, earlier: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(earlier.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}
impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}
impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s < 1.0 {
            write!(f, "{:.1}ms", s * 1e3)
        } else if s < 120.0 {
            write!(f, "{s:.1}s")
        } else {
            write!(f, "{:.1}min", s / 60.0)
        }
    }
}

/// Byte counts (files, transfers, caches).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Bytes(pub u64);

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

impl Bytes {
    pub const ZERO: Bytes = Bytes(0);

    pub fn kib(n: u64) -> Bytes {
        Bytes(n * KIB)
    }
    pub fn mib(n: u64) -> Bytes {
        Bytes(n * MIB)
    }
    pub fn gib(n: u64) -> Bytes {
        Bytes(n * GIB)
    }
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }
    /// Time to move these bytes at `rate` bytes/sec.
    pub fn time_at(self, rate_bps: f64) -> SimTime {
        debug_assert!(rate_bps > 0.0);
        SimTime::from_secs_f64(self.0 as f64 / rate_bps)
    }
}

impl Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}
impl AddAssign for Bytes {
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0 as f64;
        if b >= GIB as f64 {
            write!(f, "{:.2} GiB", b / GIB as f64)
        } else if b >= MIB as f64 {
            write!(f, "{:.2} MiB", b / MIB as f64)
        } else if b >= KIB as f64 {
            write!(f, "{:.2} KiB", b / KIB as f64)
        } else {
            write!(f, "{} B", self.0)
        }
    }
}

/// Network rate expressed the way the paper does (decimal gigabits/sec).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Gbps(pub f64);

impl Gbps {
    /// Decimal gigabits/sec -> bytes/sec (the solver's unit).
    pub fn bytes_per_sec(self) -> f64 {
        self.0 * 1e9 / 8.0
    }
    pub fn from_bytes_per_sec(bps: f64) -> Gbps {
        Gbps(bps * 8.0 / 1e9)
    }
}

impl fmt::Display for Gbps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1} Gbps", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_roundtrip() {
        let t = SimTime::from_secs_f64(12.5);
        assert!((t.as_secs_f64() - 12.5).abs() < 1e-9);
        assert_eq!(SimTime::from_secs(3).0, 3_000_000_000);
        assert_eq!(SimTime::from_millis(1500), SimTime::from_secs_f64(1.5));
    }

    #[test]
    fn simtime_arith_and_order() {
        let a = SimTime::from_secs(10);
        let b = SimTime::from_secs(4);
        assert_eq!((a + b).as_secs_f64(), 14.0);
        assert_eq!((a - b).as_secs_f64(), 6.0);
        assert_eq!((b - a).0, 0, "saturating");
        assert!(b < a);
        assert_eq!(a.since(b), SimTime::from_secs(6));
    }

    #[test]
    fn bytes_constructors() {
        assert_eq!(Bytes::gib(2).0, 2 * 1024 * 1024 * 1024);
        assert_eq!(Bytes::mib(1).0, 1 << 20);
        assert_eq!(Bytes::kib(64).0, 65536);
    }

    #[test]
    fn bytes_time_at() {
        // 2 GiB at ~11.25 GB/s (90 Gbps) ≈ 0.19 s
        let t = Bytes::gib(2).time_at(Gbps(90.0).bytes_per_sec());
        assert!((t.as_secs_f64() - 0.1908).abs() < 1e-3);
    }

    #[test]
    fn gbps_conversion() {
        let g = Gbps(100.0);
        assert_eq!(g.bytes_per_sec(), 12.5e9);
        let back = Gbps::from_bytes_per_sec(12.5e9);
        assert!((back.0 - 100.0).abs() < 1e-12);
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Bytes::gib(2)), "2.00 GiB");
        assert_eq!(format!("{}", Bytes(512)), "512 B");
        assert_eq!(format!("{}", SimTime::from_secs(300)), "5.0min");
        assert_eq!(format!("{}", Gbps(90.0)), "90.0 Gbps");
    }
}
