//! Minimal JSON parser — enough to read `artifacts/manifest.json`.
//!
//! The offline vendor set has no serde, so we carry a small recursive-
//! descent parser. It accepts the JSON the python AOT step emits (objects,
//! arrays, strings with escapes, integers/floats, booleans, null) and is
//! strict about trailing garbage.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("bad utf-8"))?;
                    let ch = text.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    s.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            Json::parse(r#""a\n\t\"\\bA""#).unwrap(),
            Json::Str("a\n\t\"\\bA".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn as_u64_rejects_fractional() {
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "abi_version": 1,
            "entries": [
                {"kind": "seal", "name": "probe", "file": "seal_probe.hlo.txt",
                 "n_blocks": 16, "tile": 16, "chunk_bytes": 1024,
                 "args": [{"shape": [8], "dtype": "u32"}],
                 "outputs": [{"shape": [16, 16], "dtype": "u32"}],
                 "sha256": "ab"}
            ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("abi_version").unwrap().as_u64(), Some(1));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("chunk_bytes").unwrap().as_u64(), Some(1024));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo — 100 Gbps\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 100 Gbps"));
    }
}
