//! Streaming statistics and histograms for experiment reports.

/// Welford online mean/variance plus min/max and a value reservoir for
/// exact percentiles (the experiment scales here are ≤ ~10⁵ samples, so we
/// just keep everything).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    values: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl OnlineStats {
    pub fn new() -> OnlineStats {
        OnlineStats::default()
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        let n = self.values.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.values.len() < 2 {
            0.0
        } else {
            self.m2 / (self.values.len() - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile (nearest-rank), p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }
}

/// Fixed-width histogram for transfer-time / rate distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    width: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            width: (hi - lo) / nbins as f64,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else {
            let i = ((x - self.lo) / self.width) as usize;
            if i >= self.bins.len() {
                self.overflow += 1;
            } else {
                self.bins[i] += 1;
            }
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * self.width
    }

    /// Render as compact ASCII rows (used by the CLI `render` command).
    pub fn ascii(&self, max_width: usize) -> String {
        let peak = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let bar = "#".repeat((c as usize * max_width).div_ceil(peak as usize).min(max_width));
            out.push_str(&format!("{:>10.2} | {:<6} {}\n", self.bin_center(i), c, bar));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = OnlineStats::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.sum(), 15.0);
    }

    #[test]
    fn percentiles() {
        let mut s = OnlineStats::new();
        for x in 0..100 {
            s.push(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
        assert!((s.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((s.percentile(90.0) - 89.0).abs() <= 1.0);
    }

    #[test]
    fn empty_percentile_nan() {
        assert!(OnlineStats::new().percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -1.0, 11.0] {
            h.push(x);
        }
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[1], 2);
        assert_eq!(h.bins()[9], 1);
        assert_eq!(h.total(), 6);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_ascii_renders() {
        let mut h = Histogram::new(0.0, 4.0, 4);
        for _ in 0..8 {
            h.push(1.5);
        }
        h.push(2.5);
        let art = h.ascii(20);
        assert!(art.contains('#'));
        assert_eq!(art.lines().count(), 4);
    }
}
