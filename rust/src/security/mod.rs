//! Security layer: authentication, session key establishment, and the
//! sealed (encrypted + integrity-digested) transfer primitives.
//!
//! The paper ran with HTCondor 9.0.1 defaults: *"all file transfers being
//! fully authenticated, AES encrypted, and integrity checked"*. We
//! reproduce that architecture:
//!
//! * [`session`] — pool-password authentication (HMAC-SHA256 challenge/
//!   response) and per-connection session key + nonce derivation.
//! * [`chacha`] — the native ChaCha20 + poly16 data-plane, bit-identical
//!   to the Pallas kernel (the AOT artifact and this module are
//!   cross-checked at engine startup and in `tests/artifact_runtime.rs`).
//! * [`aesctr`] — AES-256-CTR via the in-crate [`aes_core`] cipher, the drop-in alternate
//!   cipher (HTCondor's default is AES; ChaCha20 is our TPU-shaped path —
//!   see DESIGN.md §Hardware-Adaptation).
//!
//! Method negotiation mirrors HTCondor's `SEC_DEFAULT_ENCRYPTION` /
//! crypto-methods list: each side offers an ordered list, the first common
//! entry wins.

pub mod aes_core;
pub mod aesctr;
pub mod chacha;
pub mod session;
pub mod sha256;

/// Negotiable data-plane cipher methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// ChaCha20 + poly16 digest (the AOT/Pallas path or native Rust).
    Chacha20,
    /// AES-256-CTR + poly16 digest.
    Aes256Ctr,
    /// No encryption (integrity digest only) — for ablation runs.
    Plain,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Chacha20 => "CHACHA20",
            Method::Aes256Ctr => "AES",
            Method::Plain => "PLAIN",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.trim().to_ascii_uppercase().as_str() {
            "CHACHA20" => Some(Method::Chacha20),
            "AES" | "AES256CTR" => Some(Method::Aes256Ctr),
            "PLAIN" | "NONE" => Some(Method::Plain),
            _ => None,
        }
    }
}

/// First-common-entry method negotiation (client preference order wins,
/// as in HTCondor's security negotiation).
pub fn negotiate(client: &[Method], server: &[Method]) -> Option<Method> {
    client.iter().copied().find(|m| server.contains(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in [Method::Chacha20, Method::Aes256Ctr, Method::Plain] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("aes"), Some(Method::Aes256Ctr));
        assert_eq!(Method::parse("none"), Some(Method::Plain));
        assert_eq!(Method::parse("rot13"), None);
    }

    #[test]
    fn negotiation_prefers_client_order() {
        let client = [Method::Chacha20, Method::Aes256Ctr];
        let server = [Method::Aes256Ctr, Method::Chacha20];
        assert_eq!(negotiate(&client, &server), Some(Method::Chacha20));
    }

    #[test]
    fn negotiation_fails_on_disjoint() {
        assert_eq!(
            negotiate(&[Method::Chacha20], &[Method::Aes256Ctr]),
            None
        );
    }
}
