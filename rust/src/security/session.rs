//! Session establishment: pool-password authentication and per-connection
//! key/nonce derivation — the "fully authenticated" part of the paper's
//! default security stack.
//!
//! Protocol (a faithful miniature of HTCondor's PASSWORD method):
//!
//! 1. client → server: `ClientHello { client_nonce, methods }`
//! 2. server → client: `ServerHello { server_nonce, method, server_mac }`
//!    where `server_mac = HMAC(pool_key, "srv" || client_nonce || server_nonce)`
//! 3. client → server: `client_mac = HMAC(pool_key, "cli" || server_nonce || client_nonce)`
//! 4. both derive: `session_key = HMAC(pool_key, "key" || client_nonce || server_nonce)`
//!    and a 96-bit data-plane nonce from the same PRF with label "non".
//!
//! Mutual authentication: each side proves knowledge of the pool key over
//! the other's fresh nonce. The session key is never transmitted.

use super::sha256::{hmac_sha256, Sha256};
use super::Method;

/// Shared pool secret (HTCondor pool password).
#[derive(Debug, Clone)]
pub struct PoolKey(pub [u8; 32]);

impl PoolKey {
    /// Derive a pool key from a passphrase (sha256, as condor_store_cred
    /// effectively does).
    pub fn from_passphrase(p: &str) -> PoolKey {
        let mut h = Sha256::new();
        h.update(b"htcdm-pool-v1");
        h.update(p.as_bytes());
        PoolKey(h.finalize())
    }
}

fn prf(key: &PoolKey, label: &[u8], a: &[u8; 16], b: &[u8; 16]) -> [u8; 32] {
    hmac_sha256(&key.0, &[label, a, b])
}

/// An established, mutually-authenticated session.
#[derive(Debug, Clone, PartialEq)]
pub struct Session {
    /// Data-plane cipher key as 8 LE words (the artifact ABI's key arg).
    pub key_words: [u32; 8],
    /// 96-bit data-plane nonce as 3 LE words.
    pub nonce_words: [u32; 3],
    pub method: Method,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuthError {
    NoCommonMethod,
    BadServerMac,
    BadClientMac,
}

impl std::fmt::Display for AuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AuthError::NoCommonMethod => "no common cipher method",
            AuthError::BadServerMac => "server failed authentication (bad pool key?)",
            AuthError::BadClientMac => "client failed authentication (bad pool key?)",
        })
    }
}

impl std::error::Error for AuthError {}

/// Message 1.
#[derive(Debug, Clone)]
pub struct ClientHello {
    pub client_nonce: [u8; 16],
    pub methods: Vec<Method>,
}

/// Message 2.
#[derive(Debug, Clone)]
pub struct ServerHello {
    pub server_nonce: [u8; 16],
    pub method: Method,
    pub server_mac: [u8; 32],
}

/// Client side: start a handshake.
pub fn client_hello(client_nonce: [u8; 16], methods: &[Method]) -> ClientHello {
    ClientHello {
        client_nonce,
        methods: methods.to_vec(),
    }
}

/// Server side: answer a hello, proving pool-key knowledge.
pub fn server_respond(
    key: &PoolKey,
    hello: &ClientHello,
    server_nonce: [u8; 16],
    server_methods: &[Method],
) -> Result<ServerHello, AuthError> {
    let method = super::negotiate(&hello.methods, server_methods).ok_or(AuthError::NoCommonMethod)?;
    Ok(ServerHello {
        server_nonce,
        method,
        server_mac: prf(key, b"srv", &hello.client_nonce, &server_nonce),
    })
}

/// Client side: verify the server, produce the client MAC and the session.
pub fn client_finish(
    key: &PoolKey,
    hello: &ClientHello,
    reply: &ServerHello,
) -> Result<([u8; 32], Session), AuthError> {
    let expect = prf(key, b"srv", &hello.client_nonce, &reply.server_nonce);
    if expect != reply.server_mac {
        return Err(AuthError::BadServerMac);
    }
    let client_mac = prf(key, b"cli", &reply.server_nonce, &hello.client_nonce);
    Ok((
        client_mac,
        derive_session(key, &hello.client_nonce, &reply.server_nonce, reply.method),
    ))
}

/// Server side: verify the client MAC and derive the same session.
pub fn server_finish(
    key: &PoolKey,
    hello: &ClientHello,
    reply: &ServerHello,
    client_mac: &[u8; 32],
) -> Result<Session, AuthError> {
    let expect = prf(key, b"cli", &reply.server_nonce, &hello.client_nonce);
    if &expect != client_mac {
        return Err(AuthError::BadClientMac);
    }
    Ok(derive_session(
        key,
        &hello.client_nonce,
        &reply.server_nonce,
        reply.method,
    ))
}

fn derive_session(key: &PoolKey, cn: &[u8; 16], sn: &[u8; 16], method: Method) -> Session {
    let key_material = prf(key, b"key", cn, sn);
    let nonce_material = prf(key, b"non", cn, sn);
    let mut key_words = [0u32; 8];
    for i in 0..8 {
        key_words[i] = u32::from_le_bytes(key_material[i * 4..i * 4 + 4].try_into().unwrap());
    }
    let mut nonce_words = [0u32; 3];
    for i in 0..3 {
        nonce_words[i] = u32::from_le_bytes(nonce_material[i * 4..i * 4 + 4].try_into().unwrap());
    }
    Session {
        key_words,
        nonce_words,
        method,
    }
}

/// Run the whole handshake in-process (sim mode uses this; real mode sends
/// the three messages over the wire).
pub fn handshake(
    key: &PoolKey,
    client_nonce: [u8; 16],
    server_nonce: [u8; 16],
    client_methods: &[Method],
    server_methods: &[Method],
) -> Result<Session, AuthError> {
    let hello = client_hello(client_nonce, client_methods);
    let reply = server_respond(key, &hello, server_nonce, server_methods)?;
    let (mac, client_session) = client_finish(key, &hello, &reply)?;
    let server_session = server_finish(key, &hello, &reply, &mac)?;
    debug_assert_eq!(client_session, server_session);
    Ok(server_session)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nonce(b: u8) -> [u8; 16] {
        [b; 16]
    }

    #[test]
    fn successful_handshake_derives_same_session() {
        let key = PoolKey::from_passphrase("hunter2");
        let s = handshake(
            &key,
            nonce(1),
            nonce(2),
            &[Method::Chacha20, Method::Aes256Ctr],
            &[Method::Aes256Ctr, Method::Chacha20],
        )
        .unwrap();
        assert_eq!(s.method, Method::Chacha20);
        assert_ne!(s.key_words, [0u32; 8]);
        assert_ne!(s.nonce_words, [0u32; 3]);
    }

    #[test]
    fn wrong_pool_key_fails_both_ways() {
        let good = PoolKey::from_passphrase("right");
        let bad = PoolKey::from_passphrase("wrong");
        let hello = client_hello(nonce(1), &[Method::Chacha20]);
        let reply = server_respond(&bad, &hello, nonce(2), &[Method::Chacha20]).unwrap();
        // Client detects the imposter server.
        assert_eq!(
            client_finish(&good, &hello, &reply).unwrap_err(),
            AuthError::BadServerMac
        );
        // And an imposter client is detected by the server.
        let reply2 = server_respond(&good, &hello, nonce(2), &[Method::Chacha20]).unwrap();
        let (mac, _) = client_finish(&good, &hello, &reply2).unwrap();
        let mut tampered = mac;
        tampered[0] ^= 1;
        assert_eq!(
            server_finish(&good, &hello, &reply2, &tampered).unwrap_err(),
            AuthError::BadClientMac
        );
    }

    #[test]
    fn sessions_differ_per_nonce_pair() {
        let key = PoolKey::from_passphrase("p");
        let m = [Method::Chacha20];
        let s1 = handshake(&key, nonce(1), nonce(2), &m, &m).unwrap();
        let s2 = handshake(&key, nonce(1), nonce(3), &m, &m).unwrap();
        let s3 = handshake(&key, nonce(4), nonce(2), &m, &m).unwrap();
        assert_ne!(s1.key_words, s2.key_words);
        assert_ne!(s1.key_words, s3.key_words);
        assert_ne!(s1.nonce_words, s2.nonce_words);
    }

    #[test]
    fn no_common_method() {
        let key = PoolKey::from_passphrase("p");
        assert_eq!(
            handshake(&key, nonce(1), nonce(2), &[Method::Chacha20], &[Method::Plain]).unwrap_err(),
            AuthError::NoCommonMethod
        );
    }

    #[test]
    fn passphrase_determinism() {
        assert_eq!(
            PoolKey::from_passphrase("x").0,
            PoolKey::from_passphrase("x").0
        );
        assert_ne!(
            PoolKey::from_passphrase("x").0,
            PoolKey::from_passphrase("y").0
        );
    }
}
