//! AES-256 block encryption (FIPS 197), implemented in-crate.
//!
//! Only encryption is needed: the data plane runs AES in CTR mode, where
//! decryption is the same keystream XOR. Validated against the FIPS-197
//! Appendix C.3 known-answer vector and a Python mirror of the same code.
//!
//! This is a straightforward table-driven implementation (S-box lookups,
//! `xtime` for MixColumns) — clarity over speed; the crypto line-rate
//! bench measures ChaCha20 as the fast path.

/// The AES S-box (FIPS 197 Fig. 7).
const SBOX: [u8; 256] = [
    0x63, 0x7C, 0x77, 0x7B, 0xF2, 0x6B, 0x6F, 0xC5, 0x30, 0x01, 0x67, 0x2B, 0xFE, 0xD7, 0xAB, 0x76,
    0xCA, 0x82, 0xC9, 0x7D, 0xFA, 0x59, 0x47, 0xF0, 0xAD, 0xD4, 0xA2, 0xAF, 0x9C, 0xA4, 0x72, 0xC0,
    0xB7, 0xFD, 0x93, 0x26, 0x36, 0x3F, 0xF7, 0xCC, 0x34, 0xA5, 0xE5, 0xF1, 0x71, 0xD8, 0x31, 0x15,
    0x04, 0xC7, 0x23, 0xC3, 0x18, 0x96, 0x05, 0x9A, 0x07, 0x12, 0x80, 0xE2, 0xEB, 0x27, 0xB2, 0x75,
    0x09, 0x83, 0x2C, 0x1A, 0x1B, 0x6E, 0x5A, 0xA0, 0x52, 0x3B, 0xD6, 0xB3, 0x29, 0xE3, 0x2F, 0x84,
    0x53, 0xD1, 0x00, 0xED, 0x20, 0xFC, 0xB1, 0x5B, 0x6A, 0xCB, 0xBE, 0x39, 0x4A, 0x4C, 0x58, 0xCF,
    0xD0, 0xEF, 0xAA, 0xFB, 0x43, 0x4D, 0x33, 0x85, 0x45, 0xF9, 0x02, 0x7F, 0x50, 0x3C, 0x9F, 0xA8,
    0x51, 0xA3, 0x40, 0x8F, 0x92, 0x9D, 0x38, 0xF5, 0xBC, 0xB6, 0xDA, 0x21, 0x10, 0xFF, 0xF3, 0xD2,
    0xCD, 0x0C, 0x13, 0xEC, 0x5F, 0x97, 0x44, 0x17, 0xC4, 0xA7, 0x7E, 0x3D, 0x64, 0x5D, 0x19, 0x73,
    0x60, 0x81, 0x4F, 0xDC, 0x22, 0x2A, 0x90, 0x88, 0x46, 0xEE, 0xB8, 0x14, 0xDE, 0x5E, 0x0B, 0xDB,
    0xE0, 0x32, 0x3A, 0x0A, 0x49, 0x06, 0x24, 0x5C, 0xC2, 0xD3, 0xAC, 0x62, 0x91, 0x95, 0xE4, 0x79,
    0xE7, 0xC8, 0x37, 0x6D, 0x8D, 0xD5, 0x4E, 0xA9, 0x6C, 0x56, 0xF4, 0xEA, 0x65, 0x7A, 0xAE, 0x08,
    0xBA, 0x78, 0x25, 0x2E, 0x1C, 0xA6, 0xB4, 0xC6, 0xE8, 0xDD, 0x74, 0x1F, 0x4B, 0xBD, 0x8B, 0x8A,
    0x70, 0x3E, 0xB5, 0x66, 0x48, 0x03, 0xF6, 0x0E, 0x61, 0x35, 0x57, 0xB9, 0x86, 0xC1, 0x1D, 0x9E,
    0xE1, 0xF8, 0x98, 0x11, 0x69, 0xD9, 0x8E, 0x94, 0x9B, 0x1E, 0x87, 0xE9, 0xCE, 0x55, 0x28, 0xDF,
    0x8C, 0xA1, 0x89, 0x0D, 0xBF, 0xE6, 0x42, 0x68, 0x41, 0x99, 0x2D, 0x0F, 0xB0, 0x54, 0xBB, 0x16,
];

/// Round constants for key expansion.
const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36];

#[inline(always)]
fn xtime(a: u8) -> u8 {
    (a << 1) ^ if a & 0x80 != 0 { 0x1B } else { 0 }
}

/// AES-256 encryption context: the 15 expanded round keys.
#[derive(Debug, Clone)]
pub struct Aes256 {
    /// Round-key words, 4 bytes each; round r uses words 4r..4r+4.
    w: [[u8; 4]; 60],
}

impl Aes256 {
    pub fn new(key: &[u8; 32]) -> Aes256 {
        let mut w = [[0u8; 4]; 60];
        for (i, item) in w.iter_mut().take(8).enumerate() {
            item.copy_from_slice(&key[i * 4..i * 4 + 4]);
        }
        for i in 8..60 {
            let mut t = w[i - 1];
            if i % 8 == 0 {
                // RotWord + SubWord + Rcon.
                t = [t[1], t[2], t[3], t[0]];
                for b in t.iter_mut() {
                    *b = SBOX[*b as usize];
                }
                t[0] ^= RCON[i / 8 - 1];
            } else if i % 8 == 4 {
                // AES-256's extra SubWord.
                for b in t.iter_mut() {
                    *b = SBOX[*b as usize];
                }
            }
            for j in 0..4 {
                w[i][j] = w[i - 8][j] ^ t[j];
            }
        }
        Aes256 { w }
    }

    /// Encrypt one 16-byte block in place.
    pub fn encrypt_block(&self, block: &mut [u8; 16]) {
        // Column-major state: s[c][r] = block[4c + r].
        let mut s = [[0u8; 4]; 4];
        for c in 0..4 {
            s[c].copy_from_slice(&block[c * 4..c * 4 + 4]);
        }
        self.add_round_key(&mut s, 0);
        for round in 1..14 {
            sub_bytes(&mut s);
            shift_rows(&mut s);
            mix_columns(&mut s);
            self.add_round_key(&mut s, round);
        }
        sub_bytes(&mut s);
        shift_rows(&mut s);
        self.add_round_key(&mut s, 14);
        for c in 0..4 {
            block[c * 4..c * 4 + 4].copy_from_slice(&s[c]);
        }
    }

    #[inline(always)]
    fn add_round_key(&self, s: &mut [[u8; 4]; 4], round: usize) {
        for c in 0..4 {
            for r in 0..4 {
                s[c][r] ^= self.w[4 * round + c][r];
            }
        }
    }
}

#[inline(always)]
fn sub_bytes(s: &mut [[u8; 4]; 4]) {
    for col in s.iter_mut() {
        for b in col.iter_mut() {
            *b = SBOX[*b as usize];
        }
    }
}

#[inline(always)]
fn shift_rows(s: &mut [[u8; 4]; 4]) {
    for r in 1..4 {
        let row = [s[0][r], s[1][r], s[2][r], s[3][r]];
        for c in 0..4 {
            s[c][r] = row[(c + r) % 4];
        }
    }
}

#[inline(always)]
fn mix_columns(s: &mut [[u8; 4]; 4]) {
    for col in s.iter_mut() {
        let a = *col;
        // 2·a0 ^ 3·a1 ^ a2 ^ a3 and rotations (3·x = xtime(x) ^ x).
        col[0] = xtime(a[0]) ^ (xtime(a[1]) ^ a[1]) ^ a[2] ^ a[3];
        col[1] = a[0] ^ xtime(a[1]) ^ (xtime(a[2]) ^ a[2]) ^ a[3];
        col[2] = a[0] ^ a[1] ^ xtime(a[2]) ^ (xtime(a[3]) ^ a[3]);
        col[3] = (xtime(a[0]) ^ a[0]) ^ a[1] ^ a[2] ^ xtime(a[3]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fips197_c3_known_answer() {
        // FIPS 197 Appendix C.3: AES-256, key 00..1f, pt 00112233..eeff.
        let mut key = [0u8; 32];
        for (i, k) in key.iter_mut().enumerate() {
            *k = i as u8;
        }
        let cipher = Aes256::new(&key);
        let mut block = [
            0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88, 0x99, 0xAA, 0xBB, 0xCC, 0xDD,
            0xEE, 0xFF,
        ];
        cipher.encrypt_block(&mut block);
        let expect = [
            0x8E, 0xA2, 0xB7, 0xCA, 0x51, 0x67, 0x45, 0xBF, 0xEA, 0xFC, 0x49, 0x90, 0x4B, 0x49,
            0x60, 0x89,
        ];
        assert_eq!(block, expect);
    }

    #[test]
    fn different_keys_different_ciphertext() {
        let a = Aes256::new(&[1u8; 32]);
        let b = Aes256::new(&[2u8; 32]);
        let mut x = [7u8; 16];
        let mut y = [7u8; 16];
        a.encrypt_block(&mut x);
        b.encrypt_block(&mut y);
        assert_ne!(x, y);
    }

    #[test]
    fn deterministic() {
        let c = Aes256::new(&[9u8; 32]);
        let mut x = [3u8; 16];
        let mut y = [3u8; 16];
        c.encrypt_block(&mut x);
        c.encrypt_block(&mut y);
        assert_eq!(x, y);
    }
}
