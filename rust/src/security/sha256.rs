//! SHA-256 and HMAC-SHA256, implemented in-crate (FIPS 180-4 / RFC 2104).
//!
//! The build environment is fully offline, so the session layer cannot
//! pull `sha2`/`hmac` from crates.io; this module provides the subset the
//! pool-password handshake needs. The algorithm was validated against a
//! line-for-line Python mirror checked against `hashlib` (all lengths
//! around block boundaries) and the RFC 4231 HMAC vectors below.

/// Round constants: fractional parts of the cube roots of the first 64
/// primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5, 0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5,
    0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3, 0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174,
    0xE49B69C1, 0xEFBE4786, 0x0FC19DC6, 0x240CA1CC, 0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7, 0xC6E00BF3, 0xD5A79147, 0x06CA6351, 0x14292967,
    0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13, 0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85,
    0xA2BFE8A1, 0xA81A664B, 0xC24B8B70, 0xC76C51A3, 0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5, 0x391C0CB3, 0x4ED8AA4A, 0x5B9CCA4F, 0x682E6FF3,
    0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208, 0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
];

/// Initial hash state: fractional parts of the square roots of the first
/// 8 primes.
const H0: [u32; 8] = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A, 0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
];

/// Incremental SHA-256 (new / update / finalize), mirroring the `sha2`
/// crate's `Digest` usage in the session layer.
#[derive(Debug, Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Bytes buffered toward the next 64-byte block.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total: 0,
        }
    }

    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        // Length block bypasses `total` accounting (already captured).
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        let add = [a, b, c, d, e, f, g, h];
        for (s, v) in self.state.iter_mut().zip(add) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// HMAC-SHA256 over the concatenation of `parts` (RFC 2104).
pub fn hmac_sha256(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let mut key_block = [0u8; 64];
    if key.len() > 64 {
        key_block[..32].copy_from_slice(&sha256(key));
    } else {
        key_block[..key.len()].copy_from_slice(key);
    }
    let mut inner = Sha256::new();
    let ipad: Vec<u8> = key_block.iter().map(|k| k ^ 0x36).collect();
    inner.update(&ipad);
    for p in parts {
        inner.update(p);
    }
    let inner_digest = inner.finalize();
    let mut outer = Sha256::new();
    let opad: Vec<u8> = key_block.iter().map(|k| k ^ 0x5C).collect();
    outer.update(&opad);
    outer.update(inner_digest);
    outer.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn empty_and_abc_vectors() {
        // FIPS 180-4 / NIST example vectors.
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a_vector() {
        let mut h = Sha256::new();
        // Incremental updates in awkward sizes cross every buffer path.
        let chunk = [b'a'; 997];
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 3) as u8).collect();
        for split in [0usize, 1, 63, 64, 65, 500, 999, 1000] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha256(&data), "split {split}");
        }
    }

    #[test]
    fn hmac_rfc4231_case1() {
        // RFC 4231 test case 1.
        let key = [0x0bu8; 20];
        let out = hmac_sha256(&key, &[b"Hi There"]);
        assert_eq!(
            hex(&out),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn hmac_rfc4231_case2() {
        // RFC 4231 test case 2: key "Jefe".
        let out = hmac_sha256(b"Jefe", &[b"what do ya want ", b"for nothing?"]);
        assert_eq!(
            hex(&out),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn hmac_long_key_is_hashed_first() {
        let key = [0xAAu8; 131];
        // RFC 4231 test case 6.
        let out = hmac_sha256(
            &key,
            &[b"Test Using Larger Than Block-Size Key - Hash Key First"],
        );
        assert_eq!(
            hex(&out),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn hmac_split_parts_equal_concat() {
        let key = b"pool-password";
        let a = hmac_sha256(key, &[b"srv", b"abcd", b"efgh"]);
        let b = hmac_sha256(key, &[b"srvabcdefgh"]);
        assert_eq!(a, b);
    }
}
