//! Native ChaCha20 (RFC 7539) + poly16 integrity digest.
//!
//! This is the *reference software path* for the data plane: bit-identical
//! to the Pallas kernel / AOT artifact (`python/compile/kernels/chacha.py`
//! and `ref.py`). The runtime cross-verifies the two implementations at
//! engine startup; `tests/artifact_runtime.rs` does it exhaustively.
//!
//! All data is in little-endian u32 *words*; a chunk is `n_blocks × 16`
//! words (64 bytes per ChaCha block), matching the artifact ABI.

/// ChaCha20 "expand 32-byte k" constants.
pub const CONSTANTS: [u32; 4] = [0x6170_7865, 0x3320_646E, 0x7962_2D32, 0x6B20_6574];

// Digest constants — must match python/compile/kernels/ref.py.
pub const PHI32: u32 = 0x9E37_79B1;
pub const MIX_M1: u32 = 0x7FEB_352D;
pub const MIX_M2: u32 = 0x846C_A68B;
pub const LANE_C: u32 = 0x85EB_CA6B;

#[inline(always)]
fn qr(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

/// One 64-byte keystream block for the given counter.
pub fn keystream_block(key: &[u32; 8], nonce: &[u32; 3], counter: u32) -> [u32; 16] {
    let mut x: [u32; 16] = [
        CONSTANTS[0],
        CONSTANTS[1],
        CONSTANTS[2],
        CONSTANTS[3],
        key[0],
        key[1],
        key[2],
        key[3],
        key[4],
        key[5],
        key[6],
        key[7],
        counter,
        nonce[0],
        nonce[1],
        nonce[2],
    ];
    let x0 = x;
    for _ in 0..10 {
        qr(&mut x, 0, 4, 8, 12);
        qr(&mut x, 1, 5, 9, 13);
        qr(&mut x, 2, 6, 10, 14);
        qr(&mut x, 3, 7, 11, 15);
        qr(&mut x, 0, 5, 10, 15);
        qr(&mut x, 1, 6, 11, 12);
        qr(&mut x, 2, 7, 8, 13);
        qr(&mut x, 3, 4, 9, 14);
    }
    for i in 0..16 {
        x[i] = x[i].wrapping_add(x0[i]);
    }
    x
}

/// XOR `data` (length must be a multiple of 16 words) with the keystream
/// starting at block counter `counter0`. Encrypt == decrypt.
pub fn xor_stream(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) {
    // NOTE(perf): this scalar form is the frozen reference. A 4-way
    // transposed-state [[u32;4];16] variant measured *slower* (1.9 vs
    // 3.5 Gbps — the layout defeats auto-vectorization) and was
    // reverted; the wire path instead uses the AVX2 byte-slice twin
    // below (see docs/ARCHITECTURE.md §Data-path performance).
    assert!(data.len() % 16 == 0, "data must be whole 64-byte blocks");
    for (i, block) in data.chunks_mut(16).enumerate() {
        let ks = keystream_block(key, nonce, counter0.wrapping_add(i as u32));
        for (w, k) in block.iter_mut().zip(ks.iter()) {
            *w ^= k;
        }
    }
}

/// Murmur3-style avalanche on one word (matches `ref._mix32`).
#[inline(always)]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(MIX_M1);
    x ^= x >> 15;
    x = x.wrapping_mul(MIX_M2);
    x ^= x >> 16;
    x
}

/// 16-lane order-sensitive XOR-fold digest over whole blocks.
/// `row0` is the absolute index of the first row (= the chunk's counter0),
/// making chunked digests XOR-combinable.
pub fn poly16_digest(data: &[u32], row0: u32) -> [u32; 16] {
    assert!(data.len() % 16 == 0);
    let mut acc = [0u32; 16];
    for (i, block) in data.chunks(16).enumerate() {
        let r = row0.wrapping_add(i as u32);
        let row_tweak = r.wrapping_add(1).wrapping_mul(PHI32);
        for (j, acc_j) in acc.iter_mut().enumerate() {
            let tweak = row_tweak.wrapping_add((j as u32).wrapping_mul(LANE_C));
            *acc_j ^= mix32(block[j].wrapping_add(tweak));
        }
    }
    acc
}

/// Fold the 16-lane digest into the 4-word transfer digest, binding total
/// length (in words) and nonce (matches `ref.digest_finalize`).
pub fn digest_finalize(lane: &[u32; 16], total_words: u32, nonce: &[u32; 3]) -> [u32; 4] {
    let mut d = *lane;
    d[0] ^= total_words;
    d[1] ^= nonce[0];
    d[2] ^= nonce[1];
    d[3] ^= nonce[2];
    let mut out = [0u32; 4];
    for j in 0..4 {
        let inner3 = mix32(d[12 + j]);
        let inner2 = mix32(d[8 + j].wrapping_add(inner3));
        let inner1 = mix32(d[4 + j].wrapping_add(inner2));
        out[j] = mix32(d[j].wrapping_add(inner1));
    }
    out
}

/// Seal a chunk in place: encrypt, then digest the ciphertext.
/// Returns the 4-word transfer digest.
pub fn seal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    xor_stream(key, nonce, counter0, data);
    let lane = poly16_digest(data, counter0);
    digest_finalize(&lane, data.len() as u32, nonce)
}

/// Unseal a chunk in place: digest the (input) ciphertext, then decrypt.
pub fn unseal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    let lane = poly16_digest(data, counter0);
    let digest = digest_finalize(&lane, data.len() as u32, nonce);
    xor_stream(key, nonce, counter0, data);
    digest
}

// ---- byte-slice data path (zero-copy wire format) --------------------------
//
// The wire path keeps payloads as bytes end to end; these are the
// byte-slice twins of `xor_stream` / `poly16_digest` / `seal_chunk` /
// `unseal_chunk`, bit-identical to the word path (data is little-endian
// u32 words on the wire). On x86-64 with AVX2 they run an 8-block
// vertical keystream and a row-parallel digest, runtime-detected with
// the scalar form as fallback and as the cross-checked reference
// (`byte_path_matches_word_path` below). The scalar word path above
// stays untouched so sim, XLA-verify, and the frozen bench baselines
// keep their meaning. See docs/ARCHITECTURE.md §Data-path performance.

/// XOR one 64-byte block of `chunk` (bytes, little-endian words).
fn xor_block_bytes(key: &[u32; 8], nonce: &[u32; 3], counter: u32, chunk: &mut [u8]) {
    let ks = keystream_block(key, nonce, counter);
    for (j, k) in ks.iter().enumerate() {
        let o = j * 4;
        let w = u32::from_le_bytes([chunk[o], chunk[o + 1], chunk[o + 2], chunk[o + 3]]) ^ k;
        chunk[o..o + 4].copy_from_slice(&w.to_le_bytes());
    }
}

/// Byte-slice twin of [`xor_stream`]: `data.len()` must be a multiple
/// of 64 (whole ChaCha blocks). Encrypt == decrypt.
pub fn xor_stream_bytes(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u8]) {
    assert!(data.len() % 64 == 0, "data must be whole 64-byte blocks");
    let done = xor_stream_bytes_accel(key, nonce, counter0, data);
    let ctr = counter0.wrapping_add((done / 64) as u32);
    for (i, block) in data[done..].chunks_exact_mut(64).enumerate() {
        xor_block_bytes(key, nonce, ctr.wrapping_add(i as u32), block);
    }
}

/// Byte-slice twin of [`poly16_digest`] (little-endian words).
pub fn poly16_digest_bytes(data: &[u8], row0: u32) -> [u32; 16] {
    assert!(data.len() % 64 == 0, "data must be whole 64-byte blocks");
    if let Some(acc) = poly16_digest_bytes_accel(data, row0) {
        return acc;
    }
    let mut acc = [0u32; 16];
    for (i, block) in data.chunks_exact(64).enumerate() {
        let r = row0.wrapping_add(i as u32);
        let row_tweak = r.wrapping_add(1).wrapping_mul(PHI32);
        for (j, acc_j) in acc.iter_mut().enumerate() {
            let o = j * 4;
            let w = u32::from_le_bytes([block[o], block[o + 1], block[o + 2], block[o + 3]]);
            let tweak = row_tweak.wrapping_add((j as u32).wrapping_mul(LANE_C));
            *acc_j ^= mix32(w.wrapping_add(tweak));
        }
    }
    acc
}

/// Byte-slice twin of [`seal_chunk`]: encrypt in place, digest the
/// ciphertext. `data.len()` must be a multiple of 64.
pub fn seal_chunk_bytes(
    key: &[u32; 8],
    nonce: &[u32; 3],
    counter0: u32,
    data: &mut [u8],
) -> [u32; 4] {
    xor_stream_bytes(key, nonce, counter0, data);
    let lane = poly16_digest_bytes(data, counter0);
    digest_finalize(&lane, (data.len() / 4) as u32, nonce)
}

/// Byte-slice twin of [`unseal_chunk`]: digest the (input) ciphertext,
/// then decrypt in place. `data.len()` must be a multiple of 64.
pub fn unseal_chunk_bytes(
    key: &[u32; 8],
    nonce: &[u32; 3],
    counter0: u32,
    data: &mut [u8],
) -> [u32; 4] {
    let lane = poly16_digest_bytes(data, counter0);
    let digest = digest_finalize(&lane, (data.len() / 4) as u32, nonce);
    xor_stream_bytes(key, nonce, counter0, data);
    digest
}

#[cfg(target_arch = "x86_64")]
fn xor_stream_bytes_accel(
    key: &[u32; 8],
    nonce: &[u32; 3],
    counter0: u32,
    data: &mut [u8],
) -> usize {
    if std::arch::is_x86_feature_detected!("avx2") {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { avx2::xor_stream(key, nonce, counter0, data) }
    } else {
        0
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn xor_stream_bytes_accel(
    _key: &[u32; 8],
    _nonce: &[u32; 3],
    _counter0: u32,
    _data: &mut [u8],
) -> usize {
    0
}

#[cfg(target_arch = "x86_64")]
fn poly16_digest_bytes_accel(data: &[u8], row0: u32) -> Option<[u32; 16]> {
    if std::arch::is_x86_feature_detected!("avx2") {
        // SAFETY: AVX2 presence checked at runtime just above.
        Some(unsafe { avx2::poly16_digest(data, row0) })
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn poly16_digest_bytes_accel(_data: &[u8], _row0: u32) -> Option<[u32; 16]> {
    None
}

/// AVX2 lanes of the byte-slice data path: an 8-block vertical ChaCha20
/// keystream and a row-parallel poly16 digest, bit-identical to the
/// scalar path (asserted by the RFC vectors plus the scalar-parity
/// tests below). Callers must check `is_x86_feature_detected!("avx2")`.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{CONSTANTS, LANE_C, MIX_M1, MIX_M2, PHI32};
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl16(x: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi32::<16>(x), _mm256_srli_epi32::<16>(x))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl12(x: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi32::<12>(x), _mm256_srli_epi32::<20>(x))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl8(x: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi32::<8>(x), _mm256_srli_epi32::<24>(x))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn rotl7(x: __m256i) -> __m256i {
        _mm256_or_si256(_mm256_slli_epi32::<7>(x), _mm256_srli_epi32::<25>(x))
    }

    /// One quarter-round across all 8 block lanes at once.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vqr(v: &mut [__m256i; 16], a: usize, b: usize, c: usize, d: usize) {
        v[a] = _mm256_add_epi32(v[a], v[b]);
        v[d] = rotl16(_mm256_xor_si256(v[d], v[a]));
        v[c] = _mm256_add_epi32(v[c], v[d]);
        v[b] = rotl12(_mm256_xor_si256(v[b], v[c]));
        v[a] = _mm256_add_epi32(v[a], v[b]);
        v[d] = rotl8(_mm256_xor_si256(v[d], v[a]));
        v[c] = _mm256_add_epi32(v[c], v[d]);
        v[b] = rotl7(_mm256_xor_si256(v[b], v[c]));
    }

    /// Transpose 8 vectors of 8 u32 lanes: `out[b]` lane `j` == `v[j]`
    /// lane `b` (vertical state words -> contiguous keystream blocks).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn transpose8(v: &[__m256i; 8]) -> [__m256i; 8] {
        let t0 = _mm256_unpacklo_epi32(v[0], v[1]);
        let t1 = _mm256_unpackhi_epi32(v[0], v[1]);
        let t2 = _mm256_unpacklo_epi32(v[2], v[3]);
        let t3 = _mm256_unpackhi_epi32(v[2], v[3]);
        let t4 = _mm256_unpacklo_epi32(v[4], v[5]);
        let t5 = _mm256_unpackhi_epi32(v[4], v[5]);
        let t6 = _mm256_unpacklo_epi32(v[6], v[7]);
        let t7 = _mm256_unpackhi_epi32(v[6], v[7]);
        let u0 = _mm256_unpacklo_epi64(t0, t2);
        let u1 = _mm256_unpackhi_epi64(t0, t2);
        let u2 = _mm256_unpacklo_epi64(t1, t3);
        let u3 = _mm256_unpackhi_epi64(t1, t3);
        let u4 = _mm256_unpacklo_epi64(t4, t6);
        let u5 = _mm256_unpackhi_epi64(t4, t6);
        let u6 = _mm256_unpacklo_epi64(t5, t7);
        let u7 = _mm256_unpackhi_epi64(t5, t7);
        [
            _mm256_permute2x128_si256::<0x20>(u0, u4),
            _mm256_permute2x128_si256::<0x20>(u1, u5),
            _mm256_permute2x128_si256::<0x20>(u2, u6),
            _mm256_permute2x128_si256::<0x20>(u3, u7),
            _mm256_permute2x128_si256::<0x31>(u0, u4),
            _mm256_permute2x128_si256::<0x31>(u1, u5),
            _mm256_permute2x128_si256::<0x31>(u2, u6),
            _mm256_permute2x128_si256::<0x31>(u3, u7),
        ]
    }

    /// XOR the keystream into whole 8-block (512-byte) groups of `data`;
    /// returns the number of bytes processed (the < 8-block tail is the
    /// caller's).
    ///
    /// # Safety
    /// AVX2 must be available (runtime-detected by the caller).
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_stream(
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
        data: &mut [u8],
    ) -> usize {
        let groups = data.len() / 512;
        let lane_idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let mut base = [0u32; 16];
        base[..4].copy_from_slice(&CONSTANTS);
        base[4..12].copy_from_slice(key);
        base[13..16].copy_from_slice(nonce);
        for g in 0..groups {
            let ctr = counter0.wrapping_add((g * 8) as u32);
            let mut init = [_mm256_setzero_si256(); 16];
            for (iv, b) in init.iter_mut().zip(base.iter()) {
                *iv = _mm256_set1_epi32(*b as i32);
            }
            init[12] = _mm256_add_epi32(_mm256_set1_epi32(ctr as i32), lane_idx);
            let mut v = init;
            for _ in 0..10 {
                vqr(&mut v, 0, 4, 8, 12);
                vqr(&mut v, 1, 5, 9, 13);
                vqr(&mut v, 2, 6, 10, 14);
                vqr(&mut v, 3, 7, 11, 15);
                vqr(&mut v, 0, 5, 10, 15);
                vqr(&mut v, 1, 6, 11, 12);
                vqr(&mut v, 2, 7, 8, 13);
                vqr(&mut v, 3, 4, 9, 14);
            }
            for (x, iv) in v.iter_mut().zip(init.iter()) {
                *x = _mm256_add_epi32(*x, *iv);
            }
            let lo: [__m256i; 8] = v[..8].try_into().unwrap();
            let hi: [__m256i; 8] = v[8..].try_into().unwrap();
            let lo = transpose8(&lo); // lo[b] = words 0..8 of block b
            let hi = transpose8(&hi); // hi[b] = words 8..16 of block b
            let group = data.as_mut_ptr().add(g * 512);
            for b in 0..8 {
                let p = group.add(b * 64);
                let d0 = _mm256_loadu_si256(p as *const __m256i);
                let d1 = _mm256_loadu_si256(p.add(32) as *const __m256i);
                _mm256_storeu_si256(p as *mut __m256i, _mm256_xor_si256(d0, lo[b]));
                _mm256_storeu_si256(p.add(32) as *mut __m256i, _mm256_xor_si256(d1, hi[b]));
            }
        }
        groups * 512
    }

    /// `mix32` across 8 lanes at once.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mix32v(x: __m256i, m1: __m256i, m2: __m256i) -> __m256i {
        let mut x = _mm256_xor_si256(x, _mm256_srli_epi32::<16>(x));
        x = _mm256_mullo_epi32(x, m1);
        x = _mm256_xor_si256(x, _mm256_srli_epi32::<15>(x));
        x = _mm256_mullo_epi32(x, m2);
        _mm256_xor_si256(x, _mm256_srli_epi32::<16>(x))
    }

    /// Row-parallel poly16 over all of `data` (whole 64-byte rows): the
    /// 16 digest lanes live in two ymm accumulators, one row per
    /// iteration.
    ///
    /// # Safety
    /// AVX2 must be available (runtime-detected by the caller).
    #[target_feature(enable = "avx2")]
    pub unsafe fn poly16_digest(data: &[u8], row0: u32) -> [u32; 16] {
        let m1 = _mm256_set1_epi32(MIX_M1 as i32);
        let m2 = _mm256_set1_epi32(MIX_M2 as i32);
        let mut lane = [0u32; 16];
        for (j, l) in lane.iter_mut().enumerate() {
            *l = (j as u32).wrapping_mul(LANE_C);
        }
        let l0 = _mm256_loadu_si256(lane.as_ptr() as *const __m256i);
        let l1 = _mm256_loadu_si256(lane.as_ptr().add(8) as *const __m256i);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        for (i, row) in data.chunks_exact(64).enumerate() {
            let r = row0.wrapping_add(i as u32);
            let rt = _mm256_set1_epi32(r.wrapping_add(1).wrapping_mul(PHI32) as i32);
            let b0 = _mm256_loadu_si256(row.as_ptr() as *const __m256i);
            let b1 = _mm256_loadu_si256(row.as_ptr().add(32) as *const __m256i);
            let t0 = _mm256_add_epi32(b0, _mm256_add_epi32(rt, l0));
            let t1 = _mm256_add_epi32(b1, _mm256_add_epi32(rt, l1));
            acc0 = _mm256_xor_si256(acc0, mix32v(t0, m1, m2));
            acc1 = _mm256_xor_si256(acc1, mix32v(t1, m1, m2));
        }
        let mut out = [0u32; 16];
        _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, acc0);
        _mm256_storeu_si256(out.as_mut_ptr().add(8) as *mut __m256i, acc1);
        out
    }
}

// ---- byte-level helpers ----------------------------------------------------

/// Little-endian bytes -> words, zero-padding to whole 64-byte blocks.
pub fn bytes_to_words(b: &[u8]) -> Vec<u32> {
    let padded = b.len().div_ceil(64) * 64;
    let mut words = vec![0u32; padded / 4];
    for (i, chunk) in b.chunks(4).enumerate() {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        words[i] = u32::from_le_bytes(w);
    }
    words
}

pub fn words_to_bytes(w: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(w.len() * 4);
    for x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rfc_key() -> [u32; 8] {
        let bytes: Vec<u8> = (0..32).collect();
        let mut k = [0u32; 8];
        for i in 0..8 {
            k[i] = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
        k
    }

    #[test]
    fn rfc7539_block_vector() {
        // §2.3.2: key 00..1f, nonce 00:00:00:09:00:00:00:4a:00:00:00:00, ctr 1.
        let nonce = [0x0900_0000, 0x4a00_0000, 0x0000_0000];
        let ks = keystream_block(&rfc_key(), &nonce, 1);
        let got = words_to_bytes(&ks);
        let expected = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20,
            0x71, 0xc4, 0xc7, 0xd1, 0xf4, 0xc7, 0x33, 0xc0, 0x68, 0x03, 0x04, 0x22, 0xaa, 0x9a,
            0xc3, 0xd4, 0x6c, 0x4e, 0xd2, 0x82, 0x64, 0x46, 0x07, 0x9f, 0xaa, 0x09, 0x14, 0xc2,
            0xd7, 0x05, 0xd9, 0x8b, 0x02, 0xa2, 0xb5, 0x12, 0x9c, 0xd1, 0xde, 0x16, 0x4e, 0xb9,
            0xcb, 0xd0, 0x83, 0xe8, 0xa2, 0x50, 0x3c, 0x4e,
        ];
        assert_eq!(got, expected);
    }

    #[test]
    fn rfc7539_encryption_vector() {
        // §2.4.2 sunscreen vector.
        let plaintext: &[u8] = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.";
        let nonce = [0x0000_0000, 0x4a00_0000, 0x0000_0000];
        let mut words = bytes_to_words(plaintext);
        xor_stream(&rfc_key(), &nonce, 1, &mut words);
        let cipher = words_to_bytes(&words);
        let expected_prefix = [
            0x6e, 0x2e, 0x35, 0x9a, 0x25, 0x68, 0xf9, 0x80, 0x41, 0xba, 0x07, 0x28, 0xdd, 0x0d,
            0x69, 0x81,
        ];
        assert_eq!(&cipher[..16], &expected_prefix);
        let expected_tail = [0x87, 0x4d]; // last two bytes of the RFC vector
        assert_eq!(&cipher[plaintext.len() - 2..plaintext.len()], &expected_tail);
    }

    #[test]
    fn roundtrip() {
        let key = rfc_key();
        let nonce = [1, 2, 3];
        let mut data: Vec<u32> = (0..64u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let orig = data.clone();
        let d_seal = seal_chunk(&key, &nonce, 5, &mut data);
        assert_ne!(data, orig, "ciphertext differs");
        let d_unseal = unseal_chunk(&key, &nonce, 5, &mut data);
        assert_eq!(data, orig, "plaintext restored");
        assert_eq!(d_seal, d_unseal, "digests agree (both over ciphertext)");
    }

    #[test]
    fn digest_chunk_decomposition() {
        let data: Vec<u32> = (0..160u32).collect();
        let whole = poly16_digest(&data, 0);
        let head = poly16_digest(&data[..80], 0);
        let tail = poly16_digest(&data[80..], 5); // 80 words = 5 rows
        let mut combined = [0u32; 16];
        for i in 0..16 {
            combined[i] = head[i] ^ tail[i];
        }
        assert_eq!(whole, combined);
    }

    #[test]
    fn digest_detects_bit_flip() {
        let mut data: Vec<u32> = (0..32u32).collect();
        let d1 = poly16_digest(&data, 0);
        data[17] ^= 0x100;
        let d2 = poly16_digest(&data, 0);
        assert_ne!(d1, d2);
    }

    #[test]
    fn digest_order_sensitive() {
        let a: Vec<u32> = (0..32u32).collect();
        let mut b = a.clone();
        b.swap(0, 16); // swap across rows
        assert_ne!(poly16_digest(&a, 0), poly16_digest(&b, 0));
    }

    #[test]
    fn finalize_binds_length_and_nonce() {
        let lane = poly16_digest(&(0..16u32).collect::<Vec<_>>(), 0);
        let base = digest_finalize(&lane, 16, &[1, 2, 3]);
        assert_ne!(base, digest_finalize(&lane, 17, &[1, 2, 3]));
        assert_ne!(base, digest_finalize(&lane, 16, &[1, 2, 4]));
    }

    #[test]
    fn bytes_words_roundtrip_with_padding() {
        for n in [0usize, 1, 63, 64, 65, 1000] {
            let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            let w = bytes_to_words(&data);
            assert_eq!(w.len() % 16, 0);
            let back = words_to_bytes(&w);
            assert_eq!(&back[..n], &data[..]);
            assert!(back[n..].iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn counter_continuity() {
        // Sealing one 4-block chunk == sealing 2+2 with advanced counter.
        let key = rfc_key();
        let nonce = [9, 8, 7];
        let data: Vec<u32> = (0..64u32).map(|i| i ^ 0xABCD).collect();
        let mut whole = data.clone();
        xor_stream(&key, &nonce, 100, &mut whole);
        let mut head = data[..32].to_vec();
        let mut tail = data[32..].to_vec();
        xor_stream(&key, &nonce, 100, &mut head);
        xor_stream(&key, &nonce, 102, &mut tail);
        assert_eq!(&whole[..32], &head[..]);
        assert_eq!(&whole[32..], &tail[..]);
    }

    #[test]
    fn byte_path_matches_word_path() {
        // 0..=20 blocks spans empty input, the scalar byte tail, and
        // (on AVX2 hardware) several 8-block SIMD groups plus their
        // remainders; the counter start crosses the u32 wrap boundary.
        let key = rfc_key();
        let nonce = [7, 11, 13];
        for blocks in 0..=20usize {
            let bytes: Vec<u8> = (0..blocks * 64)
                .map(|i| (i as u32).wrapping_mul(2654435761) as u8)
                .collect();
            let mut words = bytes_to_words(&bytes);
            let mut b = bytes.clone();
            let ctr = 0xFFFF_FFF0u32;
            let dw = seal_chunk(&key, &nonce, ctr, &mut words);
            let db = seal_chunk_bytes(&key, &nonce, ctr, &mut b);
            assert_eq!(dw, db, "digest parity at {blocks} blocks");
            assert_eq!(words_to_bytes(&words), b, "ciphertext parity at {blocks} blocks");
            let du = unseal_chunk_bytes(&key, &nonce, ctr, &mut b);
            assert_eq!(du, dw, "unseal digest is over the same ciphertext");
            assert_eq!(b, bytes, "byte-path roundtrip restores plaintext");
        }
    }

    #[test]
    fn rfc7539_encryption_vector_byte_path() {
        let plaintext: &[u8] = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.";
        let nonce = [0x0000_0000, 0x4a00_0000, 0x0000_0000];
        let mut buf = plaintext.to_vec();
        buf.resize(plaintext.len().div_ceil(64) * 64, 0);
        xor_stream_bytes(&rfc_key(), &nonce, 1, &mut buf);
        let expected_prefix = [
            0x6e, 0x2e, 0x35, 0x9a, 0x25, 0x68, 0xf9, 0x80, 0x41, 0xba, 0x07, 0x28, 0xdd, 0x0d,
            0x69, 0x81,
        ];
        assert_eq!(&buf[..16], &expected_prefix);
        assert_eq!(&buf[plaintext.len() - 2..plaintext.len()], &[0x87, 0x4d]);
    }

    #[test]
    fn property_random_roundtrips() {
        crate::util::testkit::check("chacha-roundtrip", 40, |g| {
            let mut key = [0u32; 8];
            let mut nonce = [0u32; 3];
            for k in key.iter_mut() {
                *k = g.rng.next_u32();
            }
            for n in nonce.iter_mut() {
                *n = g.rng.next_u32();
            }
            let blocks = g.rng.range_usize(1, 32);
            let mut data: Vec<u32> = (0..blocks * 16).map(|_| g.rng.next_u32()).collect();
            let orig = data.clone();
            let ctr = g.rng.next_u32() & 0xFFFF;
            let d1 = seal_chunk(&key, &nonce, ctr, &mut data);
            let d2 = unseal_chunk(&key, &nonce, ctr, &mut data);
            assert_eq!(data, orig);
            assert_eq!(d1, d2);
        });
    }
}
