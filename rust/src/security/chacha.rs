//! Native ChaCha20 (RFC 7539) + poly16 integrity digest.
//!
//! This is the *reference software path* for the data plane: bit-identical
//! to the Pallas kernel / AOT artifact (`python/compile/kernels/chacha.py`
//! and `ref.py`). The runtime cross-verifies the two implementations at
//! engine startup; `tests/artifact_runtime.rs` does it exhaustively.
//!
//! All data is in little-endian u32 *words*; a chunk is `n_blocks × 16`
//! words (64 bytes per ChaCha block), matching the artifact ABI.

/// ChaCha20 "expand 32-byte k" constants.
pub const CONSTANTS: [u32; 4] = [0x6170_7865, 0x3320_646E, 0x7962_2D32, 0x6B20_6574];

// Digest constants — must match python/compile/kernels/ref.py.
pub const PHI32: u32 = 0x9E37_79B1;
pub const MIX_M1: u32 = 0x7FEB_352D;
pub const MIX_M2: u32 = 0x846C_A68B;
pub const LANE_C: u32 = 0x85EB_CA6B;

#[inline(always)]
fn qr(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

/// One 64-byte keystream block for the given counter.
pub fn keystream_block(key: &[u32; 8], nonce: &[u32; 3], counter: u32) -> [u32; 16] {
    let mut x: [u32; 16] = [
        CONSTANTS[0],
        CONSTANTS[1],
        CONSTANTS[2],
        CONSTANTS[3],
        key[0],
        key[1],
        key[2],
        key[3],
        key[4],
        key[5],
        key[6],
        key[7],
        counter,
        nonce[0],
        nonce[1],
        nonce[2],
    ];
    let x0 = x;
    for _ in 0..10 {
        qr(&mut x, 0, 4, 8, 12);
        qr(&mut x, 1, 5, 9, 13);
        qr(&mut x, 2, 6, 10, 14);
        qr(&mut x, 3, 7, 11, 15);
        qr(&mut x, 0, 5, 10, 15);
        qr(&mut x, 1, 6, 11, 12);
        qr(&mut x, 2, 7, 8, 13);
        qr(&mut x, 3, 4, 9, 14);
    }
    for i in 0..16 {
        x[i] = x[i].wrapping_add(x0[i]);
    }
    x
}

/// XOR `data` (length must be a multiple of 16 words) with the keystream
/// starting at block counter `counter0`. Encrypt == decrypt.
pub fn xor_stream(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) {
    // NOTE(perf): a 4-way transposed-state variant was tried and measured
    // *slower* than this scalar form on this CPU (1.9 vs 3.5 Gbps — the
    // [[u32;4];16] layout defeats auto-vectorization); reverted. See
    // EXPERIMENTS.md §Perf iteration log.
    assert!(data.len() % 16 == 0, "data must be whole 64-byte blocks");
    for (i, block) in data.chunks_mut(16).enumerate() {
        let ks = keystream_block(key, nonce, counter0.wrapping_add(i as u32));
        for (w, k) in block.iter_mut().zip(ks.iter()) {
            *w ^= k;
        }
    }
}

/// Murmur3-style avalanche on one word (matches `ref._mix32`).
#[inline(always)]
pub fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(MIX_M1);
    x ^= x >> 15;
    x = x.wrapping_mul(MIX_M2);
    x ^= x >> 16;
    x
}

/// 16-lane order-sensitive XOR-fold digest over whole blocks.
/// `row0` is the absolute index of the first row (= the chunk's counter0),
/// making chunked digests XOR-combinable.
pub fn poly16_digest(data: &[u32], row0: u32) -> [u32; 16] {
    assert!(data.len() % 16 == 0);
    let mut acc = [0u32; 16];
    for (i, block) in data.chunks(16).enumerate() {
        let r = row0.wrapping_add(i as u32);
        let row_tweak = r.wrapping_add(1).wrapping_mul(PHI32);
        for (j, acc_j) in acc.iter_mut().enumerate() {
            let tweak = row_tweak.wrapping_add((j as u32).wrapping_mul(LANE_C));
            *acc_j ^= mix32(block[j].wrapping_add(tweak));
        }
    }
    acc
}

/// Fold the 16-lane digest into the 4-word transfer digest, binding total
/// length (in words) and nonce (matches `ref.digest_finalize`).
pub fn digest_finalize(lane: &[u32; 16], total_words: u32, nonce: &[u32; 3]) -> [u32; 4] {
    let mut d = *lane;
    d[0] ^= total_words;
    d[1] ^= nonce[0];
    d[2] ^= nonce[1];
    d[3] ^= nonce[2];
    let mut out = [0u32; 4];
    for j in 0..4 {
        let inner3 = mix32(d[12 + j]);
        let inner2 = mix32(d[8 + j].wrapping_add(inner3));
        let inner1 = mix32(d[4 + j].wrapping_add(inner2));
        out[j] = mix32(d[j].wrapping_add(inner1));
    }
    out
}

/// Seal a chunk in place: encrypt, then digest the ciphertext.
/// Returns the 4-word transfer digest.
pub fn seal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    xor_stream(key, nonce, counter0, data);
    let lane = poly16_digest(data, counter0);
    digest_finalize(&lane, data.len() as u32, nonce)
}

/// Unseal a chunk in place: digest the (input) ciphertext, then decrypt.
pub fn unseal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    let lane = poly16_digest(data, counter0);
    let digest = digest_finalize(&lane, data.len() as u32, nonce);
    xor_stream(key, nonce, counter0, data);
    digest
}

// ---- byte-level helpers ----------------------------------------------------

/// Little-endian bytes -> words, zero-padding to whole 64-byte blocks.
pub fn bytes_to_words(b: &[u8]) -> Vec<u32> {
    let padded = b.len().div_ceil(64) * 64;
    let mut words = vec![0u32; padded / 4];
    for (i, chunk) in b.chunks(4).enumerate() {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        words[i] = u32::from_le_bytes(w);
    }
    words
}

pub fn words_to_bytes(w: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(w.len() * 4);
    for x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rfc_key() -> [u32; 8] {
        let bytes: Vec<u8> = (0..32).collect();
        let mut k = [0u32; 8];
        for i in 0..8 {
            k[i] = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
        k
    }

    #[test]
    fn rfc7539_block_vector() {
        // §2.3.2: key 00..1f, nonce 00:00:00:09:00:00:00:4a:00:00:00:00, ctr 1.
        let nonce = [0x0900_0000, 0x4a00_0000, 0x0000_0000];
        let ks = keystream_block(&rfc_key(), &nonce, 1);
        let got = words_to_bytes(&ks);
        let expected = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20,
            0x71, 0xc4, 0xc7, 0xd1, 0xf4, 0xc7, 0x33, 0xc0, 0x68, 0x03, 0x04, 0x22, 0xaa, 0x9a,
            0xc3, 0xd4, 0x6c, 0x4e, 0xd2, 0x82, 0x64, 0x46, 0x07, 0x9f, 0xaa, 0x09, 0x14, 0xc2,
            0xd7, 0x05, 0xd9, 0x8b, 0x02, 0xa2, 0xb5, 0x12, 0x9c, 0xd1, 0xde, 0x16, 0x4e, 0xb9,
            0xcb, 0xd0, 0x83, 0xe8, 0xa2, 0x50, 0x3c, 0x4e,
        ];
        assert_eq!(got, expected);
    }

    #[test]
    fn rfc7539_encryption_vector() {
        // §2.4.2 sunscreen vector.
        let plaintext: &[u8] = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.";
        let nonce = [0x0000_0000, 0x4a00_0000, 0x0000_0000];
        let mut words = bytes_to_words(plaintext);
        xor_stream(&rfc_key(), &nonce, 1, &mut words);
        let cipher = words_to_bytes(&words);
        let expected_prefix = [
            0x6e, 0x2e, 0x35, 0x9a, 0x25, 0x68, 0xf9, 0x80, 0x41, 0xba, 0x07, 0x28, 0xdd, 0x0d,
            0x69, 0x81,
        ];
        assert_eq!(&cipher[..16], &expected_prefix);
        let expected_tail = [0x87, 0x4d]; // last two bytes of the RFC vector
        assert_eq!(&cipher[plaintext.len() - 2..plaintext.len()], &expected_tail);
    }

    #[test]
    fn roundtrip() {
        let key = rfc_key();
        let nonce = [1, 2, 3];
        let mut data: Vec<u32> = (0..64u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let orig = data.clone();
        let d_seal = seal_chunk(&key, &nonce, 5, &mut data);
        assert_ne!(data, orig, "ciphertext differs");
        let d_unseal = unseal_chunk(&key, &nonce, 5, &mut data);
        assert_eq!(data, orig, "plaintext restored");
        assert_eq!(d_seal, d_unseal, "digests agree (both over ciphertext)");
    }

    #[test]
    fn digest_chunk_decomposition() {
        let data: Vec<u32> = (0..160u32).collect();
        let whole = poly16_digest(&data, 0);
        let head = poly16_digest(&data[..80], 0);
        let tail = poly16_digest(&data[80..], 5); // 80 words = 5 rows
        let mut combined = [0u32; 16];
        for i in 0..16 {
            combined[i] = head[i] ^ tail[i];
        }
        assert_eq!(whole, combined);
    }

    #[test]
    fn digest_detects_bit_flip() {
        let mut data: Vec<u32> = (0..32u32).collect();
        let d1 = poly16_digest(&data, 0);
        data[17] ^= 0x100;
        let d2 = poly16_digest(&data, 0);
        assert_ne!(d1, d2);
    }

    #[test]
    fn digest_order_sensitive() {
        let a: Vec<u32> = (0..32u32).collect();
        let mut b = a.clone();
        b.swap(0, 16); // swap across rows
        assert_ne!(poly16_digest(&a, 0), poly16_digest(&b, 0));
    }

    #[test]
    fn finalize_binds_length_and_nonce() {
        let lane = poly16_digest(&(0..16u32).collect::<Vec<_>>(), 0);
        let base = digest_finalize(&lane, 16, &[1, 2, 3]);
        assert_ne!(base, digest_finalize(&lane, 17, &[1, 2, 3]));
        assert_ne!(base, digest_finalize(&lane, 16, &[1, 2, 4]));
    }

    #[test]
    fn bytes_words_roundtrip_with_padding() {
        for n in [0usize, 1, 63, 64, 65, 1000] {
            let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            let w = bytes_to_words(&data);
            assert_eq!(w.len() % 16, 0);
            let back = words_to_bytes(&w);
            assert_eq!(&back[..n], &data[..]);
            assert!(back[n..].iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn counter_continuity() {
        // Sealing one 4-block chunk == sealing 2+2 with advanced counter.
        let key = rfc_key();
        let nonce = [9, 8, 7];
        let data: Vec<u32> = (0..64u32).map(|i| i ^ 0xABCD).collect();
        let mut whole = data.clone();
        xor_stream(&key, &nonce, 100, &mut whole);
        let mut head = data[..32].to_vec();
        let mut tail = data[32..].to_vec();
        xor_stream(&key, &nonce, 100, &mut head);
        xor_stream(&key, &nonce, 102, &mut tail);
        assert_eq!(&whole[..32], &head[..]);
        assert_eq!(&whole[32..], &tail[..]);
    }

    #[test]
    fn property_random_roundtrips() {
        crate::util::testkit::check("chacha-roundtrip", 40, |g| {
            let mut key = [0u32; 8];
            let mut nonce = [0u32; 3];
            for k in key.iter_mut() {
                *k = g.rng.next_u32();
            }
            for n in nonce.iter_mut() {
                *n = g.rng.next_u32();
            }
            let blocks = g.rng.range_usize(1, 32);
            let mut data: Vec<u32> = (0..blocks * 16).map(|_| g.rng.next_u32()).collect();
            let orig = data.clone();
            let ctr = g.rng.next_u32() & 0xFFFF;
            let d1 = seal_chunk(&key, &nonce, ctr, &mut data);
            let d2 = unseal_chunk(&key, &nonce, ctr, &mut data);
            assert_eq!(data, orig);
            assert_eq!(d1, d2);
        });
    }
}
