//! AES-256-CTR data plane via the in-crate [`super::aes_core`] block
//! cipher — the cipher HTCondor 9.0.1 actually defaults to. Selectable
//! with `SEC_DEFAULT_ENCRYPTION = AES`.
//!
//! Shares the poly16 integrity digest with the ChaCha path, so frames are
//! interchangeable apart from the keystream. The counter block layout is
//! nonce (12 bytes LE words) || counter (4 bytes LE), mirroring the ChaCha
//! (counter, nonce) addressing so the same (chunk counter0) framing works.

use super::aes_core::Aes256;
use super::chacha::{digest_finalize, poly16_digest, poly16_digest_bytes};

/// AES-256-CTR keystream XOR over whole 64-byte "rows" (4 AES blocks per
/// row, so row counters advance by 4 AES blocks).
pub struct AesCtr {
    cipher: Aes256,
    nonce: [u32; 3],
}

impl AesCtr {
    pub fn new(key_words: &[u32; 8], nonce: &[u32; 3]) -> AesCtr {
        let mut key = [0u8; 32];
        for (i, w) in key_words.iter().enumerate() {
            key[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
        }
        AesCtr {
            cipher: Aes256::new(&key),
            nonce: *nonce,
        }
    }

    fn keystream_words(&self, aes_block_counter: u64) -> [u32; 4] {
        let mut block = [0u8; 16];
        block[0..4].copy_from_slice(&self.nonce[0].to_le_bytes());
        block[4..8].copy_from_slice(&self.nonce[1].to_le_bytes());
        block[8..12].copy_from_slice(&self.nonce[2].to_le_bytes());
        block[12..16].copy_from_slice(&(aes_block_counter as u32).to_le_bytes());
        self.cipher.encrypt_block(&mut block);
        let mut out = [0u32; 4];
        for i in 0..4 {
            out[i] = u32::from_le_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        out
    }

    /// XOR data (multiple of 16 words) with the keystream; `row0` is the
    /// 64-byte-row counter (matching the ChaCha chunk counter).
    pub fn xor_stream(&self, row0: u32, data: &mut [u32]) {
        assert!(data.len() % 16 == 0);
        for (row, chunk) in data.chunks_mut(16).enumerate() {
            let base = (row0 as u64 + row as u64) * 4;
            for b in 0..4 {
                let ks = self.keystream_words(base + b as u64);
                for j in 0..4 {
                    chunk[b * 4 + j] ^= ks[j];
                }
            }
        }
    }

    /// Byte-slice twin of [`AesCtr::xor_stream`]: `data.len()` must be
    /// a multiple of 64 (whole rows, little-endian words). The AES
    /// block cipher dominates this path, so it stays scalar; the shared
    /// poly16 digest is the vectorized one from the ChaCha module.
    pub fn xor_stream_bytes(&self, row0: u32, data: &mut [u8]) {
        assert!(data.len() % 64 == 0, "data must be whole 64-byte rows");
        for (row, chunk) in data.chunks_exact_mut(64).enumerate() {
            let base = (row0 as u64 + row as u64) * 4;
            for b in 0..4 {
                let ks = self.keystream_words(base + b as u64);
                for (j, k) in ks.iter().enumerate() {
                    let o = b * 16 + j * 4;
                    let w =
                        u32::from_le_bytes([chunk[o], chunk[o + 1], chunk[o + 2], chunk[o + 3]]);
                    chunk[o..o + 4].copy_from_slice(&(w ^ k).to_le_bytes());
                }
            }
        }
    }
}

/// Seal with AES-256-CTR + poly16 (encrypt-then-digest).
pub fn seal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    let ctr = AesCtr::new(key, nonce);
    ctr.xor_stream(counter0, data);
    let lane = poly16_digest(data, counter0);
    digest_finalize(&lane, data.len() as u32, nonce)
}

/// Unseal with AES-256-CTR + poly16 (digest-then-decrypt).
pub fn unseal_chunk(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, data: &mut [u32]) -> [u32; 4] {
    let lane = poly16_digest(data, counter0);
    let digest = digest_finalize(&lane, data.len() as u32, nonce);
    let ctr = AesCtr::new(key, nonce);
    ctr.xor_stream(counter0, data);
    digest
}

/// Byte-slice twin of [`seal_chunk`] (`data.len()` multiple of 64).
pub fn seal_chunk_bytes(
    key: &[u32; 8],
    nonce: &[u32; 3],
    counter0: u32,
    data: &mut [u8],
) -> [u32; 4] {
    let ctr = AesCtr::new(key, nonce);
    ctr.xor_stream_bytes(counter0, data);
    let lane = poly16_digest_bytes(data, counter0);
    digest_finalize(&lane, (data.len() / 4) as u32, nonce)
}

/// Byte-slice twin of [`unseal_chunk`] (`data.len()` multiple of 64).
pub fn unseal_chunk_bytes(
    key: &[u32; 8],
    nonce: &[u32; 3],
    counter0: u32,
    data: &mut [u8],
) -> [u32; 4] {
    let lane = poly16_digest_bytes(data, counter0);
    let digest = digest_finalize(&lane, (data.len() / 4) as u32, nonce);
    let ctr = AesCtr::new(key, nonce);
    ctr.xor_stream_bytes(counter0, data);
    digest
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let key = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let nonce = [11, 22, 33];
        let mut data: Vec<u32> = (0..48u32).map(|i| i.wrapping_mul(0x9E3779B1)).collect();
        let orig = data.clone();
        let d1 = seal_chunk(&key, &nonce, 7, &mut data);
        assert_ne!(data, orig);
        let d2 = unseal_chunk(&key, &nonce, 7, &mut data);
        assert_eq!(data, orig);
        assert_eq!(d1, d2);
    }

    #[test]
    fn differs_from_chacha_ciphertext() {
        let key = [9u32; 8];
        let nonce = [1, 2, 3];
        let mut a: Vec<u32> = (0..16u32).collect();
        let mut b = a.clone();
        super::super::chacha::seal_chunk(&key, &nonce, 0, &mut a);
        seal_chunk(&key, &nonce, 0, &mut b);
        assert_ne!(a, b, "different ciphers, different ciphertext");
    }

    #[test]
    fn counter_continuity() {
        let key = [3u32; 8];
        let nonce = [7, 7, 7];
        let data: Vec<u32> = (0..64u32).collect();
        let mut whole = data.clone();
        AesCtr::new(&key, &nonce).xor_stream(10, &mut whole);
        let mut head = data[..32].to_vec();
        let mut tail = data[32..].to_vec();
        let c = AesCtr::new(&key, &nonce);
        c.xor_stream(10, &mut head);
        c.xor_stream(12, &mut tail);
        assert_eq!(&whole[..32], &head[..]);
        assert_eq!(&whole[32..], &tail[..]);
    }

    #[test]
    fn byte_path_matches_word_path() {
        let key = [5u32, 4, 3, 2, 1, 0, 255, 128];
        let nonce = [21, 42, 84];
        for blocks in [0usize, 1, 3, 9] {
            let bytes: Vec<u8> = (0..blocks * 64).map(|i| (i * 7 % 256) as u8).collect();
            let mut words = super::super::chacha::bytes_to_words(&bytes);
            let mut b = bytes.clone();
            let dw = seal_chunk(&key, &nonce, 3, &mut words);
            let db = seal_chunk_bytes(&key, &nonce, 3, &mut b);
            assert_eq!(dw, db, "digest parity at {blocks} blocks");
            assert_eq!(super::super::chacha::words_to_bytes(&words), b);
            let du = unseal_chunk_bytes(&key, &nonce, 3, &mut b);
            assert_eq!(du, dw);
            assert_eq!(b, bytes);
        }
    }

    #[test]
    fn keystream_nonzero_and_counter_dependent() {
        let c = AesCtr::new(&[0u32; 8], &[0, 0, 0]);
        let a = c.keystream_words(0);
        let b = c.keystream_words(1);
        assert_ne!(a, [0u32; 4]);
        assert_ne!(a, b);
    }
}
