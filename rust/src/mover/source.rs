//! The data-source plane: *where* an admitted transfer's bytes are
//! served from, decoupled from *which* submit node scheduled it.
//!
//! The paper's central caveat is that HTCondor "routes both the input
//! and output data through the submission node, making it a potential
//! bottleneck". The Petascale DTN project (arXiv:2105.12880) and the
//! Globus exascale enhancements (arXiv:2503.22981) show the production
//! answer: dedicated data-transfer nodes (DTNs) decoupled from the
//! scheduling node. This module makes the transfer *endpoint* a
//! first-class layer, so the paper's submit funnel becomes one
//! configuration of a more general data plane:
//!
//! * [`DataSource`] — the endpoint serving one admitted transfer's
//!   bytes: the scheduling node's own NIC ([`DataSource::Funnel`], the
//!   paper baseline) or a dedicated data node ([`DataSource::Dtn`]).
//! * [`SourcePlan`] — the policy choosing a source per admitted
//!   transfer: `SubmitFunnel` (every byte through the schedule node),
//!   `DedicatedDtn` (every byte through the DTN fleet, submit nodes
//!   carry only scheduling control traffic), or `Hybrid` (small
//!   sandboxes ride the funnel, sandboxes at or above a size threshold
//!   go via DTNs — the latency/throughput split Globus applies to
//!   small-file workloads).
//!
//! The [`PoolRouter`](super::PoolRouter) owns the plan: every admission
//! it reports ([`Routed`](super::Routed)) now carries a `(schedule
//! node, data source)` pair. *Which* live data node serves a DTN-bound
//! transfer is a second, orthogonal knob — the [`SourceSelector`]:
//!
//! * `RoundRobin` — deterministic rotation over the live fleet (the
//!   original PR-4 behavior, and still the default).
//! * `CacheAware` — route the transfer to the DTN already holding its
//!   [`ExtentId`](crate::storage::ExtentId) hot, the Petascale DTN
//!   lesson that data-node fleets only hit their rated throughput when
//!   transfers are steered by endpoint state. The router tracks per-DTN
//!   extent residency (seeded by the fabric, grown by serving, cleared
//!   by a kill); the simulator additionally models the cached-read
//!   speedup through each DTN's `storage::Storage` view.
//! * `OwnerAffinity` — pin each owner's sandboxes to a stable data node
//!   for claim/cache locality, mirroring what
//!   `RouterPolicy::OwnerAffinity` does one layer up, with
//!   failure-aware re-pinning: a killed DTN's owners re-pin (once,
//!   stably) onto the live fleet.
//! * `WeightedByCapacity` — deficit round-robin proportional to per-DTN
//!   NIC budgets, matching heterogeneous data fleets like
//!   `DATA_NODE_GBPS = 100, 25`.
//!
//! Selection is deterministic for every selector — the same request
//! sequence always produces the same placement (`tests/props.rs` holds
//! this as a property) — and composes with per-DTN admission budgets
//! ([`RouterConfig::dtn_slots`](super::RouterConfig::dtn_slots)):
//! a saturated data node pushes back, deferring the transfer to a peer
//! (`MoverStats::dtn_deferred`) or overflowing to the funnel when the
//! whole fleet is full (`MoverStats::dtn_overflow_to_funnel`).
//! When every DTN is dead, selection fails over to the funnel — without
//! advancing the round-robin cursor, so the rotation resumes exactly
//! where it left off once the fleet recovers — and a killed DTN's
//! in-flight transfers are re-sourced onto survivors (or the funnel) by
//! [`PoolRouter::fail_dtn`](super::PoolRouter::fail_dtn), mirroring
//! what `fail_node` does one layer up.

use crate::config::{Config, ConfigError};

/// Default `Hybrid` size threshold: sandboxes of 100 MB and above go
/// via the DTN fleet (the Petascale DTN benchmark's working set is
/// dominated by such files).
pub const DEFAULT_DTN_THRESHOLD: u64 = 100_000_000;

/// The endpoint an admitted transfer's bytes are served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataSource {
    /// The scheduling submit node's own NIC — the paper's baseline
    /// funnel (`node` is the submit node that admitted the transfer).
    Funnel { node: usize },
    /// A dedicated data-transfer node, decoupled from scheduling.
    Dtn { dtn: usize },
}

impl DataSource {
    /// Short label for reports and logs (`submit3` / `dtn1`).
    pub fn label(&self) -> String {
        match self {
            DataSource::Funnel { node } => format!("submit{node}"),
            DataSource::Dtn { dtn } => format!("dtn{dtn}"),
        }
    }

    pub fn is_dtn(&self) -> bool {
        matches!(self, DataSource::Dtn { .. })
    }
}

/// Policy choosing the [`DataSource`] of each admitted transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SourcePlan {
    /// Today's behavior and the paper baseline: every byte through the
    /// scheduling submit node.
    #[default]
    SubmitFunnel,
    /// Every byte through the DTN fleet; the submit node handles only
    /// scheduling control traffic. Requires at least one data node.
    DedicatedDtn,
    /// Sandboxes with `bytes >= threshold` go via DTNs, smaller ones
    /// ride the funnel (connection setup dominates small transfers, so
    /// the funnel's warm path wins there).
    Hybrid { threshold: u64 },
}

impl SourcePlan {
    /// Short label for reports and bench tables.
    pub fn label(&self) -> String {
        match self {
            SourcePlan::SubmitFunnel => "submit-funnel".into(),
            SourcePlan::DedicatedDtn => "dedicated-dtn".into(),
            SourcePlan::Hybrid { threshold } => format!("hybrid@{threshold}"),
        }
    }

    /// Parse a plan name (CLI flag / config value spellings). `hybrid`
    /// takes the default threshold; `hybrid:<bytes>` overrides it.
    pub fn parse(name: &str) -> Option<SourcePlan> {
        let norm = name.trim().to_ascii_uppercase().replace('-', "_");
        match norm.as_str() {
            "SUBMIT_FUNNEL" | "FUNNEL" => Some(SourcePlan::SubmitFunnel),
            "DEDICATED_DTN" | "DTN" => Some(SourcePlan::DedicatedDtn),
            "HYBRID" => Some(SourcePlan::Hybrid {
                threshold: DEFAULT_DTN_THRESHOLD,
            }),
            _ => {
                let (head, tail) = norm.split_once([':', '@'])?;
                if head != "HYBRID" {
                    return None;
                }
                tail.trim()
                    .parse()
                    .ok()
                    .map(|threshold| SourcePlan::Hybrid { threshold })
            }
        }
    }

    /// Does this plan ever route bytes via the DTN fleet?
    pub fn uses_dtns(&self) -> bool {
        !matches!(self, SourcePlan::SubmitFunnel)
    }

    /// Check the plan against the data-node fleet before running it.
    pub fn validate(&self, n_dtns: usize) -> Result<(), String> {
        if self.uses_dtns() && n_dtns == 0 {
            return Err(format!(
                "source plan '{}' needs data nodes but the pool has none \
                 (set DATA_NODES / --data-nodes)",
                self.label()
            ));
        }
        Ok(())
    }

    /// The `SOURCE_PLAN` / `DTN_THRESHOLD` condor-style knobs:
    ///
    /// ```text
    /// SOURCE_PLAN = HYBRID        # SUBMIT_FUNNEL | DEDICATED_DTN | HYBRID
    /// DTN_THRESHOLD = 64MB        # hybrid split point (size suffixes ok)
    /// ```
    pub fn from_config(cfg: &Config) -> Result<SourcePlan, ConfigError> {
        let name = cfg.get_or("SOURCE_PLAN", "SUBMIT_FUNNEL");
        let mut plan = SourcePlan::parse(&name).ok_or_else(|| {
            ConfigError::Type("SOURCE_PLAN".into(), "source plan name", name)
        })?;
        if let SourcePlan::Hybrid { ref mut threshold } = plan {
            *threshold = cfg.get_bytes("DTN_THRESHOLD", *threshold)?;
        }
        Ok(plan)
    }

    /// The `DATA_NODES` knob (default 0 — the paper has no DTN fleet).
    pub fn data_nodes_from_config(cfg: &Config) -> Result<u32, ConfigError> {
        Ok(cfg.get_u64("DATA_NODES", 0)? as u32)
    }
}

/// Strategy picking *which* live data node serves a DTN-bound transfer
/// (the [`SourcePlan`] decides funnel-vs-fleet; the selector places the
/// transfer within the fleet). See the module docs for the rationale
/// behind each strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SourceSelector {
    /// Deterministic rotation over the live fleet (the default).
    #[default]
    RoundRobin,
    /// Route to the data node already holding the transfer's extent hot
    /// (falls back to the rotation when no node does, which also makes
    /// the first placement of each extent its sticky home).
    CacheAware,
    /// Stable per-owner pinning with failure-aware re-pinning.
    OwnerAffinity,
    /// Deficit round-robin weighted by per-DTN NIC budgets.
    WeightedByCapacity,
}

impl SourceSelector {
    /// Short label for reports and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            SourceSelector::RoundRobin => "round-robin",
            SourceSelector::CacheAware => "cache-aware",
            SourceSelector::OwnerAffinity => "owner-affinity",
            SourceSelector::WeightedByCapacity => "weighted-by-capacity",
        }
    }

    /// Parse a selector name (CLI flag / config value spellings).
    pub fn parse(name: &str) -> Option<SourceSelector> {
        match name.trim().to_ascii_uppercase().replace('-', "_").as_str() {
            "ROUND_ROBIN" => Some(SourceSelector::RoundRobin),
            "CACHE_AWARE" | "CACHE" => Some(SourceSelector::CacheAware),
            "OWNER_AFFINITY" | "OWNER" => Some(SourceSelector::OwnerAffinity),
            "WEIGHTED_BY_CAPACITY" | "WEIGHTED" => Some(SourceSelector::WeightedByCapacity),
            _ => None,
        }
    }

    /// The `SOURCE_SELECTOR` condor-style knob (default: round-robin).
    ///
    /// ```text
    /// SOURCE_SELECTOR = CACHE_AWARE  # ROUND_ROBIN | CACHE_AWARE |
    ///                                # OWNER_AFFINITY | WEIGHTED_BY_CAPACITY
    /// ```
    pub fn from_config(cfg: &Config) -> Result<SourceSelector, ConfigError> {
        let name = cfg.get_or("SOURCE_SELECTOR", "ROUND_ROBIN");
        SourceSelector::parse(&name).ok_or_else(|| {
            ConfigError::Type("SOURCE_SELECTOR".into(), "source selector name", name)
        })
    }
}

/// Strategy picking *which site* serves a DTN-bound transfer in a
/// multi-site federation — the first level of two-level source
/// selection. The `SiteSelector` narrows the fleet to one site's DTNs,
/// then the [`SourceSelector`] places the transfer within that site.
/// With one site every selector degenerates to "the whole fleet" and
/// the router's decisions are bit-identical to the single-site code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SiteSelector {
    /// Prefer the requesting node's own site while it has a live DTN
    /// (never pay WAN cost for bytes a local replica can serve); scan
    /// outward to the next sites only when the local fleet is dead — a
    /// merely saturated site overflows to its own funnel instead. The
    /// default — and the Petascale DTN deployments' practice of staging
    /// data site-locally before the transfer week.
    #[default]
    LocalFirst,
    /// Follow the data: pick the site already holding the transfer's
    /// extent resident on one of its DTNs (lowest such site wins, for
    /// determinism), falling back to the local-first scan when no site
    /// holds it. Trades WAN latency for cache hits.
    CacheAware,
    /// Deterministic rotation over sites with live DTNs — the
    /// transfer-matrix shape of the Petascale DTN benchmark, where
    /// every site pair must carry traffic.
    RoundRobin,
}

impl SiteSelector {
    /// Short label for reports and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            SiteSelector::LocalFirst => "local-first",
            SiteSelector::CacheAware => "cache-aware",
            SiteSelector::RoundRobin => "round-robin",
        }
    }

    /// Parse a selector name (CLI flag / config value spellings).
    pub fn parse(name: &str) -> Option<SiteSelector> {
        match name.trim().to_ascii_uppercase().replace('-', "_").as_str() {
            "LOCAL_FIRST" | "LOCAL" => Some(SiteSelector::LocalFirst),
            "CACHE_AWARE" | "CACHE" => Some(SiteSelector::CacheAware),
            "ROUND_ROBIN" => Some(SiteSelector::RoundRobin),
            _ => None,
        }
    }

    /// The `SITE_SELECTOR` condor-style knob (default: local-first).
    ///
    /// ```text
    /// SITE_SELECTOR = ROUND_ROBIN  # LOCAL_FIRST | CACHE_AWARE | ROUND_ROBIN
    /// ```
    pub fn from_config(cfg: &Config) -> Result<SiteSelector, ConfigError> {
        let name = cfg.get_or("SITE_SELECTOR", "LOCAL_FIRST");
        SiteSelector::parse(&name).ok_or_else(|| {
            ConfigError::Type("SITE_SELECTOR".into(), "site selector name", name)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_spellings() {
        assert_eq!(SourcePlan::parse("funnel"), Some(SourcePlan::SubmitFunnel));
        assert_eq!(
            SourcePlan::parse("SUBMIT_FUNNEL"),
            Some(SourcePlan::SubmitFunnel)
        );
        assert_eq!(
            SourcePlan::parse("dedicated-dtn"),
            Some(SourcePlan::DedicatedDtn)
        );
        assert_eq!(SourcePlan::parse("dtn"), Some(SourcePlan::DedicatedDtn));
        assert_eq!(
            SourcePlan::parse("hybrid"),
            Some(SourcePlan::Hybrid {
                threshold: DEFAULT_DTN_THRESHOLD
            })
        );
        assert_eq!(
            SourcePlan::parse("hybrid:5000"),
            Some(SourcePlan::Hybrid { threshold: 5000 })
        );
        assert_eq!(SourcePlan::parse("teleport"), None);
        assert_eq!(SourcePlan::parse("hybrid:x"), None);
    }

    #[test]
    fn validate_requires_dtns_when_plan_uses_them() {
        assert!(SourcePlan::SubmitFunnel.validate(0).is_ok());
        assert!(SourcePlan::DedicatedDtn.validate(0).is_err());
        assert!(SourcePlan::DedicatedDtn.validate(1).is_ok());
        assert!(SourcePlan::Hybrid { threshold: 1 }.validate(0).is_err());
        assert!(SourcePlan::Hybrid { threshold: 1 }.validate(2).is_ok());
    }

    #[test]
    fn from_config_reads_plan_and_threshold() {
        let cfg = Config::parse("SOURCE_PLAN = HYBRID\nDTN_THRESHOLD = 64MB").unwrap();
        assert_eq!(
            SourcePlan::from_config(&cfg).unwrap(),
            SourcePlan::Hybrid {
                threshold: 64_000_000
            }
        );
        let dflt = Config::parse("").unwrap();
        assert_eq!(
            SourcePlan::from_config(&dflt).unwrap(),
            SourcePlan::SubmitFunnel
        );
        assert_eq!(SourcePlan::data_nodes_from_config(&dflt).unwrap(), 0);
        let n = Config::parse("DATA_NODES = 4").unwrap();
        assert_eq!(SourcePlan::data_nodes_from_config(&n).unwrap(), 4);
        let bad = Config::parse("SOURCE_PLAN = WARP").unwrap();
        assert!(SourcePlan::from_config(&bad).is_err());
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for plan in [
            SourcePlan::SubmitFunnel,
            SourcePlan::DedicatedDtn,
            SourcePlan::Hybrid { threshold: 1234 },
        ] {
            assert_eq!(SourcePlan::parse(&plan.label()), Some(plan));
        }
    }

    #[test]
    fn selector_parse_label_and_config() {
        for sel in [
            SourceSelector::RoundRobin,
            SourceSelector::CacheAware,
            SourceSelector::OwnerAffinity,
            SourceSelector::WeightedByCapacity,
        ] {
            assert_eq!(SourceSelector::parse(sel.label()), Some(sel));
        }
        assert_eq!(
            SourceSelector::parse("CACHE"),
            Some(SourceSelector::CacheAware)
        );
        assert_eq!(
            SourceSelector::parse("weighted"),
            Some(SourceSelector::WeightedByCapacity)
        );
        assert_eq!(SourceSelector::parse("random"), None);

        let cfg = Config::parse("SOURCE_SELECTOR = OWNER_AFFINITY").unwrap();
        assert_eq!(
            SourceSelector::from_config(&cfg).unwrap(),
            SourceSelector::OwnerAffinity
        );
        let dflt = Config::parse("").unwrap();
        assert_eq!(
            SourceSelector::from_config(&dflt).unwrap(),
            SourceSelector::RoundRobin
        );
        let bad = Config::parse("SOURCE_SELECTOR = LOTTERY").unwrap();
        assert!(SourceSelector::from_config(&bad).is_err());
    }

    #[test]
    fn site_selector_parse_label_and_config() {
        for sel in [
            SiteSelector::LocalFirst,
            SiteSelector::CacheAware,
            SiteSelector::RoundRobin,
        ] {
            assert_eq!(SiteSelector::parse(sel.label()), Some(sel));
        }
        assert_eq!(SiteSelector::parse("local"), Some(SiteSelector::LocalFirst));
        assert_eq!(SiteSelector::parse("CACHE"), Some(SiteSelector::CacheAware));
        assert_eq!(SiteSelector::parse("nearest"), None);

        let cfg = Config::parse("SITE_SELECTOR = ROUND_ROBIN").unwrap();
        assert_eq!(
            SiteSelector::from_config(&cfg).unwrap(),
            SiteSelector::RoundRobin
        );
        let dflt = Config::parse("").unwrap();
        assert_eq!(
            SiteSelector::from_config(&dflt).unwrap(),
            SiteSelector::LocalFirst
        );
        let bad = Config::parse("SITE_SELECTOR = GRAVITY").unwrap();
        assert!(SiteSelector::from_config(&bad).is_err());
    }

    #[test]
    fn source_labels() {
        assert_eq!(DataSource::Funnel { node: 3 }.label(), "submit3");
        assert_eq!(DataSource::Dtn { dtn: 1 }.label(), "dtn1");
        assert!(DataSource::Dtn { dtn: 0 }.is_dtn());
        assert!(!DataSource::Funnel { node: 0 }.is_dtn());
    }
}
