//! The unified data-mover subsystem: one sharded, policy-driven transfer
//! path consumed identically by the simulated and the real TCP fabrics.
//!
//! The paper's submit node is a single data funnel: every sandbox flows
//! through the schedd, and (in the seed reproduction) the real fabric
//! additionally funneled *all* sealing through one crypto-service thread.
//! This module turns that funnel into a tunable subsystem:
//!
//! * [`policy`] — the [`AdmissionPolicy`] trait generalizing the classic
//!   `FILE_TRANSFER_DISK_LOAD_THROTTLE` choices (`Disabled` / `DiskLoad` /
//!   `MaxConcurrent`, all FIFO) with two new scheduling policies:
//!   `FairShare` (per-owner round-robin, starvation-free) and
//!   `WeightedBySize` (smallest sandbox first).
//! * [`queue`] — [`AdmissionQueue`]: the policy-driven admission queue
//!   that owns the waiting/active bookkeeping the schedd used to hand-roll
//!   (and whose release path can no longer underflow: spurious completes
//!   are counted in [`MoverStats::released_without_active`]).
//! * [`router`] — [`PoolRouter`]: the scale-out layer above the pools —
//!   N submit-node shards (each a full [`ShadowPool`] with its own
//!   policy and NIC budget) behind a pluggable [`RouterPolicy`]
//!   (round-robin / least-loaded / owner-affinity / weighted-by-NIC-
//!   capacity), with mid-burst node-failure drain, node recovery and
//!   threshold work-stealing between node queues.
//! * [`source`] — the data-source plane: a [`SourcePlan`] decides per
//!   admitted transfer whether its bytes are served by the scheduling
//!   node's own funnel (the paper baseline) or by a dedicated
//!   data-transfer node (DTN), so the submit funnel becomes one
//!   configuration of a pluggable endpoint layer. Every routing
//!   decision is a `(schedule node, data source)` pair. A pluggable
//!   [`SourceSelector`] picks *which* live data node serves a DTN-bound
//!   transfer (round-robin / cache-aware over `storage::ExtentId`
//!   residency / owner-affinity with failure-aware re-pinning /
//!   weighted-by-capacity), composing with per-DTN admission budgets so
//!   a saturated data node pushes back
//!   ([`MoverStats::dtn_deferred`] / [`MoverStats::dtn_overflow_to_funnel`]).
//! * [`chaos`] — fault injection: a [`FaultPlan`] of ordered
//!   `KillNode` / `RecoverNode` / `DegradeNic` events (plus their DTN
//!   counterparts and parse-time-expanded `flap` schedules) executed
//!   identically by the simulator (flows abort, NICs re-rate) and the
//!   real TCP fabric (file servers crash and restart, workers retry
//!   through the router), with per-node fault timelines in the reports.
//! * [`task`] — the durable managed-transfer layer above the router:
//!   [`TransferTask`] / [`TaskRunner`] / [`TaskJournal`] make named,
//!   checkpointed multi-file tasks (per-file pending / in-flight /
//!   done+sha256, resumable across coordinator restarts) the unit the
//!   control plane owns, with per-task rate limits, deadlines, and a
//!   goodput-driven auto-tuner over concurrency and chunk size.
//! * [`pool`] — [`ShadowPool`]: the [`DataMover`] implementation that
//!   shards admitted transfers across N shadow workers, each with its
//!   *own* [`SealEngine`](crate::runtime::engine::SealEngine) service —
//!   replacing the single-crypto-thread funnel with per-shadow parallel
//!   sealing on the real fabric, and per-shard accounting in the
//!   simulator.
//!
//! The sim engine (`coordinator::engine`) drives a `ShadowPool` for
//! admission and shard accounting of fluid flows; the real TCP fabric
//! (`fabric::tcp`) drives the *same* object for admission and uses its
//! per-shadow engine handles to seal real bytes. `tests/mover_unified.rs`
//! moves one `ShadowPool` through both fabrics back to back.

pub mod chaos;
pub mod policy;
pub mod pool;
pub mod queue;
pub mod router;
pub mod source;
pub mod state;
pub mod task;

pub use chaos::{ChaosTimeline, FaultEvent, FaultPlan, FaultRecord};
pub use policy::{ActiveView, AdmissionConfig, AdmissionPolicy};
pub use pool::ShadowPool;
pub use queue::AdmissionQueue;
pub use router::{PoolRouter, Routed, RouterConfig, RouterPolicy, RouterStats};
pub use source::{DataSource, SiteSelector, SourcePlan, SourceSelector, DEFAULT_DTN_THRESHOLD};
pub use state::{shards_from_config, RouterStateHandle, DEFAULT_ROUTER_SHARDS};
pub use task::{
    sha256_hex, synth_file_bytes, synth_file_sha256, tuner_json, FileState, TaskJournal,
    TaskProgress, TaskRunner, TransferTask, TunerSample,
};

use crate::storage::ExtentId;

/// One sandbox-transfer request entering the mover.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferRequest {
    /// Caller-scoped ticket (the engine uses job procs).
    pub ticket: u32,
    /// Job owner, the fair-share scheduling key.
    pub owner: String,
    /// Sandbox size, the weighted-by-size scheduling key.
    pub bytes: u64,
    /// Physical extent behind the input sandbox (hard-linked names share
    /// one extent — the paper's §III dataset trick). Cache-aware source
    /// selection routes a transfer to the data node already holding this
    /// extent hot; `None` means no cache information is available.
    pub extent: Option<ExtentId>,
}

impl TransferRequest {
    pub fn new(ticket: u32, owner: impl Into<String>, bytes: u64) -> TransferRequest {
        TransferRequest {
            ticket,
            owner: owner.into(),
            bytes,
            extent: None,
        }
    }

    /// Attach the input sandbox's extent identity (builder style).
    pub fn with_extent(mut self, extent: ExtentId) -> TransferRequest {
        self.extent = Some(extent);
        self
    }
}

/// An admitted transfer: the ticket plus the shadow shard serving it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admitted {
    pub ticket: u32,
    pub shard: usize,
}

/// Aggregated mover accounting for reports and benches.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MoverStats {
    /// Highest concurrent admitted-transfer count observed.
    pub peak_active: u32,
    pub total_admitted: u64,
    /// Completes that arrived with no matching active transfer (the old
    /// `TransferQueue::release` underflow, now saturated and counted).
    pub released_without_active: u64,
    /// Completes that cancelled a still-waiting request — the failover
    /// path where a re-routed transfer's original executor reports in
    /// while the request queues on its new node.
    pub cancelled_waiting: u64,
    /// Transfers admitted per shadow shard. For a [`PoolRouter`] the
    /// vector concatenates every node's shards node-major.
    pub admitted_per_shard: Vec<u64>,
    /// Payload bytes routed per shadow shard (node-major for a router).
    pub bytes_per_shard: Vec<u64>,
    /// Submit-node shards poisoned mid-run (see [`PoolRouter::fail_node`]);
    /// always 0 for a plain [`ShadowPool`].
    pub shard_failed: u64,
    /// Nodes un-poisoned mid-run (see [`PoolRouter::recover_node`]).
    pub node_recovered: u64,
    /// Waiting requests work-stolen between node queues (see
    /// [`PoolRouter::rebalance`]).
    pub stolen: u64,
    /// In-flight transfers re-routed off a dead node — each one's
    /// executor retries it through the router (the real fabric's workers
    /// reconnect to the survivor; the sim engine restarts the flow).
    pub retried_after_fault: u64,
    /// DTN-bound transfers whose selector-preferred data node was at its
    /// admission budget, deferring them onto a peer with a free slot
    /// (see [`router::RouterConfig::dtn_slots`]).
    pub dtn_deferred: u64,
    /// DTN-bound transfers that overflowed to the scheduling node's
    /// funnel because every live data node was at its admission budget
    /// AND (with queues enabled) every wait queue was full.
    pub dtn_overflow_to_funnel: u64,
    /// DTN-bound transfers parked in a data node's bounded wait queue
    /// because the whole fleet was at budget (see
    /// [`router::RouterConfig::dtn_queue_depth`]); each is promoted into
    /// the next slot its DTN frees. Always 0 with `DTN_QUEUE_DEPTH = 0`.
    pub dtn_queued: u64,
}

impl MoverStats {
    /// Ratio of the busiest shard's byte load to a perfectly even split
    /// (1.0 = perfectly balanced). 0.0 when nothing moved.
    pub fn shard_imbalance(&self) -> f64 {
        let total: u64 = self.bytes_per_shard.iter().sum();
        if total == 0 || self.bytes_per_shard.is_empty() {
            return 0.0;
        }
        let even = total as f64 / self.bytes_per_shard.len() as f64;
        let max = *self.bytes_per_shard.iter().max().unwrap() as f64;
        max / even
    }
}

/// The data-mover interface both fabrics drive: request admission for a
/// sandbox transfer, learn which shard serves it, signal completion.
pub trait DataMover: Send + std::fmt::Debug {
    /// Submit a transfer request; returns every transfer (possibly
    /// including this one) admitted *now* under the policy.
    fn request(&mut self, req: TransferRequest) -> Vec<Admitted>;

    /// A transfer finished (or failed); returns newly admitted transfers.
    fn complete(&mut self, ticket: u32) -> Vec<Admitted>;

    /// Currently admitted (in-flight) transfer count.
    fn active(&self) -> u32;

    /// Requests waiting for admission.
    fn waiting(&self) -> usize;

    /// Number of shadow shards.
    fn shard_count(&self) -> usize;

    /// Shard serving an admitted, not-yet-completed ticket.
    fn shard_of(&self, ticket: u32) -> Option<usize>;

    fn stats(&self) -> MoverStats;

    fn describe(&self) -> String;
}
