//! Durable transfer tasks: the managed-transfer service layer above
//! [`PoolRouter`](crate::mover::PoolRouter).
//!
//! Everything below the router is fire-and-forget per burst: a
//! coordinator restart silently abandons every in-flight transfer,
//! because the unit the control plane owns is a socket. This module
//! makes *tasks* the owned unit (the Globus-service model): a
//! [`TransferTask`] is a named multi-file dataset transfer with a
//! JSON-serializable checkpoint — per-file state pending / in-flight /
//! done+sha256 — persisted through a [`TaskJournal`] (in-memory for the
//! simulator, file-backed under `--task-dir` for the real fabric). Kill
//! the coordinator mid-task, restart it, rebuild a [`TaskRunner`] from
//! the same journal, and the task resumes from its last checkpoint:
//! completed files are never re-transferred, and every completed file
//! carries an end-to-end SHA-256 recorded at completion.
//!
//! The runner also owns the task-scoped control loops:
//!
//! * **admission**: per-task concurrency cap, rate limit
//!   (`TASK_RATE_BPS`, a leaky-bucket arrival curve on admitted bytes)
//!   and deadline (`TASK_DEADLINE_S`, past which nothing further is
//!   admitted) — all enforced in [`TaskRunner::next_files`];
//! * **auto-tuning** (`AUTOTUNE`): a deterministic hill-climb
//!   ([`AutoTuner`]) that adjusts the task's concurrency and chunk size
//!   from observed per-window goodput, closing the loop on the static
//!   `CHUNK` knob and the `chunk_sweep` bench.
//!
//! Both fabrics drive the *same* runner object
//! (`coordinator::engine::run_task_sim` / `fabric::tcp::run_real_task`;
//! `tests/task_unified.rs` moves one task through both), per the repo's
//! sim/real unification invariant.

use crate::security::sha256::Sha256;
use crate::storage::ExtentId;
use crate::util::Prng;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Auto-tuner floor for a task's concurrent-file cap.
pub const MIN_CONCURRENCY: u32 = 1;
/// Auto-tuner ceiling for a task's concurrent-file cap.
pub const MAX_CONCURRENCY: u32 = 64;
/// Auto-tuner floor for a task's transfer chunk size (words).
pub const MIN_CHUNK_WORDS: usize = 256;
/// Auto-tuner ceiling for a task's transfer chunk size (words).
pub const MAX_CHUNK_WORDS: usize = 64 * 1024;

/// Per-file transfer state inside a task's checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FileState {
    /// Not yet admitted.
    Pending,
    /// Admitted; bytes (possibly) on the wire. A checkpoint loaded with
    /// files in this state demotes them to [`FileState::Pending`] — the
    /// transfer died with the coordinator that was running it.
    InFlight,
    /// Transferred and verified: the receiver's SHA-256 over the full
    /// payload, recorded at completion. A resumed task never re-admits
    /// a done file.
    Done {
        /// Lowercase hex SHA-256 of the received payload.
        sha256: String,
    },
}

/// One file of a task's dataset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    /// Name in the source catalog (`FileServer` key / sim storage name).
    pub name: String,
    pub bytes: u64,
    /// Physical extent behind the name, for cache-aware source
    /// selection (`None` = unknown).
    pub extent: Option<ExtentId>,
    pub state: FileState,
    /// Failed attempts so far (the file returned to pending each time).
    pub retries: u32,
}

impl FileEntry {
    pub fn is_done(&self) -> bool {
        matches!(self.state, FileState::Done { .. })
    }
}

/// A named, durable multi-file transfer task: the dataset plus the
/// task-scoped knobs, all of it JSON-serializable as the checkpoint a
/// [`TaskJournal`] persists.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferTask {
    /// Task name — the journal key (one checkpoint file per name).
    pub name: String,
    /// Owner, the router's fair-share / affinity key.
    pub owner: String,
    pub files: Vec<FileEntry>,
    /// Admission rate limit in bytes/second (0 = unlimited): cumulative
    /// admitted bytes never exceed `rate_bps × elapsed`.
    pub rate_bps: u64,
    /// Deadline in seconds from task start (0 = none): past it nothing
    /// further is admitted (in-flight files drain) and the task reports
    /// `deadline_exceeded`.
    pub deadline_s: f64,
    /// Closed-loop tuning of `concurrency` / `chunk_words` from
    /// observed per-window goodput.
    pub autotune: bool,
    /// Max concurrently admitted files (the auto-tuner's first knob).
    pub concurrency: u32,
    /// Transfer chunk size in words (the auto-tuner's second knob; the
    /// static `CHUNK` default otherwise).
    pub chunk_words: usize,
    /// Goodput observation window for the auto-tuner, seconds.
    pub tune_window_s: f64,
}

impl TransferTask {
    pub fn new(name: impl Into<String>, owner: impl Into<String>) -> TransferTask {
        TransferTask {
            name: name.into(),
            owner: owner.into(),
            files: Vec::new(),
            rate_bps: 0,
            deadline_s: 0.0,
            autotune: false,
            concurrency: 4,
            chunk_words: crate::transfer::stream::DEFAULT_CHUNK_WORDS,
            tune_window_s: 1.0,
        }
    }

    /// Append one pending file (builder style).
    pub fn with_file(mut self, name: impl Into<String>, bytes: u64) -> TransferTask {
        self.files.push(FileEntry {
            name: name.into(),
            bytes,
            extent: None,
            state: FileState::Pending,
            retries: 0,
        });
        self
    }

    /// Append `n` uniform pending files named `<stem>_0..n-1`.
    pub fn with_uniform_files(mut self, stem: &str, n: usize, bytes: u64) -> TransferTask {
        for i in 0..n {
            self = self.with_file(format!("{stem}_{i}"), bytes);
        }
        self
    }

    pub fn with_rate_bps(mut self, bps: u64) -> TransferTask {
        self.rate_bps = bps;
        self
    }

    pub fn with_deadline_s(mut self, s: f64) -> TransferTask {
        self.deadline_s = s;
        self
    }

    pub fn with_autotune(mut self, on: bool) -> TransferTask {
        self.autotune = on;
        self
    }

    pub fn with_concurrency(mut self, c: u32) -> TransferTask {
        self.concurrency = c.max(1);
        self
    }

    pub fn with_chunk_words(mut self, w: usize) -> TransferTask {
        self.chunk_words = w.clamp(MIN_CHUNK_WORDS, MAX_CHUNK_WORDS);
        self
    }

    pub fn with_tune_window_s(mut self, s: f64) -> TransferTask {
        self.tune_window_s = s.max(1e-6);
        self
    }

    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.bytes).sum()
    }

    /// Serialize the full checkpoint (dataset states + knobs).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(192 + self.files.len() * 96);
        out.push_str(&format!(
            "{{\"name\":{},\"owner\":{},\"rate_bps\":{},\"deadline_s\":{},\
             \"autotune\":{},\"concurrency\":{},\"chunk_words\":{},\"tune_window_s\":{},\
             \"files\":[",
            json::escape(&self.name),
            json::escape(&self.owner),
            self.rate_bps,
            self.deadline_s,
            self.autotune,
            self.concurrency,
            self.chunk_words,
            self.tune_window_s,
        ));
        for (i, f) in self.files.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let extent = match f.extent {
                Some(ExtentId(e)) => e.to_string(),
                None => "null".to_string(),
            };
            match &f.state {
                FileState::Done { sha256 } => out.push_str(&format!(
                    "{{\"name\":{},\"bytes\":{},\"extent\":{},\"retries\":{},\
                     \"state\":\"done\",\"sha256\":{}}}",
                    json::escape(&f.name),
                    f.bytes,
                    extent,
                    f.retries,
                    json::escape(sha256),
                )),
                state => out.push_str(&format!(
                    "{{\"name\":{},\"bytes\":{},\"extent\":{},\"retries\":{},\"state\":\"{}\"}}",
                    json::escape(&f.name),
                    f.bytes,
                    extent,
                    f.retries,
                    if *state == FileState::InFlight {
                        "in-flight"
                    } else {
                        "pending"
                    },
                )),
            }
        }
        out.push_str("]}");
        out
    }

    /// Parse a checkpoint written by [`TransferTask::to_json`].
    pub fn from_json(text: &str) -> Result<TransferTask> {
        let v = json::parse(text).context("task checkpoint")?;
        let mut task = TransferTask::new(
            v.str_field("name")?.to_string(),
            v.str_field("owner")?.to_string(),
        );
        task.rate_bps = v.u64_field("rate_bps")?;
        task.deadline_s = v.f64_field("deadline_s")?;
        task.autotune = v.bool_field("autotune")?;
        task.concurrency = (v.u64_field("concurrency")? as u32).max(1);
        task.chunk_words =
            (v.u64_field("chunk_words")? as usize).clamp(MIN_CHUNK_WORDS, MAX_CHUNK_WORDS);
        task.tune_window_s = v.f64_field("tune_window_s")?.max(1e-6);
        for fv in v.arr_field("files")? {
            let state = match fv.str_field("state")? {
                "done" => FileState::Done {
                    sha256: fv.str_field("sha256")?.to_string(),
                },
                "in-flight" => FileState::InFlight,
                "pending" => FileState::Pending,
                other => bail!("unknown file state '{other}'"),
            };
            task.files.push(FileEntry {
                name: fv.str_field("name")?.to_string(),
                bytes: fv.u64_field("bytes")?,
                extent: fv.opt_u64_field("extent")?.map(ExtentId),
                state,
                retries: fv.u64_field("retries")? as u32,
            });
        }
        Ok(task)
    }
}

/// Minimal JSON reader for task checkpoints (the crate is fully offline
/// — no serde): objects, arrays, strings with the escapes
/// [`escape`](json::escape) emits, numbers, booleans, null.
mod json {
    use anyhow::{bail, Result};

    #[derive(Debug, Clone, PartialEq)]
    pub enum Val {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Val>),
        Obj(Vec<(String, Val)>),
    }

    impl Val {
        fn field(&self, key: &str) -> Result<&Val> {
            match self {
                Val::Obj(kv) => kv
                    .iter()
                    .find(|(k, _)| k == key)
                    .map(|(_, v)| v)
                    .ok_or_else(|| anyhow::anyhow!("missing field '{key}'")),
                _ => bail!("'{key}' looked up on a non-object"),
            }
        }

        pub fn str_field(&self, key: &str) -> Result<&str> {
            match self.field(key)? {
                Val::Str(s) => Ok(s),
                v => bail!("field '{key}' is not a string: {v:?}"),
            }
        }

        pub fn f64_field(&self, key: &str) -> Result<f64> {
            match self.field(key)? {
                Val::Num(n) => Ok(*n),
                v => bail!("field '{key}' is not a number: {v:?}"),
            }
        }

        pub fn u64_field(&self, key: &str) -> Result<u64> {
            let n = self.f64_field(key)?;
            if n < 0.0 || n.fract() != 0.0 {
                bail!("field '{key}' is not a non-negative integer: {n}");
            }
            Ok(n as u64)
        }

        /// `null` → `None`; a number → `Some`.
        pub fn opt_u64_field(&self, key: &str) -> Result<Option<u64>> {
            match self.field(key)? {
                Val::Null => Ok(None),
                Val::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(Some(*n as u64)),
                v => bail!("field '{key}' is not null or an integer: {v:?}"),
            }
        }

        pub fn bool_field(&self, key: &str) -> Result<bool> {
            match self.field(key)? {
                Val::Bool(b) => Ok(*b),
                v => bail!("field '{key}' is not a bool: {v:?}"),
            }
        }

        pub fn arr_field(&self, key: &str) -> Result<&[Val]> {
            match self.field(key)? {
                Val::Arr(a) => Ok(a),
                v => bail!("field '{key}' is not an array: {v:?}"),
            }
        }
    }

    /// Quote and escape a string for embedding in JSON output.
    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    }

    pub fn parse(text: &str) -> Result<Val> {
        let b = text.as_bytes();
        let mut pos = 0;
        let v = value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
            *pos += 1;
        }
    }

    fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
        if *pos < b.len() && b[*pos] == c {
            *pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, *pos)
        }
    }

    fn value(b: &[u8], pos: &mut usize) -> Result<Val> {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'{') => object(b, pos),
            Some(b'[') => array(b, pos),
            Some(b'"') => Ok(Val::Str(string(b, pos)?)),
            Some(b't') => lit(b, pos, "true", Val::Bool(true)),
            Some(b'f') => lit(b, pos, "false", Val::Bool(false)),
            Some(b'n') => lit(b, pos, "null", Val::Null),
            Some(_) => number(b, pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(b: &[u8], pos: &mut usize, word: &str, v: Val) -> Result<Val> {
        if b[*pos..].starts_with(word.as_bytes()) {
            *pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", *pos)
        }
    }

    fn object(b: &[u8], pos: &mut usize) -> Result<Val> {
        expect(b, pos, b'{')?;
        let mut kv = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b'}') {
            *pos += 1;
            return Ok(Val::Obj(kv));
        }
        loop {
            skip_ws(b, pos);
            let key = string(b, pos)?;
            skip_ws(b, pos);
            expect(b, pos, b':')?;
            kv.push((key, value(b, pos)?));
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b'}') => {
                    *pos += 1;
                    return Ok(Val::Obj(kv));
                }
                _ => bail!("expected ',' or '}}' at byte {}", *pos),
            }
        }
    }

    fn array(b: &[u8], pos: &mut usize) -> Result<Val> {
        expect(b, pos, b'[')?;
        let mut items = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b']') {
            *pos += 1;
            return Ok(Val::Arr(items));
        }
        loop {
            items.push(value(b, pos)?);
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b']') => {
                    *pos += 1;
                    return Ok(Val::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", *pos),
            }
        }
    }

    fn string(b: &[u8], pos: &mut usize) -> Result<String> {
        expect(b, pos, b'"')?;
        let mut out = Vec::new();
        while *pos < b.len() {
            match b[*pos] {
                b'"' => {
                    *pos += 1;
                    return String::from_utf8(out).map_err(|_| anyhow::anyhow!("bad utf8"));
                }
                b'\\' => {
                    *pos += 1;
                    match b.get(*pos) {
                        Some(b'"') => out.push(b'"'),
                        Some(b'\\') => out.push(b'\\'),
                        Some(b'/') => out.push(b'/'),
                        Some(b'n') => out.push(b'\n'),
                        Some(b'r') => out.push(b'\r'),
                        Some(b't') => out.push(b'\t'),
                        Some(b'u') => {
                            let hex = b
                                .get(*pos + 1..*pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            let c = char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                            *pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", *pos),
                    }
                    *pos += 1;
                }
                c => {
                    out.push(c);
                    *pos += 1;
                }
            }
        }
        bail!("unterminated string")
    }

    fn number(b: &[u8], pos: &mut usize) -> Result<Val> {
        let start = *pos;
        while *pos < b.len()
            && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            *pos += 1;
        }
        let s = std::str::from_utf8(&b[start..*pos])?;
        Ok(Val::Num(s.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("bad number '{s}' at byte {start}")
        })?))
    }
}

/// Where task checkpoints live: the simulator keeps them in memory, the
/// real fabric writes one `<name>.json` per task under a directory
/// (`--task-dir`), atomically (tmp + rename) so a crash mid-write never
/// corrupts the last good checkpoint.
#[derive(Debug)]
pub enum TaskJournal {
    Memory(HashMap<String, String>),
    Dir(PathBuf),
}

impl TaskJournal {
    /// In-memory journal (the simulator; also unit tests).
    pub fn memory() -> TaskJournal {
        TaskJournal::Memory(HashMap::new())
    }

    /// File-backed journal under `dir` (created if missing).
    pub fn dir(dir: impl Into<PathBuf>) -> Result<TaskJournal> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create task dir {}", dir.display()))?;
        Ok(TaskJournal::Dir(dir))
    }

    fn path_for(dir: &std::path::Path, name: &str) -> PathBuf {
        // Task names key file names: keep them path-safe.
        let safe: String = name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
            .collect();
        dir.join(format!("{safe}.json"))
    }

    /// Persist one checkpoint under the task's name.
    pub fn save(&mut self, task: &TransferTask) -> Result<()> {
        let text = task.to_json();
        match self {
            TaskJournal::Memory(map) => {
                map.insert(task.name.clone(), text);
                Ok(())
            }
            TaskJournal::Dir(dir) => {
                let path = TaskJournal::path_for(dir, &task.name);
                let tmp = path.with_extension("json.tmp");
                std::fs::write(&tmp, &text)
                    .with_context(|| format!("write {}", tmp.display()))?;
                std::fs::rename(&tmp, &path)
                    .with_context(|| format!("rename into {}", path.display()))?;
                Ok(())
            }
        }
    }

    /// Load the last checkpoint saved under `name`, if any.
    pub fn load(&self, name: &str) -> Result<Option<TransferTask>> {
        let text = match self {
            TaskJournal::Memory(map) => map.get(name).cloned(),
            TaskJournal::Dir(dir) => {
                let path = TaskJournal::path_for(dir, name);
                match std::fs::read_to_string(&path) {
                    Ok(t) => Some(t),
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
                    Err(e) => {
                        return Err(anyhow!(e)).context(format!("read {}", path.display()))
                    }
                }
            }
        };
        text.map(|t| TransferTask::from_json(&t)).transpose()
    }
}

/// One auto-tuner observation: the knob settings that produced one
/// window's goodput (recorded *before* the post-window adjustment).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunerSample {
    /// Window end, seconds from task start.
    pub t_s: f64,
    pub goodput_bps: f64,
    pub concurrency: u32,
    pub chunk_words: usize,
}

/// Serialize a tuner trajectory as a JSON array (the `tuner` field of
/// the per-task report; schema in `docs/REPORTS.md`).
pub fn tuner_json(samples: &[TunerSample]) -> String {
    let rows: Vec<String> = samples
        .iter()
        .map(|s| {
            format!(
                "{{\"t_s\":{:.6},\"goodput_bps\":{:.0},\"concurrency\":{},\"chunk_words\":{}}}",
                s.t_s, s.goodput_bps, s.concurrency, s.chunk_words
            )
        })
        .collect();
    format!("[{}]", rows.join(","))
}

/// Deterministic hill-climber over a task's (concurrency, chunk size):
/// each goodput window adjusts one knob in the current direction,
/// alternating knobs between windows; a ≥5% goodput drop against the
/// previous window reverses direction. No randomness — identical inputs
/// produce identical trajectories on both fabrics.
#[derive(Debug, Default)]
pub struct AutoTuner {
    /// +1 = raising the active knob, -1 = lowering.
    direction: i8,
    /// Alternates each window between the two knobs.
    tune_chunk: bool,
    last_goodput: Option<f64>,
    trajectory: Vec<TunerSample>,
}

impl AutoTuner {
    pub fn new() -> AutoTuner {
        AutoTuner {
            direction: 1,
            tune_chunk: false,
            last_goodput: None,
            trajectory: Vec::new(),
        }
    }

    pub fn trajectory(&self) -> &[TunerSample] {
        &self.trajectory
    }

    /// Fold in one window's goodput and adjust the live knobs in place.
    fn step(&mut self, t_s: f64, goodput_bps: f64, concurrency: &mut u32, chunk_words: &mut usize) {
        self.trajectory.push(TunerSample {
            t_s,
            goodput_bps,
            concurrency: *concurrency,
            chunk_words: *chunk_words,
        });
        if let Some(prev) = self.last_goodput {
            if goodput_bps < prev * 0.95 {
                self.direction = -self.direction;
            }
        }
        self.last_goodput = Some(goodput_bps);
        if self.tune_chunk {
            *chunk_words = if self.direction > 0 {
                (*chunk_words * 2).min(MAX_CHUNK_WORDS)
            } else {
                (*chunk_words / 2).max(MIN_CHUNK_WORDS)
            };
        } else {
            let step = (*concurrency / 4).max(1);
            *concurrency = if self.direction > 0 {
                (*concurrency + step).min(MAX_CONCURRENCY)
            } else {
                concurrency.saturating_sub(step).max(MIN_CONCURRENCY)
            };
        }
        self.tune_chunk = !self.tune_chunk;
    }
}

/// A task's progress snapshot for reports (schema in `docs/REPORTS.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskProgress {
    pub name: String,
    pub files_total: usize,
    pub files_done: usize,
    /// Files already done when the runner was built — restored from the
    /// journal's checkpoint, never re-transferred.
    pub files_resumed: usize,
    pub bytes_total: u64,
    /// Bytes of completed files, each carrying its recorded SHA-256.
    pub verified_bytes: u64,
    /// Failed attempts across the task's lifetime (summed over files;
    /// survives checkpoints).
    pub retries: u64,
    pub deadline_exceeded: bool,
    /// Live (possibly auto-tuned) knob values.
    pub concurrency: u32,
    pub chunk_words: usize,
}

impl TaskProgress {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"task\":{},\"files_total\":{},\"files_done\":{},\"files_resumed\":{},\
             \"bytes_total\":{},\"verified_bytes\":{},\"retries\":{},\
             \"deadline_exceeded\":{},\"concurrency\":{},\"chunk_words\":{}}}",
            json::escape(&self.name),
            self.files_total,
            self.files_done,
            self.files_resumed,
            self.bytes_total,
            self.verified_bytes,
            self.retries,
            self.deadline_exceeded,
            self.concurrency,
            self.chunk_words,
        )
    }
}

/// The durable executor of one [`TransferTask`]: owns the live file
/// states, enforces the task's admission knobs, checkpoints through the
/// journal after every completion, and (with `autotune`) closes the
/// goodput feedback loop. Both fabrics drive the same runner API:
/// [`TaskRunner::next_files`] to admit, [`TaskRunner::file_done`] /
/// [`TaskRunner::file_failed`] to report, [`TaskRunner::observe_window`]
/// to tick the tuner.
#[derive(Debug)]
pub struct TaskRunner {
    task: TransferTask,
    journal: TaskJournal,
    tuner: AutoTuner,
    /// Task-relative clock origin, set on the first admission call.
    clock0: Option<f64>,
    window_start: Option<f64>,
    window_bytes: u64,
    /// Cumulative admitted bytes (the rate limiter's arrival curve).
    admitted_bytes: u64,
    files_resumed: usize,
    deadline_exceeded: bool,
    /// Live knob values (start from the task's, then auto-tuned).
    concurrency: u32,
    chunk_words: usize,
}

impl TaskRunner {
    /// Build a runner, resuming from the journal's checkpoint when one
    /// exists under the task's name: files the checkpoint records as
    /// done (matched by name AND size) stay done — they are never
    /// re-admitted — and checkpointed in-flight files demote to pending
    /// (their transfer died with the previous coordinator). Tuned knob
    /// values persist across the restart. Saves a fresh checkpoint.
    pub fn new(task: TransferTask, journal: TaskJournal) -> Result<TaskRunner> {
        let mut task = task;
        for f in &mut task.files {
            if f.state == FileState::InFlight {
                f.state = FileState::Pending;
            }
        }
        if let Some(saved) = journal.load(&task.name)? {
            task.concurrency = saved.concurrency.max(1);
            task.chunk_words = saved.chunk_words.clamp(MIN_CHUNK_WORDS, MAX_CHUNK_WORDS);
            for sf in saved.files {
                let Some(f) = task
                    .files
                    .iter_mut()
                    .find(|f| f.name == sf.name && f.bytes == sf.bytes)
                else {
                    continue;
                };
                f.retries = f.retries.max(sf.retries);
                if sf.is_done() && !f.is_done() {
                    f.state = sf.state;
                }
            }
        }
        let files_resumed = task.files.iter().filter(|f| f.is_done()).count();
        let concurrency = task.concurrency.max(1);
        let chunk_words = task.chunk_words.clamp(MIN_CHUNK_WORDS, MAX_CHUNK_WORDS);
        let mut runner = TaskRunner {
            task,
            journal,
            tuner: AutoTuner::new(),
            clock0: None,
            window_start: None,
            window_bytes: 0,
            admitted_bytes: 0,
            files_resumed,
            deadline_exceeded: false,
            concurrency,
            chunk_words,
        };
        runner.checkpoint()?;
        Ok(runner)
    }

    pub fn task(&self) -> &TransferTask {
        &self.task
    }

    pub fn file(&self, idx: usize) -> &FileEntry {
        &self.task.files[idx]
    }

    pub fn concurrency(&self) -> u32 {
        self.concurrency
    }

    /// Live transfer chunk size (words). Real-fabric task workers
    /// propose this through wire-format-v2 chunk negotiation
    /// ([`crate::fabric::ChunkProposal::Words`]), so the tuner's chunk
    /// moves reach the socket instead of staying simulator-only.
    pub fn chunk_words(&self) -> usize {
        self.chunk_words
    }

    pub fn deadline_exceeded(&self) -> bool {
        self.deadline_exceeded
    }

    pub fn files_resumed(&self) -> usize {
        self.files_resumed
    }

    pub fn tuner_trajectory(&self) -> &[TunerSample] {
        self.tuner.trajectory()
    }

    /// Spec-level knob overrides (the `TASK_RATE_BPS` /
    /// `TASK_DEADLINE_S` / `AUTOTUNE` config path).
    pub fn set_rate_bps(&mut self, bps: u64) {
        self.task.rate_bps = bps;
    }

    pub fn set_deadline_s(&mut self, s: f64) {
        self.task.deadline_s = s;
    }

    pub fn set_autotune(&mut self, on: bool) {
        self.task.autotune = on;
    }

    /// Every file transferred (and verified).
    pub fn done(&self) -> bool {
        self.task.files.iter().all(|f| f.is_done())
    }

    fn in_flight(&self) -> usize {
        self.task
            .files
            .iter()
            .filter(|f| f.state == FileState::InFlight)
            .count()
    }

    /// Admission: return (and mark in-flight) the pending files that may
    /// start *now*, under the task's concurrency cap, rate limit and
    /// deadline. `now_s` is fabric time — virtual seconds in the sim,
    /// wall-clock seconds on the real fabric; the first call pins the
    /// task's clock origin.
    pub fn next_files(&mut self, now_s: f64) -> Vec<usize> {
        let t0 = *self.clock0.get_or_insert(now_s);
        self.window_start.get_or_insert(now_s);
        let elapsed = (now_s - t0).max(0.0);
        let pending_left = self.task.files.iter().any(|f| f.state == FileState::Pending);
        if self.task.deadline_s > 0.0 && elapsed >= self.task.deadline_s {
            // Past the deadline nothing further is admitted; in-flight
            // files drain. The flag only trips when work was cut off.
            if pending_left {
                self.deadline_exceeded = true;
            }
            return Vec::new();
        }
        let mut admitted = Vec::new();
        let mut in_flight = self.in_flight();
        for idx in 0..self.task.files.len() {
            if self.task.files[idx].state != FileState::Pending {
                continue;
            }
            if in_flight + admitted.len() >= self.concurrency as usize {
                break;
            }
            // Leaky-bucket arrival curve: cumulative admitted bytes stay
            // under rate × elapsed (the first file always passes at 0).
            if self.task.rate_bps > 0
                && self.admitted_bytes as f64 > self.task.rate_bps as f64 * elapsed
            {
                break;
            }
            self.admitted_bytes += self.task.files[idx].bytes;
            self.task.files[idx].state = FileState::InFlight;
            admitted.push(idx);
        }
        if !admitted.is_empty() {
            in_flight += admitted.len();
            let _ = in_flight; // bookkeeping clarity; state is authoritative
        }
        admitted
    }

    /// Earliest instant [`TaskRunner::next_files`] could next admit a
    /// pending file — the rate limiter's next token instant, clamped to
    /// the deadline (where admission flips to deadline-exceeded
    /// instead). Virtual-time drivers use this to advance the clock
    /// through rate-limited idle gaps. `None` when nothing further will
    /// ever be admitted.
    pub fn next_admission_time(&self) -> Option<f64> {
        let t0 = self.clock0?;
        if self.deadline_exceeded {
            return None;
        }
        if !self.task.files.iter().any(|f| f.state == FileState::Pending) {
            return None;
        }
        let mut t = t0;
        if self.task.rate_bps > 0 {
            t = t.max(t0 + self.admitted_bytes as f64 / self.task.rate_bps as f64);
        }
        if self.task.deadline_s > 0.0 {
            t = t.min(t0 + self.task.deadline_s);
        }
        Some(t)
    }

    /// End of the current goodput window, for virtual-time drivers.
    pub fn next_window_deadline(&self) -> Option<f64> {
        if !self.task.autotune {
            return None;
        }
        Some(self.window_start? + self.task.tune_window_s)
    }

    /// A file's transfer completed; `sha256_hex` is the receiver's hash
    /// over the full payload. Checkpoints the task through the journal
    /// before returning — this is the durability point.
    pub fn file_done(&mut self, idx: usize, sha256_hex: &str, now_s: f64) -> Result<()> {
        let f = self
            .task
            .files
            .get_mut(idx)
            .ok_or_else(|| anyhow!("file index {idx} out of range"))?;
        if f.is_done() {
            bail!("file {idx} ('{}') completed twice", f.name);
        }
        f.state = FileState::Done {
            sha256: sha256_hex.to_string(),
        };
        self.window_bytes += f.bytes;
        let _ = now_s;
        self.checkpoint()
    }

    /// A file's transfer failed: back to pending for re-admission (its
    /// admitted bytes stay on the rate limiter's ledger — the attempt
    /// consumed real bandwidth). Checkpoints the retry count.
    pub fn file_failed(&mut self, idx: usize) -> Result<()> {
        let f = self
            .task
            .files
            .get_mut(idx)
            .ok_or_else(|| anyhow!("file index {idx} out of range"))?;
        if f.is_done() {
            bail!("file {idx} ('{}') failed after completing", f.name);
        }
        f.state = FileState::Pending;
        f.retries += 1;
        self.checkpoint()
    }

    /// Tick the auto-tuner: when a goodput window has elapsed, fold its
    /// observed goodput into the hill-climb and adjust the live
    /// concurrency / chunk knobs. No-op without `autotune`.
    pub fn observe_window(&mut self, now_s: f64) {
        if !self.task.autotune {
            return;
        }
        let Some(ws) = self.window_start else { return };
        if now_s - ws < self.task.tune_window_s {
            return;
        }
        let t0 = self.clock0.unwrap_or(ws);
        let goodput = self.window_bytes as f64 / (now_s - ws);
        self.tuner
            .step(now_s - t0, goodput, &mut self.concurrency, &mut self.chunk_words);
        self.window_start = Some(now_s);
        self.window_bytes = 0;
    }

    pub fn progress(&self) -> TaskProgress {
        let files_done = self.task.files.iter().filter(|f| f.is_done()).count();
        let verified_bytes = self
            .task
            .files
            .iter()
            .filter(|f| f.is_done())
            .map(|f| f.bytes)
            .sum();
        let retries = self.task.files.iter().map(|f| f.retries as u64).sum();
        TaskProgress {
            name: self.task.name.clone(),
            files_total: self.task.files.len(),
            files_done,
            files_resumed: self.files_resumed,
            bytes_total: self.task.total_bytes(),
            verified_bytes,
            retries,
            deadline_exceeded: self.deadline_exceeded,
            concurrency: self.concurrency,
            chunk_words: self.chunk_words,
        }
    }

    /// Persist the current state (live knob values included, so a
    /// restart resumes with the tuned settings).
    fn checkpoint(&mut self) -> Result<()> {
        self.task.concurrency = self.concurrency;
        self.task.chunk_words = self.chunk_words;
        self.journal.save(&self.task)
    }
}

/// Lowercase hex SHA-256 of `data` (the end-to-end integrity hash a
/// completed file records in its checkpoint).
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    let digest = h.finalize();
    let mut out = String::with_capacity(64);
    for b in digest {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Deterministic synthetic content for task file `name`: both fabrics
/// generate (and serve / hash) the same bytes, so a checkpoint's
/// SHA-256 is portable across the simulator and the real fabric.
pub fn synth_file_bytes(name: &str, bytes: u64) -> Vec<u8> {
    let mut rng = Prng::new(0x7461_736b).derive(name); // "task"
    let mut buf = vec![0u8; bytes as usize];
    rng.fill_bytes(&mut buf);
    buf
}

/// SHA-256 a file's synthetic content would hash to (what a verified
/// transfer of [`synth_file_bytes`] must record).
pub fn synth_file_sha256(name: &str, bytes: u64) -> String {
    sha256_hex(&synth_file_bytes(name, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_task() -> TransferTask {
        TransferTask::new("t", "alice").with_uniform_files("input", 4, 1000)
    }

    fn temp_journal_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("htcdm-task-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn sha256_hex_known_vector() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn synth_content_is_deterministic_and_name_keyed() {
        assert_eq!(synth_file_sha256("f0", 4096), synth_file_sha256("f0", 4096));
        assert_ne!(synth_file_sha256("f0", 4096), synth_file_sha256("f1", 4096));
    }

    #[test]
    fn checkpoint_json_roundtrips_every_state() {
        let mut task = tiny_task()
            .with_rate_bps(1_000_000)
            .with_deadline_s(60.0)
            .with_autotune(true)
            .with_concurrency(8)
            .with_chunk_words(4096);
        task.files[0].state = FileState::Done {
            sha256: synth_file_sha256("input_0", 1000),
        };
        task.files[1].state = FileState::InFlight;
        task.files[1].retries = 2;
        task.files[2].extent = Some(ExtentId(7));
        let parsed = TransferTask::from_json(&task.to_json()).unwrap();
        assert_eq!(parsed, task);
    }

    #[test]
    fn checkpoint_json_escapes_names() {
        let task = TransferTask::new("we\"ird\\name\n", "bob \"the\" owner").with_file("f", 1);
        let parsed = TransferTask::from_json(&task.to_json()).unwrap();
        assert_eq!(parsed.name, task.name);
        assert_eq!(parsed.owner, task.owner);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(TransferTask::from_json("not json").is_err());
        assert!(TransferTask::from_json("{\"name\":\"x\"}").is_err());
        assert!(TransferTask::from_json("{} trailing").is_err());
    }

    #[test]
    fn memory_journal_roundtrips() {
        let mut j = TaskJournal::memory();
        assert!(j.load("t").unwrap().is_none());
        let task = tiny_task();
        j.save(&task).unwrap();
        assert_eq!(j.load("t").unwrap().unwrap(), task);
    }

    #[test]
    fn dir_journal_roundtrips_and_overwrites() {
        let dir = temp_journal_dir("journal");
        let mut j = TaskJournal::dir(&dir).unwrap();
        assert!(j.load("t").unwrap().is_none());
        let mut task = tiny_task();
        j.save(&task).unwrap();
        task.files[3].state = FileState::Done {
            sha256: synth_file_sha256("input_3", 1000),
        };
        j.save(&task).unwrap();
        let j2 = TaskJournal::dir(&dir).unwrap();
        assert_eq!(j2.load("t").unwrap().unwrap(), task);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn runner_enforces_concurrency_cap() {
        let task = tiny_task().with_concurrency(2);
        let mut r = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        assert_eq!(r.next_files(0.0), vec![0, 1], "cap of 2");
        assert!(r.next_files(0.0).is_empty(), "both slots busy");
        r.file_done(0, &synth_file_sha256("input_0", 1000), 1.0).unwrap();
        assert_eq!(r.next_files(1.0), vec![2], "completion freed a slot");
    }

    #[test]
    fn runner_paces_admission_to_the_rate_limit() {
        // 1000-byte files against 1000 B/s: one admission per second.
        let task = tiny_task().with_rate_bps(1000).with_concurrency(8);
        let mut r = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        assert_eq!(r.next_files(0.0), vec![0], "first file rides the empty bucket");
        assert!(r.next_files(0.5).is_empty(), "bucket refills at 1000 B/s");
        assert_eq!(r.next_admission_time(), Some(1.0));
        assert_eq!(r.next_files(1.0), vec![1]);
        assert_eq!(r.next_files(3.0), vec![2, 3], "burst after a long gap");
    }

    #[test]
    fn runner_deadline_stops_admission_and_flags() {
        let task = tiny_task().with_deadline_s(2.0).with_concurrency(1);
        let mut r = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        assert_eq!(r.next_files(0.0), vec![0]);
        r.file_done(0, &synth_file_sha256("input_0", 1000), 1.0).unwrap();
        assert!(r.next_files(2.5).is_empty(), "past the deadline");
        assert!(r.deadline_exceeded());
        assert!(r.next_admission_time().is_none());
        assert!(!r.done());
    }

    #[test]
    fn runner_resumes_from_checkpoint_without_readmitting_done_files() {
        let dir = temp_journal_dir("resume");
        {
            let mut r =
                TaskRunner::new(tiny_task(), TaskJournal::dir(&dir).unwrap()).unwrap();
            let admitted = r.next_files(0.0);
            assert_eq!(admitted, vec![0, 1, 2, 3]);
            r.file_done(0, &synth_file_sha256("input_0", 1000), 0.5).unwrap();
            r.file_done(2, &synth_file_sha256("input_2", 1000), 0.7).unwrap();
            // Coordinator "dies" here: files 1 and 3 stay in-flight.
        }
        let mut r2 = TaskRunner::new(tiny_task(), TaskJournal::dir(&dir).unwrap()).unwrap();
        assert_eq!(r2.files_resumed(), 2);
        let p = r2.progress();
        assert_eq!(p.files_done, 2);
        assert_eq!(p.verified_bytes, 2000);
        assert_eq!(
            r2.next_files(0.0),
            vec![1, 3],
            "in-flight demoted to pending; done files never re-admitted"
        );
        r2.file_done(1, &synth_file_sha256("input_1", 1000), 0.2).unwrap();
        r2.file_done(3, &synth_file_sha256("input_3", 1000), 0.3).unwrap();
        assert!(r2.done());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn runner_retries_failed_files_and_counts_them() {
        let task = tiny_task().with_concurrency(1);
        let mut r = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        assert_eq!(r.next_files(0.0), vec![0]);
        r.file_failed(0).unwrap();
        assert_eq!(r.next_files(0.1), vec![0], "failed file re-admitted");
        r.file_done(0, &synth_file_sha256("input_0", 1000), 0.2).unwrap();
        assert_eq!(r.progress().retries, 1);
        assert!(r.file_done(0, "beef", 0.3).is_err(), "double complete rejected");
    }

    #[test]
    fn autotuner_climbs_under_rising_goodput_and_reverses_on_drop() {
        let mut tuner = AutoTuner::new();
        let mut c = 4u32;
        let mut w = 1024usize;
        tuner.step(1.0, 1e6, &mut c, &mut w);
        assert_eq!(c, 5, "first window raises concurrency");
        tuner.step(2.0, 2e6, &mut c, &mut w);
        assert_eq!(w, 2048, "second window raises chunk");
        tuner.step(3.0, 1e6, &mut c, &mut w);
        assert_eq!(c, 4, "50% goodput drop reverses direction");
        assert_eq!(tuner.trajectory().len(), 3);
        assert_eq!(tuner.trajectory()[0].concurrency, 4, "pre-adjust values recorded");
    }

    #[test]
    fn runner_windows_drive_the_tuner() {
        let task = tiny_task().with_autotune(true).with_tune_window_s(1.0).with_concurrency(2);
        let mut r = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        r.next_files(0.0);
        r.file_done(0, &synth_file_sha256("input_0", 1000), 0.4).unwrap();
        r.observe_window(0.5);
        assert!(r.tuner_trajectory().is_empty(), "window not elapsed yet");
        r.observe_window(1.25);
        assert_eq!(r.tuner_trajectory().len(), 1);
        assert!((r.tuner_trajectory()[0].goodput_bps - 800.0).abs() < 1.0, "1000 B / 1.25 s");
        assert_eq!(r.concurrency(), 3, "tuner raised the cap");
        assert_eq!(r.next_window_deadline(), Some(2.25));
    }

    #[test]
    fn tuned_knobs_survive_a_restart() {
        let dir = temp_journal_dir("tuned");
        {
            let task = tiny_task().with_autotune(true).with_tune_window_s(0.5);
            let mut r = TaskRunner::new(task, TaskJournal::dir(&dir).unwrap()).unwrap();
            r.next_files(0.0);
            r.file_done(0, &synth_file_sha256("input_0", 1000), 0.4).unwrap();
            r.observe_window(0.6);
            assert_eq!(r.concurrency(), 5);
            // checkpoint() runs inside file_done; force one more with the
            // tuned values by completing another file.
            r.file_done(1, &synth_file_sha256("input_1", 1000), 0.7).unwrap();
        }
        let r2 = TaskRunner::new(tiny_task(), TaskJournal::dir(&dir).unwrap()).unwrap();
        assert_eq!(r2.concurrency(), 5, "tuned concurrency resumed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn progress_json_matches_reports_schema() {
        let r = TaskRunner::new(tiny_task(), TaskJournal::memory()).unwrap();
        let json = r.progress().to_json();
        let v = TransferTask::from_json(&json);
        assert!(v.is_err(), "progress is not a task checkpoint");
        assert!(json.contains("\"task\":\"t\""));
        assert!(json.contains("\"files_total\":4"));
        assert!(json.contains("\"deadline_exceeded\":false"));
        let tuner = tuner_json(&[TunerSample {
            t_s: 1.0,
            goodput_bps: 2.5e9,
            concurrency: 8,
            chunk_words: 16384,
        }]);
        assert!(tuner.starts_with('['), "{tuner}");
        assert!(tuner.contains("\"concurrency\":8"));
    }
}
