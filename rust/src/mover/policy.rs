//! Pluggable admission policies for the data mover.
//!
//! [`AdmissionConfig`] is the serializable knob (what scenarios, configs
//! and `EngineSpec` carry); [`AdmissionPolicy`] is the behavior it builds.
//! The three classic throttle modes stay FIFO — bit-compatible with the
//! legacy `TransferQueue` — while `FairShare` and `WeightedBySize` add
//! scheduling *order* on top of the concurrency limit.

use super::TransferRequest;
use crate::config::{Config, ConfigError};
use crate::transfer::ThrottlePolicy;
use std::collections::{HashMap, VecDeque};

/// Read-only view of the queue's active-transfer bookkeeping, offered to
/// policies at selection time.
#[derive(Debug)]
pub struct ActiveView<'a> {
    pub active_total: u32,
    pub active_by_owner: &'a HashMap<String, u32>,
}

/// An admission policy: a concurrency limit plus a selection order over
/// the waiting queue. Called only while `active < limit()`.
pub trait AdmissionPolicy: std::fmt::Debug + Send {
    /// Maximum concurrent admitted transfers.
    fn limit(&self) -> u32;

    /// Index into `waiting` of the next request to admit, or `None` to
    /// hold admission. Must return a valid index when `Some`.
    fn select(&mut self, waiting: &VecDeque<TransferRequest>, view: &ActiveView<'_>)
        -> Option<usize>;

    /// Human-readable policy description for reports.
    fn describe(&self) -> String;
}

/// FIFO admission under a fixed limit — the behavior of the legacy
/// `TransferQueue` for all three [`ThrottlePolicy`] variants.
#[derive(Debug, Clone)]
pub struct Fifo {
    limit: u32,
    label: String,
}

impl Fifo {
    pub fn new(limit: u32, label: impl Into<String>) -> Fifo {
        Fifo {
            limit,
            label: label.into(),
        }
    }
}

impl AdmissionPolicy for Fifo {
    fn limit(&self) -> u32 {
        self.limit
    }

    fn select(
        &mut self,
        waiting: &VecDeque<TransferRequest>,
        _view: &ActiveView<'_>,
    ) -> Option<usize> {
        if waiting.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn describe(&self) -> String {
        self.label.clone()
    }
}

/// Per-owner round-robin: owners take turns in a fixed rotation (arrival
/// order of first sighting), FIFO within each owner. Starvation-free: in
/// any stretch where owner O has a waiting request, every other owner is
/// admitted at most once before O is.
#[derive(Debug, Clone)]
pub struct FairShare {
    limit: u32,
    /// Ring position of each owner ever seen, in first-seen order.
    ring_index: HashMap<String, usize>,
    ring_len: usize,
    /// Ring position where the next search starts (one past the owner
    /// served last).
    cursor: usize,
}

impl FairShare {
    pub fn new(limit: u32) -> FairShare {
        FairShare {
            limit,
            ring_index: HashMap::new(),
            ring_len: 0,
            cursor: 0,
        }
    }
}

impl AdmissionPolicy for FairShare {
    fn limit(&self) -> u32 {
        self.limit
    }

    fn select(
        &mut self,
        waiting: &VecDeque<TransferRequest>,
        _view: &ActiveView<'_>,
    ) -> Option<usize> {
        // One pass: pick the waiting request whose owner sits closest
        // after the cursor in the rotation ring (earliest arrival wins
        // within an owner, so per-owner order stays FIFO).
        let mut best: Option<(usize, usize, usize)> = None; // (dist, idx, ring pos)
        for (idx, req) in waiting.iter().enumerate() {
            let oi = match self.ring_index.get(&req.owner) {
                Some(&oi) => oi,
                None => {
                    let oi = self.ring_len;
                    self.ring_index.insert(req.owner.clone(), oi);
                    self.ring_len += 1;
                    oi
                }
            };
            let dist = (oi + self.ring_len - self.cursor) % self.ring_len;
            if best.is_none_or(|(bd, _, _)| dist < bd) {
                best = Some((dist, idx, oi));
            }
        }
        let (_, idx, oi) = best?;
        self.cursor = (oi + 1) % self.ring_len;
        Some(idx)
    }

    fn describe(&self) -> String {
        if self.limit == u32::MAX {
            "fair-share".to_string()
        } else {
            format!("fair-share(limit {})", self.limit)
        }
    }
}

/// Smallest sandbox first: minimizes mean wait when sizes are spread
/// (shortest-job-first applied to transfer admission). Ties break FIFO.
#[derive(Debug, Clone)]
pub struct WeightedBySize {
    limit: u32,
}

impl WeightedBySize {
    pub fn new(limit: u32) -> WeightedBySize {
        WeightedBySize { limit }
    }
}

impl AdmissionPolicy for WeightedBySize {
    fn limit(&self) -> u32 {
        self.limit
    }

    fn select(
        &mut self,
        waiting: &VecDeque<TransferRequest>,
        _view: &ActiveView<'_>,
    ) -> Option<usize> {
        // `min_by_key` keeps the first of equal keys → FIFO tie-break.
        waiting
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.bytes)
            .map(|(i, _)| i)
    }

    fn describe(&self) -> String {
        if self.limit == u32::MAX {
            "weighted-by-size".to_string()
        } else {
            format!("weighted-by-size(limit {})", self.limit)
        }
    }
}

/// The serializable admission knob: carried by `EngineSpec`, scenarios and
/// `RealPoolConfig`; parsed from HTCondor-style config files.
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionConfig {
    /// The classic throttles (FIFO order): `Disabled`, `DiskLoad`,
    /// `MaxConcurrent`.
    Throttle(ThrottlePolicy),
    /// Per-owner round-robin; `limit == u32::MAX` means unlimited
    /// concurrency (ordering still applies when a limit is later hit).
    FairShare { limit: u32 },
    /// Smallest-sandbox-first.
    WeightedBySize { limit: u32 },
}

impl From<ThrottlePolicy> for AdmissionConfig {
    fn from(t: ThrottlePolicy) -> AdmissionConfig {
        AdmissionConfig::Throttle(t)
    }
}

impl AdmissionConfig {
    /// Build the runtime policy object.
    pub fn build(&self) -> Box<dyn AdmissionPolicy + Send> {
        match self {
            AdmissionConfig::Throttle(t) => Box::new(Fifo::new(t.limit(), self.label())),
            AdmissionConfig::FairShare { limit } => Box::new(FairShare::new(*limit)),
            AdmissionConfig::WeightedBySize { limit } => Box::new(WeightedBySize::new(*limit)),
        }
    }

    /// The concurrency limit this config imposes.
    pub fn limit(&self) -> u32 {
        match self {
            AdmissionConfig::Throttle(t) => t.limit(),
            AdmissionConfig::FairShare { limit } => *limit,
            AdmissionConfig::WeightedBySize { limit } => *limit,
        }
    }

    /// Short label for reports and bench tables.
    pub fn label(&self) -> String {
        match self {
            AdmissionConfig::Throttle(ThrottlePolicy::Disabled) => "fifo/disabled".to_string(),
            AdmissionConfig::Throttle(ThrottlePolicy::DiskLoad { .. }) => {
                format!("fifo/disk-load(limit {})", self.limit())
            }
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(n)) => {
                format!("fifo/max-concurrent({n})")
            }
            AdmissionConfig::FairShare { .. } => "fair-share".to_string(),
            AdmissionConfig::WeightedBySize { .. } => "weighted-by-size".to_string(),
        }
    }

    /// Parse from HTCondor-style config knobs:
    ///
    /// ```text
    /// TRANSFER_QUEUE_POLICY = FAIR_SHARE     # DISABLED | DISK_LOAD |
    ///                                        # MAX_CONCURRENT | FAIR_SHARE |
    ///                                        # WEIGHTED_BY_SIZE
    /// TRANSFER_QUEUE_MAX_CONCURRENT = 36    # 0 = unlimited
    /// ```
    pub fn from_config(cfg: &Config) -> Result<AdmissionConfig, ConfigError> {
        let name = cfg.get_or("TRANSFER_QUEUE_POLICY", "DISABLED");
        let raw_limit = cfg.get_u64("TRANSFER_QUEUE_MAX_CONCURRENT", 0)? as u32;
        let limit = if raw_limit == 0 { u32::MAX } else { raw_limit };
        match name.trim().to_ascii_uppercase().as_str() {
            "DISABLED" | "NONE" => Ok(ThrottlePolicy::Disabled.into()),
            "DISK_LOAD" | "DISKLOAD" | "DEFAULT" => Ok(ThrottlePolicy::htcondor_default().into()),
            "MAX_CONCURRENT" => Ok(ThrottlePolicy::MaxConcurrent(limit).into()),
            "FAIR_SHARE" | "FAIRSHARE" => Ok(AdmissionConfig::FairShare { limit }),
            "WEIGHTED_BY_SIZE" | "SMALLEST_FIRST" => {
                Ok(AdmissionConfig::WeightedBySize { limit })
            }
            other => Err(ConfigError::Type(
                "TRANSFER_QUEUE_POLICY".into(),
                "policy name",
                other.to_string(),
            )),
        }
    }

    /// The shadow-pool size knob (`SHADOW_POOL_SIZE`, default 1 — the
    /// paper's single-funnel submit node).
    pub fn shadows_from_config(cfg: &Config) -> Result<u32, ConfigError> {
        Ok((cfg.get_u64("SHADOW_POOL_SIZE", 1)?).max(1) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: u32, owner: &str, bytes: u64) -> TransferRequest {
        TransferRequest::new(t, owner, bytes)
    }

    fn view<'a>(map: &'a HashMap<String, u32>) -> ActiveView<'a> {
        ActiveView {
            active_total: 0,
            active_by_owner: map,
        }
    }

    #[test]
    fn fifo_selects_front() {
        let mut p = Fifo::new(4, "fifo");
        let w: VecDeque<_> = [req(1, "a", 10), req(2, "b", 1)].into();
        let m = HashMap::new();
        assert_eq!(p.select(&w, &view(&m)), Some(0));
        assert_eq!(p.limit(), 4);
        let empty: VecDeque<TransferRequest> = VecDeque::new();
        assert_eq!(p.select(&empty, &view(&m)), None);
    }

    #[test]
    fn fair_share_rotates_owners() {
        let mut p = FairShare::new(u32::MAX);
        let m = HashMap::new();
        let w: VecDeque<_> = [
            req(0, "alice", 1),
            req(1, "alice", 1),
            req(2, "bob", 1),
            req(3, "carol", 1),
        ]
        .into();
        // Rotation starts after the cursor: alice, bob, carol, alice...
        let first = p.select(&w, &view(&m)).unwrap();
        assert_eq!(w[first].owner, "alice");
        let w2: VecDeque<_> = [req(1, "alice", 1), req(2, "bob", 1), req(3, "carol", 1)].into();
        let second = p.select(&w2, &view(&m)).unwrap();
        assert_eq!(w2[second].owner, "bob");
        let w3: VecDeque<_> = [req(1, "alice", 1), req(3, "carol", 1)].into();
        let third = p.select(&w3, &view(&m)).unwrap();
        assert_eq!(w3[third].owner, "carol");
        let w4: VecDeque<_> = [req(1, "alice", 1)].into();
        let fourth = p.select(&w4, &view(&m)).unwrap();
        assert_eq!(w4[fourth].owner, "alice");
    }

    #[test]
    fn weighted_by_size_picks_smallest_then_fifo() {
        let mut p = WeightedBySize::new(8);
        let m = HashMap::new();
        let w: VecDeque<_> = [req(0, "a", 500), req(1, "b", 20), req(2, "c", 20)].into();
        // Smallest wins; among equal sizes the earlier arrival wins.
        assert_eq!(p.select(&w, &view(&m)), Some(1));
    }

    #[test]
    fn config_roundtrip_and_labels() {
        let cfg = Config::parse(
            "TRANSFER_QUEUE_POLICY = FAIR_SHARE\nTRANSFER_QUEUE_MAX_CONCURRENT = 12\nSHADOW_POOL_SIZE = 4",
        )
        .unwrap();
        let ac = AdmissionConfig::from_config(&cfg).unwrap();
        assert_eq!(ac, AdmissionConfig::FairShare { limit: 12 });
        assert_eq!(ac.limit(), 12);
        assert_eq!(AdmissionConfig::shadows_from_config(&cfg).unwrap(), 4);

        let dflt = Config::parse("").unwrap();
        assert_eq!(
            AdmissionConfig::from_config(&dflt).unwrap(),
            AdmissionConfig::Throttle(ThrottlePolicy::Disabled)
        );
        assert_eq!(AdmissionConfig::shadows_from_config(&dflt).unwrap(), 1);

        let bad = Config::parse("TRANSFER_QUEUE_POLICY = LIFO").unwrap();
        assert!(AdmissionConfig::from_config(&bad).is_err());

        assert_eq!(
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(3)).label(),
            "fifo/max-concurrent(3)"
        );
        assert!(AdmissionConfig::Throttle(ThrottlePolicy::htcondor_default())
            .label()
            .contains("disk-load"));
    }

    #[test]
    fn throttle_conversion_preserves_limit() {
        for t in [
            ThrottlePolicy::Disabled,
            ThrottlePolicy::htcondor_default(),
            ThrottlePolicy::MaxConcurrent(7),
        ] {
            let ac: AdmissionConfig = t.into();
            assert_eq!(ac.limit(), t.limit());
            assert_eq!(ac.build().limit(), t.limit());
        }
    }
}
