//! The pool-level router: N submit-node shards behind one admission
//! front door.
//!
//! The paper's ~90 Gbps ceiling is a *single* submit node's NIC; the
//! Petascale DTN project (arXiv:2105.12880) showed the next rung is
//! parallelism across nodes. [`PoolRouter`] owns one full [`ShadowPool`]
//! per submit node — each with its own [`AdmissionConfig`] policy and NIC
//! budget — and splits an incoming job burst across them with a pluggable
//! [`RouterPolicy`]:
//!
//! * `RoundRobin` — rotate over live nodes; spread is within ±1.
//! * `LeastLoaded` — fewest active transfers first (ties: fewer waiting,
//!   then lowest index).
//! * `OwnerAffinity` — stable hash of the job owner, so one owner's
//!   sandboxes always land on the same node (cache/claim locality).
//! * `WeightedByCapacity` — deficit round-robin proportional to each
//!   node's NIC capacity (heterogeneous submit fleets).
//!
//! The router survives node loss: [`PoolRouter::fail_node`] poisons a
//! node, drains its waiting queue AND its in-flight transfers, and
//! re-routes all of them to the surviving nodes (counted in
//! [`MoverStats::shard_failed`]; re-routed in-flight transfers count in
//! [`MoverStats::retried_after_fault`]), so a burst never deadlocks on a
//! dead submit node. The loss is reversible: [`PoolRouter::recover_node`]
//! un-poisons the node and re-routes stranded work
//! ([`MoverStats::node_recovered`]), and [`PoolRouter::rebalance`]
//! work-steals waiting requests from long queues onto recovered or idle
//! nodes until the max/min queue-length gap falls within a threshold
//! ([`MoverStats::stolen`]). The `mover::chaos` fault-injection layer
//! drives all three from one `FaultPlan` on both fabrics.
//!
//! The router also owns the **data-source plane** (`mover::source`):
//! every admission it reports is a `(schedule node, data source)` pair.
//! Under the default [`SourcePlan::SubmitFunnel`] the source is the
//! scheduling node itself — the paper's funnel. With a DTN fleet
//! configured ([`RouterConfig::source_plan`] + [`RouterConfig::dtn_capacity`])
//! the plan may place the bytes on a dedicated data node instead; *which*
//! node is the [`SourceSelector`]'s call (round-robin rotation,
//! cache-aware over per-DTN extent residency, stable owner pins with
//! failure-aware re-pinning, or capacity-weighted deficit counters —
//! [`RouterConfig::source_selector`]), bounded by per-DTN admission
//! budgets ([`RouterConfig::dtn_slots`]) so a saturated data node
//! pushes back instead of silently queueing. [`PoolRouter::fail_dtn`]
//! re-sources a dead DTN's in-flight transfers onto survivors (or back
//! onto the funnel), the data-plane analogue of
//! [`PoolRouter::fail_node`]'s re-routing; it also drops the dead
//! node's residency and owner pins — its page cache died with it.
//!
//! All of these data-plane and state-plane settings live in one
//! [`RouterConfig`] struct consumed by [`PoolRouter::from_config`]; the
//! old per-setting builder methods survive as deprecated wrappers.
//!
//! Recovery is hysteretic when a ramp is configured
//! ([`RouterConfig::recovery_ramp`]): a node recovered by
//! [`PoolRouter::recover_node`] re-enters weighted-by-capacity routing
//! at a fraction of its as-built weight and ramps back to full weight
//! over the configured number of routing decisions, so a freshly
//! revived node is not instantly buried under the backlog.
//!
//! Both fabrics consume the router exactly like they consume a single
//! `ShadowPool` (it implements [`DataMover`] with node-major global shard
//! indices); `tests/router_unified.rs` drives one router object through
//! the simulator and then the real TCP loopback fabric.

use super::policy::AdmissionConfig;
use super::pool::ShadowPool;
use super::source::{DataSource, SiteSelector, SourcePlan, SourceSelector};
use super::state::{owner_hash, RouterState, RouterStateHandle, DEFAULT_ROUTER_SHARDS};
use super::{Admitted, DataMover, MoverStats, TransferRequest};
use crate::config::{Config, ConfigError};
use crate::runtime::engine::SealEngine;
use crate::runtime::service::EngineHandle;
use crate::storage::ExtentId;
use crate::util::site_of_member;
use anyhow::Result;
use std::collections::{BTreeSet, HashMap, HashSet, VecDeque};

/// The router's data-plane and state-plane configuration in one place —
/// replaces the builder-method sprawl (`with_source_plan`,
/// `with_source_selector`, `with_dtn_budget`, `with_dtn_queue`,
/// `with_state_shards`, `set_recovery_ramp`, all now deprecated thin
/// wrappers). Build a router with [`PoolRouter::from_config`]; the
/// scheduling-plane arguments (nodes, NIC capacities, routing policy)
/// stay positional because they have no sensible defaults.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Data-source plan (funnel / dedicated-DTN / hybrid-by-size).
    pub source_plan: SourcePlan,
    /// One relative NIC budget per data node; the vector's length is the
    /// DTN fleet size (empty = funnel-only pool).
    pub dtn_capacity: Vec<f64>,
    /// Which-DTN selection strategy within the plan's fleet.
    pub source_selector: SourceSelector,
    /// Per-DTN admission budget of concurrent transfers (0 = unlimited).
    pub dtn_slots: u32,
    /// Per-DTN bounded wait-queue depth (0 = queueing disabled).
    pub dtn_queue_depth: u32,
    /// Router state lock shards (`ROUTER_SHARDS` knob); pure
    /// partitioning, byte-identical decisions for every value.
    pub state_shards: usize,
    /// Recovery hysteresis: routing decisions over which a recovered
    /// node ramps its weight back to full (0 disables the ramp).
    pub recovery_ramp: u32,
    /// Federation sites the pool is partitioned into (`N_SITES` knob;
    /// 1 = the single-facility pool, bit-identical to the pre-site
    /// router). Submit nodes and DTNs split into contiguous site blocks
    /// by [`crate::util::site_of_member`].
    pub n_sites: usize,
    /// Which-site selection strategy — the first level of two-level
    /// source selection (`SITE_SELECTOR` knob; inert with one site).
    pub site_selector: SiteSelector,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            source_plan: SourcePlan::SubmitFunnel,
            dtn_capacity: Vec::new(),
            source_selector: SourceSelector::RoundRobin,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            state_shards: DEFAULT_ROUTER_SHARDS,
            recovery_ramp: 0,
            n_sites: 1,
            site_selector: SiteSelector::LocalFirst,
        }
    }
}

/// Pool-level routing strategy across submit nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Rotate over live nodes in index order.
    RoundRobin,
    /// Node with the fewest active transfers (ties: fewer waiting, then
    /// lowest index).
    LeastLoaded,
    /// Stable hash of the job owner over the live node set.
    OwnerAffinity,
    /// Deficit round-robin weighted by each node's NIC capacity.
    WeightedByCapacity,
}

impl RouterPolicy {
    /// Short label for reports and bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::OwnerAffinity => "owner-affinity",
            RouterPolicy::WeightedByCapacity => "weighted-by-capacity",
        }
    }

    /// Parse a policy name (CLI flag / config value spellings).
    pub fn parse(name: &str) -> Option<RouterPolicy> {
        match name.trim().to_ascii_uppercase().replace('-', "_").as_str() {
            "ROUND_ROBIN" => Some(RouterPolicy::RoundRobin),
            "LEAST_LOADED" => Some(RouterPolicy::LeastLoaded),
            "OWNER_AFFINITY" => Some(RouterPolicy::OwnerAffinity),
            "WEIGHTED_BY_CAPACITY" | "WEIGHTED" => Some(RouterPolicy::WeightedByCapacity),
            _ => None,
        }
    }

    /// The `ROUTER_POLICY` condor-style knob (default: least-loaded).
    ///
    /// ```text
    /// ROUTER_POLICY = ROUND_ROBIN   # ROUND_ROBIN | LEAST_LOADED |
    ///                               # OWNER_AFFINITY | WEIGHTED_BY_CAPACITY
    /// ```
    pub fn from_config(cfg: &Config) -> Result<RouterPolicy, ConfigError> {
        let name = cfg.get_or("ROUTER_POLICY", "LEAST_LOADED");
        RouterPolicy::parse(&name).ok_or_else(|| {
            ConfigError::Type("ROUTER_POLICY".into(), "router policy name", name)
        })
    }

    /// The `N_SUBMIT_NODES` knob (default 1 — the paper's single submit
    /// node).
    pub fn nodes_from_config(cfg: &Config) -> Result<u32, ConfigError> {
        Ok((cfg.get_u64("N_SUBMIT_NODES", 1)?).max(1) as u32)
    }
}

/// A routed admission: the ticket, the submit node that *scheduled* it,
/// the shadow shard (node-local index) sealing it, and the data source
/// its bytes are *served* from. With the default submit-funnel plan the
/// source is the scheduling node itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Routed {
    pub ticket: u32,
    pub node: usize,
    pub shard: usize,
    pub source: DataSource,
}

/// Per-node router accounting for reports and benches.
#[derive(Debug, Clone)]
pub struct RouterStats {
    /// Each submit node's mover accounting (node-major).
    pub per_node: Vec<MoverStats>,
    /// Routing decisions per node (re-routes after a failure count again
    /// on the surviving node).
    pub routed_per_node: Vec<u64>,
    /// Payload bytes routed per node.
    pub bytes_per_node: Vec<u64>,
    /// Nodes poisoned via [`PoolRouter::fail_node`].
    pub shard_failed: u64,
    /// Requests that could not be routed because every node had failed.
    pub stranded: usize,
    /// Admissions whose bytes were placed on each data node (empty with
    /// no DTN fleet). Re-sourced transfers count again on the new DTN.
    pub routed_per_dtn: Vec<u64>,
    /// Payload bytes placed on each data node.
    pub bytes_per_dtn: Vec<u64>,
    /// Data nodes poisoned via [`PoolRouter::fail_dtn`].
    pub dtn_failed: u64,
    /// Data nodes un-poisoned via [`PoolRouter::recover_dtn`].
    pub dtn_recovered: u64,
}

/// Sort tickets collected from the sharded maps (whose iteration order
/// is arbitrary) so every re-route/steal plan emits deterministically.
/// Every failure path MUST funnel its affected-ticket list through this
/// helper — it replaces the per-call-site `sort_unstable` workarounds
/// that `fail_node`/`fail_dtn` used to carry, so no new call site can
/// forget the sort.
fn sorted_tickets(mut tickets: Vec<u32>) -> Vec<u32> {
    tickets.sort_unstable();
    tickets
}

/// A source-selection outcome before ticket accounting: either the
/// scheduling node's funnel, or a data node — possibly via its bounded
/// wait queue when the whole fleet is at budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Placement {
    Funnel,
    Dtn { dtn: usize, queued: bool },
}

/// The data-source plane's selection state, split out of [`PoolRouter`]
/// so the hot path can borrow it alongside the sharded ticket/owner
/// maps ([`RouterState`]) without cloning the owner string per
/// decision.
#[derive(Debug)]
struct SourceSel {
    plan: SourcePlan,
    selector: SourceSelector,
    /// Federation sites the fleet partitions into (1 = single facility;
    /// site selection is then inert and every decision is bit-identical
    /// to the pre-site code).
    n_sites: usize,
    /// First-level (which-site) selection strategy.
    site_selector: SiteSelector,
    /// Site of each data node (contiguous blocks per
    /// [`crate::util::site_of_member`]).
    site_of: Vec<usize>,
    /// Rotation cursor over sites (round-robin site selection); like
    /// `dtn_cursor` it only advances when a DTN placement actually
    /// lands, so funnel overflows never skew the site rotation.
    site_cursor: usize,
    /// Transient per-decision site mask set by `choose_site` — while
    /// `Some(s)`, only site `s`'s DTNs are selectable; always `None`
    /// outside `select` (and with one site).
    allowed_site: Option<usize>,
    /// Per-DTN down flags (empty with no DTN fleet).
    dtn_down: Vec<bool>,
    /// Cached live-DTN list (ascending), rebuilt on fail/recover — the
    /// hot path never re-filters the fleet per decision.
    dtn_live: Vec<usize>,
    /// Relative NIC budget per DTN.
    dtn_capacity: Vec<f64>,
    /// As-built DTN budgets, restored by [`PoolRouter::recover_dtn`].
    dtn_nominal: Vec<f64>,
    /// Round-robin cursor over the DTN fleet (deterministic selection).
    /// The cursor survives fleet churn: it advances only when the
    /// rotation actually picks a data node, so funnel failovers and
    /// small-sandbox hybrid placements never skew it.
    dtn_cursor: usize,
    /// Per-DTN admission budget (0 = unlimited).
    dtn_slots: u32,
    /// Placed (not yet completed or re-sourced) transfers per DTN.
    dtn_active: Vec<u32>,
    /// Extents hot on each data node (cache-aware selection). Seeded by
    /// the fabric, grown by serving, cleared by a kill.
    dtn_residency: Vec<HashSet<ExtentId>>,
    /// Inverse residency index: extent → the DTNs holding it, kept
    /// sorted so "lowest-indexed live holder" is one ascending probe
    /// instead of a linear scan over the fleet. Maintained
    /// incrementally on stage/serve/`fail_dtn`/`set_dtn_residency`.
    extent_home: HashMap<ExtentId, BTreeSet<usize>>,
    /// Deficit counters for weighted-by-capacity selection.
    dtn_credit: Vec<f64>,
    /// Bounded per-DTN wait-queue depth (`DTN_QUEUE_DEPTH`; 0 disables
    /// queueing — the pre-queue behavior of overflowing straight to the
    /// funnel).
    queue_depth: u32,
    /// Tickets queued on a budget-full DTN, drained (promoted into the
    /// freed slot) on `release_source`.
    waitq: Vec<VecDeque<u32>>,
    dtn_queued: u64,
    dtn_deferred: u64,
    dtn_overflow_to_funnel: u64,
    routed_per_dtn: Vec<u64>,
    bytes_per_dtn: Vec<u64>,
    dtn_failed_count: u64,
    dtn_recovered_count: u64,
}

/// A pool-level router over per-submit-node [`ShadowPool`]s. See the
/// module docs.
pub struct PoolRouter {
    nodes: Vec<ShadowPool>,
    /// Relative NIC capacity per node (weighted-by-capacity routing).
    capacity: Vec<f64>,
    /// As-built capacities; [`PoolRouter::recover_node`] restores a
    /// node's weight to this, undoing any [`PoolRouter::set_node_capacity`]
    /// degradation.
    nominal_capacity: Vec<f64>,
    policy: RouterPolicy,
    rr_cursor: usize,
    /// Deficit counters for weighted-by-capacity routing.
    credit: Vec<f64>,
    failed: Vec<bool>,
    /// Cached live-node list (ascending), rebuilt on fail/recover so
    /// the hot path never allocates a per-decision filter.
    live_nodes: Vec<usize>,
    /// Cached per-node active counts and their pool-wide total, so
    /// per-admission peak tracking is O(1) instead of O(nodes).
    active_cache: Vec<u32>,
    active_total: u32,
    /// Data-source selection state (the byte-endpoint plane).
    sel: SourceSel,
    /// Sharded ticket maps and owner pins, shared read-side with the
    /// fabric via [`PoolRouter::state_handle`].
    state: RouterState,
    /// Recovery hysteresis: decisions a recovered node's routing weight
    /// takes to ramp back to full (0 = step-restore, the default).
    ramp_decisions: u32,
    /// Remaining ramp decisions per node (counts down on every routing
    /// decision; a node at 0 routes at full weight).
    ramp_left: Vec<u32>,
    /// Requests held because every node has failed.
    stranded: VecDeque<TransferRequest>,
    routed_per_node: Vec<u64>,
    bytes_per_node: Vec<u64>,
    shard_failed: u64,
    /// Nodes un-poisoned via [`PoolRouter::recover_node`].
    node_recovered: u64,
    /// Waiting requests moved between nodes by [`PoolRouter::rebalance`].
    stolen: u64,
    /// In-flight transfers re-routed off a dead node by
    /// [`PoolRouter::fail_node`] (each one's executor retries it).
    retried_after_fault: u64,
    /// Completes for tickets the router never routed.
    unrouted_completes: u64,
    /// Completes that cancelled a stranded (all-nodes-failed) request.
    cancelled_stranded: u64,
    /// Highest concurrent admitted count across all nodes.
    peak_active: u32,
}

impl std::fmt::Debug for PoolRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolRouter")
            .field("nodes", &self.nodes.len())
            .field("policy", &self.policy)
            .field("state_shards", &self.state.shard_count())
            .field("active", &self.active())
            .field("waiting", &self.waiting())
            .field("failed", &self.failed.iter().filter(|&&x| x).count())
            .finish()
    }
}
impl SourceSel {
    fn empty() -> SourceSel {
        SourceSel {
            plan: SourcePlan::SubmitFunnel,
            selector: SourceSelector::RoundRobin,
            n_sites: 1,
            site_selector: SiteSelector::LocalFirst,
            site_of: Vec::new(),
            site_cursor: 0,
            allowed_site: None,
            dtn_down: Vec::new(),
            dtn_live: Vec::new(),
            dtn_capacity: Vec::new(),
            dtn_nominal: Vec::new(),
            dtn_cursor: 0,
            dtn_slots: 0,
            dtn_active: Vec::new(),
            dtn_residency: Vec::new(),
            extent_home: HashMap::new(),
            dtn_credit: Vec::new(),
            queue_depth: 0,
            waitq: Vec::new(),
            dtn_queued: 0,
            dtn_deferred: 0,
            dtn_overflow_to_funnel: 0,
            routed_per_dtn: Vec::new(),
            bytes_per_dtn: Vec::new(),
            dtn_failed_count: 0,
            dtn_recovered_count: 0,
        }
    }

    fn configure_fleet(&mut self, plan: SourcePlan, dtn_capacity: Vec<f64>) {
        let n = dtn_capacity.len();
        self.plan = plan;
        self.dtn_nominal = dtn_capacity.clone();
        self.dtn_capacity = dtn_capacity;
        self.dtn_down = vec![false; n];
        self.dtn_live = (0..n).collect();
        self.dtn_active = vec![0; n];
        self.dtn_residency = vec![HashSet::new(); n];
        self.extent_home = HashMap::new();
        self.dtn_credit = vec![0.0; n];
        self.waitq = vec![VecDeque::new(); n];
        self.routed_per_dtn = vec![0; n];
        self.bytes_per_dtn = vec![0; n];
        self.site_of = (0..n).map(|d| site_of_member(d, n, self.n_sites)).collect();
    }

    /// Partition the fleet into `n_sites` contiguous blocks and install
    /// the first-level selection strategy. Must follow
    /// [`SourceSel::configure_fleet`] (the partition covers the fleet
    /// as built).
    fn set_sites(&mut self, n_sites: usize, selector: SiteSelector) {
        let n = self.dtn_down.len();
        self.n_sites = n_sites.max(1);
        self.site_selector = selector;
        self.site_of = (0..n).map(|d| site_of_member(d, n, self.n_sites)).collect();
    }

    /// May data node `d` serve the decision in flight? Down nodes never
    /// may; while a site mask is set, only that site's nodes may.
    fn allowed(&self, d: usize) -> bool {
        !self.dtn_down[d] && self.allowed_site.is_none_or(|s| self.site_of[d] == s)
    }

    /// Does site `s` have at least one live data node?
    fn site_has_live_dtn(&self, s: usize) -> bool {
        self.dtn_live.iter().any(|&d| self.site_of[d] == s)
    }

    /// The live fleet narrowed by the current site mask (equal to
    /// `dtn_live` when no mask is set).
    fn allowed_live(&self) -> Vec<usize> {
        match self.allowed_site {
            None => self.dtn_live.clone(),
            Some(s) => self
                .dtn_live
                .iter()
                .copied()
                .filter(|&d| self.site_of[d] == s)
                .collect(),
        }
    }

    /// First level of two-level selection: pick the *site* serving this
    /// admission, or `None` when site selection is inert (one site, or
    /// no site has a live DTN — the second level then works the whole
    /// fleet, preserving its all-dead funnel failover). The chosen site
    /// always has at least one live DTN.
    fn choose_site(&mut self, local_site: usize, extent: Option<ExtentId>) -> Option<usize> {
        if self.n_sites <= 1 {
            return None;
        }
        let local_scan = |sel: &SourceSel| {
            (0..sel.n_sites)
                .map(|k| (local_site + k) % sel.n_sites)
                .find(|&s| sel.site_has_live_dtn(s))
        };
        match self.site_selector {
            SiteSelector::LocalFirst => local_scan(self),
            SiteSelector::CacheAware => {
                // The site of the lowest-indexed live DTN holding the
                // extent hot — follow the data across the WAN; an
                // extent nobody holds stays site-local (its first
                // server becomes its home).
                let hit = extent.and_then(|e| {
                    self.extent_home
                        .get(&e)
                        .and_then(|homes| homes.iter().copied().find(|&d| !self.dtn_down[d]))
                        .map(|d| self.site_of[d])
                });
                hit.or_else(|| local_scan(self))
            }
            SiteSelector::RoundRobin => {
                // Deterministic rotation over sites with live DTNs —
                // the Petascale transfer-matrix shape, every site pair
                // carrying traffic.
                for _ in 0..self.n_sites {
                    let s = self.site_cursor % self.n_sites;
                    self.site_cursor += 1;
                    if self.site_has_live_dtn(s) {
                        return Some(s);
                    }
                }
                None
            }
        }
    }

    fn dtn_count(&self) -> usize {
        self.dtn_down.len()
    }

    fn rebuild_live(&mut self) {
        self.dtn_live = (0..self.dtn_down.len())
            .filter(|&d| !self.dtn_down[d])
            .collect();
    }

    /// Does data node `d` have a free admission slot?
    fn has_slot(&self, d: usize) -> bool {
        self.dtn_slots == 0 || self.dtn_active[d] < self.dtn_slots
    }

    /// Next selectable data node in rotation, advancing the cursor past
    /// the pick. Caller guarantees at least one live DTN in the current
    /// site mask (or at all, when no mask is set).
    fn rr_preferred(&mut self) -> usize {
        loop {
            let d = self.dtn_cursor % self.dtn_down.len();
            self.dtn_cursor += 1;
            if self.allowed(d) {
                return d;
            }
        }
    }

    /// Pick the data source for one admitted transfer: the plan decides
    /// funnel-vs-fleet (`Hybrid` compares `bytes >= threshold`), the
    /// selector places the transfer within the live fleet, and per-DTN
    /// admission budgets push back on saturated nodes — first deferring
    /// to a peer with a free slot, then (with `DTN_QUEUE_DEPTH > 0`)
    /// queueing on a DTN with wait-queue room, and only then
    /// overflowing to the funnel. Deterministic for every selector; an
    /// all-dead fleet fails over to the funnel WITHOUT advancing the
    /// rotation cursor, so the rotation resumes exactly where it left
    /// off after recovery. Owner pins live in the sharded `state` (the
    /// pin-shard lock nests inside the caller's ticket-shard lock; see
    /// `mover::state` for the lock order).
    ///
    /// With a multi-site partition the selection is two-level:
    /// [`SourceSel::choose_site`] first narrows the fleet to one site
    /// (by the requesting node's `local_site`, the extent's home, or
    /// the site rotation — [`SiteSelector`]), then the
    /// [`SourceSelector`] machinery below places the transfer within
    /// that site; deferrals stay site-local and a saturated site
    /// overflows to the funnel rather than silently crossing the WAN.
    fn select(
        &mut self,
        state: &RouterState,
        local_site: usize,
        bytes: u64,
        owner: &str,
        extent: Option<ExtentId>,
    ) -> Placement {
        let via_dtn = match self.plan {
            SourcePlan::SubmitFunnel => false,
            SourcePlan::DedicatedDtn => true,
            SourcePlan::Hybrid { threshold } => bytes >= threshold,
        };
        if !via_dtn || self.dtn_live.is_empty() {
            return Placement::Funnel;
        }
        // Snapshot the rotation cursors: if this transfer ends up on the
        // funnel after all (budget overflow below), the cursors are
        // restored — only an actual DTN placement may advance them.
        let cursor_before = self.dtn_cursor;
        let site_cursor_before = self.site_cursor;
        self.allowed_site = self.choose_site(local_site, extent);
        let preferred = match self.selector {
            SourceSelector::RoundRobin => self.rr_preferred(),
            SourceSelector::CacheAware => {
                // The lowest-indexed selectable DTN holding the extent
                // hot (one ascending probe of the extent→DTN index); an
                // extent nobody holds takes the rotation, which makes
                // its first server its sticky home (serving warms it).
                let hit = extent.and_then(|e| {
                    self.extent_home
                        .get(&e)
                        .and_then(|homes| homes.iter().copied().find(|&d| self.allowed(d)))
                });
                match hit {
                    Some(d) => d,
                    None => self.rr_preferred(),
                }
            }
            SourceSelector::OwnerAffinity => match state.pin_of(owner) {
                Some(d) if self.allowed(d) => d,
                _ => {
                    // First sighting, or the pinned DTN died (or sits
                    // outside the chosen site): (re-)pin by the stable
                    // owner hash over the selectable fleet. The new pin
                    // sticks even after the old node recovers — no
                    // flap-back.
                    let live = self.allowed_live();
                    let d = live[(owner_hash(owner) % live.len() as u64) as usize];
                    state.set_pin(owner, d);
                    d
                }
            },
            SourceSelector::WeightedByCapacity => {
                // Deficit round-robin over the selectable fleet,
                // mirroring the node-routing algorithm one layer up;
                // chaos re-rates (`set_dtn_capacity`) shift the split
                // mid-run.
                let live = self.allowed_live();
                let total: f64 = live.iter().map(|&d| self.dtn_capacity[d]).sum();
                if total > 0.0 {
                    for &d in live.iter() {
                        self.dtn_credit[d] += self.dtn_capacity[d] / total;
                    }
                }
                *live
                    .iter()
                    .max_by(|&&a, &&b| {
                        self.dtn_credit[a]
                            .partial_cmp(&self.dtn_credit[b])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.cmp(&a)) // ties → lowest index
                    })
                    .expect("live is non-empty")
            }
        };
        let chosen = if self.has_slot(preferred) {
            Some((preferred, false))
        } else {
            // The preferred data node's admission budget is full: it
            // pushes back, and the transfer defers to the next
            // selectable DTN (scanning from the preferred node, so
            // deferrals spread — and stay inside the chosen site).
            self.dtn_deferred += 1;
            let n = self.dtn_down.len();
            match (1..n)
                .map(|k| (preferred + k) % n)
                .find(|&d| self.allowed(d) && self.has_slot(d))
            {
                Some(d) => Some((d, false)),
                None if self.queue_depth > 0 => {
                    // Every selectable DTN is at budget, but wait
                    // queues are on: the transfer queues (scanning
                    // from the preferred node) instead of overflowing,
                    // and is promoted into the next freed slot on
                    // release.
                    (0..n)
                        .map(|k| (preferred + k) % n)
                        .find(|&d| {
                            self.allowed(d) && (self.waitq[d].len() as u32) < self.queue_depth
                        })
                        .map(|d| (d, true))
                }
                None => None,
            }
        };
        self.allowed_site = None;
        match chosen {
            Some((d, queued)) => {
                if self.selector == SourceSelector::WeightedByCapacity {
                    self.dtn_credit[d] -= 1.0;
                }
                Placement::Dtn { dtn: d, queued }
            }
            None => {
                // Every selectable DTN is at its budget AND (if
                // enabled) its wait queue is full: the site pushes back
                // and the bytes overflow to the scheduling node's
                // funnel (whose own admission already gated this
                // transfer). No DTN was picked, so both rotation
                // cursors rewind — funnel placements never skew the
                // rotations.
                self.dtn_overflow_to_funnel += 1;
                self.dtn_cursor = cursor_before;
                self.site_cursor = site_cursor_before;
                Placement::Funnel
            }
        }
    }

    /// Account a placement chosen by [`SourceSel::select`]: serving
    /// counters, the admission slot (or wait-queue entry), and the
    /// serve-warms-it residency note.
    fn place(&mut self, ticket: u32, dtn: usize, bytes: u64, extent: Option<ExtentId>, queued: bool) {
        self.routed_per_dtn[dtn] += 1;
        self.bytes_per_dtn[dtn] += bytes;
        if queued {
            self.waitq[dtn].push_back(ticket);
            self.dtn_queued += 1;
        } else {
            self.dtn_active[dtn] += 1;
        }
        // Serving the extent warms it on the chosen node (the sim
        // later re-syncs this from storage truth; the real fabric's
        // file servers share one dataset, so the note stands).
        if let Some(e) = extent {
            self.note_resident(dtn, e);
        }
    }

    /// Release a ticket's DTN placement: a still-queued ticket just
    /// frees its wait-queue entry; a slot holder frees the slot, which
    /// immediately promotes the longest-queued waiter into it — unless
    /// the node is down: during [`PoolRouter::fail_dtn`]'s re-source
    /// loop every queued waiter is itself about to be re-sourced, and
    /// promoting one into the freed slot would have it transiently
    /// holding a slot on a dead node.
    fn release_dtn(&mut self, ticket: u32, dtn: usize) {
        if let Some(q) = self.waitq.get_mut(dtn) {
            if let Some(pos) = q.iter().position(|&t| t == ticket) {
                q.remove(pos);
                return;
            }
        }
        self.dtn_active[dtn] = self.dtn_active[dtn].saturating_sub(1);
        if self.dtn_down.get(dtn).copied().unwrap_or(false) {
            return;
        }
        if let Some(q) = self.waitq.get_mut(dtn) {
            if q.pop_front().is_some() {
                // The promoted ticket now holds the freed slot; its
                // placement (and source bookkeeping) is unchanged.
                self.dtn_active[dtn] += 1;
            }
        }
    }

    /// Pick a surviving data node for a transfer whose preferred
    /// endpoint died but that is NOT going through admission again
    /// (e.g. a job output): the active selector spreads the failover
    /// traffic under the same policy as admissions — rotation for the
    /// cursor-based selectors (outputs carry no owner/extent context),
    /// the deficit counters for weighted-by-capacity — and a
    /// budget-aware forward scan prefers a node with a free admission
    /// slot. No slot is consumed: outputs are not budget-gated, the
    /// scan only steers them away from saturated nodes. `None` when
    /// the whole fleet is down.
    fn failover_dtn(&mut self) -> Option<usize> {
        if self.dtn_live.is_empty() {
            return None;
        }
        let preferred = match self.selector {
            SourceSelector::WeightedByCapacity => {
                let total: f64 = self.dtn_live.iter().map(|&d| self.dtn_capacity[d]).sum();
                if total > 0.0 {
                    let SourceSel {
                        dtn_live,
                        dtn_credit,
                        dtn_capacity,
                        ..
                    } = self;
                    for &d in dtn_live.iter() {
                        dtn_credit[d] += dtn_capacity[d] / total;
                    }
                }
                *self
                    .dtn_live
                    .iter()
                    .max_by(|&&a, &&b| {
                        self.dtn_credit[a]
                            .partial_cmp(&self.dtn_credit[b])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.cmp(&a)) // ties → lowest index
                    })
                    .expect("live is non-empty")
            }
            _ => self.rr_preferred(),
        };
        let chosen = if self.has_slot(preferred) {
            preferred
        } else {
            let n = self.dtn_down.len();
            (1..n)
                .map(|k| (preferred + k) % n)
                .find(|&d| !self.dtn_down[d] && self.has_slot(d))
                .unwrap_or(preferred)
        };
        if self.selector == SourceSelector::WeightedByCapacity {
            self.dtn_credit[chosen] -= 1.0;
        }
        Some(chosen)
    }

    /// Mark one extent hot on a data node, maintaining the inverse
    /// extent→DTN index alongside the residency set.
    fn note_resident(&mut self, dtn: usize, extent: ExtentId) {
        if self.dtn_residency[dtn].insert(extent) {
            self.extent_home.entry(extent).or_default().insert(dtn);
        }
    }

    fn unindex(extent_home: &mut HashMap<ExtentId, BTreeSet<usize>>, e: &ExtentId, dtn: usize) {
        if let Some(homes) = extent_home.get_mut(e) {
            homes.remove(&dtn);
            if homes.is_empty() {
                extent_home.remove(e);
            }
        }
    }

    /// Drop a dead node's whole residency (its page cache died),
    /// scrubbing the extent→DTN index with it.
    fn clear_residency(&mut self, dtn: usize) {
        let SourceSel {
            dtn_residency,
            extent_home,
            ..
        } = self;
        for e in dtn_residency[dtn].drain() {
            SourceSel::unindex(extent_home, &e, dtn);
        }
    }

    /// Replace a data node's residency view wholesale, diffing against
    /// the old view so the extent→DTN index stays exact.
    fn set_residency(&mut self, dtn: usize, extents: &[ExtentId]) {
        let new: HashSet<ExtentId> = extents.iter().copied().collect();
        let SourceSel {
            dtn_residency,
            extent_home,
            ..
        } = self;
        for e in dtn_residency[dtn].iter() {
            if !new.contains(e) {
                SourceSel::unindex(extent_home, e, dtn);
            }
        }
        for e in new.iter() {
            if !dtn_residency[dtn].contains(e) {
                extent_home.entry(*e).or_default().insert(dtn);
            }
        }
        dtn_residency[dtn] = new;
    }
}
impl PoolRouter {
    /// A router over the given per-node pools with explicit NIC budgets
    /// (`capacity` must match `nodes` in length; values are relative).
    pub fn new(nodes: Vec<ShadowPool>, capacity: Vec<f64>, policy: RouterPolicy) -> PoolRouter {
        assert!(!nodes.is_empty(), "router needs at least one node");
        assert_eq!(nodes.len(), capacity.len(), "one capacity per node");
        let n = nodes.len();
        let active_cache: Vec<u32> = nodes.iter().map(|p| p.active()).collect();
        let active_total = active_cache.iter().sum();
        PoolRouter {
            nodes,
            nominal_capacity: capacity.clone(),
            capacity,
            policy,
            rr_cursor: 0,
            credit: vec![0.0; n],
            failed: vec![false; n],
            live_nodes: (0..n).collect(),
            active_cache,
            active_total,
            sel: SourceSel::empty(),
            state: RouterState::new(DEFAULT_ROUTER_SHARDS, n),
            ramp_decisions: 0,
            ramp_left: vec![0; n],
            stranded: VecDeque::new(),
            routed_per_node: vec![0; n],
            bytes_per_node: vec![0; n],
            shard_failed: 0,
            node_recovered: 0,
            stolen: 0,
            retried_after_fault: 0,
            unrouted_completes: 0,
            cancelled_stranded: 0,
            peak_active: 0,
        }
    }

    /// A simulation-mode router: `n_nodes` uniform submit nodes, each a
    /// sim [`ShadowPool`] with `shards` shadow shards and its own copy of
    /// the admission policy.
    pub fn sim(n_nodes: u32, shards: u32, config: AdmissionConfig, policy: RouterPolicy) -> PoolRouter {
        let n = n_nodes.max(1) as usize;
        let nodes = (0..n)
            .map(|_| ShadowPool::sim(shards, config.clone()))
            .collect();
        PoolRouter::new(nodes, vec![1.0; n], policy)
    }

    /// The degenerate single-node router wrapping an existing pool — the
    /// paper's one-submit-node deployment expressed in router terms.
    pub fn single(pool: ShadowPool) -> PoolRouter {
        PoolRouter::new(vec![pool], vec![1.0], RouterPolicy::LeastLoaded)
    }

    /// Recover the inner pool of a single-node router (admission state
    /// and statistics intact). Errors with `self` when multi-node.
    pub fn into_single(mut self) -> Result<ShadowPool, PoolRouter> {
        if self.nodes.len() == 1 {
            Ok(self.nodes.pop().expect("one node"))
        } else {
            Err(self)
        }
    }

    /// A router over the given per-node pools, fully configured from a
    /// [`RouterConfig`] in one shot — the replacement for the old
    /// per-setting builder chain. `cfg.dtn_capacity` attaches a DTN
    /// fleet (each entry one data node's relative NIC budget; empty =
    /// funnel-only; callers should [`SourcePlan::validate`] before
    /// running a plan that needs DTNs). A saturated DTN pushes back:
    /// the selector defers the transfer to a peer with a free slot
    /// ([`MoverStats::dtn_deferred`]) and overflows to the scheduling
    /// node's funnel when the whole fleet is full
    /// ([`MoverStats::dtn_overflow_to_funnel`]) — unless per-DTN wait
    /// queues are enabled (`cfg.dtn_queue_depth > 0`), in which case
    /// budget-full transfers queue ([`MoverStats::dtn_queued`]) and are
    /// promoted into the next slot freed on that DTN, the funnel
    /// remaining the overflow of last resort once every queue is full.
    pub fn from_config(
        nodes: Vec<ShadowPool>,
        capacity: Vec<f64>,
        policy: RouterPolicy,
        cfg: RouterConfig,
    ) -> PoolRouter {
        let mut r = PoolRouter::new(nodes, capacity, policy);
        let n_dtn = cfg.dtn_capacity.len();
        r.sel.configure_fleet(cfg.source_plan, cfg.dtn_capacity);
        r.state.set_dtn_count(n_dtn);
        r.sel.selector = cfg.source_selector;
        r.sel.dtn_slots = cfg.dtn_slots;
        r.sel.queue_depth = cfg.dtn_queue_depth;
        r.sel.set_sites(cfg.n_sites, cfg.site_selector);
        r.state.set_shards(cfg.state_shards);
        r.ramp_decisions = cfg.recovery_ramp;
        r
    }

    /// Attach a data-source plan and a DTN fleet (builder style).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn with_source_plan(mut self, plan: SourcePlan, dtn_capacity: Vec<f64>) -> PoolRouter {
        let n = dtn_capacity.len();
        self.sel.configure_fleet(plan, dtn_capacity);
        self.state.set_dtn_count(n);
        self
    }

    /// Pick the which-DTN selection strategy (builder style).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn with_source_selector(mut self, selector: SourceSelector) -> PoolRouter {
        self.sel.selector = selector;
        self
    }

    /// Give every data node its own admission budget of `slots`
    /// concurrent transfers (builder style; 0 = unlimited).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn with_dtn_budget(mut self, slots: u32) -> PoolRouter {
        self.sel.dtn_slots = slots;
        self
    }

    /// Bound each data node's wait queue at `depth` tickets (builder
    /// style; 0 disables queueing).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn with_dtn_queue(mut self, depth: u32) -> PoolRouter {
        self.sel.queue_depth = depth;
        self
    }

    /// Re-shard the router's ticket/owner state into `shards` lock
    /// shards (builder style; must run before any request enters the
    /// router).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn with_state_shards(mut self, shards: usize) -> PoolRouter {
        self.state.set_shards(shards);
        self
    }

    /// Configure recovery hysteresis after construction: a node
    /// recovered by [`PoolRouter::recover_node`] ramps its
    /// weighted-by-capacity routing weight back to full over
    /// `decisions` routing decisions instead of step-restoring it
    /// (0 disables the ramp). Internal knob-application path; external
    /// callers set [`RouterConfig::recovery_ramp`] instead.
    pub(crate) fn set_ramp_decisions(&mut self, decisions: u32) {
        self.ramp_decisions = decisions;
    }

    /// Configure recovery hysteresis (see [`RouterConfig::recovery_ramp`]).
    #[deprecated(note = "fold into a RouterConfig and build with PoolRouter::from_config")]
    pub fn set_recovery_ramp(&mut self, decisions: u32) {
        self.ramp_decisions = decisions;
    }

    /// A read-side handle onto this router's sharded state: fabric
    /// workers answer `node_of`/`source_of`/liveness probes through it
    /// by locking one state shard, instead of serializing on the gate
    /// mutex wrapping the whole router.
    pub fn state_handle(&self) -> RouterStateHandle {
        self.state.handle()
    }

    /// Number of state shards (the `ROUTER_SHARDS` knob).
    pub fn state_shards(&self) -> usize {
        self.state.shard_count()
    }

    /// The data-source plan this router places bytes with.
    pub fn source_plan(&self) -> SourcePlan {
        self.sel.plan
    }

    /// The which-DTN selection strategy this router places bytes with.
    pub fn source_selector(&self) -> SourceSelector {
        self.sel.selector
    }

    /// Federation sites the pool partitions into (1 = single facility).
    pub fn n_sites(&self) -> usize {
        self.sel.n_sites
    }

    /// The which-site selection strategy (first level of two-level
    /// source selection; inert with one site).
    pub fn site_selector(&self) -> SiteSelector {
        self.sel.site_selector
    }

    /// Site of a data node (contiguous blocks; see
    /// [`crate::util::site_of_member`]).
    pub fn site_of_dtn(&self, dtn: usize) -> usize {
        self.sel.site_of.get(dtn).copied().unwrap_or(0)
    }

    /// Site of a submit node (same contiguous-block partition).
    pub fn site_of_node(&self, node: usize) -> usize {
        site_of_member(node, self.nodes.len(), self.sel.n_sites)
    }

    /// Per-DTN admission budget (0 = unlimited).
    pub fn dtn_budget(&self) -> u32 {
        self.sel.dtn_slots
    }

    /// Per-DTN wait-queue depth (0 = queueing disabled).
    pub fn dtn_queue_depth(&self) -> u32 {
        self.sel.queue_depth
    }

    /// Data-transfer-node fleet size (0 = funnel-only pool).
    pub fn dtn_count(&self) -> usize {
        self.sel.dtn_count()
    }

    pub fn is_dtn_failed(&self, dtn: usize) -> bool {
        self.sel.dtn_down[dtn]
    }

    /// Currently placed (admission-slot-holding) transfers per DTN.
    pub fn dtn_active_per_node(&self) -> Vec<u32> {
        self.sel.dtn_active.clone()
    }

    /// Tickets currently sitting in each DTN's wait queue.
    pub fn dtn_queued_per_node(&self) -> Vec<usize> {
        self.sel.waitq.iter().map(|q| q.len()).collect()
    }

    /// The data node an owner's sandboxes are pinned to (owner-affinity
    /// selection; `None` until the owner's first DTN placement).
    pub fn dtn_pin_of(&self, owner: &str) -> Option<usize> {
        self.state.pin_of(owner)
    }

    /// Mark one extent hot on a data node (cache-aware selection; the
    /// fabric seeds pre-warmed extents through this).
    pub fn note_extent_resident(&mut self, dtn: usize, extent: ExtentId) {
        self.sel.note_resident(dtn, extent);
    }

    /// Replace a data node's residency view wholesale (the sim re-syncs
    /// it from the node's `storage::Storage` truth after every read, so
    /// evictions are reflected).
    pub fn set_dtn_residency(&mut self, dtn: usize, extents: &[ExtentId]) {
        self.sel.set_residency(dtn, extents);
    }

    /// Data source of an admitted, not-yet-completed ticket.
    pub fn source_of(&self, ticket: u32) -> Option<DataSource> {
        self.state.source_of(ticket)
    }

    /// Drop a ticket's data-source placement (completion, node failure,
    /// or the re-source half of a DTN failure), releasing its DTN
    /// admission slot (or wait-queue entry).
    fn release_source(&mut self, ticket: u32) {
        if let Some(DataSource::Dtn { dtn }) = self.state.remove_source(ticket) {
            self.sel.release_dtn(ticket, dtn);
        }
    }

    /// Assign (and account) the data source of a freshly admitted
    /// ticket. A re-source first releases the ticket's previous
    /// placement so per-DTN admission slots can't leak. The request
    /// body is read in place under its shard lock — no owner-string
    /// clone per decision.
    fn assign_source(&mut self, ticket: u32, node: usize) -> DataSource {
        self.release_source(ticket);
        let local_site = site_of_member(node, self.nodes.len(), self.sel.n_sites);
        let sel = &mut self.sel;
        let state = &self.state;
        let (placement, bytes, extent) = state.with_request(ticket, |req| match req {
            Some(r) => (
                sel.select(state, local_site, r.bytes, &r.owner, r.extent),
                r.bytes,
                r.extent,
            ),
            None => (sel.select(state, local_site, 0, "", None), 0, None),
        });
        let source = match placement {
            Placement::Funnel => DataSource::Funnel { node },
            Placement::Dtn { dtn, queued } => {
                self.sel.place(ticket, dtn, bytes, extent, queued);
                DataSource::Dtn { dtn }
            }
        };
        self.state.set_source(ticket, source);
        source
    }

    /// The source an already-admitted transfer (e.g. a job output)
    /// should use NOW: `preferred` if still live, else a surviving DTN
    /// picked by the active [`SourceSelector`] (so failover traffic
    /// spreads across the fleet instead of hammering the lowest-indexed
    /// survivor), else `node`'s funnel.
    pub fn output_source(&mut self, preferred: DataSource, node: usize) -> DataSource {
        match preferred {
            DataSource::Dtn { dtn } if self.sel.dtn_down.get(dtn).copied().unwrap_or(true) => {
                match self.sel.failover_dtn() {
                    Some(live) => DataSource::Dtn { dtn: live },
                    None => DataSource::Funnel { node },
                }
            }
            other => other,
        }
    }

    /// Poison a data node: its in-flight transfers are re-sourced onto
    /// surviving DTNs (or the funnel), without touching their admission
    /// — the schedule node still holds their slots; only the byte
    /// endpoint moves. Each re-sourced transfer counts in
    /// [`MoverStats::retried_after_fault`] (its executor restarts the
    /// transfer against the new source) and is returned so the fabric
    /// can re-drive it. Idempotent per DTN.
    pub fn fail_dtn(&mut self, dtn: usize) -> Vec<Routed> {
        if !self.poison_dtn(dtn) {
            return Vec::new();
        }
        self.sel.rebuild_live();
        self.drain_dtn(dtn)
    }

    /// The mark-dead half of [`PoolRouter::fail_dtn`]: flag the node
    /// down, drop its residency and owner pins. Returns false (a no-op)
    /// when the node is already down. The caller must
    /// `sel.rebuild_live()` before re-sourcing anything — split out so
    /// [`PoolRouter::fail_site`] can poison a site's WHOLE fleet before
    /// draining any member, ensuring no re-source transiently lands on
    /// a sibling that is itself about to die.
    fn poison_dtn(&mut self, dtn: usize) -> bool {
        if self.sel.dtn_down[dtn] {
            return false;
        }
        self.sel.dtn_down[dtn] = true;
        self.sel.dtn_failed_count += 1;
        self.state.set_dtn_down(dtn, true);
        // The node's page cache dies with it, and its pinned owners
        // re-pin (stably) onto the live fleet at their next placement —
        // which, for its in-flight transfers, is the re-source in
        // `drain_dtn`.
        self.sel.clear_residency(dtn);
        self.state.drop_pins_to(dtn);
        true
    }

    /// The re-source half of [`PoolRouter::fail_dtn`]: move a poisoned
    /// node's in-flight transfers onto surviving DTNs (or the funnel).
    fn drain_dtn(&mut self, dtn: usize) -> Vec<Routed> {
        let affected = sorted_tickets(self.state.tickets_on_dtn(dtn));
        let mut out = Vec::new();
        for ticket in affected {
            let Some(node) = self.state.node_of(ticket) else {
                continue;
            };
            let Some(shard) = self.nodes[node].shard_of(ticket) else {
                continue;
            };
            let source = self.assign_source(ticket, node);
            self.retried_after_fault += 1;
            out.push(Routed {
                ticket,
                node,
                shard,
                source,
            });
        }
        // Each re-source above pulled its ticket out of the dead node's
        // wait queue (and the down flag blocks promotions into freed
        // slots), so by here both the queue and the slot count must be
        // empty — drain defensively so recovery starts clean even if a
        // ticket was skipped for missing node/shard bookkeeping.
        self.sel.waitq[dtn].clear();
        self.sel.dtn_active[dtn] = 0;
        out
    }

    /// Drain a whole federation site — the border-link cut writ large:
    /// every one of the site's data nodes is poisoned FIRST (so no
    /// re-source transiently lands on a sibling that is itself about to
    /// die), then each is drained onto surviving sites (or the funnel),
    /// then the site's submit nodes fail one by one, re-routing their
    /// waiting and in-flight admissions to surviving sites' nodes —
    /// [`PoolRouter::fail_node`] semantics, scoped to the site block.
    /// Returns every transfer the fabric must re-drive. Idempotent per
    /// site.
    pub fn fail_site(&mut self, site: usize) -> Vec<Routed> {
        // Poison the site's whole DTN fleet up front but drain LAST:
        // failing the site's submit nodes first re-routes their
        // admissions with fresh (already-site-masked) sources, so the
        // drain below only touches surviving nodes' tickets and no
        // ticket is ever re-driven twice.
        let dtns: Vec<usize> = (0..self.dtn_count())
            .filter(|&d| self.site_of_dtn(d) == site)
            .collect();
        let poisoned: Vec<usize> = dtns
            .into_iter()
            .filter(|&d| self.poison_dtn(d))
            .collect();
        self.sel.rebuild_live();
        let site_nodes: Vec<usize> = (0..self.node_count())
            .filter(|&n| self.site_of_node(n) == site)
            .collect();
        let mut out = Vec::new();
        for n in site_nodes {
            out.extend(self.fail_node(n));
        }
        for d in poisoned {
            out.extend(self.drain_dtn(d));
        }
        out
    }

    /// Un-drain a federation site: every one of its data nodes and
    /// submit nodes recovers ([`PoolRouter::recover_dtn`] /
    /// [`PoolRouter::recover_node`] semantics — cold caches, clean
    /// deficit counters, stranded work re-routed). Returns the
    /// transfers admitted NOW. Idempotent.
    pub fn recover_site(&mut self, site: usize) -> Vec<Routed> {
        let dtns: Vec<usize> = (0..self.dtn_count())
            .filter(|&d| self.site_of_dtn(d) == site)
            .collect();
        for d in dtns {
            self.recover_dtn(d);
        }
        let site_nodes: Vec<usize> = (0..self.node_count())
            .filter(|&n| self.site_of_node(n) == site)
            .collect();
        let mut out = Vec::new();
        for n in site_nodes {
            out.extend(self.recover_node(n));
        }
        out
    }

    /// Un-poison a data node: it rejoins source selection with its
    /// as-built budget, a clean deficit counter and a cold cache (its
    /// residency died with the crash). Nothing is re-driven (new
    /// admissions reach it via the selector). Idempotent.
    pub fn recover_dtn(&mut self, dtn: usize) {
        self.sel.dtn_capacity[dtn] = self.sel.dtn_nominal[dtn];
        if !self.sel.dtn_down[dtn] {
            return;
        }
        self.sel.dtn_down[dtn] = false;
        self.sel.dtn_credit[dtn] = 0.0;
        self.sel.dtn_recovered_count += 1;
        self.sel.rebuild_live();
        self.state.set_dtn_down(dtn, false);
    }

    /// Re-rate a data node's relative NIC budget (fault injection).
    /// The weighted-by-capacity selector tracks the new budget on its
    /// next deposit; the other selectors ignore capacity.
    pub fn set_dtn_capacity(&mut self, dtn: usize, capacity: f64) {
        self.sel.dtn_capacity[dtn] = capacity.max(0.0);
    }

    /// Spawn per-shard engine services on every node that has none yet
    /// (idempotent; mirrors [`ShadowPool::ensure_engines`]).
    pub fn ensure_engines<F>(&mut self, factory: F)
    where
        F: Fn(usize) -> Result<Box<dyn SealEngine>> + Send + Clone + 'static,
    {
        for node in &mut self.nodes {
            node.ensure_engines(factory.clone());
        }
    }

    /// Seal-engine handles of one node's shards (empty in sim mode).
    pub fn handles(&self, node: usize) -> Vec<EngineHandle> {
        self.nodes[node].handles()
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The node's admission configuration.
    pub fn node_config(&self, node: usize) -> &AdmissionConfig {
        self.nodes[node].config()
    }

    /// Active transfers per node (routing-visible load).
    pub fn active_per_node(&self) -> Vec<u32> {
        self.nodes.iter().map(|n| n.active()).collect()
    }

    /// Waiting requests per node.
    pub fn waiting_per_node(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n.waiting()).collect()
    }

    /// Submit node of an in-router (waiting or admitted) ticket.
    pub fn node_of(&self, ticket: u32) -> Option<usize> {
        self.state.node_of(ticket)
    }

    pub fn is_failed(&self, node: usize) -> bool {
        self.failed[node]
    }

    /// Global shard index (node-major) of an admitted ticket: the shard
    /// namespace the [`DataMover`] view exposes.
    pub fn global_shard_of(&self, ticket: u32) -> Option<usize> {
        let node = self.node_of(ticket)?;
        let local = self.nodes[node].shard_of(ticket)?;
        Some(self.shard_offset(node) + local)
    }

    fn shard_offset(&self, node: usize) -> usize {
        self.nodes[..node].iter().map(|n| n.shard_count()).sum()
    }

    fn rebuild_live_nodes(&mut self) {
        self.live_nodes = (0..self.nodes.len()).filter(|&i| !self.failed[i]).collect();
    }

    /// Re-read one node's active count into the O(1) pool-wide cache
    /// (every request/complete on a node must be followed by this).
    fn refresh_active(&mut self, node: usize) {
        let a = self.nodes[node].active();
        self.active_total = self.active_total - self.active_cache[node] + a;
        self.active_cache[node] = a;
    }
}
impl PoolRouter {
    /// Pick the submit node for a request under the routing policy, or
    /// `None` when every node has failed. Allocation-free: the live
    /// set is cached and rebuilt only on fail/recover.
    fn pick_node(&mut self, req: &TransferRequest) -> Option<usize> {
        if self.live_nodes.is_empty() {
            return None;
        }
        // Every routing decision advances all running recovery ramps.
        for l in &mut self.ramp_left {
            *l = l.saturating_sub(1);
        }
        Some(match self.policy {
            RouterPolicy::RoundRobin => loop {
                let n = self.rr_cursor % self.nodes.len();
                self.rr_cursor += 1;
                if !self.failed[n] {
                    break n;
                }
            },
            RouterPolicy::LeastLoaded => self
                .live_nodes
                .iter()
                .copied()
                .min_by_key(|&i| (self.nodes[i].active(), self.nodes[i].waiting(), i))
                .expect("live is non-empty"),
            RouterPolicy::OwnerAffinity => {
                self.live_nodes[(owner_hash(&req.owner) % self.live_nodes.len() as u64) as usize]
            }
            RouterPolicy::WeightedByCapacity => {
                // Deficit round-robin: every request deposits one request's
                // worth of credit, split proportionally to live capacity
                // (ramping recovered nodes count at their reduced weight —
                // a node `k` decisions into an `n`-decision ramp weighs
                // `capacity * (k + 1) / (n + 1)`); the node deepest in
                // credit serves it.
                let rd = self.ramp_decisions;
                let capacity = &self.capacity;
                let ramp_left = &self.ramp_left;
                let live = &self.live_nodes;
                let credit = &mut self.credit;
                let eff = |i: usize| -> f64 {
                    if rd > 0 && ramp_left[i] > 0 {
                        let total = rd as f64;
                        let done = (rd - ramp_left[i]) as f64;
                        capacity[i] * (done + 1.0) / (total + 1.0)
                    } else {
                        capacity[i]
                    }
                };
                let total: f64 = live.iter().map(|&i| eff(i)).sum();
                if total > 0.0 {
                    for &i in live.iter() {
                        credit[i] += eff(i) / total;
                    }
                }
                let &best = live
                    .iter()
                    .max_by(|&&a, &&b| {
                        credit[a]
                            .partial_cmp(&credit[b])
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.cmp(&a)) // ties → lowest index
                    })
                    .expect("live is non-empty");
                credit[best] -= 1.0;
                best
            }
        })
    }

    /// Hand a request to a node's pool and translate its admissions.
    fn route_to(&mut self, node: usize, req: TransferRequest) -> Vec<Routed> {
        self.routed_per_node[node] += 1;
        self.bytes_per_node[node] += req.bytes;
        self.state.set_node(req.ticket, node);
        let admitted = self.nodes[node].request(req);
        self.after_op(node, admitted)
    }

    fn after_op(&mut self, node: usize, admitted: Vec<Admitted>) -> Vec<Routed> {
        let mut out = Vec::with_capacity(admitted.len());
        for a in admitted {
            // Admission is the moment the data source is chosen: the
            // plan sees the final (post-failover) schedule node.
            let source = self.assign_source(a.ticket, node);
            out.push(Routed {
                ticket: a.ticket,
                node,
                shard: a.shard,
                source,
            });
        }
        self.refresh_active(node);
        self.peak_active = self.peak_active.max(self.active_total);
        out
    }

    /// Submit a transfer request; returns every transfer (possibly on a
    /// different node) admitted *now*.
    pub fn request(&mut self, req: TransferRequest) -> Vec<Routed> {
        self.state.insert_request(&req);
        match self.pick_node(&req) {
            Some(node) => self.route_to(node, req),
            None => {
                self.stranded.push_back(req);
                Vec::new()
            }
        }
    }

    /// One negotiator-style admission cycle: route a whole burst slice
    /// through the router in one call, amortizing the fabric's gate
    /// acquisition and the per-call bookkeeping across the batch.
    /// Behaviorally identical to calling [`PoolRouter::request`] once
    /// per element in order (a property `tests/props.rs` pins down) —
    /// batching changes *where* the lock round-trips happen, never what
    /// is decided.
    pub fn route_batch(&mut self, reqs: Vec<TransferRequest>) -> Vec<Routed> {
        let mut out = Vec::with_capacity(reqs.len());
        for req in reqs {
            out.extend(self.request(req));
        }
        out
    }

    /// The completion half of an admission cycle: retire a slice of
    /// tickets in one call. Equivalent to per-ticket
    /// [`PoolRouter::complete`] calls in order.
    pub fn complete_batch(&mut self, tickets: &[u32]) -> Vec<Routed> {
        let mut out = Vec::new();
        for &t in tickets {
            out.extend(self.complete(t));
        }
        out
    }

    /// A transfer finished (or failed); returns newly admitted transfers
    /// on that ticket's node. A complete for a STRANDED ticket (queued
    /// while every node was failed) cancels its entry — same
    /// no-ghost contract as the node queues' `cancelled_waiting` path.
    pub fn complete(&mut self, ticket: u32) -> Vec<Routed> {
        let (source, node) = self.state.scrub(ticket);
        if let Some(DataSource::Dtn { dtn }) = source {
            self.sel.release_dtn(ticket, dtn);
        }
        let Some(node) = node else {
            if let Some(pos) = self.stranded.iter().position(|r| r.ticket == ticket) {
                self.stranded.remove(pos);
                self.cancelled_stranded += 1;
            } else {
                self.unrouted_completes += 1;
            }
            return Vec::new();
        };
        let admitted = self.nodes[node].complete(ticket);
        self.after_op(node, admitted)
    }

    /// Poison a submit node mid-burst: its waiting queue AND its
    /// in-flight transfers are re-routed to the surviving nodes, so the
    /// burst drains instead of deadlocking. Returns the transfers newly
    /// admitted on surviving nodes. Idempotent per node.
    pub fn fail_node(&mut self, node: usize) -> Vec<Routed> {
        if self.failed[node] {
            return Vec::new();
        }
        self.failed[node] = true;
        self.shard_failed += 1;
        self.rebuild_live_nodes();
        self.state.set_node_down(node, true);

        // Waiting requests leave the dead node's queue wholesale…
        let waiting = self.nodes[node].drain_waiting();
        for req in &waiting {
            self.state.remove_node(req.ticket);
        }
        // …and transfers in flight on the dead node are lost with it:
        // clear their bookkeeping there, then resubmit them elsewhere.
        // (After the waiting drain, tickets still mapped to this node are
        // exactly the admitted ones; `sorted_tickets` makes the re-route
        // order deterministic regardless of shard iteration order.)
        let inflight = sorted_tickets(self.state.tickets_on_node(node));
        let mut to_reroute: Vec<TransferRequest> =
            Vec::with_capacity(inflight.len() + waiting.len());
        for t in inflight {
            self.state.remove_node(t);
            self.release_source(t); // a fresh source is chosen on re-admission
            let _ = self.nodes[node].complete(t); // queue already drained: admits nothing
            if let Some(req) = self.state.request_clone(t) {
                self.retried_after_fault += 1;
                to_reroute.push(req);
            }
        }
        self.refresh_active(node);
        to_reroute.extend(waiting);

        let mut out = Vec::new();
        for req in to_reroute {
            match self.pick_node(&req) {
                Some(n) => out.extend(self.route_to(n, req)),
                None => self.stranded.push_back(req),
            }
        }
        out
    }

    /// Un-poison a node: it rejoins routing with a clean deficit counter
    /// and its as-built routing weight (undoing any
    /// [`PoolRouter::set_node_capacity`] degradation — the weight restore
    /// applies even to a live node, mirroring the sim engine restoring
    /// the physical NIC rate), and requests stranded while every node
    /// was failed are routed immediately. Returns the transfers admitted
    /// NOW. Otherwise idempotent: recovering a live node admits nothing.
    /// Callers wanting the survivors' long queues rebalanced onto the
    /// recovered node follow up with [`PoolRouter::rebalance`] (the
    /// `mover::chaos` executor does both).
    pub fn recover_node(&mut self, node: usize) -> Vec<Routed> {
        self.capacity[node] = self.nominal_capacity[node];
        if !self.failed[node] {
            return Vec::new();
        }
        self.failed[node] = false;
        self.credit[node] = 0.0;
        self.node_recovered += 1;
        self.rebuild_live_nodes();
        self.state.set_node_down(node, false);
        // Hysteresis: re-enter weighted routing at reduced weight and
        // ramp back over the configured number of decisions.
        self.ramp_left[node] = self.ramp_decisions;
        let stranded: Vec<TransferRequest> = self.stranded.drain(..).collect();
        let mut out = Vec::new();
        for req in stranded {
            match self.pick_node(&req) {
                Some(n) => out.extend(self.route_to(n, req)),
                None => self.stranded.push_back(req),
            }
        }
        out
    }

    /// Threshold-triggered work-stealing, batched negotiator-style:
    /// each cycle computes ONE steal plan against a projection of the
    /// live queue lengths — move the most recently queued request from
    /// the (projected) longest queue to the (projected) shortest while
    /// the gap exceeds `threshold` (and moving strictly shrinks it) —
    /// then executes the whole plan in a single pass. Because a steal
    /// landing on an idle node may be admitted instead of queued, the
    /// cycle repeats until a plan comes up empty, so the final
    /// max/min waiting-queue gap meets the same criterion the old
    /// per-transfer loop enforced. Moves count in
    /// [`MoverStats::stolen`]; returns the transfers target nodes
    /// admitted NOW.
    pub fn rebalance(&mut self, threshold: usize) -> Vec<Routed> {
        let mut out = Vec::new();
        if self.live_nodes.len() < 2 {
            return out;
        }
        loop {
            // Plan one cycle's steals on projected queue lengths…
            let mut lens: Vec<usize> = self.nodes.iter().map(|n| n.waiting()).collect();
            let mut moves: Vec<(usize, usize)> = Vec::new();
            loop {
                let mut hi = self.live_nodes[0];
                let mut lo = self.live_nodes[0];
                for &i in &self.live_nodes {
                    if lens[i] > lens[hi] {
                        hi = i;
                    }
                    if lens[i] < lens[lo] {
                        lo = i;
                    }
                }
                let gap = lens[hi] - lens[lo];
                // gap >= 2 also guards the ping-pong a zero threshold
                // would otherwise loop on (moving across a gap of 1
                // just swaps it).
                if gap <= threshold || gap < 2 {
                    break;
                }
                lens[hi] -= 1;
                lens[lo] += 1;
                moves.push((hi, lo));
            }
            if moves.is_empty() {
                return out;
            }
            // …then execute the plan in one pass.
            for (hi, lo) in moves {
                let Some(req) = self.nodes[hi].steal_waiting() else {
                    continue;
                };
                self.stolen += 1;
                self.state.remove_node(req.ticket);
                out.extend(self.route_to(lo, req));
            }
        }
    }

    /// Re-rate a node's relative NIC budget so weighted-by-capacity
    /// routing tracks a degraded NIC. [`PoolRouter::recover_node`]
    /// restores the as-built weight.
    pub fn set_node_capacity(&mut self, node: usize, capacity: f64) {
        self.capacity[node] = capacity.max(0.0);
    }

    /// Lowest-indexed live node (`None` when every node has failed).
    pub fn first_live_node(&self) -> Option<usize> {
        self.failed.iter().position(|&f| !f)
    }

    /// Currently admitted (in-flight) transfers across all nodes
    /// (cached; O(1)).
    pub fn active(&self) -> u32 {
        self.active_total
    }

    /// Requests waiting for admission (including stranded ones).
    pub fn waiting(&self) -> usize {
        self.nodes.iter().map(|n| n.waiting()).sum::<usize>() + self.stranded.len()
    }

    /// Total shadow shards across all nodes.
    pub fn shard_count(&self) -> usize {
        self.nodes.iter().map(|n| n.shard_count()).sum()
    }

    /// Per-node detail (per-node mover stats, routing counts, failures,
    /// per-DTN source placement).
    pub fn router_stats(&self) -> RouterStats {
        RouterStats {
            per_node: self.nodes.iter().map(|n| n.stats()).collect(),
            routed_per_node: self.routed_per_node.clone(),
            bytes_per_node: self.bytes_per_node.clone(),
            shard_failed: self.shard_failed,
            stranded: self.stranded.len(),
            routed_per_dtn: self.sel.routed_per_dtn.clone(),
            bytes_per_dtn: self.sel.bytes_per_dtn.clone(),
            dtn_failed: self.sel.dtn_failed_count,
            dtn_recovered: self.sel.dtn_recovered_count,
        }
    }

    /// Aggregate mover accounting: per-shard vectors concatenate
    /// node-major (node 0's shards first), so their length is
    /// [`PoolRouter::shard_count`] and their sums cover the whole pool.
    pub fn stats(&self) -> MoverStats {
        let per_node: Vec<MoverStats> = self.nodes.iter().map(|n| n.stats()).collect();
        MoverStats {
            peak_active: self.peak_active,
            total_admitted: per_node.iter().map(|s| s.total_admitted).sum(),
            released_without_active: self.unrouted_completes
                + per_node.iter().map(|s| s.released_without_active).sum::<u64>(),
            cancelled_waiting: self.cancelled_stranded
                + per_node.iter().map(|s| s.cancelled_waiting).sum::<u64>(),
            admitted_per_shard: per_node
                .iter()
                .flat_map(|s| s.admitted_per_shard.iter().copied())
                .collect(),
            bytes_per_shard: per_node
                .iter()
                .flat_map(|s| s.bytes_per_shard.iter().copied())
                .collect(),
            shard_failed: self.shard_failed,
            node_recovered: self.node_recovered,
            stolen: self.stolen,
            retried_after_fault: self.retried_after_fault,
            dtn_deferred: self.sel.dtn_deferred,
            dtn_overflow_to_funnel: self.sel.dtn_overflow_to_funnel,
            dtn_queued: self.sel.dtn_queued,
        }
    }

    pub fn describe(&self) -> String {
        let sources = if self.dtn_count() > 0 {
            let federation = if self.n_sites() > 1 {
                format!(
                    " across {} sites by {}",
                    self.n_sites(),
                    self.sel.site_selector.label()
                )
            } else {
                String::new()
            };
            format!(
                ", {} over {} dtn(s) by {}{}",
                self.sel.plan.label(),
                self.dtn_count(),
                self.sel.selector.label(),
                federation
            )
        } else {
            String::new()
        };
        format!(
            "pool-router[{} node{}, {}{}, {}]",
            self.nodes.len(),
            if self.nodes.len() == 1 { "" } else { "s" },
            self.policy.label(),
            sources,
            self.nodes
                .first()
                .map(|n| n.describe())
                .unwrap_or_else(|| "empty".into())
        )
    }
}

/// The router is itself a [`DataMover`]: callers that only understand a
/// flat shard namespace see node-major global shard indices.
impl DataMover for PoolRouter {
    fn request(&mut self, req: TransferRequest) -> Vec<Admitted> {
        PoolRouter::request(self, req)
            .into_iter()
            .map(|r| Admitted {
                ticket: r.ticket,
                shard: self.shard_offset(r.node) + r.shard,
            })
            .collect()
    }

    fn complete(&mut self, ticket: u32) -> Vec<Admitted> {
        PoolRouter::complete(self, ticket)
            .into_iter()
            .map(|r| Admitted {
                ticket: r.ticket,
                shard: self.shard_offset(r.node) + r.shard,
            })
            .collect()
    }

    fn active(&self) -> u32 {
        PoolRouter::active(self)
    }

    fn waiting(&self) -> usize {
        PoolRouter::waiting(self)
    }

    fn shard_count(&self) -> usize {
        PoolRouter::shard_count(self)
    }

    fn shard_of(&self, ticket: u32) -> Option<usize> {
        self.global_shard_of(ticket)
    }

    fn stats(&self) -> MoverStats {
        PoolRouter::stats(self)
    }

    fn describe(&self) -> String {
        PoolRouter::describe(self)
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::ThrottlePolicy;

    fn r(t: u32, owner: &str, bytes: u64) -> TransferRequest {
        TransferRequest::new(t, owner, bytes)
    }

    fn rr_router(nodes: u32) -> PoolRouter {
        PoolRouter::sim(
            nodes,
            1,
            ThrottlePolicy::Disabled.into(),
            RouterPolicy::RoundRobin,
        )
    }

    /// Round-robin sim router built through the one-shot config path.
    fn rr_cfg(nodes: u32, cfg: RouterConfig) -> PoolRouter {
        let n = nodes.max(1) as usize;
        let pools = (0..n)
            .map(|_| ShadowPool::sim(1, ThrottlePolicy::Disabled.into()))
            .collect();
        PoolRouter::from_config(pools, vec![1.0; n], RouterPolicy::RoundRobin, cfg)
    }

    #[test]
    fn round_robin_rotates_nodes() {
        let mut router = rr_router(3);
        for t in 0..9 {
            let adm = router.request(r(t, "o", 10));
            assert_eq!(adm.len(), 1);
            assert_eq!(adm[0].node, (t as usize) % 3);
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_node, vec![3, 3, 3]);
        assert_eq!(st.bytes_per_node, vec![30, 30, 30]);
        assert_eq!(st.shard_failed, 0);
    }

    #[test]
    fn least_loaded_prefers_idle_node() {
        let mut router = PoolRouter::sim(
            2,
            1,
            ThrottlePolicy::Disabled.into(),
            RouterPolicy::LeastLoaded,
        );
        let a = router.request(r(0, "o", 1));
        assert_eq!(a[0].node, 0);
        let b = router.request(r(1, "o", 1));
        assert_eq!(b[0].node, 1, "node 0 is busier");
        router.complete(0);
        let c = router.request(r(2, "o", 1));
        assert_eq!(c[0].node, 0, "node 0 drained back to idle");
    }

    #[test]
    fn owner_affinity_is_sticky() {
        let mut router = PoolRouter::sim(
            4,
            1,
            ThrottlePolicy::Disabled.into(),
            RouterPolicy::OwnerAffinity,
        );
        let mut homes: HashMap<String, usize> = HashMap::new();
        for t in 0..40 {
            let owner = format!("user{}", t % 5);
            let adm = router.request(r(t, &owner, 1));
            let node = adm[0].node;
            let prev = homes.entry(owner).or_insert(node);
            assert_eq!(*prev, node, "owner moved nodes");
        }
    }

    #[test]
    fn weighted_by_capacity_splits_proportionally() {
        let nodes = vec![
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
        ];
        let mut router =
            PoolRouter::new(nodes, vec![100.0, 25.0], RouterPolicy::WeightedByCapacity);
        for t in 0..100 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_node[0] + st.routed_per_node[1], 100);
        assert_eq!(st.routed_per_node[0], 80, "100:25 split of 100 requests");
        assert_eq!(st.routed_per_node[1], 20);
    }

    #[test]
    fn fail_node_reroutes_waiting_and_inflight() {
        // Per-node limit 2, so node 0 holds 2 active + a backlog.
        let mut router = PoolRouter::sim(
            2,
            1,
            ThrottlePolicy::MaxConcurrent(2).into(),
            RouterPolicy::RoundRobin,
        );
        for t in 0..10 {
            router.request(r(t, "o", 5));
        }
        assert_eq!(router.active(), 4, "2 per node");
        assert_eq!(router.waiting(), 6);

        let rescued = router.fail_node(0);
        assert!(router.is_failed(0));
        // Node 1 was already at its limit of 2, so nothing admits NOW…
        assert!(rescued.is_empty());
        // …but node 0's whole backlog (3 waiting + 2 in-flight) moved over.
        assert_eq!(router.active(), 2);
        assert_eq!(router.waiting(), 8);
        assert_eq!(router.stats().shard_failed, 1);
        // The re-route corrupted no accounting on the dead node.
        assert_eq!(router.stats().released_without_active, 0);

        // Drain: completing everything on node 1 admits the full backlog.
        let mut done = 0u32;
        let mut pending: Vec<u32> =
            (0..10).filter(|&t| router.global_shard_of(t).is_some()).collect();
        let mut guard = 0;
        while let Some(t) = pending.pop() {
            guard += 1;
            assert!(guard < 100, "drain deadlocked");
            done += 1;
            for a in router.complete(t) {
                assert_eq!(a.node, 1, "survivor serves everything");
                pending.push(a.ticket);
            }
        }
        assert_eq!(done, 10, "every transfer finished despite the dead node");
        assert_eq!(router.active(), 0);
        assert_eq!(router.waiting(), 0);
    }

    #[test]
    fn complete_after_reroute_cancels_instead_of_ghosting() {
        // T1 active on node 0, T2 active on node 1 (limit 1 each).
        let mut router = PoolRouter::sim(
            2,
            1,
            ThrottlePolicy::MaxConcurrent(1).into(),
            RouterPolicy::RoundRobin,
        );
        assert_eq!(router.request(r(1, "o", 1)).len(), 1);
        assert_eq!(router.request(r(2, "o", 1)).len(), 1);
        // Node 0 dies: T1 re-routes to node 1's queue (node 1 is full).
        let rescued = router.fail_node(0);
        assert!(rescued.is_empty(), "survivor is at its limit");
        assert_eq!(router.waiting(), 1, "T1 waits on node 1");
        // T1's original executor reports the (failed) transfer done while
        // T1 still waits — that must cancel the entry, not ghost it.
        assert!(router.complete(1).is_empty());
        assert_eq!(router.waiting(), 0, "waiting entry cancelled");
        let st = router.stats();
        assert_eq!(st.cancelled_waiting, 1);
        assert_eq!(st.released_without_active, 0);
        // Completing T2 must NOT resurrect T1 as an ownerless admission.
        assert!(router.complete(2).is_empty());
        assert_eq!(router.active(), 0);
        assert_eq!(router.waiting(), 0);
        assert_eq!(router.stats().total_admitted, 2);
    }

    #[test]
    fn fail_node_is_idempotent_and_avoids_dead_nodes() {
        let mut router = rr_router(2);
        router.request(r(0, "o", 1));
        assert!(router.fail_node(1).is_empty());
        assert!(router.fail_node(1).is_empty(), "second poison is a no-op");
        assert_eq!(router.stats().shard_failed, 1);
        for t in 1..5 {
            let adm = router.request(r(t, "o", 1));
            assert_eq!(adm[0].node, 0, "round-robin skips the dead node");
        }
    }

    #[test]
    fn all_nodes_failed_strands_requests() {
        let mut router = rr_router(2);
        router.fail_node(0);
        router.fail_node(1);
        assert!(router.request(r(0, "o", 1)).is_empty());
        assert_eq!(router.waiting(), 1);
        assert_eq!(router.router_stats().stranded, 1);
        // A complete for the stranded ticket cancels it — no ghost entry
        // keeps waiting()/stranded overcounting forever.
        assert!(router.complete(0).is_empty());
        assert_eq!(router.waiting(), 0);
        assert_eq!(router.router_stats().stranded, 0);
        assert_eq!(router.stats().cancelled_waiting, 1);
        assert_eq!(router.stats().released_without_active, 0);
    }

    #[test]
    fn aggregate_stats_concat_node_major() {
        let mut router = PoolRouter::sim(
            2,
            2,
            ThrottlePolicy::Disabled.into(),
            RouterPolicy::RoundRobin,
        );
        for t in 0..8 {
            router.request(r(t, "o", 100));
        }
        let st = router.stats();
        assert_eq!(st.admitted_per_shard.len(), 4, "2 nodes × 2 shards");
        assert_eq!(st.total_admitted, 8);
        assert_eq!(st.bytes_per_shard.iter().sum::<u64>(), 800);
        assert_eq!(st.peak_active, 8);
        assert_eq!(st.shard_failed, 0);
        assert_eq!(router.shard_count(), 4);
    }

    #[test]
    fn router_as_dyn_data_mover_uses_global_shards() {
        let mut mover: Box<dyn DataMover> = Box::new(PoolRouter::sim(
            2,
            3,
            ThrottlePolicy::Disabled.into(),
            RouterPolicy::RoundRobin,
        ));
        let a = mover.request(TransferRequest::new(1, "a", 10));
        assert_eq!(a[0].shard, 0, "node 0, local shard 0");
        let b = mover.request(TransferRequest::new(2, "a", 10));
        assert_eq!(b[0].shard, 3, "node 1's shards start at offset 3");
        assert_eq!(mover.shard_count(), 6);
        assert_eq!(mover.shard_of(2), Some(3));
        assert!(mover.describe().contains("pool-router"));
        mover.complete(2);
        assert_eq!(mover.shard_of(2), None);
    }

    #[test]
    fn single_roundtrip_preserves_pool_state() {
        let mut pool = ShadowPool::sim(2, ThrottlePolicy::Disabled.into());
        pool.request(r(7, "o", 42));
        let mut router = PoolRouter::single(pool);
        assert_eq!(router.node_count(), 1);
        assert_eq!(router.active(), 1);
        router.request(r(8, "o", 1));
        let pool = router.into_single().expect("single node");
        assert_eq!(pool.stats().total_admitted, 2);
        assert_eq!(pool.shard_of(7), Some(0));
    }

    #[test]
    fn unrouted_complete_is_counted() {
        let mut router = rr_router(2);
        assert!(router.complete(99).is_empty());
        assert_eq!(router.stats().released_without_active, 1);
    }

    #[test]
    fn policy_parse_and_config() {
        assert_eq!(
            RouterPolicy::parse("round-robin"),
            Some(RouterPolicy::RoundRobin)
        );
        assert_eq!(
            RouterPolicy::parse("WEIGHTED_BY_CAPACITY"),
            Some(RouterPolicy::WeightedByCapacity)
        );
        assert_eq!(RouterPolicy::parse("nope"), None);

        let cfg = Config::parse("N_SUBMIT_NODES = 4\nROUTER_POLICY = OWNER_AFFINITY").unwrap();
        assert_eq!(
            RouterPolicy::from_config(&cfg).unwrap(),
            RouterPolicy::OwnerAffinity
        );
        assert_eq!(RouterPolicy::nodes_from_config(&cfg).unwrap(), 4);

        let dflt = Config::parse("").unwrap();
        assert_eq!(
            RouterPolicy::from_config(&dflt).unwrap(),
            RouterPolicy::LeastLoaded
        );
        assert_eq!(RouterPolicy::nodes_from_config(&dflt).unwrap(), 1);

        let bad = Config::parse("ROUTER_POLICY = HASH").unwrap();
        assert!(RouterPolicy::from_config(&bad).is_err());
    }

    #[test]
    fn recover_node_rejoins_routing_and_unstrands() {
        let mut router = rr_router(2);
        router.fail_node(0);
        router.fail_node(1);
        // Both nodes down: requests strand.
        assert!(router.request(r(0, "o", 1)).is_empty());
        assert!(router.request(r(1, "o", 1)).is_empty());
        assert_eq!(router.router_stats().stranded, 2);
        assert_eq!(router.first_live_node(), None);

        // Recovery re-routes the stranded backlog immediately.
        let admitted = router.recover_node(1);
        assert_eq!(admitted.len(), 2, "stranded requests admit on recovery");
        assert!(admitted.iter().all(|a| a.node == 1));
        assert_eq!(router.router_stats().stranded, 0);
        assert_eq!(router.first_live_node(), Some(1));
        let st = router.stats();
        assert_eq!(st.node_recovered, 1);
        assert_eq!(st.shard_failed, 2);

        // Idempotent: recovering a live node is a no-op.
        assert!(router.recover_node(1).is_empty());
        assert_eq!(router.stats().node_recovered, 1);

        // New requests route again (only node 1 is live).
        let adm = router.request(r(2, "o", 1));
        assert_eq!(adm[0].node, 1);
    }

    #[test]
    fn fail_node_counts_inflight_retries() {
        let mut router = PoolRouter::sim(
            2,
            1,
            ThrottlePolicy::MaxConcurrent(2).into(),
            RouterPolicy::RoundRobin,
        );
        for t in 0..8 {
            router.request(r(t, "o", 1));
        }
        // Node 0: 2 in-flight + 2 waiting. Only the in-flight pair counts
        // as retried (their executors must re-run them); the waiting pair
        // just moves queues.
        router.fail_node(0);
        let st = router.stats();
        assert_eq!(st.retried_after_fault, 2);
        assert_eq!(st.shard_failed, 1);
    }

    #[test]
    fn rebalance_steals_until_gap_within_threshold() {
        // Owner-affinity with one owner piles everything on one node.
        let mut router = PoolRouter::sim(
            3,
            1,
            ThrottlePolicy::MaxConcurrent(1).into(),
            RouterPolicy::OwnerAffinity,
        );
        for t in 0..16 {
            router.request(r(t, "alice", 1));
        }
        let lens = router.waiting_per_node();
        assert_eq!(lens.iter().sum::<usize>(), 15, "1 active + 15 waiting");
        assert_eq!(lens.iter().filter(|&&l| l > 0).count(), 1, "one hot node");

        let admitted = router.rebalance(2);
        // The two idle nodes each admit a stolen transfer immediately…
        assert_eq!(admitted.len(), 2);
        // …and the queues settle within the threshold.
        let lens = router.waiting_per_node();
        let max = lens.iter().max().unwrap();
        let min = lens.iter().min().unwrap();
        assert!(max - min <= 2, "imbalance {lens:?} above threshold");
        assert!(router.stats().stolen > 0);
        // Nothing lost or duplicated: 3 active + waiting == 16.
        assert_eq!(router.active() as usize + router.waiting(), 16);

        // A second pass is a no-op (already balanced).
        let before = router.stats().stolen;
        assert!(router.rebalance(2).is_empty());
        assert_eq!(router.stats().stolen, before);
    }

    #[test]
    fn rebalance_zero_threshold_terminates() {
        let mut router = PoolRouter::sim(
            2,
            1,
            ThrottlePolicy::MaxConcurrent(1).into(),
            RouterPolicy::OwnerAffinity,
        );
        for t in 0..6 {
            router.request(r(t, "bob", 1));
        }
        router.rebalance(0);
        let lens = router.waiting_per_node();
        let max = lens.iter().max().unwrap();
        let min = lens.iter().min().unwrap();
        assert!(max - min <= 1, "gap {lens:?} not minimal");
    }

    #[test]
    fn funnel_plan_sources_on_schedule_node() {
        let mut router = rr_router(2);
        for t in 0..4 {
            let adm = router.request(r(t, "o", 10));
            assert_eq!(
                adm[0].source,
                DataSource::Funnel { node: adm[0].node },
                "default plan serves bytes from the scheduling node"
            );
        }
        let st = router.router_stats();
        assert!(st.routed_per_dtn.is_empty());
        assert_eq!(st.dtn_failed, 0);
    }

    #[test]
    fn dedicated_dtn_round_robins_live_fleet() {
        let mut router = rr_cfg(
            2,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 3],
                ..RouterConfig::default()
            },
        );
        assert_eq!(router.dtn_count(), 3);
        for t in 0..6 {
            let adm = router.request(r(t, "o", 10));
            assert_eq!(
                adm[0].source,
                DataSource::Dtn {
                    dtn: (t as usize) % 3
                },
                "round-robin over the fleet"
            );
            assert_eq!(router.source_of(t), Some(adm[0].source));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_dtn, vec![2, 2, 2]);
        assert_eq!(st.bytes_per_dtn, vec![20, 20, 20]);
        // Completion clears the source bookkeeping.
        router.complete(0);
        assert_eq!(router.source_of(0), None);
    }

    #[test]
    fn hybrid_respects_threshold_at_the_boundary() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::Hybrid { threshold: 100 },
                dtn_capacity: vec![1.0; 2],
                ..RouterConfig::default()
            },
        );
        let small = router.request(r(0, "o", 99));
        assert_eq!(small[0].source, DataSource::Funnel { node: 0 });
        let exact = router.request(r(1, "o", 100));
        assert!(exact[0].source.is_dtn(), "bytes == threshold goes via DTN");
        let big = router.request(r(2, "o", 101));
        assert!(big[0].source.is_dtn());
    }

    #[test]
    fn fail_dtn_resources_inflight_then_fails_over_to_funnel() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 2],
                ..RouterConfig::default()
            },
        );
        for t in 0..4 {
            router.request(r(t, "o", 5));
        }
        // Tickets 0,2 sit on dtn 0; 1,3 on dtn 1.
        let moved = router.fail_dtn(0);
        assert_eq!(moved.len(), 2, "dtn 0's transfers re-source");
        for m in &moved {
            assert_eq!(m.source, DataSource::Dtn { dtn: 1 });
            assert_eq!(m.node, 0, "admission (schedule node) is untouched");
        }
        assert!(router.is_dtn_failed(0));
        assert!(router.fail_dtn(0).is_empty(), "second poison is a no-op");
        let st = router.stats();
        assert_eq!(st.retried_after_fault, 2);
        assert_eq!(router.router_stats().dtn_failed, 1);
        // Admission accounting never moved: everything still active.
        assert_eq!(router.active(), 4);

        // The whole fleet dies: re-sourcing falls back to the funnel.
        let moved = router.fail_dtn(1);
        assert_eq!(moved.len(), 4, "all four were on dtn 1 by now");
        assert!(moved
            .iter()
            .all(|m| m.source == DataSource::Funnel { node: 0 }));
        let adm = router.request(r(9, "o", 5));
        assert_eq!(
            adm[0].source,
            DataSource::Funnel { node: 0 },
            "new admissions also fail over to the funnel"
        );

        // Recovery: the fleet serves again.
        router.recover_dtn(0);
        assert!(!router.is_dtn_failed(0));
        let adm = router.request(r(10, "o", 5));
        assert_eq!(adm[0].source, DataSource::Dtn { dtn: 0 });
        assert_eq!(router.router_stats().dtn_recovered, 1);
        router.recover_dtn(0);
        assert_eq!(
            router.router_stats().dtn_recovered,
            1,
            "recover is idempotent"
        );
    }

    #[test]
    fn rr_cursor_survives_fleet_churn_and_funnel_failover() {
        // Regression: the hybrid plan's all-DTNs-dead funnel failover
        // must neither reset nor advance the round-robin cursor, so the
        // rotation resumes exactly where it left off after recovery.
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::Hybrid { threshold: 100 },
                dtn_capacity: vec![1.0; 3],
                ..RouterConfig::default()
            },
        );
        assert_eq!(router.request(r(0, "o", 100))[0].source, DataSource::Dtn { dtn: 0 });
        assert_eq!(router.request(r(1, "o", 100))[0].source, DataSource::Dtn { dtn: 1 });
        // Nothing in flight when the fleet dies (in-flight re-sources
        // are themselves rotation picks and legitimately advance it).
        router.complete(0);
        router.complete(1);
        // Small sandboxes ride the funnel without consuming rotation
        // slots...
        assert_eq!(router.request(r(2, "o", 99))[0].source, DataSource::Funnel { node: 0 });
        // ...and so do large ones while the whole fleet is dead.
        router.fail_dtn(0);
        router.fail_dtn(1);
        router.fail_dtn(2);
        assert_eq!(router.request(r(3, "o", 100))[0].source, DataSource::Funnel { node: 0 });
        assert_eq!(router.request(r(4, "o", 100))[0].source, DataSource::Funnel { node: 0 });
        router.recover_dtn(0);
        router.recover_dtn(1);
        router.recover_dtn(2);
        // After d1 comes d2: the failover episode did not skew the
        // rotation.
        assert_eq!(router.request(r(5, "o", 100))[0].source, DataSource::Dtn { dtn: 2 });
        assert_eq!(router.request(r(6, "o", 100))[0].source, DataSource::Dtn { dtn: 0 });
    }

    #[test]
    fn dtn_budget_defers_then_overflows_to_funnel() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 2],
                dtn_slots: 1,
                ..RouterConfig::default()
            },
        );
        assert_eq!(router.dtn_budget(), 1);
        // Two admissions fill both data nodes' single slots.
        assert_eq!(router.request(r(0, "o", 5))[0].source, DataSource::Dtn { dtn: 0 });
        assert_eq!(router.request(r(1, "o", 5))[0].source, DataSource::Dtn { dtn: 1 });
        assert_eq!(router.dtn_active_per_node(), vec![1, 1]);
        // The fleet is saturated: the next transfer overflows to the
        // funnel (its schedule-node admission already gated it).
        assert_eq!(router.request(r(2, "o", 5))[0].source, DataSource::Funnel { node: 0 });
        let st = router.stats();
        assert_eq!(st.dtn_overflow_to_funnel, 1);
        assert_eq!(st.dtn_deferred, 1, "the preferred node pushed back first");
        // Completion frees dtn 0's slot. The overflow rewound the
        // rotation cursor (funnel placements never skew it), so the
        // rotation prefers dtn 0 directly — no deferral this time.
        router.complete(0);
        assert_eq!(router.dtn_active_per_node(), vec![0, 1]);
        let adm = router.request(r(3, "o", 5));
        assert_eq!(adm[0].source, DataSource::Dtn { dtn: 0 });
        let st = router.stats();
        assert_eq!(st.dtn_deferred, 1, "the restored rotation hit a free slot");
        assert_eq!(st.dtn_overflow_to_funnel, 1);
        // The funnel-overflowed ticket holds no DTN slot to release.
        router.complete(2);
        assert_eq!(router.dtn_active_per_node(), vec![1, 1]);
    }

    #[test]
    fn cache_aware_selector_homes_extents_and_forgets_on_kill() {
        use crate::storage::ExtentId;
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 3],
                source_selector: SourceSelector::CacheAware,
                ..RouterConfig::default()
            },
        );
        // Pre-warmed residency wins over the rotation.
        router.note_extent_resident(2, ExtentId(7));
        let req = |t: u32, e: u64| r(t, "o", 10).with_extent(ExtentId(e));
        assert_eq!(router.request(req(0, 7))[0].source, DataSource::Dtn { dtn: 2 });
        // An unknown extent takes the rotation and becomes sticky there.
        let first = router.request(req(1, 3))[0].source;
        assert_eq!(first, DataSource::Dtn { dtn: 0 });
        assert_eq!(router.request(req(2, 3))[0].source, first, "extent homed");
        // A kill clears the dead node's residency: the extent re-homes
        // on a live node and sticks to it.
        router.complete(1);
        router.complete(2);
        router.fail_dtn(0);
        let rehomed = router.request(req(3, 3))[0].source;
        assert!(matches!(rehomed, DataSource::Dtn { dtn } if dtn != 0));
        router.recover_dtn(0);
        assert_eq!(
            router.request(req(4, 3))[0].source,
            rehomed,
            "no flap-back to the recovered node"
        );
        // The sim's truth re-sync replaces the residency view wholesale.
        router.set_dtn_residency(1, &[ExtentId(9)]);
        assert_eq!(router.request(req(5, 9))[0].source, DataSource::Dtn { dtn: 1 });
    }

    #[test]
    fn owner_affinity_selector_pins_and_repins_on_kill() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 3],
                source_selector: SourceSelector::OwnerAffinity,
                ..RouterConfig::default()
            },
        );
        let first = router.request(r(0, "alice", 10))[0].source;
        let DataSource::Dtn { dtn: home } = first else {
            panic!("dedicated plan placed {first:?}");
        };
        assert_eq!(router.dtn_pin_of("alice"), Some(home));
        for t in 1..6 {
            assert_eq!(router.request(r(t, "alice", 10))[0].source, first);
        }
        // Kill the pinned node: its in-flight transfers re-source AND
        // re-pin the owner onto one stable live node.
        let moved = router.fail_dtn(home);
        assert_eq!(moved.len(), 6, "alice's whole in-flight set re-sources");
        let new_home = router.dtn_pin_of("alice").expect("re-pinned");
        assert_ne!(new_home, home);
        assert!(moved
            .iter()
            .all(|m| m.source == DataSource::Dtn { dtn: new_home }));
        // The new pin survives the old node's recovery (no flap-back).
        router.recover_dtn(home);
        assert_eq!(
            router.request(r(6, "alice", 10))[0].source,
            DataSource::Dtn { dtn: new_home }
        );
        assert_eq!(router.stats().retried_after_fault, 6);
    }

    #[test]
    fn weighted_selector_splits_by_dtn_capacity() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![100.0, 25.0],
                source_selector: SourceSelector::WeightedByCapacity,
                ..RouterConfig::default()
            },
        );
        for t in 0..100 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_dtn, vec![80, 20], "100:25 split of 100 requests");
        // A chaos re-rate shifts the split for the next batch.
        router.set_dtn_capacity(0, 25.0);
        for t in 100..200 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_dtn[0] - 80, 50, "even split after degrade");
        assert_eq!(st.routed_per_dtn[1] - 20, 50);
    }

    #[test]
    fn output_source_prefers_live_preferred_then_survivor_then_funnel() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 2],
                ..RouterConfig::default()
            },
        );
        let d0 = DataSource::Dtn { dtn: 0 };
        assert_eq!(router.output_source(d0, 0), d0, "live preferred wins");
        router.fail_dtn(0);
        assert_eq!(
            router.output_source(d0, 0),
            DataSource::Dtn { dtn: 1 },
            "survivor replaces the dead preferred"
        );
        router.fail_dtn(1);
        assert_eq!(
            router.output_source(d0, 0),
            DataSource::Funnel { node: 0 },
            "funnel is the last resort"
        );
        let funnel = DataSource::Funnel { node: 0 };
        assert_eq!(router.output_source(funnel, 0), funnel);
    }

    #[test]
    fn output_failover_spreads_across_survivors() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 4],
                ..RouterConfig::default()
            },
        );
        router.fail_dtn(0);
        let mut counts = [0u32; 4];
        for _ in 0..30 {
            match router.output_source(DataSource::Dtn { dtn: 0 }, 0) {
                DataSource::Dtn { dtn } => counts[dtn] += 1,
                other => panic!("expected a DTN failover, got {other:?}"),
            }
        }
        assert_eq!(counts[0], 0, "dead node serves nothing");
        for (d, &c) in counts.iter().enumerate().skip(1) {
            assert_eq!(c, 10, "rotation spreads outputs evenly, dtn {d} got {c}");
        }
    }

    #[test]
    fn output_failover_follows_weighted_selector() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0, 75.0, 25.0],
                source_selector: SourceSelector::WeightedByCapacity,
                ..RouterConfig::default()
            },
        );
        router.fail_dtn(0);
        let mut counts = [0u32; 3];
        for _ in 0..100 {
            match router.output_source(DataSource::Dtn { dtn: 0 }, 0) {
                DataSource::Dtn { dtn } => counts[dtn] += 1,
                other => panic!("expected a DTN failover, got {other:?}"),
            }
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 75, "capacity-weighted failover split");
        assert_eq!(counts[2], 25);
    }

    #[test]
    fn output_failover_prefers_free_admission_slots() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 3],
                dtn_slots: 1,
                ..RouterConfig::default()
            },
        );
        // Saturate dtn 1's only slot, then kill dtn 0: the rotation
        // would hand the next failover to dtn 1, but the budget scan
        // steers it to dtn 2's free slot instead.
        for t in 0..3 {
            let adm = router.request(r(t, "o", 10));
            assert_eq!(adm[0].source, DataSource::Dtn { dtn: t as usize });
        }
        router.complete(0);
        router.complete(2);
        router.fail_dtn(0);
        assert_eq!(
            router.output_source(DataSource::Dtn { dtn: 0 }, 0),
            DataSource::Dtn { dtn: 2 },
            "budget-aware scan skips the saturated survivor"
        );
    }

    #[test]
    fn recovery_ramp_rebuilds_weight_gradually() {
        let nodes = vec![
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
        ];
        let mut router = PoolRouter::from_config(
            nodes,
            vec![100.0, 100.0],
            RouterPolicy::WeightedByCapacity,
            RouterConfig {
                recovery_ramp: 40,
                ..RouterConfig::default()
            },
        );
        router.fail_node(1);
        router.recover_node(1);
        // First batch: node 1 is still ramping, so node 0 carries more.
        for t in 0..40 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert!(
            st.routed_per_node[0] > st.routed_per_node[1],
            "ramping node under-weighted: {:?}",
            st.routed_per_node
        );
        // After the ramp the split returns to even.
        let before = router.router_stats().routed_per_node.clone();
        for t in 40..140 {
            router.request(r(t, "o", 1));
        }
        let after = router.router_stats().routed_per_node.clone();
        let d0 = after[0] - before[0];
        let d1 = after[1] - before[1];
        assert!(
            d0.abs_diff(d1) <= 2,
            "post-ramp split should be even: +{d0} vs +{d1}"
        );
    }

    #[test]
    fn zero_ramp_step_restores_weight() {
        let nodes = vec![
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
        ];
        let mut router =
            PoolRouter::new(nodes, vec![100.0, 100.0], RouterPolicy::WeightedByCapacity);
        router.fail_node(1);
        router.recover_node(1);
        for t in 0..100 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_node, vec![50, 50], "no ramp: instant even split");
    }

    #[test]
    fn degraded_capacity_shifts_weighted_routing() {
        let nodes = vec![
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
            ShadowPool::sim(1, ThrottlePolicy::Disabled.into()),
        ];
        let mut router =
            PoolRouter::new(nodes, vec![100.0, 100.0], RouterPolicy::WeightedByCapacity);
        router.set_node_capacity(1, 25.0);
        for t in 0..100 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_node[0], 80, "100:25 after degrade");
        assert_eq!(st.routed_per_node[1], 20);

        // Recovery restores the as-built weight (even on a live node),
        // so the next batch splits evenly again.
        assert!(router.recover_node(1).is_empty(), "live node: admits nothing");
        for t in 100..200 {
            router.request(r(t, "o", 1));
        }
        let st = router.router_stats();
        assert_eq!(st.routed_per_node[0] - 80, 50, "even split after restore");
        assert_eq!(st.routed_per_node[1] - 20, 50);
    }

    #[test]
    fn dtn_wait_queue_holds_then_promotes() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 2],
                dtn_slots: 1,
                dtn_queue_depth: 1,
                ..RouterConfig::default()
            },
        );
        assert_eq!(router.dtn_queue_depth(), 1);
        // t0/t1 take the two slots; t2/t3 queue (one per DTN); t4 finds
        // every slot AND every queue full and overflows to the funnel.
        for t in 0..4 {
            let adm = router.request(r(t, "o", 10));
            assert!(matches!(adm[0].source, DataSource::Dtn { .. }));
        }
        assert_eq!(router.dtn_active_per_node(), vec![1, 1]);
        assert_eq!(router.dtn_queued_per_node(), vec![1, 1]);
        let adm = router.request(r(4, "o", 10));
        assert_eq!(adm[0].source, DataSource::Funnel { node: 0 });
        let st = router.stats();
        assert_eq!(st.dtn_queued, 2, "two tickets rode the wait queues");
        assert_eq!(st.dtn_overflow_to_funnel, 1, "funnel only when queues full");
        // Completing a slot holder promotes that DTN's queued ticket
        // into the freed slot.
        router.complete(0);
        assert_eq!(router.dtn_active_per_node(), vec![1, 1]);
        assert_eq!(router.dtn_queued_per_node(), vec![0, 1]);
    }

    #[test]
    fn completing_queued_ticket_frees_queue_entry() {
        let mut router = rr_cfg(
            1,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 1],
                dtn_slots: 1,
                dtn_queue_depth: 2,
                ..RouterConfig::default()
            },
        );
        for t in 0..3 {
            router.request(r(t, "o", 10));
        }
        assert_eq!(router.dtn_active_per_node(), vec![1]);
        assert_eq!(router.dtn_queued_per_node(), vec![2]);
        // A queued ticket cancelled mid-wait must not free a slot…
        router.complete(1);
        assert_eq!(router.dtn_active_per_node(), vec![1]);
        assert_eq!(router.dtn_queued_per_node(), vec![1]);
        // …and the slot holder's completion promotes the survivor.
        router.complete(0);
        assert_eq!(router.dtn_active_per_node(), vec![1]);
        assert_eq!(router.dtn_queued_per_node(), vec![0]);
    }

    #[test]
    fn route_batch_matches_single_routing() {
        let build = || {
            let pools = (0..3)
                .map(|_| ShadowPool::sim(2, ThrottlePolicy::MaxConcurrent(2).into()))
                .collect();
            PoolRouter::from_config(
                pools,
                vec![1.0; 3],
                RouterPolicy::LeastLoaded,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; 2],
                    dtn_slots: 2,
                    ..RouterConfig::default()
                },
            )
        };
        let reqs: Vec<TransferRequest> = (0..40)
            .map(|t| r(t, ["a", "b", "c"][t as usize % 3], 10 + t as u64))
            .collect();
        let mut singles = build();
        let mut one_by_one = Vec::new();
        for req in reqs.clone() {
            one_by_one.extend(singles.request(req));
        }
        let mut batched = build();
        let cycle = batched.route_batch(reqs);
        assert_eq!(cycle, one_by_one, "one cycle ≡ the same singles in order");
        assert_eq!(batched.stats(), singles.stats());
        let done: Vec<u32> = (0..40).collect();
        let mut singles_out = Vec::new();
        for &t in &done {
            singles_out.extend(singles.complete(t));
        }
        assert_eq!(batched.complete_batch(&done), singles_out);
        assert_eq!(batched.stats(), singles.stats());
    }

    #[test]
    fn state_shards_do_not_change_decisions() {
        let run = |shards: usize| {
            let pools = (0..4)
                .map(|_| ShadowPool::sim(1, ThrottlePolicy::MaxConcurrent(3).into()))
                .collect();
            let mut router = PoolRouter::from_config(
                pools,
                vec![1.0; 4],
                RouterPolicy::OwnerAffinity,
                RouterConfig {
                    source_plan: SourcePlan::DedicatedDtn,
                    dtn_capacity: vec![1.0; 3],
                    source_selector: SourceSelector::OwnerAffinity,
                    state_shards: shards,
                    ..RouterConfig::default()
                },
            );
            let mut out = Vec::new();
            for t in 0..60 {
                out.extend(router.request(r(t, &format!("u{}", t % 7), 10)));
            }
            out.extend(router.fail_node(1));
            out.extend(router.fail_dtn(0));
            for t in 0..30 {
                out.extend(router.complete(t));
            }
            out.extend(router.recover_node(1));
            router.recover_dtn(0);
            for t in 60..90 {
                out.extend(router.request(r(t, &format!("u{}", t % 7), 10)));
            }
            (out, router.stats())
        };
        let (routed_1, stats_1) = run(1);
        for k in [2, 7, DEFAULT_ROUTER_SHARDS] {
            let (routed_k, stats_k) = run(k);
            assert_eq!(routed_k, routed_1, "sharding is pure partitioning (K={k})");
            assert_eq!(stats_k, stats_1);
        }
    }

    /// Round-robin-routed pool with `nodes` submit nodes and a DTN
    /// fleet split over `n_sites` federation sites.
    fn site_router(nodes: u32, dtns: usize, n_sites: usize, site_sel: SiteSelector) -> PoolRouter {
        rr_cfg(
            nodes,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; dtns],
                n_sites,
                site_selector: site_sel,
                ..RouterConfig::default()
            },
        )
    }

    #[test]
    fn local_first_stays_site_local_until_the_site_dies() {
        // 2 submit nodes / 4 DTNs / 2 sites: node 0 + DTNs {0,1} are
        // site 0, node 1 + DTNs {2,3} are site 1.
        let mut router = site_router(2, 4, 2, SiteSelector::LocalFirst);
        assert_eq!(router.n_sites(), 2);
        assert_eq!(router.site_of_node(0), 0);
        assert_eq!(router.site_of_node(1), 1);
        assert_eq!(
            (0..4).map(|d| router.site_of_dtn(d)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1]
        );
        // The round-robin router alternates schedule nodes; each
        // admission's bytes stay inside the scheduling node's site.
        for t in 0..8 {
            let adm = router.request(r(t, "o", 10));
            let DataSource::Dtn { dtn } = adm[0].source else {
                panic!("dedicated plan placed {:?}", adm[0].source);
            };
            assert_eq!(
                router.site_of_dtn(dtn),
                router.site_of_node(adm[0].node),
                "local-first crossed the WAN with a live local fleet"
            );
        }
        // Site 0's fleet dies: node 0's admissions now cross the WAN.
        router.fail_dtn(0);
        router.fail_dtn(1);
        let adm = router.request(r(100, "o", 10));
        if adm[0].node == 0 {
            assert!(matches!(adm[0].source, DataSource::Dtn { dtn } if dtn >= 2));
        }
    }

    #[test]
    fn site_round_robin_carries_every_pair() {
        // One submit node, 4 DTNs over 2 sites, rotating sites: the
        // placement alternates site 0 / site 1 regardless of locality.
        let mut router = site_router(1, 4, 2, SiteSelector::RoundRobin);
        let mut per_site = [0u32; 2];
        for t in 0..8 {
            let adm = router.request(r(t, "o", 10));
            let DataSource::Dtn { dtn } = adm[0].source else {
                panic!("expected a DTN placement");
            };
            per_site[router.site_of_dtn(dtn)] += 1;
        }
        assert_eq!(per_site, [4, 4], "site rotation splits evenly");
    }

    #[test]
    fn cache_aware_site_selection_follows_the_extent_home() {
        use crate::storage::ExtentId;
        let mut router = rr_cfg(
            2,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 4],
                source_selector: SourceSelector::CacheAware,
                n_sites: 2,
                site_selector: SiteSelector::CacheAware,
                ..RouterConfig::default()
            },
        );
        // Extent 7 is hot only on dtn 3 (site 1): even node 0 (site 0)
        // crosses the WAN to the cached replica.
        router.note_extent_resident(3, ExtentId(7));
        let adm = router.request(r(0, "o", 10).with_extent(ExtentId(7)));
        assert_eq!(adm[0].node, 0, "round-robin starts at node 0");
        assert_eq!(adm[0].source, DataSource::Dtn { dtn: 3 });
        // An unhomed extent stays site-local (and then homes there).
        let adm = router.request(r(1, "o", 10).with_extent(ExtentId(9)));
        assert_eq!(adm[0].node, 1);
        let DataSource::Dtn { dtn } = adm[0].source else {
            panic!("expected a DTN placement");
        };
        assert_eq!(router.site_of_dtn(dtn), 1, "unhomed extent stays local");
    }

    #[test]
    fn fail_site_drains_dtns_and_submit_nodes_to_survivors() {
        let mut router = site_router(2, 4, 2, SiteSelector::LocalFirst);
        for t in 0..8 {
            router.request(r(t, "o", 10));
        }
        assert_eq!(router.active(), 8);
        let moved = router.fail_site(0);
        // Site 0's four transfers re-drive: their bytes re-source onto
        // site 1's DTNs and their admissions re-route to node 1.
        assert_eq!(moved.len(), 4, "site 0's transfers re-drive");
        for m in &moved {
            assert_eq!(m.node, 1, "survivor site schedules everything");
            assert!(
                matches!(m.source, DataSource::Dtn { dtn } if router.site_of_dtn(dtn) == 1),
                "re-sourced bytes must come from the surviving site"
            );
        }
        assert!(router.is_failed(0));
        assert!(router.is_dtn_failed(0) && router.is_dtn_failed(1));
        assert!(!router.is_dtn_failed(2) && !router.is_dtn_failed(3));
        // Exact slot accounting: nothing lost, nothing duplicated.
        assert_eq!(router.active(), 8);
        assert_eq!(router.waiting(), 0);
        assert!(router.fail_site(0).is_empty(), "idempotent per site");
        let st = router.router_stats();
        assert_eq!(st.dtn_failed, 2);
        assert_eq!(st.shard_failed, 1);

        // Recovery: the site rejoins scheduling and source selection.
        assert!(router.recover_site(0).is_empty(), "no stranded work");
        assert!(!router.is_failed(0));
        assert!(!router.is_dtn_failed(0));
        let adm = router.request(r(100, "o", 10));
        assert_eq!(adm[0].node, 0, "round-robin resumes on the recovered node");
        assert_eq!(router.router_stats().dtn_recovered, 2);
    }

    #[test]
    fn saturated_site_overflows_to_funnel_not_across_the_wan() {
        // 2 sites × 1 DTN, one slot each, local-first: when node 0's
        // local DTN is at budget the transfer overflows to the funnel
        // rather than silently paying WAN cost on the remote site.
        let mut router = rr_cfg(
            2,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0; 2],
                dtn_slots: 1,
                n_sites: 2,
                site_selector: SiteSelector::LocalFirst,
                ..RouterConfig::default()
            },
        );
        // t0 → node 0 / dtn 0 (site 0), t1 → node 1 / dtn 1 (site 1).
        assert_eq!(router.request(r(0, "o", 5))[0].source, DataSource::Dtn { dtn: 0 });
        assert_eq!(router.request(r(1, "o", 5))[0].source, DataSource::Dtn { dtn: 1 });
        // t2 schedules on node 0 again; its site's only DTN is full and
        // the remote site is NOT an overflow target.
        let adm = router.request(r(2, "o", 5));
        assert_eq!(adm[0].node, 0);
        assert_eq!(adm[0].source, DataSource::Funnel { node: 0 });
        let st = router.stats();
        assert_eq!(st.dtn_overflow_to_funnel, 1);
        // Once a site's whole fleet is DEAD (not just saturated),
        // liveness wins over locality and the WAN carries the bytes:
        // whichever node schedules t3, only site 1's DTN can serve it.
        router.complete(0);
        router.complete(1);
        router.fail_dtn(0);
        let adm = router.request(r(3, "o", 5));
        assert_eq!(adm[0].source, DataSource::Dtn { dtn: 1 });
    }
}
