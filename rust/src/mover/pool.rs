//! The sharded shadow pool: the one [`DataMover`] implementation both
//! fabrics consume.
//!
//! HTCondor forks one *shadow* process per running job on the submit
//! node; the paper's observation is that the submit node — not the
//! shadows — is the funnel. The seed reproduction narrowed that funnel
//! further by routing every connection's sealing through a single
//! crypto-service thread. `ShadowPool` generalizes both: admitted
//! transfers are assigned to one of N shadow shards (least-loaded first),
//! and in real mode each shard owns a dedicated
//! [`EngineService`](crate::runtime::service::EngineService) — its own
//! [`SealEngine`](crate::runtime::engine::SealEngine) on its own thread —
//! so sealing scales with the shard count instead of serializing.
//!
//! In sim mode no engine threads are spawned; shards are an accounting
//! and admission structure (per-shard byte routing feeds the report and
//! the multi-shard scaling scenarios).

use super::policy::AdmissionConfig;
use super::queue::AdmissionQueue;
use super::{Admitted, DataMover, MoverStats, TransferRequest};
use crate::runtime::engine::SealEngine;
use crate::runtime::service::{EngineHandle, EngineService};
use anyhow::Result;
use std::collections::HashMap;

/// A sharded, policy-driven data mover. See the module docs.
pub struct ShadowPool {
    queue: AdmissionQueue,
    config: AdmissionConfig,
    /// Shard serving each admitted, not-yet-completed ticket.
    assignment: HashMap<u32, usize>,
    active_per_shard: Vec<u32>,
    admitted_per_shard: Vec<u64>,
    bytes_per_shard: Vec<u64>,
    /// One crypto service per shard in real mode; empty in sim mode.
    engines: Vec<EngineService>,
}

impl std::fmt::Debug for ShadowPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShadowPool")
            .field("shards", &self.active_per_shard.len())
            .field("policy", &self.queue.policy_desc())
            .field("active", &self.queue.active())
            .field("waiting", &self.queue.waiting())
            .field("engines", &self.engines.len())
            .finish()
    }
}

impl ShadowPool {
    /// A simulation-mode pool: admission + shard accounting, no engine
    /// threads.
    pub fn sim(shards: u32, config: AdmissionConfig) -> ShadowPool {
        let n = shards.max(1) as usize;
        ShadowPool {
            queue: AdmissionQueue::new(config.build()),
            config,
            assignment: HashMap::new(),
            active_per_shard: vec![0; n],
            admitted_per_shard: vec![0; n],
            bytes_per_shard: vec![0; n],
            engines: Vec::new(),
        }
    }

    /// A real-mode pool: one [`EngineService`] (dedicated seal-engine
    /// thread) per shard, built by `factory(shard)` inside each service
    /// thread (so non-`Send` engines work).
    pub fn with_engines<F>(shards: u32, config: AdmissionConfig, factory: F) -> ShadowPool
    where
        F: Fn(usize) -> Result<Box<dyn SealEngine>> + Send + Clone + 'static,
    {
        let mut pool = ShadowPool::sim(shards, config);
        pool.spawn_engines(factory);
        pool
    }

    /// Spawn per-shard engine services if none exist yet (idempotent).
    /// Lets a sim-mode pool be handed to the real fabric afterwards —
    /// admission state and statistics carry over.
    pub fn ensure_engines<F>(&mut self, factory: F)
    where
        F: Fn(usize) -> Result<Box<dyn SealEngine>> + Send + Clone + 'static,
    {
        if self.engines.is_empty() {
            self.spawn_engines(factory);
        }
    }

    fn spawn_engines<F>(&mut self, factory: F)
    where
        F: Fn(usize) -> Result<Box<dyn SealEngine>> + Send + Clone + 'static,
    {
        let n = self.active_per_shard.len();
        self.engines = (0..n)
            .map(|shard| {
                let f = factory.clone();
                EngineService::spawn(move || f(shard))
            })
            .collect();
    }

    /// Per-shard seal-engine handles (empty in sim mode). Index = shard.
    pub fn handles(&self) -> Vec<EngineHandle> {
        self.engines.iter().map(|e| e.handle()).collect()
    }

    /// The admission configuration this pool was built with.
    pub fn config(&self) -> &AdmissionConfig {
        &self.config
    }

    /// Remove and return every waiting request (failover drain — see
    /// [`PoolRouter::fail_node`](super::PoolRouter::fail_node)). Waiting
    /// requests have no shard assignment yet, so only the queue empties.
    pub fn drain_waiting(&mut self) -> Vec<TransferRequest> {
        self.queue.drain_waiting()
    }

    /// Remove and return the most recently queued waiting request (the
    /// router's work-stealing path — see
    /// [`PoolRouter::rebalance`](super::PoolRouter::rebalance)). The head
    /// of the queue keeps its admission priority.
    pub fn steal_waiting(&mut self) -> Option<TransferRequest> {
        self.queue.steal_waiting()
    }

    /// Least-loaded shard (fewest active transfers; ties → lowest index).
    fn pick_shard(&self) -> usize {
        self.active_per_shard
            .iter()
            .enumerate()
            .min_by_key(|(_, &a)| a)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn assign(&mut self, admitted: Vec<TransferRequest>) -> Vec<Admitted> {
        admitted
            .into_iter()
            .map(|req| {
                let shard = self.pick_shard();
                self.active_per_shard[shard] += 1;
                self.admitted_per_shard[shard] += 1;
                self.bytes_per_shard[shard] += req.bytes;
                self.assignment.insert(req.ticket, shard);
                Admitted {
                    ticket: req.ticket,
                    shard,
                }
            })
            .collect()
    }

    // Inherent mirrors of the DataMover methods so callers holding the
    // concrete type need no trait import.

    pub fn request(&mut self, req: TransferRequest) -> Vec<Admitted> {
        let admitted = self.queue.enqueue(req);
        self.assign(admitted)
    }

    pub fn complete(&mut self, ticket: u32) -> Vec<Admitted> {
        if let Some(shard) = self.assignment.remove(&ticket) {
            self.active_per_shard[shard] = self.active_per_shard[shard].saturating_sub(1);
        }
        let admitted = self.queue.complete(ticket);
        self.assign(admitted)
    }

    pub fn active(&self) -> u32 {
        self.queue.active()
    }

    pub fn waiting(&self) -> usize {
        self.queue.waiting()
    }

    pub fn shard_count(&self) -> usize {
        self.active_per_shard.len()
    }

    pub fn shard_of(&self, ticket: u32) -> Option<usize> {
        self.assignment.get(&ticket).copied()
    }

    pub fn stats(&self) -> MoverStats {
        MoverStats {
            peak_active: self.queue.peak_active,
            total_admitted: self.queue.total_admitted,
            released_without_active: self.queue.released_without_active,
            cancelled_waiting: self.queue.cancelled_waiting,
            admitted_per_shard: self.admitted_per_shard.clone(),
            bytes_per_shard: self.bytes_per_shard.clone(),
            shard_failed: 0,
            node_recovered: 0,
            stolen: 0,
            retried_after_fault: 0,
            dtn_deferred: 0,
            dtn_overflow_to_funnel: 0,
            dtn_queued: 0,
        }
    }

    pub fn describe(&self) -> String {
        format!(
            "shadow-pool[{} shard{}, {}, {}]",
            self.shard_count(),
            if self.shard_count() == 1 { "" } else { "s" },
            self.queue.policy_desc(),
            if self.engines.is_empty() {
                "sim".to_string()
            } else {
                "sealing".to_string()
            }
        )
    }
}

impl DataMover for ShadowPool {
    fn request(&mut self, req: TransferRequest) -> Vec<Admitted> {
        ShadowPool::request(self, req)
    }

    fn complete(&mut self, ticket: u32) -> Vec<Admitted> {
        ShadowPool::complete(self, ticket)
    }

    fn active(&self) -> u32 {
        ShadowPool::active(self)
    }

    fn waiting(&self) -> usize {
        ShadowPool::waiting(self)
    }

    fn shard_count(&self) -> usize {
        ShadowPool::shard_count(self)
    }

    fn shard_of(&self, ticket: u32) -> Option<usize> {
        ShadowPool::shard_of(self, ticket)
    }

    fn stats(&self) -> MoverStats {
        ShadowPool::stats(self)
    }

    fn describe(&self) -> String {
        ShadowPool::describe(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::{Kind, NativeEngine};
    use crate::security::Method;
    use crate::transfer::ThrottlePolicy;

    fn r(t: u32, bytes: u64) -> TransferRequest {
        TransferRequest::new(t, "owner", bytes)
    }

    #[test]
    fn shards_balance_least_loaded() {
        let mut p = ShadowPool::sim(3, ThrottlePolicy::Disabled.into());
        for t in 0..9 {
            let adm = p.request(r(t, 100));
            assert_eq!(adm.len(), 1);
        }
        let st = p.stats();
        assert_eq!(st.admitted_per_shard, vec![3, 3, 3]);
        assert_eq!(st.bytes_per_shard, vec![300, 300, 300]);
        assert!((st.shard_imbalance() - 1.0).abs() < 1e-12);
        // Completing a shard-0 transfer makes shard 0 least-loaded again.
        let s0_ticket = (0..9).find(|&t| p.shard_of(t) == Some(0)).unwrap();
        p.complete(s0_ticket);
        let adm = p.request(r(100, 50));
        assert_eq!(adm[0].shard, 0);
    }

    #[test]
    fn admission_respects_policy_limit() {
        let mut p = ShadowPool::sim(2, ThrottlePolicy::MaxConcurrent(2).into());
        assert_eq!(p.request(r(1, 1)).len(), 1);
        assert_eq!(p.request(r(2, 1)).len(), 1);
        assert_eq!(p.request(r(3, 1)).len(), 0);
        assert_eq!(p.active(), 2);
        assert_eq!(p.waiting(), 1);
        let adm = p.complete(1);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].ticket, 3);
        assert_eq!(p.shard_of(3), Some(adm[0].shard));
        assert_eq!(p.shard_of(1), None, "completed tickets are unassigned");
    }

    #[test]
    fn spurious_complete_counted() {
        let mut p = ShadowPool::sim(1, ThrottlePolicy::Disabled.into());
        p.complete(42);
        assert_eq!(p.stats().released_without_active, 1);
        assert_eq!(p.active(), 0);
    }

    #[test]
    fn engine_per_shard_seals_independently() {
        let p = ShadowPool::with_engines(3, ThrottlePolicy::Disabled.into(), |_shard| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        });
        let handles = p.handles();
        assert_eq!(handles.len(), 3);
        // All shards produce identical sealing for identical inputs (they
        // are interchangeable engines, just parallel).
        let key = [1u32; 8];
        let nonce = [2, 3, 4];
        let mut outs = Vec::new();
        for mut h in handles {
            let mut data: Vec<u32> = (0..32u32).collect();
            let d = h.process(Kind::Seal, &key, &nonce, 0, &mut data).unwrap();
            outs.push((data, d));
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn ensure_engines_is_idempotent_and_preserves_state() {
        let mut p = ShadowPool::sim(2, ThrottlePolicy::Disabled.into());
        p.request(r(1, 10));
        let factory = |_s: usize| {
            Ok(Box::new(NativeEngine::new(Method::Chacha20)) as Box<dyn SealEngine>)
        };
        p.ensure_engines(factory);
        assert_eq!(p.handles().len(), 2);
        p.ensure_engines(factory);
        assert_eq!(p.handles().len(), 2, "no respawn");
        assert_eq!(p.active(), 1, "admission state preserved");
        assert_eq!(p.stats().total_admitted, 1);
    }

    #[test]
    fn describe_mentions_shards_and_policy() {
        let p = ShadowPool::sim(4, AdmissionConfig::FairShare { limit: 8 });
        let d = p.describe();
        assert!(d.contains("4 shards"), "{d}");
        assert!(d.contains("fair-share"), "{d}");
    }
}
