//! Fault injection and recovery: one [`FaultPlan`] drives BOTH fabrics.
//!
//! The Petascale DTN project (arXiv:2105.12880) frames sustained transfer
//! throughput as a property that must survive node-level churn; once the
//! pool router sharded the paper's single submit node across N nodes, the
//! next question is whether the pool keeps serving at line rate when one
//! of those nodes dies mid-burst — and comes back. This module is the
//! shared vocabulary for that experiment:
//!
//! * [`FaultEvent`] — `KillNode` / `RecoverNode` / `DegradeNic` (and
//!   their `KillDtn` / `RecoverDtn` / `DegradeDtnNic` data-node
//!   counterparts, spelled `dN` in plan text), each at a fabric-local
//!   time (virtual seconds in the simulator, wall-clock seconds on the
//!   real TCP fabric). `flap:N@T:PERIOD:GBPS` terms expand at parse
//!   time into [`FLAP_CYCLES`] periodic degrade/restore pairs — the
//!   slow-NIC flap model.
//! * [`FaultPlan`] — an ordered list of events plus an optional
//!   work-stealing threshold and recovery-ramp width (hysteresis:
//!   [`super::RouterConfig::recovery_ramp`]), attached to `EngineSpec`,
//!   `RealPoolConfig` and the `kill-recover-4` scenario, and parseable
//!   from the `FAULT_PLAN` condor-style knob / `--fault` CLI flag.
//! * [`apply_to_router`] — the router-side half of every event, shared
//!   verbatim by both consumers: kill poisons + drains
//!   ([`PoolRouter::fail_node`]), recover un-poisons + re-routes
//!   stranded work ([`PoolRouter::recover_node`]), degrade re-rates the
//!   node's routing weight, and each applied batch ends with ONE
//!   threshold work-stealing pass ([`PoolRouter::rebalance`] via
//!   [`apply_batch`]).
//! * [`ChaosTimeline`] — the per-node fault timeline reports carry: what
//!   was applied, when, how many transfers it re-admitted, and how many
//!   bytes the node had served at that instant.
//!
//! Fabric-specific effects wrap around [`apply_to_router`]: the sim
//! engine tears down the dead node's in-flight flows and re-rates its
//! monitored NIC; the real fabric crashes / restarts the node's
//! `FileServer` and lets workers retry through the router.
//! `tests/chaos_unified.rs` proves one plan drives both fabrics to
//! equivalent drain/recover behavior.

use super::router::{PoolRouter, Routed};
use crate::config::{Config, ConfigError};

/// Flap schedules (`flap:N@T:PERIOD:GBPS`) expand at parse time into
/// this many degrade/restore cycles; compose several flap terms for
/// longer schedules.
pub const FLAP_CYCLES: usize = 6;

/// One injected fault, at a fabric-local time in seconds. Events target
/// either a submit node or (with the `d` prefix in plan text) a
/// dedicated data node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The submit node crashes: its file server / NIC vanish and the
    /// router drains its waiting AND in-flight transfers to survivors.
    KillNode { node: usize, at: f64 },
    /// The node comes back: it rejoins routing, stranded work is
    /// re-routed, and long survivor queues rebalance onto it.
    RecoverNode { node: usize, at: f64 },
    /// The node's NIC degrades to `gbps` (nominal): the sim re-rates the
    /// monitored link, and weighted-by-capacity routing tracks the new
    /// budget on both fabrics.
    DegradeNic { node: usize, at: f64, gbps: f64 },
    /// The data node crashes: its in-flight transfers re-source onto
    /// surviving DTNs or fail over to the submit funnel
    /// ([`PoolRouter::fail_dtn`]); scheduling state is untouched.
    KillDtn { dtn: usize, at: f64 },
    /// The data node comes back and rejoins source selection.
    RecoverDtn { dtn: usize, at: f64 },
    /// The data node's NIC degrades to `gbps` (nominal).
    DegradeDtnNic { dtn: usize, at: f64, gbps: f64 },
    /// A whole federation site goes dark (border-link cut, spelled `sN`
    /// in plan text): every one of its data nodes AND submit nodes
    /// fails in one stroke ([`PoolRouter::fail_site`]) — in-flight
    /// transfers re-source and re-route onto surviving sites, and the
    /// sim drains the site's border link like a killed node's NIC.
    KillSite { site: usize, at: f64 },
    /// The site's border link and fleets come back
    /// ([`PoolRouter::recover_site`]).
    RecoverSite { site: usize, at: f64 },
}

impl FaultEvent {
    /// Fabric-local injection time in seconds.
    pub fn at(&self) -> f64 {
        match *self {
            FaultEvent::KillNode { at, .. }
            | FaultEvent::RecoverNode { at, .. }
            | FaultEvent::DegradeNic { at, .. }
            | FaultEvent::KillDtn { at, .. }
            | FaultEvent::RecoverDtn { at, .. }
            | FaultEvent::DegradeDtnNic { at, .. }
            | FaultEvent::KillSite { at, .. }
            | FaultEvent::RecoverSite { at, .. } => at,
        }
    }

    /// Index of the node the event targets — a submit node, a data
    /// node when [`FaultEvent::is_dtn`] is true, or a federation site
    /// when [`FaultEvent::is_site`] is true.
    pub fn node(&self) -> usize {
        match *self {
            FaultEvent::KillNode { node, .. }
            | FaultEvent::RecoverNode { node, .. }
            | FaultEvent::DegradeNic { node, .. } => node,
            FaultEvent::KillDtn { dtn, .. }
            | FaultEvent::RecoverDtn { dtn, .. }
            | FaultEvent::DegradeDtnNic { dtn, .. } => dtn,
            FaultEvent::KillSite { site, .. } | FaultEvent::RecoverSite { site, .. } => site,
        }
    }

    /// Does the event target a dedicated data node (vs a submit node)?
    pub fn is_dtn(&self) -> bool {
        matches!(
            self,
            FaultEvent::KillDtn { .. }
                | FaultEvent::RecoverDtn { .. }
                | FaultEvent::DegradeDtnNic { .. }
        )
    }

    /// Does the event target a whole federation site?
    pub fn is_site(&self) -> bool {
        matches!(
            self,
            FaultEvent::KillSite { .. } | FaultEvent::RecoverSite { .. }
        )
    }

    /// Short action label for timelines and plan text.
    pub fn label(&self) -> &'static str {
        match self {
            FaultEvent::KillNode { .. } => "kill",
            FaultEvent::RecoverNode { .. } => "recover",
            FaultEvent::DegradeNic { .. } => "degrade",
            FaultEvent::KillDtn { .. } => "kill-dtn",
            FaultEvent::RecoverDtn { .. } => "recover-dtn",
            FaultEvent::DegradeDtnNic { .. } => "degrade-dtn",
            FaultEvent::KillSite { .. } => "kill-site",
            FaultEvent::RecoverSite { .. } => "recover-site",
        }
    }
}

/// An ordered fault schedule, executed identically by both fabrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// When set, every applied event is followed by
    /// [`PoolRouter::rebalance`] with this threshold, so long per-node
    /// queues spill onto recovered or idle nodes.
    pub steal_threshold: Option<usize>,
    /// When set, a recovered node's routing weight ramps back over this
    /// many routing decisions instead of step-restoring
    /// ([`super::RouterConfig::recovery_ramp`]); both fabrics arm the
    /// router with it before the burst.
    pub recovery_ramp: Option<u32>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append a `KillNode` event (builder style).
    pub fn kill(mut self, node: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::KillNode { node, at });
        self
    }

    /// Append a `RecoverNode` event (builder style).
    pub fn recover(mut self, node: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::RecoverNode { node, at });
        self
    }

    /// Append a `DegradeNic` event (builder style).
    pub fn degrade(mut self, node: usize, at: f64, gbps: f64) -> FaultPlan {
        self.events.push(FaultEvent::DegradeNic { node, at, gbps });
        self
    }

    /// Append a `KillDtn` event (builder style).
    pub fn kill_dtn(mut self, dtn: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::KillDtn { dtn, at });
        self
    }

    /// Append a `RecoverDtn` event (builder style).
    pub fn recover_dtn(mut self, dtn: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::RecoverDtn { dtn, at });
        self
    }

    /// Append a `DegradeDtnNic` event (builder style).
    pub fn degrade_dtn(mut self, dtn: usize, at: f64, gbps: f64) -> FaultPlan {
        self.events.push(FaultEvent::DegradeDtnNic { dtn, at, gbps });
        self
    }

    /// Append a `KillSite` event (builder style).
    pub fn kill_site(mut self, site: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::KillSite { site, at });
        self
    }

    /// Append a `RecoverSite` event (builder style).
    pub fn recover_site(mut self, site: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::RecoverSite { site, at });
        self
    }

    /// Append a slow-NIC flap schedule (builder style): [`FLAP_CYCLES`]
    /// degrade/restore pairs starting at `at`, one per `period` seconds
    /// (degrade at the cycle start, restore half a period later). The
    /// restore is a `RecoverNode`, which on a live node only restores
    /// the NIC rate and routing weight.
    pub fn flap(mut self, node: usize, at: f64, period: f64, gbps: f64) -> FaultPlan {
        for k in 0..FLAP_CYCLES {
            let start = at + k as f64 * period;
            self.events.push(FaultEvent::DegradeNic {
                node,
                at: start,
                gbps,
            });
            self.events.push(FaultEvent::RecoverNode {
                node,
                at: start + period / 2.0,
            });
        }
        self
    }

    /// [`FaultPlan::flap`] against a data node (`flap:dN@T:PERIOD:GBPS`).
    pub fn flap_dtn(mut self, dtn: usize, at: f64, period: f64, gbps: f64) -> FaultPlan {
        for k in 0..FLAP_CYCLES {
            let start = at + k as f64 * period;
            self.events.push(FaultEvent::DegradeDtnNic {
                dtn,
                at: start,
                gbps,
            });
            self.events.push(FaultEvent::RecoverDtn {
                dtn,
                at: start + period / 2.0,
            });
        }
        self
    }

    /// Set the work-stealing threshold (builder style).
    pub fn with_steal_threshold(mut self, threshold: usize) -> FaultPlan {
        self.steal_threshold = Some(threshold);
        self
    }

    /// Set the recovery-ramp decision count (builder style).
    pub fn with_recovery_ramp(mut self, decisions: u32) -> FaultPlan {
        self.recovery_ramp = Some(decisions);
        self
    }

    /// Events in injection order (stable sort by time, so same-instant
    /// events keep their listed order).
    pub fn sorted(&self) -> Vec<FaultEvent> {
        let mut v = self.events.clone();
        v.sort_by(|a, b| {
            a.at()
                .partial_cmp(&b.at())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        v
    }

    /// Check every event against the pool shape (submit nodes, data
    /// nodes AND federation sites) before running it.
    pub fn validate(&self, n_nodes: usize, n_dtns: usize, n_sites: usize) -> Result<(), String> {
        for ev in &self.events {
            if ev.is_site() {
                if ev.node() >= n_sites.max(1) {
                    return Err(format!(
                        "{} targets site {} but the pool has {} site(s)",
                        ev.label(),
                        ev.node(),
                        n_sites.max(1)
                    ));
                }
            } else if ev.is_dtn() {
                if ev.node() >= n_dtns {
                    return Err(format!(
                        "{} targets data node {} but the pool has {} data node(s)",
                        ev.label(),
                        ev.node(),
                        n_dtns
                    ));
                }
            } else if ev.node() >= n_nodes {
                return Err(format!(
                    "{} targets node {} but the pool has {} submit node(s)",
                    ev.label(),
                    ev.node(),
                    n_nodes
                ));
            }
            if !ev.at().is_finite() || ev.at() < 0.0 {
                return Err(format!("{} at {} — time must be >= 0", ev.label(), ev.at()));
            }
            if let FaultEvent::DegradeNic { gbps, .. } | FaultEvent::DegradeDtnNic { gbps, .. } =
                ev
            {
                if !gbps.is_finite() || *gbps <= 0.0 {
                    return Err(format!("degrade to {gbps} Gbps — must be > 0"));
                }
            }
        }
        Ok(())
    }

    /// Parse the plan text used by the `FAULT_PLAN` knob and the
    /// `--fault` CLI flag:
    ///
    /// ```text
    /// FAULT_PLAN = kill:1@30; recover:1@90; degrade:0@10:25; kill:d0@40; flap:d1@60:20:25
    /// ```
    ///
    /// Events are `;`- or `,`-separated; each is `ACTION:NODE@SECONDS`,
    /// with degrade taking a trailing `:GBPS`. A node spelled `dN`
    /// targets data node N instead of submit node N; `sN` targets
    /// federation site N (kill/recover only — a site has no single NIC
    /// to degrade or flap).
    /// `flap:NODE@START:PERIOD:GBPS` expands at parse time into
    /// [`FLAP_CYCLES`] periodic degrade/restore pairs (degrade at each
    /// cycle start, restore half a period later).
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        #[derive(Clone, Copy, PartialEq)]
        enum Target {
            Node,
            Dtn,
            Site,
        }
        let mut plan = FaultPlan::default();
        for part in text.split([';', ',']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (action, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("'{part}': expected ACTION:NODE@SECONDS"))?;
            let (node_s, time_s) = rest
                .split_once('@')
                .ok_or_else(|| format!("'{part}': expected NODE@SECONDS"))?;
            let node_s = node_s.trim();
            let (target, idx_s) = if let Some(idx) = node_s.strip_prefix(['d', 'D']) {
                (Target::Dtn, idx)
            } else if let Some(idx) = node_s.strip_prefix(['s', 'S']) {
                (Target::Site, idx)
            } else {
                (Target::Node, node_s)
            };
            let node: usize = idx_s
                .parse()
                .map_err(|_| format!("'{part}': bad node index '{node_s}'"))?;
            match (action.trim().to_ascii_lowercase().as_str(), target) {
                ("kill", Target::Node) => {
                    plan = plan.kill(node, parse_secs(time_s, part)?);
                }
                ("kill", Target::Dtn) => {
                    plan = plan.kill_dtn(node, parse_secs(time_s, part)?);
                }
                ("kill", Target::Site) => {
                    plan = plan.kill_site(node, parse_secs(time_s, part)?);
                }
                ("recover", Target::Node) => {
                    plan = plan.recover(node, parse_secs(time_s, part)?);
                }
                ("recover", Target::Dtn) => {
                    plan = plan.recover_dtn(node, parse_secs(time_s, part)?);
                }
                ("recover", Target::Site) => {
                    plan = plan.recover_site(node, parse_secs(time_s, part)?);
                }
                ("degrade" | "flap", Target::Site) => {
                    return Err(format!(
                        "'{part}': a site has no single NIC — only kill/recover target sN"
                    ))
                }
                ("degrade", target) => {
                    let (t_s, g_s) = time_s
                        .split_once(':')
                        .ok_or_else(|| format!("'{part}': degrade needs NODE@SECONDS:GBPS"))?;
                    let gbps = parse_gbps(g_s, part)?;
                    let at = parse_secs(t_s, part)?;
                    plan = if target == Target::Dtn {
                        plan.degrade_dtn(node, at, gbps)
                    } else {
                        plan.degrade(node, at, gbps)
                    };
                }
                ("flap", target) => {
                    let mut it = time_s.split(':');
                    let t_s = it.next().unwrap_or("");
                    let (p_s, g_s) = match (it.next(), it.next(), it.next()) {
                        (Some(p), Some(g), None) => (p, g),
                        _ => {
                            return Err(format!(
                                "'{part}': flap needs NODE@START:PERIOD:GBPS"
                            ))
                        }
                    };
                    let at = parse_secs(t_s, part)?;
                    let period: f64 = p_s
                        .trim()
                        .parse()
                        .map_err(|_| format!("'{part}': bad period '{p_s}'"))?;
                    if !period.is_finite() || period <= 0.0 {
                        return Err(format!("'{part}': flap period must be > 0"));
                    }
                    let gbps = parse_gbps(g_s, part)?;
                    plan = if target == Target::Dtn {
                        plan.flap_dtn(node, at, period, gbps)
                    } else {
                        plan.flap(node, at, period, gbps)
                    };
                }
                (other, _) => return Err(format!("unknown fault action '{other}'")),
            }
        }
        Ok(plan)
    }

    /// The `FAULT_PLAN` / `STEAL_THRESHOLD` / `RECOVERY_RAMP`
    /// condor-style knobs (an absent `FAULT_PLAN` yields the empty plan).
    pub fn from_config(cfg: &Config) -> Result<FaultPlan, ConfigError> {
        let mut plan = match cfg.raw("FAULT_PLAN") {
            Some(raw) => FaultPlan::parse(raw).map_err(|_| {
                ConfigError::Type(
                    "FAULT_PLAN".into(),
                    "fault plan (kill:N@T; recover:N@T; degrade:N@T:GBPS; flap:N@T:PERIOD:GBPS; dN targets data nodes, sN whole sites)",
                    raw.to_string(),
                )
            })?,
            None => FaultPlan::default(),
        };
        if cfg.raw("STEAL_THRESHOLD").is_some() {
            plan.steal_threshold = Some(cfg.get_u64("STEAL_THRESHOLD", 0)? as usize);
        }
        if cfg.raw("RECOVERY_RAMP").is_some() {
            plan.recovery_ramp = Some(cfg.get_u64("RECOVERY_RAMP", 0)? as u32);
        }
        Ok(plan)
    }

    /// Plan text in the same spelling [`FaultPlan::parse`] accepts
    /// (flap schedules appear in their expanded degrade/restore form).
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .events
            .iter()
            .map(|ev| match *ev {
                FaultEvent::KillNode { node, at } => format!("kill:{node}@{at}"),
                FaultEvent::RecoverNode { node, at } => format!("recover:{node}@{at}"),
                FaultEvent::DegradeNic { node, at, gbps } => {
                    format!("degrade:{node}@{at}:{gbps}")
                }
                FaultEvent::KillDtn { dtn, at } => format!("kill:d{dtn}@{at}"),
                FaultEvent::RecoverDtn { dtn, at } => format!("recover:d{dtn}@{at}"),
                FaultEvent::DegradeDtnNic { dtn, at, gbps } => {
                    format!("degrade:d{dtn}@{at}:{gbps}")
                }
                FaultEvent::KillSite { site, at } => format!("kill:s{site}@{at}"),
                FaultEvent::RecoverSite { site, at } => format!("recover:s{site}@{at}"),
            })
            .collect();
        parts.join("; ")
    }
}

fn parse_secs(text: &str, part: &str) -> Result<f64, String> {
    let at: f64 = text
        .trim()
        .parse()
        .map_err(|_| format!("'{part}': bad time '{text}'"))?;
    if !at.is_finite() || at < 0.0 {
        return Err(format!("'{part}': time must be >= 0"));
    }
    Ok(at)
}

fn parse_gbps(text: &str, part: &str) -> Result<f64, String> {
    text.trim()
        .parse()
        .map_err(|_| format!("'{part}': bad Gbps '{text}'"))
}

/// The router-side half of one fault event — identical for both fabrics
/// (fabric-specific effects wrap around it: the sim tears down flows and
/// re-rates NICs, the real fabric crashes / restarts file servers).
/// Returns every transfer admitted or re-sourced NOW on the surviving /
/// recovered nodes, including any freed by threshold work-stealing.
pub fn apply_to_router(
    ev: &FaultEvent,
    router: &mut PoolRouter,
    steal_threshold: Option<usize>,
) -> Vec<Routed> {
    apply_batch(std::slice::from_ref(ev), router, steal_threshold)
}

/// The batched form of [`apply_to_router`]: apply every event's
/// router-side half, then run ONE threshold work-stealing pass over the
/// result. Callers firing several co-due events (one chaos wakeup, one
/// sim tick) use this so the steal plan is computed once per cycle
/// against the final post-fault queue lengths, instead of once per
/// event against intermediate states.
pub fn apply_batch(
    events: &[FaultEvent],
    router: &mut PoolRouter,
    steal_threshold: Option<usize>,
) -> Vec<Routed> {
    let mut out = Vec::new();
    for ev in events {
        out.extend(match *ev {
            FaultEvent::KillNode { node, .. } => router.fail_node(node),
            FaultEvent::RecoverNode { node, .. } => router.recover_node(node),
            FaultEvent::DegradeNic { node, gbps, .. } => {
                router.set_node_capacity(node, gbps);
                Vec::new()
            }
            FaultEvent::KillDtn { dtn, .. } => router.fail_dtn(dtn),
            FaultEvent::RecoverDtn { dtn, .. } => {
                router.recover_dtn(dtn);
                Vec::new()
            }
            FaultEvent::DegradeDtnNic { dtn, gbps, .. } => {
                router.set_dtn_capacity(dtn, gbps);
                Vec::new()
            }
            FaultEvent::KillSite { site, .. } => router.fail_site(site),
            FaultEvent::RecoverSite { site, .. } => router.recover_site(site),
        });
    }
    if let Some(threshold) = steal_threshold {
        out.extend(router.rebalance(threshold));
    }
    out
}

/// One applied fault, for reports. `node` indexes the submit fleet for
/// plain actions, the DATA fleet for `*-dtn` actions, and the site list
/// for `*-site` actions ([`FaultRecord::is_dtn`] /
/// [`FaultRecord::is_site`] discriminate).
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub node: usize,
    /// `"kill"` / `"recover"` / `"degrade"` and their `-dtn` and
    /// `-site` variants (see [`FaultEvent::label`]).
    pub action: &'static str,
    /// When the plan scheduled the event (fabric-local seconds).
    pub planned_s: f64,
    /// When the fabric actually applied it.
    pub applied_s: f64,
    /// Transfers admitted on surviving/recovered nodes by this event.
    pub admitted: usize,
    /// Bytes the node had served when the event applied (submit-NIC
    /// `bytes_carried` in the simulator; cumulative `FileServer` payload
    /// bytes on the real fabric). A recovered node whose final served
    /// total exceeds its recovery record's value demonstrably served
    /// bytes again.
    pub bytes_served_before: u64,
}

impl FaultRecord {
    /// Does this record target a data node (vs a submit node)?
    pub fn is_dtn(&self) -> bool {
        self.action.ends_with("-dtn")
    }

    /// Does this record target a whole federation site?
    pub fn is_site(&self) -> bool {
        self.action.ends_with("-site")
    }
}

/// The per-node fault timeline a chaos run reports.
#[derive(Debug, Clone, Default)]
pub struct ChaosTimeline {
    pub records: Vec<FaultRecord>,
}

impl ChaosTimeline {
    pub fn record(
        &mut self,
        node: usize,
        action: &'static str,
        planned_s: f64,
        applied_s: f64,
        admitted: usize,
        bytes_served_before: u64,
    ) {
        self.records.push(FaultRecord {
            node,
            action,
            planned_s,
            applied_s,
            admitted,
            bytes_served_before,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records touching one SUBMIT node, in application order (data-node
    /// and site records live in their own index spaces — see
    /// [`ChaosTimeline::for_dtn`] / [`ChaosTimeline::for_site`]).
    pub fn for_node(&self, node: usize) -> Vec<&FaultRecord> {
        self.records
            .iter()
            .filter(|r| !r.is_dtn() && !r.is_site() && r.node == node)
            .collect()
    }

    /// Records touching one DATA node, in application order.
    pub fn for_dtn(&self, dtn: usize) -> Vec<&FaultRecord> {
        self.records
            .iter()
            .filter(|r| r.is_dtn() && r.node == dtn)
            .collect()
    }

    /// Records touching one federation SITE, in application order.
    pub fn for_site(&self, site: usize) -> Vec<&FaultRecord> {
        self.records
            .iter()
            .filter(|r| r.is_site() && r.node == site)
            .collect()
    }

    /// Applied events with the given action label.
    pub fn count(&self, action: &str) -> usize {
        self.records.iter().filter(|r| r.action == action).count()
    }

    /// One line per applied event, for the CLI.
    pub fn render(&self) -> String {
        self.records
            .iter()
            .map(|r| {
                format!(
                    "{} {} {} @{:.2}s (planned {:.2}s): {} re-admitted, {} B served before",
                    r.action,
                    if r.is_dtn() {
                        "data node"
                    } else if r.is_site() {
                        "site"
                    } else {
                        "node"
                    },
                    r.node,
                    r.applied_s,
                    r.planned_s,
                    r.admitted,
                    r.bytes_served_before
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mover::{AdmissionConfig, RouterPolicy, TransferRequest};
    use crate::transfer::ThrottlePolicy;

    #[test]
    fn parse_roundtrip_and_sort() {
        let plan = FaultPlan::parse("recover:1@90; kill:1@30, degrade:0@10.5:25").unwrap();
        assert_eq!(plan.events.len(), 3);
        let sorted = plan.sorted();
        assert_eq!(sorted[0].label(), "degrade");
        assert_eq!(sorted[1].label(), "kill");
        assert_eq!(sorted[2].label(), "recover");
        assert_eq!(sorted[0].node(), 0);
        assert!((sorted[0].at() - 10.5).abs() < 1e-12);
        // describe() re-parses to the same plan.
        let text = plan.describe();
        assert_eq!(FaultPlan::parse(&text).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        assert!(FaultPlan::parse("kill1@30").is_err());
        assert!(FaultPlan::parse("kill:x@30").is_err());
        assert!(FaultPlan::parse("kill:1@-3").is_err());
        assert!(FaultPlan::parse("explode:1@3").is_err());
        assert!(FaultPlan::parse("degrade:1@3").is_err(), "degrade needs Gbps");
        assert!(
            FaultPlan::parse("degrade:1@3:0")
                .unwrap()
                .validate(2, 0, 1)
                .is_err()
        );
        assert!(FaultPlan::parse("flap:1@3:20").is_err(), "flap needs Gbps");
        assert!(FaultPlan::parse("flap:1@3:0:25").is_err(), "period > 0");
        assert!(FaultPlan::parse("kill:dx@3").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn validate_checks_node_bounds() {
        let plan = FaultPlan::default().kill(3, 1.0);
        assert!(plan.validate(4, 0, 1).is_ok());
        assert!(plan.validate(3, 0, 1).is_err());
    }

    #[test]
    fn validate_checks_dtn_bounds_separately() {
        // kill:d3 needs 4 DATA nodes, regardless of submit-node count.
        let plan = FaultPlan::default().kill_dtn(3, 1.0);
        assert!(plan.validate(1, 4, 1).is_ok());
        assert!(plan.validate(8, 3, 1).is_err());
    }

    #[test]
    fn parse_dtn_events_roundtrip() {
        let plan = FaultPlan::parse("kill:d1@30; recover:d1@90; degrade:d0@10:25").unwrap();
        assert_eq!(
            plan.events,
            vec![
                FaultEvent::KillDtn { dtn: 1, at: 30.0 },
                FaultEvent::RecoverDtn { dtn: 1, at: 90.0 },
                FaultEvent::DegradeDtnNic {
                    dtn: 0,
                    at: 10.0,
                    gbps: 25.0
                },
            ]
        );
        assert!(plan.events.iter().all(|e| e.is_dtn()));
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
    }

    #[test]
    fn flap_expands_to_periodic_degrade_restore_pairs() {
        let plan = FaultPlan::parse("flap:1@30:20:25").unwrap();
        assert_eq!(plan.events.len(), 2 * FLAP_CYCLES);
        assert_eq!(
            plan.events[0],
            FaultEvent::DegradeNic {
                node: 1,
                at: 30.0,
                gbps: 25.0
            }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent::RecoverNode { node: 1, at: 40.0 }
        );
        assert_eq!(
            plan.events[2],
            FaultEvent::DegradeNic {
                node: 1,
                at: 50.0,
                gbps: 25.0
            }
        );
        // Expanded form survives a describe/parse roundtrip and the
        // events are already in time order.
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
        assert_eq!(plan.sorted(), plan.events);
        assert!(plan.validate(2, 0, 1).is_ok());

        // The same schedule against a data node.
        let dplan = FaultPlan::parse("flap:d0@0:10:5").unwrap();
        assert_eq!(dplan.events.len(), 2 * FLAP_CYCLES);
        assert!(dplan.events.iter().all(|e| e.is_dtn()));
        assert_eq!(
            dplan.events[1],
            FaultEvent::RecoverDtn { dtn: 0, at: 5.0 }
        );
        assert!(dplan.validate(1, 1, 1).is_ok());
        assert!(dplan.validate(1, 0, 1).is_err());
    }

    #[test]
    fn apply_to_router_drives_dtn_kill_and_recover() {
        use crate::mover::{DataSource, RouterConfig, ShadowPool, SourcePlan};
        let mut router = PoolRouter::from_config(
            vec![ShadowPool::sim(
                1,
                AdmissionConfig::Throttle(ThrottlePolicy::Disabled),
            )],
            vec![1.0],
            RouterPolicy::RoundRobin,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0, 1.0],
                ..RouterConfig::default()
            },
        );
        for t in 0..4 {
            router.request(TransferRequest::new(t, "o", 5));
        }
        let kill = FaultEvent::KillDtn { dtn: 0, at: 1.0 };
        let moved = apply_to_router(&kill, &mut router, None);
        assert_eq!(moved.len(), 2, "dtn 0's two transfers re-source");
        assert!(moved
            .iter()
            .all(|m| m.source == DataSource::Dtn { dtn: 1 }));
        assert!(router.is_dtn_failed(0));

        let recover = FaultEvent::RecoverDtn { dtn: 0, at: 2.0 };
        assert!(apply_to_router(&recover, &mut router, None).is_empty());
        assert!(!router.is_dtn_failed(0));
        let st = router.router_stats();
        assert_eq!(st.dtn_failed, 1);
        assert_eq!(st.dtn_recovered, 1);
    }

    #[test]
    fn from_config_reads_plan_and_threshold() {
        let cfg = Config::parse(
            "FAULT_PLAN = kill:1@30; recover:1@90\nSTEAL_THRESHOLD = 4\nRECOVERY_RAMP = 16",
        )
        .unwrap();
        let plan = FaultPlan::from_config(&cfg).unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.steal_threshold, Some(4));
        assert_eq!(plan.recovery_ramp, Some(16));

        let empty = Config::parse("").unwrap();
        assert!(FaultPlan::from_config(&empty).unwrap().is_empty());

        let bad = Config::parse("FAULT_PLAN = frobnicate:1@2").unwrap();
        assert!(FaultPlan::from_config(&bad).is_err());
    }

    #[test]
    fn apply_to_router_drives_kill_recover_and_steal() {
        let mut router = PoolRouter::sim(
            2,
            1,
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(1)),
            RouterPolicy::RoundRobin,
        );
        for t in 0..10 {
            router.request(TransferRequest::new(t, "o", 5));
        }
        let kill = FaultEvent::KillNode { node: 0, at: 1.0 };
        let rescued = apply_to_router(&kill, &mut router, Some(1));
        assert!(rescued.is_empty(), "survivor is at its limit");
        assert!(router.is_failed(0));
        assert_eq!(router.stats().shard_failed, 1);
        assert_eq!(router.waiting(), 9, "node 0's backlog moved to node 1");

        let recover = FaultEvent::RecoverNode { node: 0, at: 2.0 };
        let admitted = apply_to_router(&recover, &mut router, Some(1));
        assert!(!router.is_failed(0));
        // The recovered node admits a stolen transfer immediately…
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].node, 0);
        // …and the queues end up within the threshold.
        let lens = router.waiting_per_node();
        let max = lens.iter().max().unwrap();
        let min = lens.iter().min().unwrap();
        assert!(max - min <= 1, "imbalance {lens:?} above threshold");
        let st = router.stats();
        assert_eq!(st.node_recovered, 1);
        assert!(st.stolen > 0);
    }

    #[test]
    fn timeline_accounting() {
        let mut tl = ChaosTimeline::default();
        tl.record(1, "kill", 30.0, 30.1, 4, 1000);
        tl.record(1, "recover", 90.0, 90.0, 2, 1000);
        tl.record(0, "degrade", 10.0, 10.0, 0, 0);
        // Data node 1's fault must NOT be conflated with submit node 1,
        // and neither may site 1's.
        tl.record(1, "kill-dtn", 40.0, 40.0, 3, 500);
        tl.record(1, "kill-site", 50.0, 50.0, 2, 0);
        assert_eq!(tl.count("kill"), 1);
        assert_eq!(tl.count("kill-dtn"), 1);
        assert_eq!(tl.count("kill-site"), 1);
        assert_eq!(tl.for_node(1).len(), 2, "submit records only");
        assert_eq!(tl.for_dtn(1).len(), 1);
        assert_eq!(tl.for_site(1).len(), 1);
        assert!(tl.for_node(1).iter().all(|r| !r.is_dtn() && !r.is_site()));
        assert!(!tl.is_empty());
        let text = tl.render();
        assert!(text.contains("kill node 1"), "{text}");
        assert!(text.contains("recover node 1"), "{text}");
        assert!(text.contains("kill-dtn data node 1"), "{text}");
        assert!(text.contains("kill-site site 1"), "{text}");
    }

    #[test]
    fn parse_site_events_roundtrip() {
        let plan = FaultPlan::parse("kill:s0@30; recover:s0@90").unwrap();
        assert_eq!(
            plan.events,
            vec![
                FaultEvent::KillSite { site: 0, at: 30.0 },
                FaultEvent::RecoverSite { site: 0, at: 90.0 },
            ]
        );
        assert!(plan.events.iter().all(|e| e.is_site() && !e.is_dtn()));
        assert_eq!(plan.events[0].label(), "kill-site");
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
        // A site has no single NIC: degrade/flap reject the `s` prefix.
        assert!(FaultPlan::parse("degrade:s0@10:25").is_err());
        assert!(FaultPlan::parse("flap:s1@0:10:5").is_err());
        assert!(FaultPlan::parse("kill:sx@3").is_err());
    }

    #[test]
    fn validate_checks_site_bounds() {
        let plan = FaultPlan::default().kill_site(1, 5.0).recover_site(1, 9.0);
        assert!(plan.validate(4, 4, 2).is_ok());
        assert!(plan.validate(4, 4, 1).is_err());
    }

    #[test]
    fn apply_to_router_drives_site_kill_and_recover() {
        use crate::mover::{
            DataSource, RouterConfig, ShadowPool, SiteSelector, SourcePlan,
        };
        let pools = (0..2)
            .map(|_| ShadowPool::sim(1, AdmissionConfig::Throttle(ThrottlePolicy::Disabled)))
            .collect();
        let mut router = PoolRouter::from_config(
            pools,
            vec![1.0, 1.0],
            RouterPolicy::RoundRobin,
            RouterConfig {
                source_plan: SourcePlan::DedicatedDtn,
                dtn_capacity: vec![1.0, 1.0],
                n_sites: 2,
                site_selector: SiteSelector::LocalFirst,
                ..RouterConfig::default()
            },
        );
        // Round-robin lands two transfers on each node; LocalFirst keeps
        // each node on its own site's data node.
        for t in 0..4 {
            router.request(TransferRequest::new(t, "o", 5));
        }
        let moved = apply_to_router(&FaultEvent::KillSite { site: 0, at: 1.0 }, &mut router, None);
        assert_eq!(moved.len(), 2, "site 0's transfers re-route and re-source");
        assert!(moved
            .iter()
            .all(|m| m.node == 1 && m.source == DataSource::Dtn { dtn: 1 }));
        assert!(router.is_failed(0));
        assert!(router.is_dtn_failed(0));
        assert!(!router.is_dtn_failed(1));

        let back = apply_to_router(&FaultEvent::RecoverSite { site: 0, at: 2.0 }, &mut router, None);
        assert!(back.is_empty(), "nothing was stranded waiting");
        assert!(!router.is_failed(0));
        assert!(!router.is_dtn_failed(0));
        let st = router.router_stats();
        assert_eq!(st.dtn_failed, 1);
        assert_eq!(st.dtn_recovered, 1);
        assert_eq!(router.stats().shard_failed, 1);
        assert_eq!(router.stats().node_recovered, 1);
    }
}
