//! Fault injection and recovery: one [`FaultPlan`] drives BOTH fabrics.
//!
//! The Petascale DTN project (arXiv:2105.12880) frames sustained transfer
//! throughput as a property that must survive node-level churn; once the
//! pool router sharded the paper's single submit node across N nodes, the
//! next question is whether the pool keeps serving at line rate when one
//! of those nodes dies mid-burst — and comes back. This module is the
//! shared vocabulary for that experiment:
//!
//! * [`FaultEvent`] — `KillNode` / `RecoverNode` / `DegradeNic`, each at
//!   a fabric-local time (virtual seconds in the simulator, wall-clock
//!   seconds on the real TCP fabric).
//! * [`FaultPlan`] — an ordered list of events plus an optional
//!   work-stealing threshold, attached to `EngineSpec`,
//!   `RealPoolConfig` and the `kill-recover-4` scenario, and parseable
//!   from the `FAULT_PLAN` condor-style knob / `--fault` CLI flag.
//! * [`apply_to_router`] — the router-side half of every event, shared
//!   verbatim by both consumers: kill poisons + drains
//!   ([`PoolRouter::fail_node`]), recover un-poisons + re-routes
//!   stranded work ([`PoolRouter::recover_node`]), degrade re-rates the
//!   node's routing weight, and each event triggers threshold
//!   work-stealing ([`PoolRouter::rebalance`]).
//! * [`ChaosTimeline`] — the per-node fault timeline reports carry: what
//!   was applied, when, how many transfers it re-admitted, and how many
//!   bytes the node had served at that instant.
//!
//! Fabric-specific effects wrap around [`apply_to_router`]: the sim
//! engine tears down the dead node's in-flight flows and re-rates its
//! monitored NIC; the real fabric crashes / restarts the node's
//! `FileServer` and lets workers retry through the router.
//! `tests/chaos_unified.rs` proves one plan drives both fabrics to
//! equivalent drain/recover behavior.

use super::router::{PoolRouter, Routed};
use crate::config::{Config, ConfigError};

/// One injected fault, at a fabric-local time in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// The submit node crashes: its file server / NIC vanish and the
    /// router drains its waiting AND in-flight transfers to survivors.
    KillNode { node: usize, at: f64 },
    /// The node comes back: it rejoins routing, stranded work is
    /// re-routed, and long survivor queues rebalance onto it.
    RecoverNode { node: usize, at: f64 },
    /// The node's NIC degrades to `gbps` (nominal): the sim re-rates the
    /// monitored link, and weighted-by-capacity routing tracks the new
    /// budget on both fabrics.
    DegradeNic { node: usize, at: f64, gbps: f64 },
}

impl FaultEvent {
    /// Fabric-local injection time in seconds.
    pub fn at(&self) -> f64 {
        match *self {
            FaultEvent::KillNode { at, .. }
            | FaultEvent::RecoverNode { at, .. }
            | FaultEvent::DegradeNic { at, .. } => at,
        }
    }

    /// Submit node the event targets.
    pub fn node(&self) -> usize {
        match *self {
            FaultEvent::KillNode { node, .. }
            | FaultEvent::RecoverNode { node, .. }
            | FaultEvent::DegradeNic { node, .. } => node,
        }
    }

    /// Short action label for timelines and plan text.
    pub fn label(&self) -> &'static str {
        match self {
            FaultEvent::KillNode { .. } => "kill",
            FaultEvent::RecoverNode { .. } => "recover",
            FaultEvent::DegradeNic { .. } => "degrade",
        }
    }
}

/// An ordered fault schedule, executed identically by both fabrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// When set, every applied event is followed by
    /// [`PoolRouter::rebalance`] with this threshold, so long per-node
    /// queues spill onto recovered or idle nodes.
    pub steal_threshold: Option<usize>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append a `KillNode` event (builder style).
    pub fn kill(mut self, node: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::KillNode { node, at });
        self
    }

    /// Append a `RecoverNode` event (builder style).
    pub fn recover(mut self, node: usize, at: f64) -> FaultPlan {
        self.events.push(FaultEvent::RecoverNode { node, at });
        self
    }

    /// Append a `DegradeNic` event (builder style).
    pub fn degrade(mut self, node: usize, at: f64, gbps: f64) -> FaultPlan {
        self.events.push(FaultEvent::DegradeNic { node, at, gbps });
        self
    }

    /// Set the work-stealing threshold (builder style).
    pub fn with_steal_threshold(mut self, threshold: usize) -> FaultPlan {
        self.steal_threshold = Some(threshold);
        self
    }

    /// Events in injection order (stable sort by time, so same-instant
    /// events keep their listed order).
    pub fn sorted(&self) -> Vec<FaultEvent> {
        let mut v = self.events.clone();
        v.sort_by(|a, b| {
            a.at()
                .partial_cmp(&b.at())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        v
    }

    /// Check every event against the pool shape before running it.
    pub fn validate(&self, n_nodes: usize) -> Result<(), String> {
        for ev in &self.events {
            if ev.node() >= n_nodes {
                return Err(format!(
                    "{} targets node {} but the pool has {} submit node(s)",
                    ev.label(),
                    ev.node(),
                    n_nodes
                ));
            }
            if !ev.at().is_finite() || ev.at() < 0.0 {
                return Err(format!("{} at {} — time must be >= 0", ev.label(), ev.at()));
            }
            if let FaultEvent::DegradeNic { gbps, .. } = ev {
                if !gbps.is_finite() || *gbps <= 0.0 {
                    return Err(format!("degrade to {gbps} Gbps — must be > 0"));
                }
            }
        }
        Ok(())
    }

    /// Parse the plan text used by the `FAULT_PLAN` knob and the
    /// `--fault` CLI flag:
    ///
    /// ```text
    /// FAULT_PLAN = kill:1@30; recover:1@90; degrade:0@10:25
    /// ```
    ///
    /// Events are `;`- or `,`-separated; each is `ACTION:NODE@SECONDS`,
    /// with degrade taking a trailing `:GBPS`.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in text.split([';', ',']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (action, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("'{part}': expected ACTION:NODE@SECONDS"))?;
            let (node_s, time_s) = rest
                .split_once('@')
                .ok_or_else(|| format!("'{part}': expected NODE@SECONDS"))?;
            let node: usize = node_s
                .trim()
                .parse()
                .map_err(|_| format!("'{part}': bad node index '{node_s}'"))?;
            match action.trim().to_ascii_lowercase().as_str() {
                "kill" => events.push(FaultEvent::KillNode {
                    node,
                    at: parse_secs(time_s, part)?,
                }),
                "recover" => events.push(FaultEvent::RecoverNode {
                    node,
                    at: parse_secs(time_s, part)?,
                }),
                "degrade" => {
                    let (t_s, g_s) = time_s
                        .split_once(':')
                        .ok_or_else(|| format!("'{part}': degrade needs NODE@SECONDS:GBPS"))?;
                    let gbps: f64 = g_s
                        .trim()
                        .parse()
                        .map_err(|_| format!("'{part}': bad Gbps '{g_s}'"))?;
                    events.push(FaultEvent::DegradeNic {
                        node,
                        at: parse_secs(t_s, part)?,
                        gbps,
                    });
                }
                other => return Err(format!("unknown fault action '{other}'")),
            }
        }
        Ok(FaultPlan {
            events,
            steal_threshold: None,
        })
    }

    /// The `FAULT_PLAN` / `STEAL_THRESHOLD` condor-style knobs (an absent
    /// `FAULT_PLAN` yields the empty plan).
    pub fn from_config(cfg: &Config) -> Result<FaultPlan, ConfigError> {
        let mut plan = match cfg.raw("FAULT_PLAN") {
            Some(raw) => FaultPlan::parse(raw).map_err(|_| {
                ConfigError::Type(
                    "FAULT_PLAN".into(),
                    "fault plan (kill:N@T; recover:N@T; degrade:N@T:GBPS)",
                    raw.to_string(),
                )
            })?,
            None => FaultPlan::default(),
        };
        if cfg.raw("STEAL_THRESHOLD").is_some() {
            plan.steal_threshold = Some(cfg.get_u64("STEAL_THRESHOLD", 0)? as usize);
        }
        Ok(plan)
    }

    /// Plan text in the same spelling [`FaultPlan::parse`] accepts.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .events
            .iter()
            .map(|ev| match *ev {
                FaultEvent::KillNode { node, at } => format!("kill:{node}@{at}"),
                FaultEvent::RecoverNode { node, at } => format!("recover:{node}@{at}"),
                FaultEvent::DegradeNic { node, at, gbps } => {
                    format!("degrade:{node}@{at}:{gbps}")
                }
            })
            .collect();
        parts.join("; ")
    }
}

fn parse_secs(text: &str, part: &str) -> Result<f64, String> {
    let at: f64 = text
        .trim()
        .parse()
        .map_err(|_| format!("'{part}': bad time '{text}'"))?;
    if !at.is_finite() || at < 0.0 {
        return Err(format!("'{part}': time must be >= 0"));
    }
    Ok(at)
}

/// The router-side half of one fault event — identical for both fabrics
/// (fabric-specific effects wrap around it: the sim tears down flows and
/// re-rates NICs, the real fabric crashes / restarts file servers).
/// Returns every transfer admitted NOW on the surviving / recovered
/// nodes, including any freed by threshold work-stealing.
pub fn apply_to_router(
    ev: &FaultEvent,
    router: &mut PoolRouter,
    steal_threshold: Option<usize>,
) -> Vec<Routed> {
    let mut out = match *ev {
        FaultEvent::KillNode { node, .. } => router.fail_node(node),
        FaultEvent::RecoverNode { node, .. } => router.recover_node(node),
        FaultEvent::DegradeNic { node, gbps, .. } => {
            router.set_node_capacity(node, gbps);
            Vec::new()
        }
    };
    if let Some(threshold) = steal_threshold {
        out.extend(router.rebalance(threshold));
    }
    out
}

/// One applied fault, for reports.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub node: usize,
    /// `"kill"` / `"recover"` / `"degrade"` (see [`FaultEvent::label`]).
    pub action: &'static str,
    /// When the plan scheduled the event (fabric-local seconds).
    pub planned_s: f64,
    /// When the fabric actually applied it.
    pub applied_s: f64,
    /// Transfers admitted on surviving/recovered nodes by this event.
    pub admitted: usize,
    /// Bytes the node had served when the event applied (submit-NIC
    /// `bytes_carried` in the simulator; cumulative `FileServer` payload
    /// bytes on the real fabric). A recovered node whose final served
    /// total exceeds its recovery record's value demonstrably served
    /// bytes again.
    pub bytes_served_before: u64,
}

/// The per-node fault timeline a chaos run reports.
#[derive(Debug, Clone, Default)]
pub struct ChaosTimeline {
    pub records: Vec<FaultRecord>,
}

impl ChaosTimeline {
    pub fn record(
        &mut self,
        node: usize,
        action: &'static str,
        planned_s: f64,
        applied_s: f64,
        admitted: usize,
        bytes_served_before: u64,
    ) {
        self.records.push(FaultRecord {
            node,
            action,
            planned_s,
            applied_s,
            admitted,
            bytes_served_before,
        });
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records touching one node, in application order.
    pub fn for_node(&self, node: usize) -> Vec<&FaultRecord> {
        self.records.iter().filter(|r| r.node == node).collect()
    }

    /// Applied events with the given action label.
    pub fn count(&self, action: &str) -> usize {
        self.records.iter().filter(|r| r.action == action).count()
    }

    /// One line per applied event, for the CLI.
    pub fn render(&self) -> String {
        self.records
            .iter()
            .map(|r| {
                format!(
                    "{} node {} @{:.2}s (planned {:.2}s): {} re-admitted, {} B served before",
                    r.action, r.node, r.applied_s, r.planned_s, r.admitted, r.bytes_served_before
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mover::{AdmissionConfig, RouterPolicy, TransferRequest};
    use crate::transfer::ThrottlePolicy;

    #[test]
    fn parse_roundtrip_and_sort() {
        let plan = FaultPlan::parse("recover:1@90; kill:1@30, degrade:0@10.5:25").unwrap();
        assert_eq!(plan.events.len(), 3);
        let sorted = plan.sorted();
        assert_eq!(sorted[0].label(), "degrade");
        assert_eq!(sorted[1].label(), "kill");
        assert_eq!(sorted[2].label(), "recover");
        assert_eq!(sorted[0].node(), 0);
        assert!((sorted[0].at() - 10.5).abs() < 1e-12);
        // describe() re-parses to the same plan.
        let text = plan.describe();
        assert_eq!(FaultPlan::parse(&text).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        assert!(FaultPlan::parse("kill1@30").is_err());
        assert!(FaultPlan::parse("kill:x@30").is_err());
        assert!(FaultPlan::parse("kill:1@-3").is_err());
        assert!(FaultPlan::parse("explode:1@3").is_err());
        assert!(FaultPlan::parse("degrade:1@3").is_err(), "degrade needs Gbps");
        assert!(FaultPlan::parse("degrade:1@3:0").unwrap().validate(2).is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn validate_checks_node_bounds() {
        let plan = FaultPlan::default().kill(3, 1.0);
        assert!(plan.validate(4).is_ok());
        assert!(plan.validate(3).is_err());
    }

    #[test]
    fn from_config_reads_plan_and_threshold() {
        let cfg = Config::parse(
            "FAULT_PLAN = kill:1@30; recover:1@90\nSTEAL_THRESHOLD = 4",
        )
        .unwrap();
        let plan = FaultPlan::from_config(&cfg).unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.steal_threshold, Some(4));

        let empty = Config::parse("").unwrap();
        assert!(FaultPlan::from_config(&empty).unwrap().is_empty());

        let bad = Config::parse("FAULT_PLAN = frobnicate:1@2").unwrap();
        assert!(FaultPlan::from_config(&bad).is_err());
    }

    #[test]
    fn apply_to_router_drives_kill_recover_and_steal() {
        let mut router = PoolRouter::sim(
            2,
            1,
            AdmissionConfig::Throttle(ThrottlePolicy::MaxConcurrent(1)),
            RouterPolicy::RoundRobin,
        );
        for t in 0..10 {
            router.request(TransferRequest::new(t, "o", 5));
        }
        let kill = FaultEvent::KillNode { node: 0, at: 1.0 };
        let rescued = apply_to_router(&kill, &mut router, Some(1));
        assert!(rescued.is_empty(), "survivor is at its limit");
        assert!(router.is_failed(0));
        assert_eq!(router.stats().shard_failed, 1);
        assert_eq!(router.waiting(), 9, "node 0's backlog moved to node 1");

        let recover = FaultEvent::RecoverNode { node: 0, at: 2.0 };
        let admitted = apply_to_router(&recover, &mut router, Some(1));
        assert!(!router.is_failed(0));
        // The recovered node admits a stolen transfer immediately…
        assert_eq!(admitted.len(), 1);
        assert_eq!(admitted[0].node, 0);
        // …and the queues end up within the threshold.
        let lens = router.waiting_per_node();
        let max = lens.iter().max().unwrap();
        let min = lens.iter().min().unwrap();
        assert!(max - min <= 1, "imbalance {lens:?} above threshold");
        let st = router.stats();
        assert_eq!(st.node_recovered, 1);
        assert!(st.stolen > 0);
    }

    #[test]
    fn timeline_accounting() {
        let mut tl = ChaosTimeline::default();
        tl.record(1, "kill", 30.0, 30.1, 4, 1000);
        tl.record(1, "recover", 90.0, 90.0, 2, 1000);
        tl.record(0, "degrade", 10.0, 10.0, 0, 0);
        assert_eq!(tl.count("kill"), 1);
        assert_eq!(tl.for_node(1).len(), 2);
        assert!(!tl.is_empty());
        let text = tl.render();
        assert!(text.contains("kill node 1"), "{text}");
        assert!(text.contains("recover node 1"), "{text}");
    }
}
