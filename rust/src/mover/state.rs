//! Sharded, concurrently-readable router state.
//!
//! At million-owner scale the [`PoolRouter`](super::PoolRouter)'s
//! per-ticket maps (`source_of`, `node_of`, `requests`) and its
//! per-owner affinity pins (`dtn_pin`) dominate both memory traffic and
//! lock hold time: on the real TCP fabric every worker that wants to
//! know "which node serves my ticket?" had to take the *one* mutex
//! wrapping the whole router. [`RouterState`] splits that state into K
//! independently locked shards — ticket maps sharded by ticket, owner
//! pins sharded by the stable FNV-1a owner hash — so
//!
//! * the router's own mutations touch exactly one ticket shard (and at
//!   most one pin shard) per decision instead of one global map, and
//! * the fabric's readers ([`RouterStateHandle`]) answer
//!   `node_of`/`source_of`/liveness probes by locking one shard,
//!   concurrently with each other and without the router-wide gate.
//!
//! Sharding is pure partitioning: for any shard count the maps hold
//! exactly the same entries, so routing decisions are byte-identical
//! across K (a property `tests/props.rs` checks). The shard count is
//! the `ROUTER_SHARDS` knob ([`shards_from_config`]).
//!
//! Lock order: a ticket-shard lock may be held while taking a pin-shard
//! lock (the selector pins an owner while reading the request body),
//! never the reverse — the two live in disjoint mutex sets, handle
//! readers take exactly one lock at a time, and the router's mutating
//! half is serialized by `&mut self`, so the nesting cannot deadlock.

use super::source::DataSource;
use super::TransferRequest;
use crate::config::{Config, ConfigError};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Default `ROUTER_SHARDS`: enough to keep real-fabric readers off each
/// other's locks without bloating the sim's per-router footprint.
pub const DEFAULT_ROUTER_SHARDS: usize = 16;

/// The `ROUTER_SHARDS` condor-style knob (default
/// [`DEFAULT_ROUTER_SHARDS`]; clamped to at least 1).
///
/// ```text
/// ROUTER_SHARDS = 32   # state shards per router
/// ```
pub fn shards_from_config(cfg: &Config) -> Result<usize, ConfigError> {
    Ok((cfg.get_u64("ROUTER_SHARDS", DEFAULT_ROUTER_SHARDS as u64)?).max(1) as usize)
}

/// FNV-1a over the owner string — the same stable hash the router's
/// owner-affinity policy uses, so pin placement is deterministic.
pub(crate) fn owner_hash(owner: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in owner.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One ticket shard: the per-ticket maps for tickets hashing here.
#[derive(Debug, Default)]
struct TicketShard {
    /// Data source of every admitted, not-yet-completed ticket.
    source_of: HashMap<u32, DataSource>,
    /// Submit node of every in-router (waiting or active) ticket.
    node_of: HashMap<u32, usize>,
    /// Request bodies of in-router tickets, kept so a node failure can
    /// re-route its whole backlog — waiting AND in-flight.
    requests: HashMap<u32, TransferRequest>,
}

/// One pin shard: owner → pinned data node for owners hashing here.
#[derive(Debug, Default)]
struct PinShard {
    dtn_pin: HashMap<String, usize>,
}

#[derive(Debug)]
struct StateInner {
    tickets: Vec<Mutex<TicketShard>>,
    pins: Vec<Mutex<PinShard>>,
    /// Submit-node down flags, readable without any shard lock.
    node_down: Vec<AtomicBool>,
    /// DTN down flags (empty with no DTN fleet).
    dtn_down: Vec<AtomicBool>,
}

/// The router's sharded ticket/owner state. Cheap to hand out as a
/// read-side [`RouterStateHandle`]; all map operations lock exactly one
/// shard.
#[derive(Debug)]
pub struct RouterState {
    inner: Arc<StateInner>,
}

impl RouterState {
    pub fn new(shards: usize, n_nodes: usize) -> RouterState {
        let k = shards.max(1);
        RouterState {
            inner: Arc::new(StateInner {
                tickets: (0..k).map(|_| Mutex::new(TicketShard::default())).collect(),
                pins: (0..k).map(|_| Mutex::new(PinShard::default())).collect(),
                node_down: (0..n_nodes).map(|_| AtomicBool::new(false)).collect(),
                dtn_down: Vec::new(),
            }),
        }
    }

    /// Number of state shards.
    pub fn shard_count(&self) -> usize {
        self.inner.tickets.len()
    }

    /// Builder-phase reconfiguration (shard count / DTN fleet size).
    /// Panics if a [`RouterStateHandle`] was already taken — resizing
    /// would strand readers on stale shards.
    fn rebuild(&mut self, shards: usize, dtns: usize) {
        let n_nodes = self.inner.node_down.len();
        assert!(
            Arc::get_mut(&mut self.inner).is_some(),
            "configure router state before taking handles"
        );
        let k = shards.max(1);
        self.inner = Arc::new(StateInner {
            tickets: (0..k).map(|_| Mutex::new(TicketShard::default())).collect(),
            pins: (0..k).map(|_| Mutex::new(PinShard::default())).collect(),
            node_down: (0..n_nodes).map(|_| AtomicBool::new(false)).collect(),
            dtn_down: (0..dtns).map(|_| AtomicBool::new(false)).collect(),
        });
    }

    /// Re-shard to `shards` (builder phase: maps must be empty).
    pub(crate) fn set_shards(&mut self, shards: usize) {
        let dtns = self.inner.dtn_down.len();
        self.rebuild(shards, dtns);
    }

    /// Size the DTN down-flag set (builder phase).
    pub(crate) fn set_dtn_count(&mut self, dtns: usize) {
        let k = self.inner.tickets.len();
        self.rebuild(k, dtns);
    }

    fn tshard(&self, ticket: u32) -> &Mutex<TicketShard> {
        &self.inner.tickets[ticket as usize % self.inner.tickets.len()]
    }

    fn pshard(&self, owner: &str) -> &Mutex<PinShard> {
        &self.inner.pins[(owner_hash(owner) % self.inner.pins.len() as u64) as usize]
    }

    pub(crate) fn insert_request(&self, req: &TransferRequest) {
        let mut s = self.tshard(req.ticket).lock().unwrap();
        s.requests.insert(req.ticket, req.clone());
    }

    pub(crate) fn request_clone(&self, ticket: u32) -> Option<TransferRequest> {
        self.tshard(ticket).lock().unwrap().requests.get(&ticket).cloned()
    }

    /// Read the request body under the shard lock without cloning the
    /// owner string — the hot path's per-decision view.
    pub(crate) fn with_request<R>(
        &self,
        ticket: u32,
        f: impl FnOnce(Option<&TransferRequest>) -> R,
    ) -> R {
        let s = self.tshard(ticket).lock().unwrap();
        f(s.requests.get(&ticket))
    }

    pub(crate) fn set_source(&self, ticket: u32, source: DataSource) {
        self.tshard(ticket).lock().unwrap().source_of.insert(ticket, source);
    }

    pub(crate) fn remove_source(&self, ticket: u32) -> Option<DataSource> {
        self.tshard(ticket).lock().unwrap().source_of.remove(&ticket)
    }

    pub(crate) fn source_of(&self, ticket: u32) -> Option<DataSource> {
        self.tshard(ticket).lock().unwrap().source_of.get(&ticket).copied()
    }

    pub(crate) fn set_node(&self, ticket: u32, node: usize) {
        self.tshard(ticket).lock().unwrap().node_of.insert(ticket, node);
    }

    pub(crate) fn remove_node(&self, ticket: u32) -> Option<usize> {
        self.tshard(ticket).lock().unwrap().node_of.remove(&ticket)
    }

    pub(crate) fn node_of(&self, ticket: u32) -> Option<usize> {
        self.tshard(ticket).lock().unwrap().node_of.get(&ticket).copied()
    }

    /// Completion scrub: drop the ticket's request body, source
    /// placement and node mapping in one shard lock.
    pub(crate) fn scrub(&self, ticket: u32) -> (Option<DataSource>, Option<usize>) {
        let mut s = self.tshard(ticket).lock().unwrap();
        s.requests.remove(&ticket);
        (s.source_of.remove(&ticket), s.node_of.remove(&ticket))
    }

    /// Tickets currently mapped to submit node `node`, in arbitrary
    /// shard-major order — callers re-routing them must sort first
    /// (`router::sorted_tickets`).
    pub(crate) fn tickets_on_node(&self, node: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for shard in &self.inner.tickets {
            let s = shard.lock().unwrap();
            out.extend(s.node_of.iter().filter(|&(_, &n)| n == node).map(|(&t, _)| t));
        }
        out
    }

    /// Tickets currently placed on DTN `dtn` (slot holders and queued
    /// alike), in arbitrary shard-major order — sort before re-sourcing.
    pub(crate) fn tickets_on_dtn(&self, dtn: usize) -> Vec<u32> {
        let mut out = Vec::new();
        for shard in &self.inner.tickets {
            let s = shard.lock().unwrap();
            out.extend(
                s.source_of
                    .iter()
                    .filter(|&(_, &src)| src == DataSource::Dtn { dtn })
                    .map(|(&t, _)| t),
            );
        }
        out
    }

    pub(crate) fn pin_of(&self, owner: &str) -> Option<usize> {
        self.pshard(owner).lock().unwrap().dtn_pin.get(owner).copied()
    }

    pub(crate) fn set_pin(&self, owner: &str, dtn: usize) {
        self.pshard(owner).lock().unwrap().dtn_pin.insert(owner.to_string(), dtn);
    }

    /// Drop every owner pin pointing at `dtn` (its page cache died).
    pub(crate) fn drop_pins_to(&self, dtn: usize) {
        for shard in &self.inner.pins {
            shard.lock().unwrap().dtn_pin.retain(|_, &mut d| d != dtn);
        }
    }

    pub(crate) fn set_node_down(&self, node: usize, down: bool) {
        self.inner.node_down[node].store(down, Ordering::Relaxed);
    }

    pub(crate) fn set_dtn_down(&self, dtn: usize, down: bool) {
        self.inner.dtn_down[dtn].store(down, Ordering::Relaxed);
    }

    /// A read-side handle sharing this router's state. Readers lock one
    /// shard per query — never the router, never the fabric gate.
    pub fn handle(&self) -> RouterStateHandle {
        RouterStateHandle {
            inner: Arc::clone(&self.inner),
        }
    }
}

/// Concurrent read access to a router's sharded state, for fabric
/// workers that only need ticket lookups and liveness probes (the
/// mid-transfer retry path). Cloning is an `Arc` bump.
#[derive(Debug, Clone)]
pub struct RouterStateHandle {
    inner: Arc<StateInner>,
}

impl RouterStateHandle {
    fn tshard(&self, ticket: u32) -> &Mutex<TicketShard> {
        &self.inner.tickets[ticket as usize % self.inner.tickets.len()]
    }

    /// Submit node of an in-router (waiting or admitted) ticket.
    pub fn node_of(&self, ticket: u32) -> Option<usize> {
        self.tshard(ticket).lock().unwrap().node_of.get(&ticket).copied()
    }

    /// Data source of an admitted, not-yet-completed ticket.
    pub fn source_of(&self, ticket: u32) -> Option<DataSource> {
        self.tshard(ticket).lock().unwrap().source_of.get(&ticket).copied()
    }

    /// Is the submit node poisoned right now?
    pub fn is_node_down(&self, node: usize) -> bool {
        self.inner
            .node_down
            .get(node)
            .map(|f| f.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Is the data node poisoned right now?
    pub fn is_dtn_down(&self, dtn: usize) -> bool {
        self.inner
            .dtn_down
            .get(dtn)
            .map(|f| f.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Number of state shards (reporting/bench visibility).
    pub fn shard_count(&self) -> usize {
        self.inner.tickets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_maps_shard_and_scrub() {
        let st = RouterState::new(4, 2);
        for t in 0..32 {
            st.insert_request(&TransferRequest::new(t, format!("u{t}"), 10));
            st.set_node(t, (t % 2) as usize);
            st.set_source(t, DataSource::Funnel { node: 0 });
        }
        assert_eq!(st.node_of(7), Some(1));
        assert!(st.with_request(9, |r| r.map(|r| r.bytes)) == Some(10));
        let mut on0 = st.tickets_on_node(0);
        on0.sort_unstable();
        assert_eq!(on0.len(), 16);
        let (src, node) = st.scrub(7);
        assert_eq!(src, Some(DataSource::Funnel { node: 0 }));
        assert_eq!(node, Some(1));
        assert_eq!(st.node_of(7), None);
        assert!(st.request_clone(7).is_none());
    }

    #[test]
    fn pins_shard_by_owner_and_drop_by_dtn() {
        let st = RouterState::new(8, 1);
        st.set_pin("alice", 2);
        st.set_pin("bob", 3);
        assert_eq!(st.pin_of("alice"), Some(2));
        st.drop_pins_to(2);
        assert_eq!(st.pin_of("alice"), None);
        assert_eq!(st.pin_of("bob"), Some(3));
    }

    #[test]
    fn handle_reads_concurrently_with_down_flags() {
        let mut st = RouterState::new(2, 3);
        st.set_dtn_count(2);
        st.set_node(5, 1);
        st.set_source(5, DataSource::Dtn { dtn: 1 });
        let h = st.handle();
        assert_eq!(h.node_of(5), Some(1));
        assert_eq!(h.source_of(5), Some(DataSource::Dtn { dtn: 1 }));
        assert!(!h.is_node_down(2));
        st.set_node_down(2, true);
        assert!(h.is_node_down(2));
        st.set_dtn_down(0, true);
        assert!(h.is_dtn_down(0));
        assert!(!h.is_dtn_down(1));
        // Out-of-range probes are "not down", matching an empty fleet.
        assert!(!h.is_dtn_down(99));
        assert_eq!(h.shard_count(), 2);
    }

    #[test]
    fn shards_knob_parses_and_clamps() {
        let cfg = Config::parse("ROUTER_SHARDS = 32").unwrap();
        assert_eq!(shards_from_config(&cfg).unwrap(), 32);
        let dflt = Config::parse("").unwrap();
        assert_eq!(shards_from_config(&dflt).unwrap(), DEFAULT_ROUTER_SHARDS);
        let zero = Config::parse("ROUTER_SHARDS = 0").unwrap();
        assert_eq!(shards_from_config(&zero).unwrap(), 1);
    }
}
