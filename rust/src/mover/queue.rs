//! The policy-driven admission queue: waiting/active bookkeeping that the
//! schedd (and the real fabric) delegate to instead of owning.
//!
//! Replaces the mechanics of the legacy FIFO `TransferQueue` with a
//! pluggable selection order, per-owner accounting for fair-share, and a
//! saturating complete path: a spurious `complete` (the old `release`
//! underflow) is counted instead of corrupting the active count.

use super::policy::{ActiveView, AdmissionPolicy};
use super::TransferRequest;
use std::collections::{HashMap, VecDeque};

/// A transfer-admission queue driven by an [`AdmissionPolicy`].
#[derive(Debug)]
pub struct AdmissionQueue {
    policy: Box<dyn AdmissionPolicy + Send>,
    waiting: VecDeque<TransferRequest>,
    /// Owner of each admitted, not-yet-completed ticket.
    active_owner: HashMap<u32, String>,
    active_by_owner: HashMap<String, u32>,
    active: u32,
    pub peak_active: u32,
    pub total_admitted: u64,
    /// Completes with no matching active OR waiting transfer (saturated,
    /// counted).
    pub released_without_active: u64,
    /// Completes that cancelled a still-waiting request (failover: the
    /// original executor of a re-routed transfer reporting in).
    pub cancelled_waiting: u64,
}

impl AdmissionQueue {
    pub fn new(policy: Box<dyn AdmissionPolicy + Send>) -> AdmissionQueue {
        AdmissionQueue {
            policy,
            waiting: VecDeque::new(),
            active_owner: HashMap::new(),
            active_by_owner: HashMap::new(),
            active: 0,
            peak_active: 0,
            total_admitted: 0,
            released_without_active: 0,
            cancelled_waiting: 0,
        }
    }

    pub fn active(&self) -> u32 {
        self.active
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn policy_desc(&self) -> String {
        self.policy.describe()
    }

    /// Enqueue a request; returns the requests admitted NOW (possibly
    /// including this one), in admission order.
    pub fn enqueue(&mut self, req: TransferRequest) -> Vec<TransferRequest> {
        self.waiting.push_back(req);
        self.admit()
    }

    /// Remove and return every waiting (not-yet-admitted) request — the
    /// failover path when this queue's submit node dies and the router
    /// re-routes its backlog. Active transfers are untouched.
    pub fn drain_waiting(&mut self) -> Vec<TransferRequest> {
        self.waiting.drain(..).collect()
    }

    /// Remove and return the most recently enqueued waiting request —
    /// the work-stealing path steals from the tail so the oldest
    /// requests keep their admission order on their home node.
    pub fn steal_waiting(&mut self) -> Option<TransferRequest> {
        self.waiting.pop_back()
    }

    /// A transfer finished; returns newly admitted requests. A complete
    /// for a still-WAITING ticket cancels its queue entry (the failover
    /// path: after `PoolRouter::fail_node` re-routes an in-flight
    /// transfer, the original executor's completion must not leave a
    /// ghost request that would later be admitted with no owner). A
    /// ticket with neither an active nor a waiting transfer increments
    /// `released_without_active` instead of underflowing.
    pub fn complete(&mut self, ticket: u32) -> Vec<TransferRequest> {
        match self.active_owner.remove(&ticket) {
            Some(owner) => {
                self.active = self.active.saturating_sub(1);
                if let Some(n) = self.active_by_owner.get_mut(&owner) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        self.active_by_owner.remove(&owner);
                    }
                }
            }
            None => {
                if let Some(pos) = self.waiting.iter().position(|r| r.ticket == ticket) {
                    self.waiting.remove(pos);
                    self.cancelled_waiting += 1;
                } else {
                    self.released_without_active += 1;
                }
            }
        }
        self.admit()
    }

    fn admit(&mut self) -> Vec<TransferRequest> {
        let mut out = Vec::new();
        while self.active < self.policy.limit() && !self.waiting.is_empty() {
            let view = ActiveView {
                active_total: self.active,
                active_by_owner: &self.active_by_owner,
            };
            let Some(idx) = self.policy.select(&self.waiting, &view) else {
                break;
            };
            let req = self
                .waiting
                .remove(idx)
                .expect("policy selected a valid waiting index");
            self.active += 1;
            *self.active_by_owner.entry(req.owner.clone()).or_insert(0) += 1;
            self.active_owner.insert(req.ticket, req.owner.clone());
            self.total_admitted += 1;
            self.peak_active = self.peak_active.max(self.active);
            out.push(req);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mover::policy::AdmissionConfig;
    use crate::transfer::ThrottlePolicy;

    fn q(cfg: AdmissionConfig) -> AdmissionQueue {
        AdmissionQueue::new(cfg.build())
    }

    fn r(t: u32, owner: &str, bytes: u64) -> TransferRequest {
        TransferRequest::new(t, owner, bytes)
    }

    fn tickets(v: &[TransferRequest]) -> Vec<u32> {
        v.iter().map(|x| x.ticket).collect()
    }

    #[test]
    fn fifo_matches_legacy_queue_semantics() {
        let mut aq = q(ThrottlePolicy::MaxConcurrent(2).into());
        assert_eq!(tickets(&aq.enqueue(r(1, "a", 10))), vec![1]);
        assert_eq!(tickets(&aq.enqueue(r(2, "a", 10))), vec![2]);
        assert_eq!(tickets(&aq.enqueue(r(3, "a", 10))), Vec::<u32>::new());
        assert_eq!(aq.active(), 2);
        assert_eq!(aq.waiting(), 1);
        assert_eq!(tickets(&aq.complete(1)), vec![3]);
        assert_eq!(aq.active(), 2);
        aq.complete(2);
        aq.complete(3);
        assert_eq!(aq.active(), 0);
        assert_eq!(aq.peak_active, 2);
        assert_eq!(aq.total_admitted, 3);
    }

    #[test]
    fn spurious_complete_is_counted_not_underflowed() {
        let mut aq = q(ThrottlePolicy::Disabled.into());
        assert!(aq.complete(99).is_empty());
        assert_eq!(aq.active(), 0, "no underflow");
        assert_eq!(aq.released_without_active, 1);
        // Queue still functions normally afterwards.
        assert_eq!(tickets(&aq.enqueue(r(1, "a", 1))), vec![1]);
        aq.complete(1);
        assert_eq!(aq.active(), 0);
        // Double-complete of a finished ticket is also just counted.
        aq.complete(1);
        assert_eq!(aq.released_without_active, 2);
    }

    #[test]
    fn complete_of_waiting_ticket_cancels_it() {
        let mut aq = q(ThrottlePolicy::MaxConcurrent(1).into());
        assert_eq!(tickets(&aq.enqueue(r(1, "a", 1))), vec![1]);
        assert!(aq.enqueue(r(2, "a", 1)).is_empty(), "queued behind 1");
        // The failover path: ticket 2's original executor reports in
        // while 2 is still waiting — the entry must vanish, not ghost.
        assert!(aq.complete(2).is_empty());
        assert_eq!(aq.waiting(), 0, "waiting entry cancelled");
        assert_eq!(aq.cancelled_waiting, 1);
        assert_eq!(aq.released_without_active, 0);
        // Completing 1 must not resurrect 2.
        assert!(aq.complete(1).is_empty());
        assert_eq!(aq.active(), 0);
        assert_eq!(aq.total_admitted, 1, "2 was never admitted");
    }

    #[test]
    fn fair_share_interleaves_two_owners() {
        let mut aq = q(AdmissionConfig::FairShare { limit: 1 });
        // alice floods first, bob arrives later — strict alternation.
        aq.enqueue(r(0, "alice", 1));
        for t in 1..4 {
            aq.enqueue(r(t, "alice", 1));
        }
        for t in 4..7 {
            aq.enqueue(r(t, "bob", 1));
        }
        let mut order = Vec::new();
        let mut last = 0u32;
        for _ in 0..6 {
            let adm = aq.complete(last);
            assert_eq!(adm.len(), 1);
            order.push(adm[0].owner.clone());
            last = adm[0].ticket;
        }
        assert_eq!(
            order,
            vec!["alice", "bob", "alice", "bob", "alice", "bob"],
            "owners alternate once both are waiting"
        );
    }

    #[test]
    fn weighted_by_size_admits_small_first() {
        let mut aq = q(AdmissionConfig::WeightedBySize { limit: 1 });
        aq.enqueue(r(0, "a", 1000)); // admitted immediately (capacity free)
        aq.enqueue(r(1, "a", 500));
        aq.enqueue(r(2, "a", 10));
        aq.enqueue(r(3, "a", 200));
        let next = aq.complete(0);
        assert_eq!(tickets(&next), vec![2], "smallest first");
        let next = aq.complete(2);
        assert_eq!(tickets(&next), vec![3]);
        let next = aq.complete(3);
        assert_eq!(tickets(&next), vec![1]);
    }

    #[test]
    fn per_owner_accounting_tracks_completion() {
        let mut aq = q(ThrottlePolicy::Disabled.into());
        aq.enqueue(r(1, "a", 1));
        aq.enqueue(r(2, "b", 1));
        aq.enqueue(r(3, "a", 1));
        assert_eq!(aq.active(), 3);
        aq.complete(1);
        aq.complete(3);
        aq.complete(2);
        assert_eq!(aq.active(), 0);
        assert_eq!(aq.released_without_active, 0);
    }
}
