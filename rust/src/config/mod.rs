//! HTCondor-style configuration: `KEY = value` files with `$(MACRO)`
//! expansion, comments, line continuations and typed accessors.
//!
//! This is both a faithful substrate (HTCondor pools are driven by exactly
//! this format) and the crate's own config system — every knob the paper's
//! experiments touch (transfer queue throttle, security method, NIC
//! capacities…) is a named knob with a registered default, so experiment
//! configs only state their deltas, like a real condor_config.local.
//!
//! ```text
//! # fig1 LAN experiment
//! WORKERS = 6
//! SLOTS_TOTAL = 200
//! FILE_TRANSFER_DISK_LOAD_THROTTLE = false
//! SEC_DEFAULT_ENCRYPTION = CHACHA20
//! SUBMIT_NIC_GBPS = 100
//! POOL = htcdm-$(WORKERS)w
//! ```

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration table with macro expansion.
#[derive(Debug, Clone, Default)]
pub struct Config {
    entries: BTreeMap<String, String>,
}

#[derive(Debug)]
pub enum ConfigError {
    Parse { line: usize, msg: String },
    Recursion(String),
    Type(String, &'static str, String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            ConfigError::Recursion(m) => write!(f, "macro recursion while expanding $({m})"),
            ConfigError::Type(knob, want, got) => {
                write!(f, "knob {knob}: expected {want}, got '{got}'")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse config text, layering on top of the existing entries
    /// (later files override earlier ones, as in HTCondor).
    pub fn parse_into(&mut self, text: &str) -> Result<(), ConfigError> {
        let mut pending = String::new();
        let mut start_line = 0usize;
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if pending.is_empty() {
                start_line = i + 1;
            }
            // Continuation: trailing backslash.
            if let Some(stripped) = line.strip_suffix('\\') {
                pending.push_str(stripped);
                pending.push(' ');
                continue;
            }
            pending.push_str(line);
            let full = std::mem::take(&mut pending);
            self.parse_line(&full, start_line)?;
        }
        if !pending.trim().is_empty() {
            return Err(ConfigError::Parse {
                line: start_line,
                msg: "dangling continuation".into(),
            });
        }
        Ok(())
    }

    fn parse_line(&mut self, line: &str, lineno: usize) -> Result<(), ConfigError> {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            return Ok(());
        }
        let (k, v) = t.split_once('=').ok_or_else(|| ConfigError::Parse {
            line: lineno,
            msg: format!("expected KEY = value, got '{t}'"),
        })?;
        let key = k.trim();
        if key.is_empty()
            || !key
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
        {
            return Err(ConfigError::Parse {
                line: lineno,
                msg: format!("bad key '{key}'"),
            });
        }
        self.entries
            .insert(key.to_ascii_uppercase(), v.trim().to_string());
        Ok(())
    }

    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut c = Config::new();
        c.parse_into(text)?;
        Ok(c)
    }

    /// Set a knob programmatically (same override semantics as a file).
    pub fn set(&mut self, key: &str, value: impl fmt::Display) {
        self.entries
            .insert(key.to_ascii_uppercase(), value.to_string());
    }

    /// Raw (unexpanded) lookup.
    pub fn raw(&self, key: &str) -> Option<&str> {
        self.entries.get(&key.to_ascii_uppercase()).map(|s| s.as_str())
    }

    /// Lookup with `$(MACRO)` expansion.
    pub fn get(&self, key: &str) -> Result<Option<String>, ConfigError> {
        match self.raw(key) {
            None => Ok(None),
            Some(v) => Ok(Some(self.expand(v, 0)?)),
        }
    }

    fn expand(&self, value: &str, depth: usize) -> Result<String, ConfigError> {
        if depth > 16 {
            return Err(ConfigError::Recursion(value.to_string()));
        }
        let mut out = String::with_capacity(value.len());
        let mut rest = value;
        while let Some(start) = rest.find("$(") {
            out.push_str(&rest[..start]);
            let after = &rest[start + 2..];
            let end = after.find(')').ok_or_else(|| ConfigError::Parse {
                line: 0,
                msg: format!("unterminated $( in '{value}'"),
            })?;
            let name = &after[..end];
            match self.raw(name) {
                Some(sub) => out.push_str(&self.expand(sub, depth + 1)?),
                None => {} // undefined macros expand to empty, as in HTCondor
            }
            rest = &after[end + 1..];
        }
        out.push_str(rest);
        Ok(out)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).ok().flatten().unwrap_or_else(|| default.to_string())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, ConfigError> {
        match self.get(key).ok().flatten() {
            None => Ok(default),
            Some(v) => v
                .trim()
                .parse()
                .map_err(|_| ConfigError::Type(key.into(), "integer", v)),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key).ok().flatten() {
            None => Ok(default),
            Some(v) => v
                .trim()
                .parse()
                .map_err(|_| ConfigError::Type(key.into(), "float", v)),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key).ok().flatten() {
            None => Ok(default),
            Some(v) => match v.trim().to_ascii_lowercase().as_str() {
                "true" | "yes" | "1" | "on" => Ok(true),
                "false" | "no" | "0" | "off" => Ok(false),
                _ => Err(ConfigError::Type(key.into(), "bool", v)),
            },
        }
    }

    /// Byte sizes with HTCondor-ish suffixes: `2GB`, `64KB`, `1MB`, `512`.
    pub fn get_bytes(&self, key: &str, default: u64) -> Result<u64, ConfigError> {
        let Some(v) = self.get(key).ok().flatten() else {
            return Ok(default);
        };
        parse_bytes(&v).ok_or_else(|| ConfigError::Type(key.into(), "byte size", v))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// `2GB` / `64KB` / `1.5MB` / `512` -> bytes (decimal multipliers, then
/// binary `KiB/MiB/GiB` also accepted).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim();
    let (num, mult) = if let Some(p) = t.strip_suffix("GiB") {
        (p, 1u64 << 30)
    } else if let Some(p) = t.strip_suffix("MiB") {
        (p, 1 << 20)
    } else if let Some(p) = t.strip_suffix("KiB") {
        (p, 1 << 10)
    } else if let Some(p) = t.strip_suffix("GB") {
        (p, 1_000_000_000)
    } else if let Some(p) = t.strip_suffix("MB") {
        (p, 1_000_000)
    } else if let Some(p) = t.strip_suffix("KB") {
        (p, 1_000)
    } else if let Some(p) = t.strip_suffix('B') {
        (p, 1)
    } else {
        (t, 1)
    };
    let n: f64 = num.trim().parse().ok()?;
    if n < 0.0 {
        return None;
    }
    Some((n * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_parse_and_override() {
        let mut c = Config::parse("A = 1\nB = two\n# comment\n\nA=3").unwrap();
        assert_eq!(c.get("a").unwrap().unwrap(), "3");
        assert_eq!(c.get("B").unwrap().unwrap(), "two");
        c.parse_into("B = overridden").unwrap();
        assert_eq!(c.get("b").unwrap().unwrap(), "overridden");
    }

    #[test]
    fn macro_expansion() {
        let c = Config::parse("POOL = prp\nNAME = htcdm-$(POOL)-$(MISSING)x").unwrap();
        assert_eq!(c.get("NAME").unwrap().unwrap(), "htcdm-prp-x");
    }

    #[test]
    fn nested_macros() {
        let c = Config::parse("A = a\nB = $(A)b\nC = $(B)c").unwrap();
        assert_eq!(c.get("C").unwrap().unwrap(), "abc");
    }

    #[test]
    fn recursion_detected() {
        let c = Config::parse("A = $(B)\nB = $(A)").unwrap();
        assert!(matches!(c.get("A"), Err(ConfigError::Recursion(_))));
    }

    #[test]
    fn continuations() {
        let c = Config::parse("LONG = a \\\n  b \\\n  c").unwrap();
        assert_eq!(c.get("LONG").unwrap().unwrap(), "a    b    c");
    }

    #[test]
    fn typed_accessors() {
        let c = Config::parse("N = 42\nF = 2.5\nT = True\nX = nope").unwrap();
        assert_eq!(c.get_u64("N", 0).unwrap(), 42);
        assert_eq!(c.get_u64("MISSING", 7).unwrap(), 7);
        assert_eq!(c.get_f64("F", 0.0).unwrap(), 2.5);
        assert!(c.get_bool("T", false).unwrap());
        assert!(c.get_bool("X", false).is_err());
        assert!(c.get_u64("X", 0).is_err());
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(parse_bytes("2GB"), Some(2_000_000_000));
        assert_eq!(parse_bytes("2GiB"), Some(2 << 30));
        assert_eq!(parse_bytes("64KB"), Some(64_000));
        assert_eq!(parse_bytes("1.5MB"), Some(1_500_000));
        assert_eq!(parse_bytes("512"), Some(512));
        assert_eq!(parse_bytes("-1"), None);
        assert_eq!(parse_bytes("junk"), None);
        let c = Config::parse("SZ = 2GB").unwrap();
        assert_eq!(c.get_bytes("SZ", 0).unwrap(), 2_000_000_000);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Config::parse("NOEQUALS").is_err());
        assert!(Config::parse("BAD KEY = 1").is_err());
        assert!(Config::parse("= 1").is_err());
    }

    #[test]
    fn keys_case_insensitive() {
        let c = Config::parse("MiXeD = v").unwrap();
        assert_eq!(c.raw("mixed"), Some("v"));
        assert_eq!(c.raw("MIXED"), Some("v"));
    }
}
