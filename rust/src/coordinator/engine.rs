//! The virtual-time experiment engine: a full HTCondor-shaped pool
//! (schedd + negotiator + startds + transfer queue) driving sandbox
//! transfers as fluid flows over the simulated testbed.
//!
//! Every piece of the real system participates: jobs are ClassAd-matched
//! to slots by the negotiator (with autoclustering), claims are reused for
//! back-to-back jobs, the schedd's transfer queue gates concurrent
//! uploads, per-stream TCP caps come from the path profile, and the
//! submit NIC monitor produces the Fig. 1/2 timeseries.

use crate::daemons::{Collector, Negotiator, Schedd, SlotId, Startd};
use crate::jobs::JobSpec;
use crate::metrics::BinSeries;
use crate::mover::chaos::{apply_to_router, ChaosTimeline, FaultEvent, FaultPlan};
use crate::mover::task::{TaskProgress, TaskRunner, TunerSample};
use crate::mover::{
    AdmissionConfig, DataSource, MoverStats, PoolRouter, RouterConfig, RouterPolicy, RouterStats,
    ShadowPool, SiteSelector, SourcePlan, SourceSelector,
};
use crate::netsim::solver::SolverKind;
use crate::netsim::topology::{Testbed, TestbedSpec};
use crate::netsim::{calib, FlowId};
use crate::sim::EventQueue;
use crate::storage::{DeviceProfile, ExtentId, Storage};
use crate::transfer::ThrottlePolicy;
use crate::util::units::{Bytes, Gbps, SimTime};
use crate::util::Prng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Everything one simulated experiment needs.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub testbed: TestbedSpec,
    pub n_jobs: u32,
    pub input_bytes: Bytes,
    pub output_bytes: Bytes,
    pub runtime_median_s: f64,
    /// Transfer-admission policy driving the schedd's data mover.
    pub policy: AdmissionConfig,
    /// Shadow-pool shard count (1 = the paper's single-funnel submit
    /// node; >1 models multi-shard data movers).
    pub shadows: u32,
    /// Submit-node count: each node runs its own `ShadowPool` (with
    /// `shadows` shards and its own copy of `policy`) behind a
    /// [`PoolRouter`], and gets its own monitored NIC in the topology.
    /// [`Engine::new`] takes the max of this and the testbed's own
    /// `n_submit_nodes`, then syncs both; a caller-supplied router
    /// ([`Engine::with_router`]) overrides both.
    pub n_submit_nodes: u32,
    /// Pool-level routing strategy splitting the burst across submit
    /// nodes (irrelevant when `n_submit_nodes == 1`).
    pub router: RouterPolicy,
    /// Dedicated data-transfer-node fleet size: each data node gets its
    /// own monitored NIC in the topology and serves sandbox bytes under
    /// the `source` plan. [`Engine::new`] takes the max of this and the
    /// testbed's own `n_data_nodes`, then syncs both; a caller-supplied
    /// router overrides both.
    pub n_data_nodes: u32,
    /// Data-source plan: whether admitted transfers' bytes are served
    /// by the scheduling node's funnel (the paper baseline), the DTN
    /// fleet, or a size-split hybrid.
    pub source: SourcePlan,
    /// Which-DTN selection strategy within the plan's fleet
    /// (round-robin / cache-aware / owner-affinity /
    /// weighted-by-capacity).
    pub source_selector: SourceSelector,
    /// Which-site selection strategy above the DTN selector when the
    /// testbed federates (`N_SITES > 1`): local-first / cache-aware /
    /// round-robin. Irrelevant with one site.
    pub site_selector: SiteSelector,
    /// Per-DTN admission budget: max concurrent transfers one data node
    /// serves (0 = unlimited). A saturated DTN defers placements to its
    /// peers and overflows to the funnel when the whole fleet is full.
    pub dtn_slots: u32,
    /// Per-DTN bounded wait-queue depth (0 = disabled): with queues on,
    /// a budget-full fleet parks transfers on a data node's queue
    /// instead of overflowing to the funnel, promoting each into the
    /// next slot that node frees.
    pub dtn_queue_depth: u32,
    /// Router state shards (`ROUTER_SHARDS` knob): lock shards for the
    /// router's ticket/owner maps. Pure partitioning — decisions are
    /// identical for every value; more shards only cut real-fabric lock
    /// contention.
    pub router_shards: usize,
    /// Admission-cycle batch size (`CYCLE_SIZE` knob): matches handed to
    /// the router per `route_batch` call within one negotiation cycle
    /// (0 = the whole cycle in one batch). Batching is
    /// behavior-preserving — it only amortizes per-call overhead.
    pub cycle_size: usize,
    /// Distinct physical extents behind the job inputs (1 = the paper's
    /// single hard-linked extent; >1 gives cache-aware selection a
    /// working set to place — job `p` reads extent `p % n_extents`).
    pub n_extents: u32,
    /// Distinct job owners, round-robined over procs (1 = the paper's
    /// single benchmark user; >1 makes fair-share scheduling visible).
    pub n_owners: u32,
    /// Fault-injection schedule (virtual-time seconds): submit nodes are
    /// killed / recovered / degraded mid-burst, with the router draining,
    /// re-admitting and work-stealing exactly as on the real fabric.
    /// Empty = the paper's fault-free runs.
    pub faults: FaultPlan,
    pub seed: u64,
    /// Negotiator cycle interval (HTCondor default: 60 s).
    pub negotiation_interval_s: f64,
    /// Per-task admission rate limit in bytes/s (`TASK_RATE_BPS` knob;
    /// 0 = unlimited). Applied on top of a
    /// [`TransferTask`](crate::mover::task::TransferTask)'s own value by
    /// the task drivers ([`run_task_sim`], the real fabric's task
    /// runner) — not by the plain burst engine.
    pub task_rate_bps: u64,
    /// Per-task deadline in seconds (`TASK_DEADLINE_S` knob; 0 = none).
    pub task_deadline_s: f64,
    /// Closed-loop task auto-tuning (`AUTOTUNE` knob): adjust a task's
    /// concurrency and chunk size from observed per-window goodput.
    pub autotune: bool,
    /// Rate solver for the fluid network (`SOLVER` knob / `--solver`
    /// flag): steady-state max-min fair share (the default) or per-flow
    /// TCP windows with slow start, AIMD and sampled loss
    /// ([`SolverKind::TcpDynamic`]). Under the dynamic solver the
    /// per-stream cap drops its Mathis loss term and setup latency its
    /// ramp allowance — both are modeled in-band by the windows.
    pub solver: SolverKind,
}

impl EngineSpec {
    /// The paper's main workload on the given testbed with one of the
    /// classic throttle knobs.
    pub fn paper(testbed: TestbedSpec, throttle: ThrottlePolicy) -> EngineSpec {
        EngineSpec {
            testbed,
            n_jobs: 10_000,
            input_bytes: Bytes(2_000_000_000), // the paper's 2 GB files
            output_bytes: Bytes(4_000),
            runtime_median_s: 5.0,
            policy: throttle.into(),
            shadows: 1,
            n_submit_nodes: 1,
            router: RouterPolicy::LeastLoaded,
            n_data_nodes: 0,
            source: SourcePlan::SubmitFunnel,
            source_selector: SourceSelector::RoundRobin,
            site_selector: SiteSelector::LocalFirst,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            router_shards: crate::mover::DEFAULT_ROUTER_SHARDS,
            cycle_size: 0,
            n_extents: 1,
            n_owners: 1,
            faults: FaultPlan::default(),
            seed: 20210901, // eScience 2021
            negotiation_interval_s: 60.0,
            task_rate_bps: 0,
            task_deadline_s: 0.0,
            autotune: false,
            solver: SolverKind::FairShare,
        }
    }

    /// Apply HTCondor-style config knobs on top of this spec (only knobs
    /// present in the config override; see `config` module docs):
    ///
    /// ```text
    /// JOBS = 1000
    /// INPUT_SIZE = 2GB
    /// OUTPUT_SIZE = 4KB
    /// N_OWNERS = 4
    /// TRANSFER_QUEUE_POLICY = FAIR_SHARE
    /// TRANSFER_QUEUE_MAX_CONCURRENT = 200
    /// SHADOW_POOL_SIZE = 4
    /// N_SUBMIT_NODES = 4
    /// ROUTER_POLICY = ROUND_ROBIN
    /// DATA_NODES = 4
    /// SOURCE_PLAN = DEDICATED_DTN
    /// DTN_THRESHOLD = 64MB
    /// SOURCE_SELECTOR = CACHE_AWARE
    /// DTN_MAX_CONCURRENT = 50
    /// N_EXTENTS = 8
    /// FAULT_PLAN = kill:1@300; recover:1@900
    /// STEAL_THRESHOLD = 4
    /// RECOVERY_RAMP = 32
    /// ```
    ///
    /// `docs/KNOBS.md` is the complete reference for every knob, CLI
    /// flag and environment variable.
    pub fn apply_config(
        &mut self,
        cfg: &crate::config::Config,
    ) -> Result<(), crate::config::ConfigError> {
        self.n_jobs = cfg.get_u64("JOBS", self.n_jobs as u64)? as u32;
        self.input_bytes = Bytes(cfg.get_bytes("INPUT_SIZE", self.input_bytes.0)?);
        self.output_bytes = Bytes(cfg.get_bytes("OUTPUT_SIZE", self.output_bytes.0)?);
        self.n_owners = (cfg.get_u64("N_OWNERS", self.n_owners as u64)? as u32).max(1);
        if cfg.raw("TRANSFER_QUEUE_POLICY").is_some()
            || cfg.raw("TRANSFER_QUEUE_MAX_CONCURRENT").is_some()
        {
            self.policy = AdmissionConfig::from_config(cfg)?;
        }
        if cfg.raw("SHADOW_POOL_SIZE").is_some() {
            self.shadows = AdmissionConfig::shadows_from_config(cfg)?;
        }
        if cfg.raw("N_SUBMIT_NODES").is_some() {
            self.n_submit_nodes = RouterPolicy::nodes_from_config(cfg)?;
        }
        if cfg.raw("ROUTER_POLICY").is_some() {
            self.router = RouterPolicy::from_config(cfg)?;
        }
        // FAULT_PLAN replaces the event schedule; STEAL_THRESHOLD and
        // RECOVERY_RAMP are individual overrides, so a config carrying
        // only a tuning knob doesn't wipe a scenario's built-in plan.
        if cfg.raw("FAULT_PLAN").is_some() {
            self.faults.events = FaultPlan::from_config(cfg)?.events;
        }
        if cfg.raw("STEAL_THRESHOLD").is_some() {
            self.faults.steal_threshold = Some(cfg.get_u64("STEAL_THRESHOLD", 0)? as usize);
        }
        if cfg.raw("RECOVERY_RAMP").is_some() {
            self.faults.recovery_ramp = Some(cfg.get_u64("RECOVERY_RAMP", 0)? as u32);
        }
        if cfg.raw("DATA_NODES").is_some() {
            self.n_data_nodes = SourcePlan::data_nodes_from_config(cfg)?;
        }
        // SOURCE_PLAN replaces the plan; DTN_THRESHOLD alone only
        // re-tunes an existing hybrid plan (it must not silently reset
        // a scenario's preset plan to the funnel default).
        if cfg.raw("SOURCE_PLAN").is_some() {
            self.source = SourcePlan::from_config(cfg)?;
        } else if let SourcePlan::Hybrid { ref mut threshold } = self.source {
            *threshold = cfg.get_bytes("DTN_THRESHOLD", *threshold)?;
        }
        if cfg.raw("SOURCE_SELECTOR").is_some() {
            self.source_selector = SourceSelector::from_config(cfg)?;
        }
        // Federation knobs: site count, per-site border/WAN shape and
        // the two-level site selector.
        self.testbed.n_sites =
            (cfg.get_u64("N_SITES", self.testbed.n_sites as u64)? as u32).max(1);
        self.testbed.site_wan_gbps = cfg.get_f64("SITE_WAN_GBPS", self.testbed.site_wan_gbps)?;
        self.testbed.site_wan_rtt_ms =
            cfg.get_f64("SITE_WAN_RTT_MS", self.testbed.site_wan_rtt_ms)?;
        self.testbed.site_wan_loss = cfg.get_f64("SITE_WAN_LOSS", self.testbed.site_wan_loss)?;
        if cfg.raw("SITE_SELECTOR").is_some() {
            self.site_selector = SiteSelector::from_config(cfg)?;
        }
        self.dtn_slots = cfg.get_u64("DTN_MAX_CONCURRENT", self.dtn_slots as u64)? as u32;
        self.dtn_queue_depth = cfg.get_u64("DTN_QUEUE_DEPTH", self.dtn_queue_depth as u64)? as u32;
        if cfg.raw("ROUTER_SHARDS").is_some() {
            self.router_shards = crate::mover::shards_from_config(cfg)?;
        }
        self.cycle_size = cfg.get_u64("CYCLE_SIZE", self.cycle_size as u64)? as usize;
        // SOLVER picks the rate model; LINK_RTT_MS / LINK_LOSS override
        // the path RTT and loss probability the topology (and a dynamic
        // solver) see — absent knobs keep the calibrated defaults.
        if let Some(raw) = cfg.raw("SOLVER") {
            self.solver = SolverKind::parse(raw).ok_or_else(|| {
                crate::config::ConfigError::Type(
                    "SOLVER".into(),
                    "fair-share | tcp-dynamic",
                    raw.to_string(),
                )
            })?;
        }
        if cfg.raw("LINK_RTT_MS").is_some() {
            self.testbed.link_rtt_ms = Some(cfg.get_f64("LINK_RTT_MS", 0.0)?);
        }
        if cfg.raw("LINK_LOSS").is_some() {
            self.testbed.link_loss = Some(cfg.get_f64("LINK_LOSS", 0.0)?);
        }
        self.task_rate_bps = cfg.get_bytes("TASK_RATE_BPS", self.task_rate_bps)?;
        self.task_deadline_s = cfg.get_f64("TASK_DEADLINE_S", self.task_deadline_s)?;
        self.autotune = cfg.get_bool("AUTOTUNE", self.autotune)?;
        self.n_extents = (cfg.get_u64("N_EXTENTS", self.n_extents as u64)? as u32).max(1);
        // Heterogeneous data fleets: DATA_NODE_GBPS = 100, 25 sets
        // per-DTN NIC capacity.
        if let Some(raw) = cfg.raw("DATA_NODE_GBPS") {
            let caps: Result<Vec<f64>, _> =
                raw.split(',').map(|s| s.trim().parse::<f64>()).collect();
            self.testbed.data_node_gbps = caps.map_err(|_| {
                crate::config::ConfigError::Type(
                    "DATA_NODE_GBPS".into(),
                    "comma-separated Gbps list",
                    raw.to_string(),
                )
            })?;
        }
        // Heterogeneous submit fleets: SUBMIT_NODE_GBPS = 100, 100, 25
        // sets per-node NIC capacity (topology AND router weights).
        if let Some(raw) = cfg.raw("SUBMIT_NODE_GBPS") {
            let caps: Result<Vec<f64>, _> =
                raw.split(',').map(|s| s.trim().parse::<f64>()).collect();
            self.testbed.submit_node_gbps = caps.map_err(|_| {
                crate::config::ConfigError::Type(
                    "SUBMIT_NODE_GBPS".into(),
                    "comma-separated Gbps list",
                    raw.to_string(),
                )
            })?;
        }
        Ok(())
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Negotiation cycle.
    Negotiate,
    /// An admitted transfer's connection setup finished; put it on the
    /// wire. The epoch stamps one routing decision: a node failure
    /// re-routes the proc and bumps its epoch, so stale starts (scheduled
    /// before the failure) are dropped instead of double-starting.
    StartInputFlow { proc_: u32, epoch: u32 },
    /// Job payload finished executing on its slot.
    RunDone { proc_: u32 },
    /// Background-traffic step on the shared backbone.
    BgUpdate,
    /// Injected fault from the spec's `FaultPlan` (index into the sorted
    /// event list).
    Fault { idx: usize },
}

#[derive(Debug, Clone, Copy)]
enum FlowKind {
    Input,
    Output,
}

#[derive(Debug, Clone, Copy)]
struct FlowCtx {
    proc_: u32,
    kind: FlowKind,
    /// Endpoint serving the flow's bytes; a DTN-sourced INPUT flow holds
    /// one of that node's device-reader slots until it finishes/aborts.
    source: DataSource,
}

/// Raw engine outputs, consumed by `experiment::Report`.
#[derive(Debug)]
pub struct EngineResult {
    pub schedd: Schedd,
    /// Aggregate data-plane throughput: the element-wise sum of every
    /// monitored source NIC — `monitors` AND `dtn_monitors` (with one
    /// submit node and no DTNs, identical to `monitors[0]`).
    pub monitor: BinSeries,
    /// Per-submit-node NIC throughput series, index = node.
    pub monitors: Vec<BinSeries>,
    /// Per-data-node NIC throughput series, index = dtn (empty with no
    /// DTN fleet).
    pub dtn_monitors: Vec<BinSeries>,
    pub finished_at: SimTime,
    pub negotiation_cycles: u64,
    pub peak_concurrent_transfers: u32,
    pub total_input_bytes: f64,
    pub errors: u64,
    /// DTN storage-cache accounting summed over the fleet: reads served
    /// from a data node's page cache vs its (slower) device. (0, 0) with
    /// no DTN fleet.
    pub dtn_cache_hits: u64,
    pub dtn_cache_misses: u64,
    /// Aggregate data-mover accounting (per-shard routing node-major,
    /// admission totals, failed/recovered-node and work-stealing counts).
    pub mover: MoverStats,
    /// Per-submit-node router accounting.
    pub router: RouterStats,
    /// Applied fault events (empty for fault-free runs).
    pub chaos: ChaosTimeline,
    /// Site×site goodput matrix: `site_matrix[src][dst]` is the input
    /// payload bytes served by a site-`src` source (funnel or DTN) to a
    /// site-`dst` worker. A 1×1 matrix on unfederated runs; the
    /// Petascale DTN transfer-matrix benchmark shape otherwise.
    pub site_matrix: Vec<Vec<u64>>,
}

pub struct Engine {
    spec: EngineSpec,
    tb: Testbed,
    schedd: Schedd,
    startds: Vec<Startd>,
    collector: Collector,
    negotiator: Negotiator,
    events: EventQueue<Ev>,
    rng: Prng,
    /// proc -> assigned slot (claims).
    assignment: HashMap<u32, SlotId>,
    /// proc -> submit node serving its sandbox (recorded at admission,
    /// dropped once the output sandbox goes on the wire, or when the
    /// node is killed — outputs then return through a survivor).
    node_by_proc: HashMap<u32, usize>,
    /// proc -> data source serving its sandbox bytes (recorded at
    /// admission alongside `node_by_proc`; a killed DTN's entries are
    /// re-recorded when the router re-sources them).
    source_by_proc: HashMap<u32, DataSource>,
    /// proc -> routing epoch: bumped on every (re-)admission so pending
    /// `StartInputFlow` events from a superseded routing are stale.
    epoch_by_proc: HashMap<u32, u32>,
    flows: HashMap<FlowId, FlowCtx>,
    /// Per-data-node storage view (catalog + page cache): the sim's
    /// model of what a DTN serves fast (cache) vs slow (device). The
    /// router's cache-aware residency view is re-synced from this truth
    /// after every read.
    dtn_storage: Vec<Storage>,
    /// Input flows currently reading from each DTN's storage (device
    /// concurrency for the seek-degradation model).
    dtn_readers: Vec<u32>,
    bg_nominal_gbps: f64,
    /// The spec's fault plan, sorted by injection time (`Ev::Fault`
    /// carries an index into this).
    faults: Vec<FaultEvent>,
    /// Applied-fault timeline for the report.
    chaos: ChaosTimeline,
    /// Input payload bytes by (source site, worker site); see
    /// [`EngineResult::site_matrix`].
    site_matrix: Vec<Vec<u64>>,
}

/// Build the spec's pool router: the submit-node fleet, NIC-budget
/// weights, data-source plane and state sharding, exactly as
/// [`Engine::new`] wires them. Shared with the task drivers
/// ([`run_task_sim`]) so a durable task and a plain burst route through
/// identically configured control planes.
///
/// The spec and its testbed both carry a submit-node count (the
/// testbed's is honored by `Testbed::build` standalone); whichever was
/// raised wins, so neither knob is silently a no-op. Router NIC budgets
/// mirror the topology's per-node capacities, so weighted-by-capacity
/// routing tracks heterogeneous fleets; the DTN fleet mirrors the
/// data-node NIC budgets the same way.
pub fn router_from_spec(spec: &EngineSpec) -> PoolRouter {
    let n = spec.n_submit_nodes.max(spec.testbed.n_submit_nodes).max(1) as usize;
    let nodes: Vec<ShadowPool> = (0..n)
        .map(|_| ShadowPool::sim(spec.shadows.max(1), spec.policy.clone()))
        .collect();
    let capacities: Vec<f64> = (0..n)
        .map(|s| spec.testbed.submit_node_nic_gbps(s))
        .collect();
    let n_dtns = spec.n_data_nodes.max(spec.testbed.n_data_nodes) as usize;
    let dtn_caps: Vec<f64> = (0..n_dtns)
        .map(|d| spec.testbed.data_node_nic_gbps(d))
        .collect();
    PoolRouter::from_config(
        nodes,
        capacities,
        spec.router,
        RouterConfig {
            source_plan: spec.source,
            dtn_capacity: dtn_caps,
            source_selector: spec.source_selector,
            dtn_slots: spec.dtn_slots,
            dtn_queue_depth: spec.dtn_queue_depth,
            state_shards: spec.router_shards,
            recovery_ramp: spec.faults.recovery_ramp.unwrap_or(0),
            n_sites: spec.testbed.n_sites.max(1) as usize,
            site_selector: spec.site_selector,
        },
    )
}

impl Engine {
    pub fn new(spec: EngineSpec) -> Engine {
        let router = router_from_spec(&spec);
        Engine::with_router(spec, router)
    }

    /// Build an engine around an existing single-node data mover (e.g.
    /// to drive the same policy object through the simulator and then
    /// the real fabric — see `tests/mover_unified.rs`). The mover's
    /// shard count and policy override the spec's knobs.
    pub fn with_mover(spec: EngineSpec, mover: ShadowPool) -> Engine {
        Engine::with_router(spec, PoolRouter::single(mover))
    }

    /// Build an engine around an existing pool router (the multi-node
    /// analogue of [`Engine::with_mover`] — see
    /// `tests/router_unified.rs`). The router's node count overrides the
    /// spec's `n_submit_nodes`, and the topology gets one monitored
    /// submit NIC per node.
    pub fn with_router(mut spec: EngineSpec, mut router: PoolRouter) -> Engine {
        spec.n_submit_nodes = router.node_count() as u32;
        spec.testbed.n_submit_nodes = router.node_count() as u32;
        spec.n_data_nodes = router.dtn_count() as u32;
        spec.testbed.n_data_nodes = router.dtn_count() as u32;
        spec.source = router.source_plan();
        spec.source_selector = router.source_selector();
        spec.testbed.n_sites = router.n_sites() as u32;
        spec.site_selector = router.site_selector();
        spec.dtn_slots = router.dtn_budget();
        spec.dtn_queue_depth = router.dtn_queue_depth();
        spec.router_shards = router.state_shards();
        if let Some(ramp) = spec.faults.recovery_ramp {
            router.set_ramp_decisions(ramp);
        }
        let mut tb = Testbed::build(spec.testbed.clone());
        tb.net.set_solver(spec.solver.build(spec.seed));
        // The data-node storage model: every DTN serves the same
        // hard-linked catalog (names `input_0..n_jobs-1` over
        // `n_extents` physical extents) but owns its OWN page cache.
        // Extents are pre-warmed block-wise across the fleet — extent
        // `e` is staged hot on node `e * n_dtns / n_extents`, the
        // natural layout after a staging pass — and the router's
        // cache-aware residency view is seeded to match, so a
        // cache-aware burst starts warm while a placement-blind one
        // pays the device rate.
        let n_dtns = router.dtn_count();
        let n_ext = spec.n_extents.max(1).min(spec.n_jobs.max(1)) as usize;
        let mut dtn_storage: Vec<Storage> = Vec::with_capacity(n_dtns);
        for d in 0..n_dtns {
            let device = if spec.testbed.dtn_spinning {
                DeviceProfile::spinning()
            } else {
                DeviceProfile::nvme()
            };
            let mut st = Storage::new(device, spec.testbed.dtn_cache_bytes);
            for p in 0..spec.n_jobs as usize {
                if p < n_ext {
                    st.create(&format!("input_{p}"), spec.input_bytes.0);
                } else {
                    st.hardlink(&format!("input_{}", p % n_ext), &format!("input_{p}"))
                        .expect("extent representative exists");
                }
            }
            for e in 0..n_ext {
                if e * n_dtns / n_ext == d && st.warm(&format!("input_{e}")) {
                    router.note_extent_resident(d, ExtentId(e as u64));
                }
            }
            dtn_storage.push(st);
        }
        let schedd = Schedd::with_router("schedd@submit", router);
        let startds: Vec<Startd> = spec
            .testbed
            .workers
            .iter()
            .enumerate()
            .map(|(w, ws)| Startd::new(w as u32, ws.slots))
            .collect();
        let bg_nominal_gbps = tb
            .background()
            .map(|(_, _, _, _, nominal)| nominal)
            .unwrap_or(0.0);
        let faults = spec.faults.sorted();
        let n_sites = tb.n_sites();
        Engine {
            site_matrix: vec![vec![0u64; n_sites]; n_sites],
            rng: Prng::new(spec.seed),
            spec,
            tb,
            schedd,
            startds,
            collector: Collector::new(),
            negotiator: Negotiator::new(),
            events: EventQueue::new(),
            assignment: HashMap::new(),
            node_by_proc: HashMap::new(),
            source_by_proc: HashMap::new(),
            epoch_by_proc: HashMap::new(),
            flows: HashMap::new(),
            dtn_readers: vec![0; dtn_storage.len()],
            dtn_storage,
            bg_nominal_gbps,
            faults,
            chaos: ChaosTimeline::default(),
        }
    }

    /// Build the job specs for the paper workload (unique hard-linked
    /// input names, as in §III). With `n_owners > 1` the burst is
    /// attributed round-robin to distinct owners so owner-aware
    /// admission policies have something to schedule between.
    fn job_specs(&self) -> Vec<JobSpec> {
        let n_owners = self.spec.n_owners.max(1);
        let n_ext = self.spec.n_extents.max(1).min(self.spec.n_jobs.max(1));
        (0..self.spec.n_jobs)
            .map(|p| JobSpec {
                id: crate::jobs::JobId { cluster: 1, proc: p },
                owner: if n_owners == 1 {
                    "benchmark".into()
                } else {
                    format!("user{}", p % n_owners)
                },
                input_file: format!("input_{p}"),
                input_extent: Some(ExtentId((p % n_ext) as u64)),
                input_bytes: self.spec.input_bytes,
                output_bytes: self.spec.output_bytes,
                runtime_median_s: self.spec.runtime_median_s,
            })
            .collect()
    }

    /// Run to completion; consumes the engine.
    pub fn run(mut self) -> Result<EngineResult> {
        // Advertise slots, submit the transaction, kick off negotiation.
        for sd in &self.startds {
            for s in 0..sd.slots.len() as u32 {
                self.collector
                    .advertise(&SlotId { worker: sd.worker, slot: s }.to_string(), sd.slot_ad(s));
            }
        }
        self.schedd
            .submit_transaction(self.job_specs(), SimTime::ZERO);
        self.events.push(SimTime::ZERO, Ev::Negotiate);
        if let Err(e) = self
            .spec
            .faults
            .validate(
                self.schedd.mover.node_count(),
                self.schedd.mover.dtn_count(),
                self.schedd.mover.n_sites(),
            )
        {
            bail!("invalid fault plan: {e}");
        }
        if let Err(e) = self
            .schedd
            .mover
            .source_plan()
            .validate(self.schedd.mover.dtn_count())
        {
            bail!("invalid source plan: {e}");
        }
        for (idx, ev) in self.faults.iter().enumerate() {
            self.events
                .push(SimTime::from_secs_f64(ev.at()), Ev::Fault { idx });
        }
        if self.tb.background().is_some() {
            self.events.push(
                SimTime::from_secs_f64(calib::WAN_BG_STEP_S),
                Ev::BgUpdate,
            );
        }

        let mut guard: u64 = 0;
        let max_events = 40 * self.spec.n_jobs as u64 + 10_000;

        while !self.schedd.all_completed() {
            guard += 1;
            if guard > max_events {
                bail!("engine exceeded event budget — likely stuck");
            }

            let t_ev = self.events.peek_time();
            let t_net = self.tb.net.next_completion();
            let (t, network_first) = match (t_ev, t_net) {
                (Some(a), Some(b)) => {
                    if b <= a {
                        (b, true)
                    } else {
                        (a, false)
                    }
                }
                (Some(a), None) => (a, false),
                (None, Some(b)) => (b, true),
                (None, None) => bail!(
                    "deadlock at t={} with {} jobs incomplete",
                    self.tb.net.now(),
                    self.spec.n_jobs as usize - self.schedd.completed_count()
                ),
            };
            self.tb.net.advance_to(t);

            if network_first {
                for fid in self.tb.net.completed() {
                    self.tb.net.finish_flow(fid);
                    let ctx = self.flows.remove(&fid).expect("flow context");
                    self.on_flow_done(ctx, t);
                }
            } else {
                let (_, ev) = self.events.pop().expect("peeked event exists");
                self.handle_event(ev, t);
            }
        }

        let finished_at = self.tb.net.now();
        let monitors: Vec<BinSeries> = self
            .tb
            .submit_txs
            .clone()
            .into_iter()
            .map(|tx| {
                self.tb
                    .net
                    .take_monitor(tx)
                    .expect("every submit NIC is monitored")
            })
            .collect();
        let dtn_monitors: Vec<BinSeries> = self
            .tb
            .data_txs
            .clone()
            .into_iter()
            .map(|tx| {
                self.tb
                    .net
                    .take_monitor(tx)
                    .expect("every data NIC is monitored")
            })
            .collect();
        // The aggregate covers the whole data plane: submit funnels AND
        // the DTN fleet (per-source series sum to it by construction).
        let all: Vec<BinSeries> = monitors
            .iter()
            .chain(dtn_monitors.iter())
            .cloned()
            .collect();
        let monitor = BinSeries::sum(&all);
        let mover = self.schedd.mover.stats();
        let router = self.schedd.mover.router_stats();
        let dtn_cache_hits: u64 = self.dtn_storage.iter().map(|s| s.cache_hits).sum();
        let dtn_cache_misses: u64 = self.dtn_storage.iter().map(|s| s.cache_misses).sum();
        Ok(EngineResult {
            total_input_bytes: self.spec.n_jobs as f64 * self.spec.input_bytes.0 as f64,
            peak_concurrent_transfers: mover.peak_active,
            schedd: self.schedd,
            monitor,
            monitors,
            dtn_monitors,
            finished_at,
            negotiation_cycles: self.negotiator.cycles,
            errors: 0,
            dtn_cache_hits,
            dtn_cache_misses,
            mover,
            router,
            chaos: self.chaos,
            site_matrix: self.site_matrix,
        })
    }

    fn handle_event(&mut self, ev: Ev, t: SimTime) {
        match ev {
            Ev::Negotiate => self.do_negotiate(t),
            Ev::StartInputFlow { proc_, epoch } => self.start_input_flow(proc_, epoch, t),
            Ev::RunDone { proc_ } => self.on_run_done(proc_, t),
            Ev::BgUpdate => self.do_bg_update(t),
            Ev::Fault { idx } => self.apply_fault(idx, t),
        }
    }

    fn do_negotiate(&mut self, t: SimTime) {
        let idle = self.schedd.idle_jobs();
        // Unclaimed slot ads from the collector's current view.
        let mut slots: Vec<(SlotId, crate::classad::Ad)> = Vec::new();
        for sd in &self.startds {
            for (i, s) in sd.slots.iter().enumerate() {
                if s.state == crate::daemons::SlotState::Unclaimed {
                    slots.push((s.id, sd.slot_ad(i as u32)));
                }
            }
        }
        let result = self.negotiator.negotiate(&idle, &slots);
        // Claim/activate bookkeeping per match, then hand the whole
        // cycle's matches to the mover in `cycle_size`-job admission
        // batches (0 = one batch) — the negotiator-style control plane.
        // Batching is behavior-preserving (`route_batch` ≡ the same
        // singles in order); it amortizes the per-call plumbing.
        let mut matched: Vec<u32> = Vec::with_capacity(result.matches.len());
        for (job_id, slot_id) in result.matches {
            let proc_ = job_id.proc;
            self.schedd.take_idle(proc_);
            let sd = &mut self.startds[slot_id.worker as usize];
            sd.claim(slot_id.slot);
            sd.activate(slot_id.slot, job_id);
            self.collector
                .advertise(&slot_id.to_string(), sd.slot_ad(slot_id.slot));
            self.assignment.insert(proc_, slot_id);
            matched.push(proc_);
        }
        let chunk = if self.spec.cycle_size == 0 {
            matched.len().max(1)
        } else {
            self.spec.cycle_size
        };
        let mut to_start: Vec<crate::mover::Routed> = Vec::new();
        for batch in matched.chunks(chunk) {
            to_start.extend(self.schedd.job_matched_batch(batch, t));
        }
        self.start_routed(to_start, t);
        // Re-negotiate while unmatched jobs and unclaimed slots remain.
        if self.schedd.idle_count() > 0
            && self
                .startds
                .iter()
                .any(|sd| sd.count(crate::daemons::SlotState::Unclaimed) > 0)
        {
            self.events.push(
                t + SimTime::from_secs_f64(self.spec.negotiation_interval_s),
                Ev::Negotiate,
            );
        }
    }

    /// Record each admitted transfer's submit node and schedule its
    /// connection setup — the single bookkeeping point for every
    /// admission the router returns. Each (re-)admission bumps the
    /// proc's routing epoch so starts scheduled by a superseded routing
    /// (the node died during connection setup) fall stale.
    fn start_routed(&mut self, routed: Vec<crate::mover::Routed>, t: SimTime) {
        for r in routed {
            self.node_by_proc.insert(r.ticket, r.node);
            self.source_by_proc.insert(r.ticket, r.source);
            let epoch = {
                let e = self.epoch_by_proc.entry(r.ticket).or_insert(0);
                *e += 1;
                *e
            };
            self.schedule_input_start(r.ticket, epoch, t);
        }
    }

    /// Per-stream TCP cap under the active solver. Fair share folds the
    /// full steady-state model (window, Mathis loss, endpoint) into a
    /// static cap; the dynamic solver models loss and the ramp through
    /// its windows, so its cap keeps only the window/endpoint ceilings —
    /// folding Mathis in too would count loss twice.
    fn stream_cap(&self) -> f64 {
        let p = self.tb.path_profile();
        match self.spec.solver {
            SolverKind::FairShare => p.stream_cap_bps(),
            SolverKind::TcpDynamic => p.stream_cap_loss_free_bps(),
        }
    }

    /// Connection-setup latency under the active solver: fair share adds
    /// a slow-start ramp allowance, the dynamic solver replays the ramp
    /// in-band and pays only the auth handshake.
    fn setup_latency_s(&self) -> f64 {
        let p = self.tb.path_profile();
        match self.spec.solver {
            SolverKind::FairShare => p.setup_latency_s(),
            SolverKind::TcpDynamic => p.handshake_latency_s(),
        }
    }

    /// Admitted by the transfer queue: connection setup (auth handshake +
    /// slow start) delays the wire by the path's setup latency.
    fn schedule_input_start(&mut self, proc_: u32, epoch: u32, t: SimTime) {
        let setup = self.setup_latency_s();
        self.events.push(
            t + SimTime::from_secs_f64(setup),
            Ev::StartInputFlow { proc_, epoch },
        );
    }

    fn start_input_flow(&mut self, proc_: u32, epoch: u32, t: SimTime) {
        // Stale start: the proc's submit node died after this event was
        // scheduled and the router re-routed it (a fresh start event is
        // scheduled when its new node admits it).
        if self.epoch_by_proc.get(&proc_) != Some(&epoch) {
            return;
        }
        let slot = self.assignment[&proc_];
        let node = self.node_by_proc[&proc_];
        let source = self
            .source_by_proc
            .get(&proc_)
            .copied()
            .unwrap_or(DataSource::Funnel { node });
        self.schedd.input_started(proc_, t);
        let path = self.source_path(source, slot.worker as usize);
        let mut cap = self.stream_cap();
        if let DataSource::Dtn { dtn } = source {
            // The storage model: a cache-hot extent streams at page-cache
            // rate (never the bottleneck); a cold one is capped by the
            // node's device, degraded by its concurrent readers.
            cap = cap.min(self.dtn_read_bps(dtn, proc_));
            self.dtn_readers[dtn] += 1;
        }
        let bytes = self.schedd.job(proc_).spec.input_bytes.0 as f64;
        let fid = self.tb.net.start_flow(path, bytes, cap);
        self.flows.insert(
            fid,
            FlowCtx {
                proc_,
                kind: FlowKind::Input,
                source,
            },
        );
    }

    /// Effective per-stream read bandwidth for `proc_`'s input on data
    /// node `dtn`: [`calib::PAGE_CACHE_BPS`]-class on a cache hit, the
    /// device's concurrency-degraded aggregate share on a miss
    /// ([`DeviceProfile::aggregate_bps`]). Reading admits the extent to
    /// the node's cache, and the router's cache-aware residency view is
    /// re-synced from the storage truth (so evictions are visible).
    fn dtn_read_bps(&mut self, dtn: usize, proc_: u32) -> f64 {
        let name = self.schedd.job(proc_).spec.input_file.clone();
        let Some(src) = self.dtn_storage[dtn].open_read(&name) else {
            return f64::INFINITY; // name unknown to the catalog: unmodeled
        };
        let resident = self.dtn_storage[dtn].cached_extents();
        self.schedd.mover.set_dtn_residency(dtn, &resident);
        if src.cached {
            src.bps
        } else {
            let readers = self.dtn_readers[dtn] + 1;
            self.dtn_storage[dtn].device().aggregate_bps(readers) / readers as f64
        }
    }

    /// An input flow left the wire (completed or aborted): free its DTN
    /// device-reader slot, if it held one.
    fn release_reader(&mut self, ctx: &FlowCtx) {
        if let (FlowKind::Input, DataSource::Dtn { dtn }) = (ctx.kind, ctx.source) {
            self.dtn_readers[dtn] = self.dtn_readers[dtn].saturating_sub(1);
        }
    }

    fn on_flow_done(&mut self, ctx: FlowCtx, t: SimTime) {
        self.release_reader(&ctx);
        match ctx.kind {
            FlowKind::Input => {
                // Site×site goodput accounting: credit the completed
                // payload to (source site, worker site).
                let src_site = match ctx.source {
                    DataSource::Funnel { node } => self.tb.site_of_submit(node),
                    DataSource::Dtn { dtn } => self.tb.site_of_dtn(dtn),
                };
                let dst_site = self
                    .tb
                    .site_of_worker(self.assignment[&ctx.proc_].worker as usize);
                self.site_matrix[src_site][dst_site] +=
                    self.schedd.job(ctx.proc_).spec.input_bytes.0;
                let admitted = self.schedd.input_done(ctx.proc_, t);
                self.start_routed(admitted, t);
                // Execute the payload: the paper's validation script,
                // median ≈ 5 s, mild spread. A non-positive median means
                // a pure-transfer burst (the calibration harness): no
                // payload, the output goes straight on the wire.
                let median = self.schedd.job(ctx.proc_).spec.runtime_median_s;
                let runtime = if median <= 0.0 {
                    0.0
                } else {
                    self.rng.lognormal(median, 0.25).clamp(0.5, 600.0)
                };
                self.events.push(
                    t + SimTime::from_secs_f64(runtime),
                    Ev::RunDone { proc_: ctx.proc_ },
                );
            }
            FlowKind::Output => {
                self.schedd.job_completed(ctx.proc_, t);
                let slot = self.assignment.remove(&ctx.proc_).expect("assigned slot");
                let sd = &mut self.startds[slot.worker as usize];
                sd.deactivate(slot.slot);
                // Claim reuse: pull the next idle job straight onto the
                // still-claimed slot (no negotiation round trip).
                if let Some(next) = self.schedd.take_next_idle() {
                    let job_id = self.schedd.job(next).spec.id;
                    sd.activate(slot.slot, job_id);
                    self.assignment.insert(next, slot);
                    let admitted = self.schedd.job_matched(next, t);
                    self.start_routed(admitted, t);
                } else {
                    sd.release(slot.slot);
                }
            }
        }
    }

    /// Links a transfer from `source` to `worker` crosses.
    fn source_path(&self, source: DataSource, worker: usize) -> Vec<crate::netsim::LinkId> {
        match source {
            DataSource::Funnel { node } => self.tb.path_to_worker(node, worker),
            DataSource::Dtn { dtn } => self.tb.dtn_path_to_worker(dtn, worker),
        }
    }

    fn on_run_done(&mut self, proc_: u32, t: SimTime) {
        self.schedd.run_done(proc_, t);
        let slot = self.assignment[&proc_];
        // Output sandbox flows worker -> its data source (not queued:
        // HTCondor's download throttle exists but outputs here are 4 KB).
        // If that node was killed while the payload ran, the (tiny)
        // output returns through a survivor instead — the sim analogue of
        // workers retrying through the router.
        let node = match self.node_by_proc.remove(&proc_) {
            Some(n) => n,
            None => self.schedd.mover.first_live_node().unwrap_or(0),
        };
        let preferred = self
            .source_by_proc
            .remove(&proc_)
            .unwrap_or(DataSource::Funnel { node });
        let source = self.schedd.mover.output_source(preferred, node);
        let path = match source {
            DataSource::Funnel { node } => self.tb.path_from_worker(node, slot.worker as usize),
            DataSource::Dtn { dtn } => self.tb.dtn_path_from_worker(dtn, slot.worker as usize),
        };
        let cap = self.stream_cap();
        let bytes = self.schedd.job(proc_).spec.output_bytes.0.max(1) as f64;
        let fid = self.tb.net.start_flow(path, bytes, cap);
        self.flows.insert(
            fid,
            FlowCtx {
                proc_,
                kind: FlowKind::Output,
                source,
            },
        );
    }

    fn do_bg_update(&mut self, t: SimTime) {
        if let Some((link, mean, sd, step, _)) = self.tb.background() {
            let u = (mean + sd * self.rng.normal()).clamp(0.0, 0.6);
            self.tb
                .net
                .set_capacity(link, Gbps(self.bg_nominal_gbps * (1.0 - u)));
            self.events
                .push(t + SimTime::from_secs_f64(step), Ev::BgUpdate);
        }
    }

    /// Tear down the transfers a fault strands: bump the procs' routing
    /// epochs (pending `StartInputFlow` events fall stale) and abort
    /// their in-flight INPUT flows (partial bytes stay accounted, the
    /// jobs return to `TransferQueued` for re-admission). Shared by the
    /// submit-node and data-node kill paths.
    fn abort_input_procs(&mut self, procs: &[u32], t: SimTime) {
        for &p in procs {
            *self.epoch_by_proc.entry(p).or_insert(0) += 1;
        }
        let aborted: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, ctx)| {
                matches!(ctx.kind, FlowKind::Input) && procs.contains(&ctx.proc_)
            })
            .map(|(&fid, _)| fid)
            .collect();
        for fid in aborted {
            let ctx = self.flows.remove(&fid).expect("aborted flow has context");
            self.release_reader(&ctx);
            self.tb.net.finish_flow(fid);
            self.schedd.input_aborted(ctx.proc_, t);
        }
    }

    /// Inject one fault event: engine-side teardown/restore first (flows,
    /// NIC rates, job states), then the router-side half that is shared
    /// verbatim with the real fabric (`chaos::apply_to_router`), then
    /// start whatever the surviving/recovered nodes admitted.
    fn apply_fault(&mut self, idx: usize, t: SimTime) {
        let ev = self.faults[idx];
        let node = ev.node();
        let bytes_before = if ev.is_site() {
            self.tb
                .site_borders
                .get(node)
                .map(|&l| self.tb.net.link(l).bytes_carried as u64)
                .unwrap_or(0)
        } else if ev.is_dtn() {
            self.tb.net.link(self.tb.data_txs[node]).bytes_carried as u64
        } else {
            self.tb.net.link(self.tb.submit_txs[node]).bytes_carried as u64
        };
        match ev {
            FaultEvent::KillNode { .. } => {
                // Everything the dead node was serving is torn down
                // BEFORE the router re-routes: in-flight input flows
                // abort (partial bytes stay accounted and the jobs
                // return to TransferQueued), procs still in connection
                // setup lose their pending start via the epoch bump, and
                // running jobs' outputs will return through a survivor.
                let procs: Vec<u32> = self
                    .node_by_proc
                    .iter()
                    .filter(|&(_, &n)| n == node)
                    .map(|(&p, _)| p)
                    .collect();
                for &p in &procs {
                    self.node_by_proc.remove(&p);
                    // A source pointing at the dead funnel dies with it
                    // (outputs fall back to a survivor); a DTN source
                    // outlives its scheduling node.
                    if self.source_by_proc.get(&p) == Some(&DataSource::Funnel { node }) {
                        self.source_by_proc.remove(&p);
                    }
                }
                self.abort_input_procs(&procs, t);
            }
            FaultEvent::RecoverNode { .. } => {
                // Restore the node's full NIC rate (undoes DegradeNic).
                let gbps = self.tb.spec.submit_node_nic_gbps(node);
                self.tb.set_submit_nic_gbps(node, gbps);
            }
            FaultEvent::DegradeNic { gbps, .. } => {
                self.tb.set_submit_nic_gbps(node, gbps);
            }
            FaultEvent::KillDtn { dtn, .. } => {
                // The node's page cache dies with the crash (the router
                // clears its residency view in `fail_dtn` below): a
                // recovered node reads cold until re-warmed.
                self.dtn_storage[dtn].clear_cache();
                // The data node's in-flight INPUT transfers die with it;
                // scheduling state (admission slots) survives — the
                // router re-sources the tickets and fresh starts are
                // scheduled below. Jobs already executing keep running;
                // their outputs return via `output_source`'s fallback.
                let candidates: Vec<u32> = self
                    .source_by_proc
                    .iter()
                    .filter(|&(_, &s)| s == DataSource::Dtn { dtn })
                    .map(|(&p, _)| p)
                    .collect();
                let torn: Vec<u32> = candidates
                    .into_iter()
                    .filter(|&p| {
                        matches!(
                            self.schedd.job(p).state,
                            crate::jobs::JobState::TransferQueued
                                | crate::jobs::JobState::TransferringInput
                        )
                    })
                    .collect();
                for &p in &torn {
                    self.source_by_proc.remove(&p);
                }
                self.abort_input_procs(&torn, t);
            }
            FaultEvent::RecoverDtn { dtn, .. } => {
                let gbps = self.tb.spec.data_node_nic_gbps(dtn);
                self.tb.set_data_nic_gbps(dtn, gbps);
            }
            FaultEvent::DegradeDtnNic { dtn, gbps, .. } => {
                self.tb.set_data_nic_gbps(dtn, gbps);
            }
            FaultEvent::KillSite { site, .. } => {
                // The whole site goes dark: its DTN page caches die, every
                // transfer served by one of its members (funnel OR DTN
                // source, from any scheduling node) is torn down, and its
                // border link drains — `fail_site` below re-routes and
                // re-sources the tickets onto surviving sites.
                let dead_nodes: Vec<usize> = (0..self.schedd.mover.node_count())
                    .filter(|&n| self.schedd.mover.site_of_node(n) == site)
                    .collect();
                let dead_dtns: Vec<usize> = (0..self.schedd.mover.dtn_count())
                    .filter(|&d| self.schedd.mover.site_of_dtn(d) == site)
                    .collect();
                for &d in &dead_dtns {
                    self.dtn_storage[d].clear_cache();
                }
                let node_procs: Vec<u32> = self
                    .node_by_proc
                    .iter()
                    .filter(|&(_, n)| dead_nodes.contains(n))
                    .map(|(&p, _)| p)
                    .collect();
                for &p in &node_procs {
                    self.node_by_proc.remove(&p);
                    if matches!(
                        self.source_by_proc.get(&p),
                        Some(DataSource::Funnel { node }) if dead_nodes.contains(node)
                    ) {
                        self.source_by_proc.remove(&p);
                    }
                }
                let dtn_procs: Vec<u32> = self
                    .source_by_proc
                    .iter()
                    .filter(
                        |&(_, &s)| matches!(s, DataSource::Dtn { dtn } if dead_dtns.contains(&dtn)),
                    )
                    .map(|(&p, _)| p)
                    .filter(|&p| {
                        matches!(
                            self.schedd.job(p).state,
                            crate::jobs::JobState::TransferQueued
                                | crate::jobs::JobState::TransferringInput
                        )
                    })
                    .collect();
                for &p in &dtn_procs {
                    self.source_by_proc.remove(&p);
                }
                let mut torn = node_procs;
                torn.extend(dtn_procs);
                torn.sort_unstable();
                torn.dedup();
                self.abort_input_procs(&torn, t);
                if !self.tb.site_borders.is_empty() {
                    self.tb.set_site_border_gbps(site, 0.0);
                }
            }
            FaultEvent::RecoverSite { site, .. } => {
                // Restore the border and every member NIC (undoing the
                // kill's drain and any earlier degrades), mirroring the
                // per-member recover arms.
                if !self.tb.site_borders.is_empty() {
                    let gbps = self.tb.spec.site_wan_gbps;
                    self.tb.set_site_border_gbps(site, gbps);
                }
                for n in 0..self.schedd.mover.node_count() {
                    if self.schedd.mover.site_of_node(n) == site {
                        let gbps = self.tb.spec.submit_node_nic_gbps(n);
                        self.tb.set_submit_nic_gbps(n, gbps);
                    }
                }
                for d in 0..self.schedd.mover.dtn_count() {
                    if self.schedd.mover.site_of_dtn(d) == site {
                        let gbps = self.tb.spec.data_node_nic_gbps(d);
                        self.tb.set_data_nic_gbps(d, gbps);
                    }
                }
            }
        }
        let admitted = apply_to_router(
            &ev,
            &mut self.schedd.mover,
            self.spec.faults.steal_threshold,
        );
        self.chaos.record(
            node,
            ev.label(),
            ev.at(),
            t.as_secs_f64(),
            admitted.len(),
            bytes_before,
        );
        self.start_routed(admitted, t);
    }
}

/// Outcome of driving one durable task through the simulated fabric.
#[derive(Debug)]
pub struct TaskSimReport {
    /// Per-task progress snapshot (files/bytes done, resumed, retries,
    /// deadline flag, final knob values); see `docs/REPORTS.md`.
    pub progress: TaskProgress,
    /// Auto-tuner trajectory (empty without `AUTOTUNE`).
    pub tuner: Vec<TunerSample>,
    /// Virtual seconds from task start to the last event this run saw.
    pub makespan_s: f64,
    pub mover: MoverStats,
    pub router: RouterStats,
    /// The run was cut short by `kill_after_files` (the chaos hook).
    pub killed: bool,
}

/// Transfer efficiency of the task's chunk size on the simulated wire:
/// each chunk pays one fixed round of per-chunk overhead (framing, seal
/// hand-off), so per-stream goodput scales as `w / (w + 1024)` — the
/// fluid-model analogue of what the `chunk_sweep` bench measures on the
/// real fabric. Monotone in `w`, which is what lets the auto-tuner's
/// hill-climb find the ceiling.
fn chunk_efficiency(chunk_words: usize) -> f64 {
    let w = chunk_words as f64;
    w / (w + 1024.0)
}

/// Drive a durable task to completion (or its deadline) on the
/// simulated fabric: the sim-side counterpart of
/// `fabric::tcp::run_real_task`, sharing the same [`TaskRunner`] object
/// per the repo's sim/real unification invariant. Admission, rate
/// limiting, deadlines and auto-tuning all live in the runner; this
/// driver supplies virtual time, the routed data plane
/// ([`router_from_spec`]) and a fluid flow model whose per-stream rate
/// honors the runner's live chunk size ([`chunk_efficiency`]) and
/// shares each source NIC among its concurrent flows.
pub fn run_task_sim(spec: &EngineSpec, runner: &mut TaskRunner) -> Result<TaskSimReport> {
    run_task_sim_with_kill(spec, runner, None)
}

/// [`run_task_sim`] with a chaos hook: kill the coordinator after this
/// many file completions *this run* — admissions stop, in-flight flows
/// are abandoned (exactly what a crash does), and the journal keeps the
/// last checkpoint for a later resume.
pub fn run_task_sim_with_kill(
    spec: &EngineSpec,
    runner: &mut TaskRunner,
    kill_after_files: Option<usize>,
) -> Result<TaskSimReport> {
    if spec.task_rate_bps > 0 {
        runner.set_rate_bps(spec.task_rate_bps);
    }
    if spec.task_deadline_s > 0.0 {
        runner.set_deadline_s(spec.task_deadline_s);
    }
    if spec.autotune {
        runner.set_autotune(true);
    }
    let mut schedd = Schedd::with_router("schedd@task", router_from_spec(spec));
    if let Err(e) = schedd
        .mover
        .source_plan()
        .validate(schedd.mover.dtn_count())
    {
        bail!("invalid source plan: {e}");
    }
    let mapping = schedd.submit_task(runner.task(), SimTime::ZERO);
    let file_of: HashMap<u32, usize> = mapping.iter().copied().collect();
    let proc_of: HashMap<usize, u32> = mapping.iter().map(|&(p, i)| (i, p)).collect();

    struct Flow {
        remaining: f64,
        source: DataSource,
    }
    let mut flows: HashMap<u32, Flow> = HashMap::new();
    let start = |routed: Vec<crate::mover::Routed>,
                     flows: &mut HashMap<u32, Flow>,
                     schedd: &mut Schedd,
                     now: f64| {
        for r in routed {
            schedd.input_started(r.ticket, SimTime::from_secs_f64(now));
            flows.insert(
                r.ticket,
                Flow {
                    remaining: schedd.job(r.ticket).spec.input_bytes.0 as f64,
                    source: r.source,
                },
            );
        }
    };

    let mut now = 0.0f64;
    let mut killed = false;
    let mut done_this_run = 0usize;
    let mut guard = 0u64;
    loop {
        guard += 1;
        if guard > 200_000 {
            bail!("task sim exceeded iteration budget — likely stuck");
        }
        if !killed {
            let mut routed = Vec::new();
            for idx in runner.next_files(now) {
                let proc_ = proc_of[&idx];
                schedd.take_idle(proc_);
                routed.extend(schedd.job_matched(proc_, SimTime::from_secs_f64(now)));
            }
            start(routed, &mut flows, &mut schedd, now);
        }
        runner.observe_window(now);
        if flows.is_empty() {
            if killed || runner.done() || runner.deadline_exceeded() {
                break;
            }
            // Rate-limited idle gap: jump to the limiter's next token.
            match runner.next_admission_time() {
                Some(t) if t > now => {
                    now = t;
                    continue;
                }
                Some(_) => continue,
                None => break,
            }
        }
        // Fluid rates: each flow takes the per-stream TCP ceiling scaled
        // by the task's chunk efficiency, capped by an even share of its
        // source NIC (protocol-derated, split among that source's flows).
        let stream_bps = calib::PER_STREAM_ENDPOINT_BPS * chunk_efficiency(runner.chunk_words());
        let rate_of = |f: &Flow, flows: &HashMap<u32, Flow>, spec: &EngineSpec| {
            let nic_gbps = match f.source {
                DataSource::Funnel { node } => spec.testbed.submit_node_nic_gbps(node),
                DataSource::Dtn { dtn } => spec.testbed.data_node_nic_gbps(dtn),
            };
            let sharing = flows.values().filter(|o| o.source == f.source).count().max(1);
            let nic_share =
                nic_gbps * 1e9 / 8.0 * calib::NIC_PROTOCOL_EFFICIENCY / sharing as f64;
            stream_bps.min(nic_share).max(1.0)
        };
        let mut dt = f64::INFINITY;
        for f in flows.values() {
            dt = dt.min(f.remaining / rate_of(f, &flows, spec));
        }
        if let Some(wd) = runner.next_window_deadline() {
            if wd > now {
                dt = dt.min(wd - now);
            }
        }
        if let Some(at) = runner.next_admission_time() {
            if at > now {
                dt = dt.min(at - now);
            }
        }
        let dt = dt.max(1e-9);
        let rates: HashMap<u32, f64> = flows
            .iter()
            .map(|(&p, f)| (p, rate_of(f, &flows, spec)))
            .collect();
        now += dt;
        let mut completed: Vec<u32> = Vec::new();
        for (&p, f) in flows.iter_mut() {
            f.remaining -= rates[&p] * dt;
            if f.remaining <= 0.5 {
                completed.push(p);
            }
        }
        completed.sort_unstable();
        for proc_ in completed {
            flows.remove(&proc_);
            let t = SimTime::from_secs_f64(now);
            let admitted = schedd.input_done(proc_, t);
            schedd.run_done(proc_, t);
            schedd.job_completed(proc_, t);
            let idx = file_of[&proc_];
            let (name, bytes) = {
                let f = runner.file(idx);
                (f.name.clone(), f.bytes)
            };
            runner.file_done(idx, &crate::mover::task::synth_file_sha256(&name, bytes), now)?;
            done_this_run += 1;
            if kill_after_files == Some(done_this_run) {
                // Coordinator crash: in-flight transfers die on the
                // floor; the journal holds the checkpoint just written.
                killed = true;
                flows.clear();
                break;
            }
            start(admitted, &mut flows, &mut schedd, now);
        }
    }
    Ok(TaskSimReport {
        progress: runner.progress(),
        tuner: runner.tuner_trajectory().to_vec(),
        makespan_s: now,
        mover: schedd.mover.stats(),
        router: schedd.mover.router_stats(),
        killed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small LAN run must complete with sane accounting.
    fn tiny_spec() -> EngineSpec {
        let mut tb = TestbedSpec::lan_paper();
        tb.workers.truncate(2);
        tb.workers[0].slots = 4;
        tb.workers[1].slots = 4;
        tb.monitor_bin = SimTime::from_secs(10);
        EngineSpec {
            testbed: tb,
            n_jobs: 40,
            input_bytes: Bytes(100_000_000), // 100 MB
            output_bytes: Bytes(4_000),
            runtime_median_s: 2.0,
            policy: ThrottlePolicy::Disabled.into(),
            shadows: 1,
            n_submit_nodes: 1,
            router: RouterPolicy::LeastLoaded,
            n_data_nodes: 0,
            source: SourcePlan::SubmitFunnel,
            source_selector: SourceSelector::RoundRobin,
            site_selector: SiteSelector::LocalFirst,
            dtn_slots: 0,
            dtn_queue_depth: 0,
            router_shards: crate::mover::DEFAULT_ROUTER_SHARDS,
            cycle_size: 0,
            n_extents: 1,
            n_owners: 1,
            faults: FaultPlan::default(),
            seed: 1,
            negotiation_interval_s: 60.0,
            task_rate_bps: 0,
            task_deadline_s: 0.0,
            autotune: false,
            solver: SolverKind::FairShare,
        }
    }

    #[test]
    fn tiny_lan_run_completes() {
        let r = Engine::new(tiny_spec()).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(r.errors, 0);
        assert!(r.finished_at > SimTime::ZERO);
        // All input bytes crossed the submit NIC monitor.
        let total = r.monitor.total_bytes();
        assert!(
            total >= r.total_input_bytes,
            "monitor {total} >= inputs {}",
            r.total_input_bytes
        );
        assert!(r.negotiation_cycles >= 1);
        assert!(r.peak_concurrent_transfers <= 8);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = Engine::new(tiny_spec()).run().unwrap();
        let b = Engine::new(tiny_spec()).run().unwrap();
        assert_eq!(a.finished_at, b.finished_at);
        assert_eq!(
            a.schedd.makespan().unwrap(),
            b.schedd.makespan().unwrap()
        );
    }

    #[test]
    fn throttle_slows_makespan() {
        let fast = Engine::new(tiny_spec()).run().unwrap();
        let mut spec = tiny_spec();
        spec.policy = ThrottlePolicy::MaxConcurrent(2).into();
        let slow = Engine::new(spec).run().unwrap();
        assert!(
            slow.finished_at > fast.finished_at,
            "throttled {} !> unthrottled {}",
            slow.finished_at,
            fast.finished_at
        );
        assert!(slow.peak_concurrent_transfers <= 2);
    }

    #[test]
    fn job_timestamps_ordered() {
        let r = Engine::new(tiny_spec()).run().unwrap();
        for j in &r.schedd.jobs {
            assert_eq!(j.state, crate::jobs::JobState::Completed);
            let tq = j.t_transfer_queued.unwrap();
            let ts = j.t_input_started.unwrap();
            let td = j.t_input_done.unwrap();
            let tr = j.t_run_done.unwrap();
            let tc = j.t_completed.unwrap();
            assert!(tq <= ts && ts < td && td < tr && tr <= tc, "{j:?}");
        }
    }

    #[test]
    fn wan_run_with_background_completes() {
        let mut spec = tiny_spec();
        spec.testbed = TestbedSpec::wan_paper();
        spec.testbed.workers.truncate(2);
        spec.testbed.workers[0].slots = 4;
        spec.testbed.workers[1].slots = 4;
        spec.n_jobs = 20;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 20);
    }

    #[test]
    fn multi_shard_sim_balances_bytes_across_shadows() {
        let mut spec = tiny_spec();
        spec.shadows = 4;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(r.mover.bytes_per_shard.len(), 4);
        let routed: u64 = r.mover.bytes_per_shard.iter().sum();
        assert_eq!(routed as f64, r.total_input_bytes, "all inputs routed");
        assert!(
            r.mover.shard_imbalance() < 1.5,
            "least-loaded assignment stays roughly even: {:?}",
            r.mover.bytes_per_shard
        );
        assert_eq!(r.mover.released_without_active, 0);
    }

    #[test]
    fn multi_submit_nodes_split_the_burst() {
        let mut spec = tiny_spec();
        spec.n_submit_nodes = 4;
        spec.router = RouterPolicy::RoundRobin;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        // One monitored NIC per submit node, summing to the aggregate.
        assert_eq!(r.monitors.len(), 4);
        let per_node_total: f64 = r.monitors.iter().map(|m| m.total_bytes()).sum();
        assert!((per_node_total - r.monitor.total_bytes()).abs() < 1e-6);
        // Round-robin put exactly a quarter of the burst on each node.
        assert_eq!(r.router.routed_per_node, vec![10, 10, 10, 10]);
        let routed: u64 = r.router.bytes_per_node.iter().sum();
        assert_eq!(routed as f64, r.total_input_bytes);
        // Every node's NIC actually carried its share of input bytes.
        for (i, m) in r.monitors.iter().enumerate() {
            assert!(
                m.total_bytes() >= r.router.bytes_per_node[i] as f64,
                "node {i}: NIC {} < routed {}",
                m.total_bytes(),
                r.router.bytes_per_node[i]
            );
        }
        assert_eq!(r.mover.shard_failed, 0);
    }

    #[test]
    fn weighted_by_capacity_tracks_heterogeneous_nics() {
        let mut spec = tiny_spec();
        spec.n_submit_nodes = 2;
        spec.testbed.submit_node_gbps = vec![100.0, 25.0];
        spec.router = RouterPolicy::WeightedByCapacity;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        // Deficit round-robin at 100:25 puts exactly 4/5 of the burst on
        // the fat node.
        assert_eq!(r.router.routed_per_node, vec![32, 8]);
        // And the fat node's NIC really carried the larger share.
        assert!(r.monitors[0].total_bytes() > r.monitors[1].total_bytes());
    }

    #[test]
    fn dedicated_dtn_offloads_the_submit_nic() {
        let mut spec = tiny_spec();
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(r.dtn_monitors.len(), 2);
        // Every input byte crossed a data-node NIC...
        let dtn_total: f64 = r.dtn_monitors.iter().map(|m| m.total_bytes()).sum();
        assert!(
            dtn_total >= r.total_input_bytes,
            "dtn NICs {dtn_total} >= inputs {}",
            r.total_input_bytes
        );
        // ...and the submit funnel carried nothing (control traffic is
        // not modeled on the NIC).
        assert_eq!(r.monitors.len(), 1);
        assert_eq!(r.monitors[0].total_bytes(), 0.0);
        // Round-robin placement across the fleet.
        assert_eq!(r.router.routed_per_dtn, vec![20, 20]);
        assert_eq!(r.router.dtn_failed, 0);
        // Per-source series sum to the aggregate.
        let sum: f64 = r.monitors.iter().chain(r.dtn_monitors.iter())
            .map(|m| m.total_bytes())
            .sum();
        assert!((sum - r.monitor.total_bytes()).abs() < 1e-6);
    }

    #[test]
    fn cache_aware_run_hits_every_warm_extent() {
        // 4 extents pre-warmed block-wise over 2 DTNs: the cache-aware
        // selector routes every read to its extent's home, so the whole
        // burst is served from page cache.
        let mut spec = tiny_spec();
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        spec.source_selector = SourceSelector::CacheAware;
        spec.n_extents = 4;
        spec.testbed.dtn_cache_bytes = 2 * spec.input_bytes.0;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(r.dtn_cache_misses, 0, "{} hits", r.dtn_cache_hits);
        assert_eq!(r.dtn_cache_hits, 40, "one read per job");
        // Extents 0,1 home on dtn 0 and 2,3 on dtn 1 — an even split of
        // the p % 4 workload.
        assert_eq!(r.router.routed_per_dtn, vec![20, 20]);
    }

    #[test]
    fn dtn_budget_caps_per_node_concurrency_in_sim() {
        let mut spec = tiny_spec();
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        spec.dtn_slots = 1;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        // 8 slots feed 2 single-slot DTNs: the budget pushed back.
        let st = &r.mover;
        assert!(
            st.dtn_deferred > 0 || st.dtn_overflow_to_funnel > 0,
            "a 1-deep budget under an 8-wide burst must defer or overflow"
        );
        // Overflowed transfers ride the funnel; everything still lands.
        let funnel_bytes: f64 = r.monitors.iter().map(|m| m.total_bytes()).sum();
        let dtn_bytes: f64 = r.dtn_monitors.iter().map(|m| m.total_bytes()).sum();
        assert!(
            funnel_bytes + dtn_bytes >= r.total_input_bytes,
            "funnel {funnel_bytes} + dtn {dtn_bytes} < inputs"
        );
    }

    #[test]
    fn dtn_plan_without_data_nodes_errors() {
        let mut spec = tiny_spec();
        spec.source = SourcePlan::DedicatedDtn; // no data nodes
        assert!(Engine::new(spec).run().is_err());
    }

    #[test]
    fn dtn_kill_fails_over_mid_burst() {
        let mut spec = tiny_spec();
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        // Kill dtn 0 early in the burst; never recover it.
        spec.faults = FaultPlan::default().kill_dtn(0, 5.0);
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40, "burst survives the dead DTN");
        assert_eq!(r.chaos.count("kill-dtn"), 1);
        assert_eq!(r.router.dtn_failed, 1);
        // The survivor picked up everything admitted after the kill.
        assert!(
            r.router.routed_per_dtn[1] > r.router.routed_per_dtn[0],
            "survivor serves more: {:?}",
            r.router.routed_per_dtn
        );
        assert_eq!(r.errors, 0);
    }

    #[test]
    fn dtn_flap_schedule_completes() {
        let mut spec = tiny_spec();
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        spec.faults = FaultPlan::default().flap_dtn(0, 2.0, 10.0, 1.0);
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(
            r.chaos.count("degrade-dtn") + r.chaos.count("recover-dtn"),
            r.chaos.records.len(),
            "only flap events fired"
        );
        assert!(r.chaos.count("degrade-dtn") >= 1);
    }

    #[test]
    fn federated_sites_report_a_goodput_matrix() {
        let mut spec = tiny_spec();
        spec.testbed.n_sites = 2;
        spec.n_submit_nodes = 2;
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        spec.router = RouterPolicy::RoundRobin;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert_eq!(r.site_matrix.len(), 2);
        assert!(r.site_matrix.iter().all(|row| row.len() == 2));
        // Every input byte lands in exactly one matrix cell.
        let total: u64 = r.site_matrix.iter().flatten().sum();
        assert_eq!(total as f64, r.total_input_bytes);
        // Both sites sourced traffic (round-robin nodes, local-first
        // DTNs keep each node on its own site's fleet).
        assert!(r.site_matrix[0].iter().sum::<u64>() > 0);
        assert!(r.site_matrix[1].iter().sum::<u64>() > 0);
    }

    #[test]
    fn unfederated_runs_report_a_one_by_one_matrix() {
        let r = Engine::new(tiny_spec()).run().unwrap();
        assert_eq!(r.site_matrix.len(), 1);
        assert_eq!(r.site_matrix[0][0] as f64, r.total_input_bytes);
    }

    #[test]
    fn site_kill_fails_over_to_the_surviving_site() {
        let mut spec = tiny_spec();
        spec.testbed.n_sites = 2;
        spec.n_submit_nodes = 2;
        spec.n_data_nodes = 2;
        spec.source = SourcePlan::DedicatedDtn;
        spec.router = RouterPolicy::RoundRobin;
        spec.faults = FaultPlan::default().kill_site(0, 5.0);
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40, "burst survives the dark site");
        assert_eq!(r.chaos.count("kill-site"), 1);
        assert_eq!(r.chaos.for_site(0).len(), 1);
        assert_eq!(r.router.dtn_failed, 1, "site 0's single DTN failed");
        assert_eq!(r.mover.shard_failed, 1, "site 0's single node failed");
        // The surviving site served (at least) everything after the kill.
        assert!(
            r.router.routed_per_dtn[1] > r.router.routed_per_dtn[0],
            "survivor serves more: {:?}",
            r.router.routed_per_dtn
        );
        assert!(r.site_matrix[1].iter().sum::<u64>() > 0);
        assert_eq!(r.errors, 0);
    }

    #[test]
    fn fair_share_policy_completes_and_respects_limit() {
        let mut spec = tiny_spec();
        spec.policy = crate::mover::AdmissionConfig::FairShare { limit: 3 };
        spec.n_owners = 4;
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert!(r.peak_concurrent_transfers <= 3);
        // The burst really is multi-owner (fair-share had work to do).
        let owners: std::collections::HashSet<&str> = r
            .schedd
            .jobs
            .iter()
            .map(|j| j.spec.owner.as_str())
            .collect();
        assert_eq!(owners.len(), 4);
    }

    #[test]
    fn apply_config_overrides_mover_knobs() {
        let cfg = crate::config::Config::parse(
            "JOBS = 12\n\
             INPUT_SIZE = 10MB\n\
             N_OWNERS = 3\n\
             TRANSFER_QUEUE_POLICY = WEIGHTED_BY_SIZE\n\
             TRANSFER_QUEUE_MAX_CONCURRENT = 5\n\
             SHADOW_POOL_SIZE = 2\n\
             N_SUBMIT_NODES = 2\n\
             ROUTER_POLICY = ROUND_ROBIN\n\
             SUBMIT_NODE_GBPS = 100, 25\n\
             DATA_NODES = 2\n\
             SOURCE_PLAN = HYBRID\n\
             DTN_THRESHOLD = 50MB\n\
             SOURCE_SELECTOR = CACHE_AWARE\n\
             DTN_MAX_CONCURRENT = 6\n\
             N_EXTENTS = 4\n\
             DATA_NODE_GBPS = 100, 40\n\
             FAULT_PLAN = kill:1@5; recover:1@20\n\
             STEAL_THRESHOLD = 3\n\
             RECOVERY_RAMP = 8\n\
             DTN_QUEUE_DEPTH = 4\n\
             ROUTER_SHARDS = 8\n\
             CYCLE_SIZE = 32\n",
        )
        .unwrap();
        let mut spec = tiny_spec();
        spec.apply_config(&cfg).unwrap();
        assert_eq!(spec.faults.events.len(), 2);
        assert_eq!(spec.faults.steal_threshold, Some(3));
        assert_eq!(spec.faults.recovery_ramp, Some(8));
        assert_eq!(spec.n_data_nodes, 2);
        assert_eq!(
            spec.source,
            SourcePlan::Hybrid {
                threshold: 50_000_000
            }
        );
        assert_eq!(spec.source_selector, SourceSelector::CacheAware);
        assert_eq!(spec.dtn_slots, 6);
        assert_eq!(spec.dtn_queue_depth, 4);
        assert_eq!(spec.router_shards, 8);
        assert_eq!(spec.cycle_size, 32);
        assert_eq!(spec.n_extents, 4);
        assert_eq!(spec.testbed.data_node_gbps, vec![100.0, 40.0]);
        assert_eq!(spec.n_jobs, 12);
        assert_eq!(spec.input_bytes, Bytes(10_000_000));
        assert_eq!(spec.n_owners, 3);
        assert_eq!(
            spec.policy,
            crate::mover::AdmissionConfig::WeightedBySize { limit: 5 }
        );
        assert_eq!(spec.shadows, 2);
        assert_eq!(spec.n_submit_nodes, 2);
        assert_eq!(spec.router, RouterPolicy::RoundRobin);
        assert_eq!(spec.testbed.submit_node_gbps, vec![100.0, 25.0]);
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 12);
        assert!(
            r.peak_concurrent_transfers <= 10,
            "per-node limit 5 x 2 nodes"
        );
        assert_eq!(r.mover.bytes_per_shard.len(), 4, "2 nodes x 2 shards");
        assert_eq!(r.monitors.len(), 2);

        // A config carrying only a fault TUNING knob must not wipe a
        // pre-set fault schedule (e.g. a scenario's built-in plan).
        let tune_only = crate::config::Config::parse("RECOVERY_RAMP = 16").unwrap();
        let mut spec3 = tiny_spec();
        spec3.faults = FaultPlan::default().kill(0, 5.0).with_steal_threshold(2);
        spec3.apply_config(&tune_only).unwrap();
        assert_eq!(spec3.faults.events.len(), 1, "schedule survives");
        assert_eq!(spec3.faults.steal_threshold, Some(2));
        assert_eq!(spec3.faults.recovery_ramp, Some(16));

        // Likewise DTN_THRESHOLD alone re-tunes a hybrid plan but never
        // resets a preset plan to the funnel default.
        let thr_only = crate::config::Config::parse("DTN_THRESHOLD = 7MB").unwrap();
        let mut spec4 = tiny_spec();
        spec4.source = SourcePlan::DedicatedDtn;
        spec4.apply_config(&thr_only).unwrap();
        assert_eq!(spec4.source, SourcePlan::DedicatedDtn, "plan survives");
        let mut spec5 = tiny_spec();
        spec5.source = SourcePlan::Hybrid { threshold: 1 };
        spec5.apply_config(&thr_only).unwrap();
        assert_eq!(
            spec5.source,
            SourcePlan::Hybrid {
                threshold: 7_000_000
            }
        );

        // Knobs absent from the config leave the spec untouched.
        let empty = crate::config::Config::parse("").unwrap();
        let mut spec2 = tiny_spec();
        spec2.shadows = 7;
        spec2.n_submit_nodes = 3;
        spec2.apply_config(&empty).unwrap();
        assert_eq!(spec2.shadows, 7);
        assert_eq!(spec2.n_submit_nodes, 3);
        assert_eq!(spec2.router, RouterPolicy::LeastLoaded);
        assert_eq!(spec2.n_jobs, 40);
    }

    #[test]
    fn weighted_by_size_policy_completes() {
        let mut spec = tiny_spec();
        spec.policy = crate::mover::AdmissionConfig::WeightedBySize { limit: 4 };
        let r = Engine::new(spec).run().unwrap();
        assert_eq!(r.schedd.completed_count(), 40);
        assert!(r.peak_concurrent_transfers <= 4);
        assert_eq!(r.errors, 0);
    }

    use crate::mover::task::{synth_file_sha256, FileState, TaskJournal, TransferTask};

    fn sim_task(n: usize, bytes: u64) -> TransferTask {
        TransferTask::new("sim-task", "alice").with_uniform_files("input", n, bytes)
    }

    #[test]
    fn task_knobs_parse_from_config() {
        let cfg = crate::config::Config::parse(
            "TASK_RATE_BPS = 100MB\n\
             TASK_DEADLINE_S = 30\n\
             AUTOTUNE = true\n",
        )
        .unwrap();
        let mut spec = tiny_spec();
        spec.apply_config(&cfg).unwrap();
        assert_eq!(spec.task_rate_bps, 100_000_000);
        assert_eq!(spec.task_deadline_s, 30.0);
        assert!(spec.autotune);
        // Absent knobs leave the spec untouched.
        let empty = crate::config::Config::parse("").unwrap();
        let mut spec2 = tiny_spec();
        spec2.task_rate_bps = 7;
        spec2.apply_config(&empty).unwrap();
        assert_eq!(spec2.task_rate_bps, 7);
        assert!(!spec2.autotune);
    }

    #[test]
    fn task_sim_completes_and_verifies_every_file() {
        let mut runner =
            TaskRunner::new(sim_task(6, 50_000_000), TaskJournal::memory()).unwrap();
        let r = run_task_sim(&tiny_spec(), &mut runner).unwrap();
        assert!(!r.killed);
        assert_eq!(r.progress.files_done, 6);
        assert_eq!(r.progress.verified_bytes, 6 * 50_000_000);
        assert!(!r.progress.deadline_exceeded);
        assert!(r.makespan_s > 0.0);
        for i in 0..6 {
            let f = runner.file(i);
            assert_eq!(
                f.state,
                FileState::Done {
                    sha256: synth_file_sha256(&f.name, f.bytes)
                },
                "file {i} carries its content hash"
            );
        }
        // Every admitted byte went through the router's data plane.
        let routed: u64 = r.router.bytes_per_node.iter().sum();
        assert_eq!(routed, 6 * 50_000_000);
    }

    #[test]
    fn task_sim_rate_limit_paces_admission() {
        let fast = {
            let mut runner =
                TaskRunner::new(sim_task(4, 10_000_000), TaskJournal::memory()).unwrap();
            run_task_sim(&tiny_spec(), &mut runner).unwrap()
        };
        let mut spec = tiny_spec();
        spec.task_rate_bps = 10_000_000; // one 10 MB file per second
        let mut runner =
            TaskRunner::new(sim_task(4, 10_000_000), TaskJournal::memory()).unwrap();
        let slow = run_task_sim(&spec, &mut runner).unwrap();
        assert_eq!(slow.progress.files_done, 4);
        assert!(
            slow.makespan_s >= 3.0,
            "4 files at 1 file/s admission: {} s",
            slow.makespan_s
        );
        assert!(slow.makespan_s > fast.makespan_s * 2.0);
    }

    #[test]
    fn task_sim_deadline_cuts_the_task_short() {
        let mut spec = tiny_spec();
        spec.task_rate_bps = 10_000_000;
        spec.task_deadline_s = 1.5; // room for ~2 of 4 admissions
        let mut runner =
            TaskRunner::new(sim_task(4, 10_000_000), TaskJournal::memory()).unwrap();
        let r = run_task_sim(&spec, &mut runner).unwrap();
        assert!(r.progress.deadline_exceeded);
        assert!(r.progress.files_done < 4, "{} done", r.progress.files_done);
        assert!(r.progress.files_done >= 1);
    }

    #[test]
    fn task_sim_kill_and_resume_never_retransfers_done_files() {
        let dir = std::env::temp_dir()
            .join(format!("htcdm-engine-task-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = tiny_spec();
        let run1 = {
            let mut runner =
                TaskRunner::new(sim_task(6, 50_000_000), TaskJournal::dir(&dir).unwrap())
                    .unwrap();
            run_task_sim_with_kill(&spec, &mut runner, Some(2)).unwrap()
        };
        assert!(run1.killed);
        assert_eq!(run1.progress.files_done, 2);
        // Restart: a fresh runner over the same journal resumes from the
        // checkpoint; the new run's router moves ONLY the remaining
        // bytes — completed files are never re-transferred.
        let mut runner =
            TaskRunner::new(sim_task(6, 50_000_000), TaskJournal::dir(&dir).unwrap()).unwrap();
        assert_eq!(runner.files_resumed(), 2);
        let run2 = run_task_sim(&spec, &mut runner).unwrap();
        assert!(!run2.killed);
        assert_eq!(run2.progress.files_done, 6);
        assert_eq!(run2.progress.files_resumed, 2);
        assert_eq!(run2.progress.verified_bytes, 6 * 50_000_000);
        let routed2: u64 = run2.router.bytes_per_node.iter().sum();
        assert_eq!(routed2, 4 * 50_000_000, "only the 4 unfinished files moved");
        for i in 0..6 {
            let f = runner.file(i);
            assert_eq!(
                f.state,
                FileState::Done {
                    sha256: synth_file_sha256(&f.name, f.bytes)
                }
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn task_sim_autotune_climbs_concurrency() {
        let mut spec = tiny_spec();
        spec.autotune = true;
        let mut task = sim_task(24, 20_000_000).with_concurrency(1);
        task.tune_window_s = 0.15;
        let mut runner = TaskRunner::new(task, TaskJournal::memory()).unwrap();
        let r = run_task_sim(&spec, &mut runner).unwrap();
        assert_eq!(r.progress.files_done, 24);
        assert!(r.tuner.len() >= 2, "tuner observed multiple windows");
        let max_c = r.tuner.iter().map(|s| s.concurrency).max().unwrap();
        assert!(max_c > 1, "hill-climb raised the cap: {:?}", r.tuner);
    }
}
